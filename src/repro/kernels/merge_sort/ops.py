"""Jitted wrapper: full external merge sort with REMOP-planned runs/fan-in."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.planner import plan_sort
from repro.kernels.merge_sort.merge_sort import merge_pass, sort_blocks
from repro.kernels.runtime import resolve_interpret


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@functools.partial(jax.jit, static_argnames=("run_items", "interpret"))
def remop_sort(keys: jnp.ndarray, values: jnp.ndarray | None = None,
               run_items: int | None = None, interpret: bool | None = None):
    """Sort (keys[, values]) ascending via blocked bitonic merge sort.

    `run_items` (power of two) is the in-core run size; defaults to the
    REMOP sort plan's run for the key dtype.  ``interpret=None`` auto-detects
    the Pallas mode (compiled on TPU/GPU, interpreter on CPU).
    """
    interpret = resolve_interpret(interpret)
    n = keys.shape[0]
    if values is None:
        values = jnp.arange(n, dtype=jnp.int32)
    if run_items is None:
        plan = plan_sort(n, item_bytes=keys.dtype.itemsize + 4)
        run_items = min(_next_pow2(plan.run_items), 1 << 14)
    run_items = max(2, min(_next_pow2(run_items), _next_pow2(n)))
    n_pad = max(_next_pow2(n), run_items)
    if keys.dtype.kind == "f":
        sentinel = jnp.array(jnp.inf, keys.dtype)
    else:
        sentinel = jnp.array(jnp.iinfo(keys.dtype).max, keys.dtype)
    kp = jnp.full((n_pad,), sentinel, keys.dtype).at[:n].set(keys)
    vp = jnp.zeros((n_pad,), values.dtype).at[:n].set(values)

    kp, vp = sort_blocks(kp, vp, min(run_items, n_pad), interpret=interpret)
    run = min(run_items, n_pad)
    while run < n_pad:
        kp, vp = merge_pass(kp, vp, run, interpret=interpret)
        run *= 2
    return kp[:n], vp[:n]


def argsort_by_key(keys: jnp.ndarray, interpret: bool | None = None,
                   max_key: int | None = None) -> jnp.ndarray:
    """Stable argsort via unique composite keys (key-major, index-minor).

    Requires ``max(keys) * n + n < 2**31`` (the composite is built in int32).
    The precondition is checked at trace time from static bounds: ``max_key``
    when given (a static promise about the key range — e.g. ``n_experts - 1``
    for MoE expert ids), else the key dtype's maximum.  A violated bound
    raises ``ValueError`` instead of silently overflowing into a wrong
    permutation.
    """
    n = int(keys.shape[0])
    if keys.dtype.kind not in "iu":
        raise ValueError(
            f"argsort_by_key needs integer keys, got dtype {keys.dtype}"
        )
    bound = int(jnp.iinfo(keys.dtype).max) if max_key is None else int(max_key)
    if bound < 0:
        raise ValueError(f"max_key must be >= 0, got {max_key}")
    if n and bound * n + n >= 2**31:
        raise ValueError(
            f"argsort_by_key composite overflows int32: "
            f"max_key({bound}) * n({n}) + n >= 2**31 — pass a tighter "
            f"static max_key= bound for the actual key range"
        )
    composite = keys.astype(jnp.int32) * jnp.int32(n) + jnp.arange(n, dtype=jnp.int32)
    _, idx = remop_sort(composite, jnp.arange(n, dtype=jnp.int32),
                        interpret=interpret)
    return idx
