"""Blocked bitonic merge sort — the EMS analogue as Pallas TPU kernels.

Structure mirrors external merge sort (§III-B):
  * run formation: each VMEM-sized block is sorted in-core by a bitonic
    network (`sort_blocks`) — one grid step = one HBM->VMEM->HBM round trip;
  * merge passes: adjacent sorted runs are merged pairwise by a bitonic
    merge ladder (`merge_pass`) until one run remains.

Hardware adaptation (DESIGN.md §7): the paper's tournament tree is
data-dependent and does not vectorize on the VPU; the bitonic ladder has a
fixed dataflow built entirely from power-of-two reshapes + min/max (lane
shuffles on TPU — no gathers).  A logical fan-in-k merge pass is log2(k)
pairwise ladders; ``core.planner.plan_sort`` picks k from Table IV with tau
calibrated to DMA overhead, trading pass count (volume D) against per-pass
rounds (C) exactly as the paper does.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cmp_exchange(keys, values, j: int, dirs):
    """One compare-exchange stage at distance 2^j with per-group directions."""
    n = keys.shape[-1]
    d = 1 << j
    g = n // (2 * d)
    kr = keys.reshape(g, 2, d)
    lo = jnp.minimum(kr[:, 0], kr[:, 1])
    hi = jnp.maximum(kr[:, 0], kr[:, 1])
    swap = dirs[:, None]
    k0 = jnp.where(swap, hi, lo)
    k1 = jnp.where(swap, lo, hi)
    if values is None:
        return jnp.stack([k0, k1], 1).reshape(n), None
    vr = values.reshape(g, 2, d)
    take_lo_first = (kr[:, 0] <= kr[:, 1])  # where first already holds lo
    v_lo = jnp.where(take_lo_first, vr[:, 0], vr[:, 1])
    v_hi = jnp.where(take_lo_first, vr[:, 1], vr[:, 0])
    v0 = jnp.where(swap, v_hi, v_lo)
    v1 = jnp.where(swap, v_lo, v_hi)
    return (jnp.stack([k0, k1], 1).reshape(n),
            jnp.stack([v0, v1], 1).reshape(n))


def _bitonic_sort(keys, values=None):
    """Full ascending bitonic sort of a 2^m-length vector."""
    n = keys.shape[-1]
    m = n.bit_length() - 1
    for k in range(1, m + 1):
        for j in range(k - 1, -1, -1):
            d = 1 << j
            g = n // (2 * d)
            dirs = ((jnp.arange(g) >> (k - 1 - j)) & 1).astype(bool)
            keys, values = _cmp_exchange(keys, values, j, dirs)
    return keys, values


def _bitonic_merge(keys, values=None):
    """Merge a bitonic vector (asc run ++ desc run) into ascending order."""
    n = keys.shape[-1]
    m = n.bit_length() - 1
    for j in range(m - 1, -1, -1):
        g = n // (2 << j)
        dirs = jnp.zeros((g,), bool)  # all ascending
        keys, values = _cmp_exchange(keys, values, j, dirs)
    return keys, values


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------


def _sort_block_kernel(k_ref, v_ref, ko_ref, vo_ref):
    keys, values = _bitonic_sort(k_ref[...], v_ref[...])
    ko_ref[...] = keys
    vo_ref[...] = values


def _merge_pair_kernel(k_ref, v_ref, ko_ref, vo_ref):
    n = k_ref.shape[-1]
    keys = k_ref[...]
    values = v_ref[...]
    # Reverse the second run -> bitonic sequence, then merge.
    half = n // 2
    keys = jnp.concatenate([keys[:half], keys[half:][::-1]])
    values = jnp.concatenate([values[:half], values[half:][::-1]])
    keys, values = _bitonic_merge(keys, values)
    ko_ref[...] = keys
    vo_ref[...] = values


def sort_blocks(keys, values, block: int, interpret: bool = True):
    """Sort each `block`-length run in-core. len(keys) % block == 0, block=2^m."""
    n = keys.shape[0]
    assert n % block == 0 and block & (block - 1) == 0
    grid = (n // block,)
    return pl.pallas_call(
        _sort_block_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct(keys.shape, keys.dtype),
                   jax.ShapeDtypeStruct(values.shape, values.dtype)],
        interpret=interpret,
    )(keys, values)


def merge_pass(keys, values, run: int, interpret: bool = True):
    """One pairwise merge pass: adjacent runs of length `run` -> length 2*run."""
    n = keys.shape[0]
    assert n % (2 * run) == 0
    grid = (n // (2 * run),)
    return pl.pallas_call(
        _merge_pair_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((2 * run,), lambda i: (i,)),
                  pl.BlockSpec((2 * run,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((2 * run,), lambda i: (i,)),
                   pl.BlockSpec((2 * run,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct(keys.shape, keys.dtype),
                   jax.ShapeDtypeStruct(values.shape, values.dtype)],
        interpret=interpret,
    )(keys, values)
