"""Pure-jnp oracle for the blocked merge sort."""

import jax.numpy as jnp


def sort_ref(keys: jnp.ndarray) -> jnp.ndarray:
    return jnp.sort(keys)


def sort_pairs_ref(keys: jnp.ndarray, values: jnp.ndarray):
    order = jnp.argsort(keys, stable=True)
    return keys[order], values[order]
