"""Runtime helpers shared by the kernel wrappers (`*/ops.py`)."""

from __future__ import annotations

import functools

import jax


@functools.cache
def default_interpret() -> bool:
    """Whether kernel wrappers should default to Pallas interpret mode.

    Compiled Mosaic/Triton lowering needs a real accelerator; on CPU the
    interpreter is the only way to run the kernels at all, so it stays the
    default there.  On TPU/GPU the compiled path is the point of shipping
    kernels, so interpretation is opt-in.
    """
    return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a wrapper's ``interpret=`` argument to a concrete mode.

    ``None`` (the wrappers' default) auto-detects from the jax backend:
    interpret mode on CPU (identical to the historical ``interpret=True``
    default there), compiled execution on TPU/GPU.  An explicit
    ``True``/``False`` always wins.  Runs at trace time — ``interpret`` is a
    static argument everywhere it reaches a ``pallas_call``.
    """
    if interpret is None:
        return default_interpret()
    return bool(interpret)
