"""Jitted MoE dispatch/combine built on the sort + gather kernels."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dispatch.dispatch import gather_rows
from repro.kernels.merge_sort.ops import argsort_by_key
from repro.kernels.runtime import resolve_interpret


@functools.partial(jax.jit, static_argnames=("n_experts", "capacity", "interpret"))
def remop_dispatch(x: jnp.ndarray, expert_ids: jnp.ndarray, n_experts: int,
                   capacity: int, interpret: bool | None = None):
    """Partition assignment rows into per-expert buffers (EHJ build phase).

    x: [A, d] rows (token features repeated per expert choice);
    expert_ids: [A].  Returns (expert_in [E, C, d], slot [A]).
    """
    interpret = resolve_interpret(interpret)
    a, d = x.shape
    # Expert-major, stable; expert ids are static-bounded by n_experts.
    order = argsort_by_key(expert_ids, interpret=interpret,
                           max_key=n_experts - 1)
    sorted_ids = expert_ids[order]
    # Rank within expert among sorted assignments.
    counts = jnp.bincount(expert_ids, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(a, dtype=jnp.int32) - starts[sorted_ids]
    keep = rank < capacity
    # Destination-driven gather: for dest slot (e, c) the source row is
    # order[starts[e] + c] when c < counts[e].
    e_idx = jnp.repeat(jnp.arange(n_experts, dtype=jnp.int32), capacity)
    c_idx = jnp.tile(jnp.arange(capacity, dtype=jnp.int32), n_experts)
    valid = c_idx < counts[e_idx]
    src = jnp.where(valid, starts[e_idx] + c_idx, 0)
    src_rows = jnp.where(valid, order[src], 0)
    gathered = gather_rows(x, src_rows.astype(jnp.int32), interpret=interpret)
    gathered = jnp.where(valid[:, None], gathered, 0)
    expert_in = gathered.reshape(n_experts, capacity, d)
    # Slot per assignment (for combine): e*C + rank, -1 when dropped.
    slot_sorted = jnp.where(keep, sorted_ids * capacity + rank, -1)
    slot = jnp.zeros((a,), jnp.int32).at[order].set(slot_sorted)
    return expert_in, slot


@functools.partial(jax.jit, static_argnames=("top_k", "interpret"))
def remop_combine(expert_out: jnp.ndarray, slot: jnp.ndarray,
                  weights: jnp.ndarray, top_k: int, interpret: bool | None = None):
    """Gather expert outputs back to token order and weight-sum over top-k."""
    interpret = resolve_interpret(interpret)
    e, c, d = expert_out.shape
    a = slot.shape[0]
    flat = expert_out.reshape(e * c, d)
    rows = gather_rows(flat, jnp.maximum(slot, 0).astype(jnp.int32),
                       interpret=interpret)
    rows = jnp.where(slot[:, None] >= 0, rows, 0)
    rows = rows * weights[:, None].astype(rows.dtype)
    return rows.reshape(a // top_k, top_k, d).sum(axis=1)
