"""Pure-jnp oracle for MoE token dispatch/combine."""

import jax
import jax.numpy as jnp


def dispatch_ref(x: jnp.ndarray, expert_ids: jnp.ndarray, n_experts: int,
                 capacity: int):
    """x: [A, d] assignment-expanded rows; expert_ids: [A].

    Returns (expert_in [E, C, d], slot [A] (-1 if dropped)) with tokens placed
    in assignment order per expert (stable), dropped beyond capacity.
    """
    a = x.shape[0]
    one_hot = jax.nn.one_hot(expert_ids, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(one_hot, axis=0) - 1
    pos_in_e = jnp.take_along_axis(pos, expert_ids[:, None], axis=1)[:, 0]
    keep = pos_in_e < capacity
    slot = jnp.where(keep, expert_ids * capacity + pos_in_e, -1)
    flat = jnp.zeros((n_experts * capacity, x.shape[1]), x.dtype)
    flat = flat.at[jnp.where(keep, slot, 0)].add(
        jnp.where(keep[:, None], x, 0)
    )
    return flat.reshape(n_experts, capacity, x.shape[1]), slot


def combine_ref(expert_out: jnp.ndarray, slot: jnp.ndarray,
                weights: jnp.ndarray, n_tokens: int, top_k: int):
    """expert_out: [E, C, d]; slot: [A]; weights: [A] -> y [T, d]."""
    e, c, d = expert_out.shape
    flat = expert_out.reshape(e * c, d)
    rows = jnp.where(slot[:, None] >= 0, flat[jnp.maximum(slot, 0)], 0)
    rows = rows * weights[:, None].astype(rows.dtype)
    return rows.reshape(n_tokens, top_k, d).sum(axis=1)
