"""MoE dispatch gather — the EHJ radix-partition analogue in Pallas.

After the merge-sort kernel orders assignments by expert (the paper's radix
partitioning), moving token rows into per-expert contiguous buffers is a pure
gather.  The kernel below is that gather: the row index vector is a
scalar-prefetch operand consumed by the BlockSpec index_map, so each grid
step DMAs exactly one source row-block HBM->VMEM->HBM — one transfer round
per block, with Pallas double-buffering adjacent steps (§IV-E prefetch).

Staging-pool sizing (how many rows per all-to-all round when experts live on
other chips) comes from ``core.planner.plan_dispatch`` (Property 6 waterfill).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def gather_rows(x: jnp.ndarray, idx: jnp.ndarray, rows_per_block: int = 1,
                interpret: bool = True) -> jnp.ndarray:
    """out[i] = x[idx[i]] with blocked row DMA.

    idx must have length divisible by rows_per_block and contiguous runs when
    rows_per_block > 1 (the sorted-dispatch property); rows_per_block=1 is
    always correct.
    """
    t, d = x.shape
    n = idx.shape[0]
    assert n % rows_per_block == 0
    grid = (n // rows_per_block,)
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((rows_per_block, d),
                             lambda i, idx_ref: (idx_ref[i * rows_per_block]
                                                 // rows_per_block
                                                 if rows_per_block > 1
                                                 else idx_ref[i], 0)),
            ],
            out_specs=pl.BlockSpec((rows_per_block, d), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(idx, x)
