"""Jitted flash-attention wrapper with REMOP block planning."""

from __future__ import annotations

import functools

import jax

from repro.core.cost_model import TPU_V5E
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.runtime import resolve_interpret


def plan_blocks(s: int, t: int, hd: int, dtype_bytes: int = 2,
                vmem_budget: int | None = None) -> tuple[int, int]:
    """(bq, bk) minimizing DMA rounds under the VMEM budget (BNLJ split).

    Working set per grid step ~ 2*(bq + 2*bk)*hd*dtype (double-buffered
    q + k + v) + bq*hd*4 (acc).  Rounds ~ (S/bq)*(T/bk)/2 (causal skip), so
    the L-optimal split is near-equal bq:bk — Property 4 with tau >> R_in.
    """
    vmem_budget = vmem_budget or (TPU_V5E.vmem_bytes // 4)
    best = (128, 128)
    best_rounds = float("inf")
    for bq in (128, 256, 512, 1024):
        if s % bq:
            continue
        for bk in (128, 256, 512, 1024):
            if t % bk:
                continue
            vmem = 2 * (bq + 2 * bk) * hd * dtype_bytes + bq * hd * 4
            if vmem > vmem_budget:
                continue
            rounds = (s / bq) * (t / bk)
            if rounds < best_rounds:
                best_rounds = rounds
                best = (bq, bk)
    return best


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def remop_flash_attention(q, k, v, bq: int | None = None, bk: int | None = None,
                          interpret: bool | None = None):
    interpret = resolve_interpret(interpret)
    b, h, s, hd = q.shape
    t = k.shape[2]
    if bq is None or bk is None:
        pbq, pbk = plan_blocks(s, t, hd, q.dtype.itemsize)
        bq, bk = bq or min(pbq, s), bk or min(pbk, t)
    bq, bk = min(bq, s), min(bk, t)
    return flash_attention(q, k, v, bq=bq, bk=bk, interpret=interpret)
