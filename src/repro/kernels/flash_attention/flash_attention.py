"""Causal flash attention (prefill) with masked-block skipping.

REMOP framing: K/V stream HBM->VMEM in (bq, bk)-blocked rounds with an online
softmax in VMEM scratch; block sizes are the buffer partition (bigger blocks
=> fewer DMA rounds => more VMEM), and *fully-masked* causal blocks are
skipped with `pl.when` — removing ~half of both the D term (those blocks'
DMAs are still issued by the grid, but no compute) and the compute term that
the pure-jnp chunked oracle pays.

Grid: (batch, q_head, q_block, kv_block) with kv innermost/sequential so the
(m, l, acc) scratch accumulates per q_block and Pallas double-buffers the
next KV block's DMA behind the current block's compute (§IV-E).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the TPU compiler-params dataclass as TPUCompilerParams;
# newer releases rename it to CompilerParams.  Resolve once, use everywhere.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bk: int, n_kv: int, q_offset: int):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal block skip: block (i, j) is fully masked iff its smallest q pos
    # is below its smallest kv pos.
    q_base = i * bq + q_offset
    k_base = j * bk

    @pl.when(q_base + bq - 1 >= k_base)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T) / math.sqrt(q.shape[-1])  # [bq, bk]
        q_pos = q_base + jax.lax.iota(jnp.int32, bq)
        k_pos = k_base + jax.lax.iota(jnp.int32, bk)
        mask = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, v)
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: [B, H, S, hd]; k/v: [B, KV, T, hd]; causal with offset T - S."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    t = k.shape[2]
    g = h // kv
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    grid = (b, h, s // bq, t // bk)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, n_kv=t // bk,
                          q_offset=t - s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bb, hh, ii, jj: (bb, hh, ii, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bb, hh, ii, jj: (bb, hh // g, jj, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bb, hh, ii, jj: (bb, hh // g, jj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bb, hh, ii, jj: (bb, hh, ii, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
