"""Pure-jnp oracle for causal flash attention (prefill)."""

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v):
    """q: [B, H, S, hd]; k/v: [B, KV, T, hd]; causal (q pos offset = T - S)."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    t = k.shape[2]
    qg = q.reshape(b, kv, g, s, hd)
    scores = jnp.einsum("bkgsh,bkth->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = jnp.arange(s) + (t - s)
    mask = q_pos[:, None] >= jnp.arange(t)[None, :]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bkth->bkgsh", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, hd).astype(q.dtype)
