"""Jitted paged-attention wrapper with REMOP page planning."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.planner import plan_kv_pages
from repro.kernels.paged_attention.paged_attention import paged_attention
from repro.kernels.runtime import resolve_interpret


def planned_page(context_len: int, kv_heads: int, head_dim: int,
                 kv_bytes: int = 2) -> int:
    plan = plan_kv_pages(context_len, kv_heads, head_dim, kv_bytes)
    return plan.page_tokens


@functools.partial(jax.jit, static_argnames=("page", "interpret"))
def remop_paged_attention(q, k_cache, v_cache, lengths, page: int | None = None,
                          interpret: bool | None = None):
    """Decode attention over an HBM-paged KV cache.

    q: [B, KV, G, hd]; caches [B, S, KV, hd]; lengths [B].
    Pads S to a page multiple (masked by lengths).
    """
    interpret = resolve_interpret(interpret)
    b, s, kv, hd = k_cache.shape
    page = page or min(s, 128)
    pad = (-s) % page
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return paged_attention(q, k_cache, v_cache, lengths.astype(jnp.int32),
                           page=page, interpret=interpret)
