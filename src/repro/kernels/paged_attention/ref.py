"""Pure-jnp oracle for paged decode attention."""

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_cache, v_cache, lengths):
    """q: [B, KV, G, hd]; k/v_cache: [B, S, KV, hd]; lengths: [B] -> [B, KV, G, hd]."""
    b, s, kv, hd = k_cache.shape
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]  # [B, S]
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32)).astype(q.dtype)
