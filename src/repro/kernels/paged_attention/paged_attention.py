"""Paged decode attention (flash-decoding) — KV paging as REMOP rounds.

The KV cache lives in HBM ("remote memory" relative to VMEM); each grid step
DMAs one page of K and V into VMEM — one transfer round — and folds it into
an online softmax held in VMEM scratch.  Page size comes from
``core.planner.plan_kv_pages``: L = D + tau_dma * C over page candidates,
trading tail over-fetch (D) against round count (C), exactly the paper's
Eq. (2) with DMA constants.

Grid: (batch, kv_head, page) with the page axis innermost/sequential so the
scratch (m, l, acc) accumulates across pages and Pallas double-buffers the
next page's DMA behind the current page's compute (§IV-E prefetch buffer).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the TPU compiler-params dataclass as TPUCompilerParams;
# newer releases rename it to CompilerParams.  Resolve once, use everywhere.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _paged_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, page: int, n_pages: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # [G, hd]
    k = k_ref[0, :, 0, :]  # [page, hd]
    v = v_ref[0, :, 0, :]
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale  # [G, page]
    positions = p * page + jax.lax.iota(jnp.int32, page)
    valid = positions < len_ref[b]
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    pexp = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(pexp, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(pexp, v.astype(jnp.float32))
    m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention(q, k_cache, v_cache, lengths, page: int = 128,
                    interpret: bool = True):
    """q: [B, KV, G, hd]; k/v_cache: [B, S, KV, hd]; lengths: [B] int32."""
    b, kv, g, hd = q.shape
    s = k_cache.shape[1]
    assert s % page == 0, (s, page)
    n_pages = s // page
    grid = (b, kv, n_pages)
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, page=page, n_pages=n_pages),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda bb, hh, pp, len_ref: (bb, hh, 0, 0)),
                pl.BlockSpec((1, page, 1, hd), lambda bb, hh, pp, len_ref: (bb, pp, hh, 0)),
                pl.BlockSpec((1, page, 1, hd), lambda bb, hh, pp, len_ref: (bb, pp, hh, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd), lambda bb, hh, pp, len_ref: (bb, hh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
