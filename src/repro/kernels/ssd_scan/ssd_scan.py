"""SSD inter-chunk state scan — the sequential hot-spot of Mamba-2 as Pallas.

In the chunked SSD algorithm the intra-chunk work is dense matmuls (MXU);
what remains serial is the [H, P, N] state passed between chunks:

    carry_{c+1} = carry_c * decay_c + state_c

The REMOP shape: the carry stays RESIDENT in VMEM scratch across the whole
grid (the pinned outer block) while per-chunk states stream HBM->VMEM one
round each, with Pallas double-buffering chunk c+1's DMA behind chunk c's
update (§IV-E).  A pure-jnp lax.scan instead round-trips the carry through
HBM every chunk — 2x the rounds on the carried state.

Grid: (batch, chunk) with chunk innermost/sequential.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the TPU compiler-params dataclass as TPUCompilerParams;
# newer releases rename it to CompilerParams.  Resolve once, use everywhere.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _ssd_scan_kernel(states_ref, decay_ref, prev_ref, final_ref, carry_ref,
                     *, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    carry = carry_ref[...]
    prev_ref[0, 0] = carry.astype(prev_ref.dtype)  # exclusive output
    decay = decay_ref[0, 0]  # [H]
    state = states_ref[0, 0].astype(jnp.float32)  # [H, P, N]
    carry_ref[...] = carry * decay[:, None, None].astype(jnp.float32) + state

    @pl.when(c == n_chunks - 1)
    def _final():
        final_ref[0] = carry_ref[...].astype(final_ref.dtype)


def ssd_scan(states: jnp.ndarray, decays: jnp.ndarray,
             interpret: bool = True):
    """states: [B, NC, H, P, N]; decays: [B, NC, H] ->
    (prev_states [B, NC, H, P, N], final [B, H, P, N])."""
    b, nc, h, p, n = states.shape
    grid = (b, nc)
    prev, final = pl.pallas_call(
        functools.partial(_ssd_scan_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, h, p, n), lambda bb, cc: (bb, cc, 0, 0, 0)),
            pl.BlockSpec((1, 1, h), lambda bb, cc: (bb, cc, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, h, p, n), lambda bb, cc: (bb, cc, 0, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda bb, cc: (bb, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(states.shape, states.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), states.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(states, decays)
    return prev, final
