"""Pure-jnp oracle for the SSD inter-chunk state scan."""

import jax
import jax.numpy as jnp


def ssd_scan_ref(states: jnp.ndarray, decays: jnp.ndarray,
                 initial: jnp.ndarray | None = None):
    """Exclusive scan of the SSD inter-chunk recurrence.

    states: [B, NC, H, P, N] chunk-local states; decays: [B, NC, H].
    Returns (prev_states [B, NC, H, P, N], final_state [B, H, P, N]) where
    prev_states[:, c] is the carried state ENTERING chunk c:
        carry_{c+1} = carry_c * decays[:, c] + states[:, c].
    """
    b, nc, h, p, n = states.shape
    s0 = (jnp.zeros((b, h, p, n), states.dtype) if initial is None
          else initial.astype(states.dtype))

    def step(carry, xs):
        st, dec = xs
        new = carry * dec[..., None, None] + st
        return new, carry

    final, prev = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   decays.transpose(1, 0, 2)))
    return prev.transpose(1, 0, 2, 3, 4), final
