"""Jitted wrapper for the SSD inter-chunk scan kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_scan
from repro.kernels.runtime import resolve_interpret


@functools.partial(jax.jit, static_argnames=("interpret",))
def remop_ssd_scan(states, decays, interpret: bool | None = None):
    return ssd_scan(states, decays, interpret=resolve_interpret(interpret))
