"""Jitted wrapper: REMOP-planned blocked matmul with padding + policy."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.planner import MatmulTilePlan, conventional_matmul_tiles, plan_matmul_tiles
from repro.kernels.matmul.matmul import matmul_pallas
from repro.kernels.runtime import resolve_interpret


def _pad_to(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def plan_for(a_shape, b_shape, dtype=jnp.bfloat16, policy: str = "remop",
             vmem_budget: int | None = None) -> MatmulTilePlan:
    m, k = a_shape
    _, n = b_shape
    in_bytes = jnp.dtype(dtype).itemsize
    if policy == "conventional":
        return conventional_matmul_tiles(m, n, k, in_bytes=in_bytes,
                                         vmem_budget=vmem_budget)
    return plan_matmul_tiles(m, n, k, in_bytes=in_bytes,
                             vmem_budget=vmem_budget,
                             exhaustive=(policy == "remop"))


@functools.partial(jax.jit, static_argnames=("policy", "interpret", "out_dtype"))
def remop_matmul(a: jnp.ndarray, b: jnp.ndarray, policy: str = "remop",
                 interpret: bool | None = None, out_dtype=None) -> jnp.ndarray:
    """Blocked matmul with REMOP-planned tiles (pads to tile multiples)."""
    interpret = resolve_interpret(interpret)
    m, k = a.shape
    _, n = b.shape
    plan = plan_for(a.shape, b.shape, a.dtype, policy)
    bm, bn, bk = (min(plan.bm, m) or 8, min(plan.bn, n) or 128, min(plan.bk, k) or 128)
    # Clamp to padded problem dims.
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    out = matmul_pallas(ap, bp, bm, bn, bk,
                        out_dtype=out_dtype or a.dtype, interpret=interpret)
    return out[:m, :n]
