"""REMOP blocked matmul — the BNLJ analogue as a Pallas TPU kernel.

The loop nest IS Algorithm 1: the A row-panel is the pinned outer block
(held across the inner sweep), B column-panels stream through VMEM as the
inner relation, and the (bm, bn) accumulator is the output region flushed
once per (i, j) tile.  Tile shapes come from ``core.planner.plan_matmul_tiles``
which minimizes L = D + tau_dma * C over hardware-legal shapes — the same
algebra as the paper's p_R:p_S split with tau calibrated to DMA issue
overhead instead of network RTT.

Grid order (i, j, k): k innermost so the f32 accumulator lives in VMEM
scratch across the K sweep; Pallas's sequential-grid pipelining provides the
§IV-E prefetch double buffer (block (i, j, k+1) DMAs overlap compute on
(i, j, k)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the TPU compiler-params dataclass as TPUCompilerParams;
# newer releases rename it to CompilerParams.  Resolve once, use everywhere.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *, n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k_idx == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int,
    bn: int,
    bk: int,
    out_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Tiled matmul with explicit BlockSpec VMEM tiling.

    a: [M, K]; b: [K, N].  M % bm == K % bk == N % bn == 0 (caller pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    out_dtype = out_dtype or a.dtype
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
