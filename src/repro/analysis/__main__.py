"""CLI: ``python -m repro.analysis [--root DIR] [--format text|json] ...``.

Exit status is 1 when any unsuppressed finding exists (CI blocks on it),
0 otherwise.  ``--show-suppressed`` additionally lists findings that a
``# lint: ignore[CODE]`` comment silenced — useful for auditing that the
repo is clean with *zero* suppressions, not clean by silencing.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import Project, all_rules, run_analysis


def _default_root() -> Path:
    # src/repro/analysis/__main__.py -> repo root is three levels up from
    # the package directory.
    return Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static contract checks for the REMOP repro "
        "(ledger completeness, operator contracts, layering, parity).",
    )
    parser.add_argument(
        "--root",
        type=Path,
        default=None,
        help="project root containing src/repro and tests/ "
        "(default: this checkout)",
    )
    parser.add_argument(
        "--format",
        choices=("text", "json"),
        default="text",
        help="output format (default: text)",
    )
    parser.add_argument(
        "--select",
        action="append",
        default=None,
        metavar="CODE",
        help="only run rules whose code starts with CODE "
        "(repeatable; e.g. --select LED --select OPS204)",
    )
    parser.add_argument(
        "--show-suppressed",
        action="store_true",
        help="also list findings silenced by # lint: ignore[...] comments",
    )
    parser.add_argument(
        "--list-rules",
        action="store_true",
        help="print the rule catalog and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for r in all_rules():
            print(f"{r.code}  {r.summary}")
        return 0

    root = args.root if args.root is not None else _default_root()
    project = Project(root)
    findings, suppressed = run_analysis(project, select=args.select)

    if args.format == "json":
        print(
            json.dumps(
                {
                    "root": str(root),
                    "findings": [f.to_dict() for f in findings],
                    "suppressed": [f.to_dict() for f in suppressed],
                },
                indent=2,
            )
        )
    else:
        for f in findings:
            print(f.render())
        if args.show_suppressed:
            for f in suppressed:
                print(f"{f.render()}  [suppressed]")
        n, s = len(findings), len(suppressed)
        print(
            f"{n} finding{'s' if n != 1 else ''}"
            f" ({s} suppressed)" if s else
            f"{n} finding{'s' if n != 1 else ''}"
        )

    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
