"""Analysis framework: findings, the project model, rules, suppressions.

Everything here is pure AST work — the analyzed code is never imported, so
the linter can check a broken tree (that is rather the point) and fixture
mini-packages in tests can seed violations without polluting ``sys.path``.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

# Trailing-comment suppression: ``x = 1  # lint: ignore[LED104]`` silences
# the named code(s) on that line; codes are comma-separated.  Suppressed
# findings are still collected (reported separately), so "lints clean with
# zero suppressions" is checkable.
SUPPRESS_RE = re.compile(r"#\s*lint:\s*ignore\[([A-Z0-9,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to ``path:line``."""

    code: str  # e.g. "LED104"
    path: str  # project-root-relative, posix separators
    line: int
    message: str
    suppressed: bool = False

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    def to_dict(self) -> Dict[str, object]:
        return {
            "code": self.code,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "suppressed": self.suppressed,
        }


@dataclasses.dataclass(frozen=True)
class Rule:
    """One registered rule family entry: a code, its docs, and its checker.

    ``check(project)`` yields raw findings (suppression is applied by the
    runner, not by rules).  One checker function may own several codes —
    register one :class:`Rule` per code so ``--list-rules`` and the README
    catalog stay complete — the registry de-duplicates checkers at run time.
    """

    code: str
    summary: str
    check: Callable[["Project"], Iterable[Finding]]


_RULES: Dict[str, Rule] = {}


def rule(code: str, summary: str) -> Callable[[Callable], Callable]:
    """Register ``check`` under ``code``; returns the function unchanged."""

    def deco(check: Callable[["Project"], Iterable[Finding]]) -> Callable:
        if code in _RULES:
            raise ValueError(f"rule {code!r} already registered")
        _RULES[code] = Rule(code=code, summary=summary, check=check)
        return check

    return deco


def all_rules() -> Tuple[Rule, ...]:
    return tuple(_RULES[c] for c in sorted(_RULES))


class Project:
    """A repo-shaped tree under analysis: ``<root>/src/repro`` + ``tests``.

    Loads and parses each file once; missing files/directories are simply
    absent (fixture mini-packages carry only the files their seeded
    violation needs — a rule finding nothing to check reports nothing).
    """

    def __init__(self, root: Path) -> None:
        self.root = Path(root)
        self._sources: Dict[Path, str] = {}
        self._trees: Dict[Path, Optional[ast.Module]] = {}

    @property
    def src(self) -> Path:
        return self.root / "src" / "repro"

    @property
    def tests_dir(self) -> Path:
        return self.root / "tests"

    def rel(self, path: Path) -> str:
        return path.relative_to(self.root).as_posix()

    def source(self, path: Path) -> str:
        if path not in self._sources:
            self._sources[path] = path.read_text()
        return self._sources[path]

    def tree(self, path: Path) -> Optional[ast.Module]:
        """Parse ``path``; ``None`` when the file is missing or unparsable
        (a syntax error is the compiler's finding to make, not ours)."""
        if path not in self._trees:
            try:
                self._trees[path] = ast.parse(self.source(path))
            except (OSError, SyntaxError):
                self._trees[path] = None
        return self._trees[path]

    def src_files(self, *parts: str) -> List[Path]:
        """All ``.py`` files under ``src/repro/<parts...>``, sorted."""
        base = self.src.joinpath(*parts)
        if not base.is_dir():
            return []
        return sorted(p for p in base.rglob("*.py") if p.is_file())

    def test_files(self) -> List[Path]:
        if not self.tests_dir.is_dir():
            return []
        return sorted(self.tests_dir.glob("*.py"))

    def module_path(self, dotted: str) -> Path:
        """``repro.remote.bnlj`` -> ``<root>/src/repro/remote/bnlj.py``."""
        rel = Path(*dotted.split("."))
        cand = self.root / "src" / rel.with_suffix(".py")
        if cand.is_file():
            return cand
        return self.root / "src" / rel / "__init__.py"

    # -- suppressions --------------------------------------------------------

    def suppressed_codes(self, path: Path, line: int) -> frozenset:
        """Codes silenced by a ``# lint: ignore[...]`` comment on ``line``."""
        try:
            text = self.source(path).splitlines()[line - 1]
        except (OSError, IndexError):
            return frozenset()
        m = SUPPRESS_RE.search(text)
        if not m:
            return frozenset()
        return frozenset(c.strip() for c in m.group(1).split(",") if c.strip())


def run_analysis(
    project: Project, select: Optional[Iterable[str]] = None
) -> Tuple[List[Finding], List[Finding]]:
    """Run every registered rule; returns ``(findings, suppressed)``.

    ``select`` filters by code or code prefix (``LED``, ``OPS204``); both
    lists are sorted by (path, line, code) for stable output.
    """
    prefixes = None if select is None else tuple(select)
    checks: List[Callable[[Project], Iterable[Finding]]] = []
    seen = set()
    for r in all_rules():
        if prefixes is not None and not any(
            r.code.startswith(p) for p in prefixes
        ):
            continue
        if r.check not in seen:
            seen.add(r.check)
            checks.append(r.check)

    active: List[Finding] = []
    suppressed: List[Finding] = []
    for check in checks:
        for f in check(project):
            if prefixes is not None and not any(
                f.code.startswith(p) for p in prefixes
            ):
                continue
            codes = project.suppressed_codes(project.root / f.path, f.line)
            if f.code in codes:
                suppressed.append(dataclasses.replace(f, suppressed=True))
            else:
                active.append(f)
    key = lambda f: (f.path, f.line, f.code)  # noqa: E731
    return sorted(active, key=key), sorted(suppressed, key=key)


# -- shared AST helpers ------------------------------------------------------


def class_def(tree: Optional[ast.Module], name: str) -> Optional[ast.ClassDef]:
    if tree is None:
        return None
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def func_def(
    body: Iterable[ast.stmt], name: str
) -> Optional[ast.FunctionDef]:
    for node in body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def dataclass_fields(cls: Optional[ast.ClassDef]) -> List[Tuple[str, int]]:
    """Annotated class-level fields ``(name, line)``, declaration order."""
    if cls is None:
        return []
    out: List[Tuple[str, int]] = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(
            node.target, ast.Name
        ):
            if not node.target.id.startswith("_"):
                out.append((node.target.id, node.lineno))
    return out


def attr_chain(node: ast.AST) -> List[str]:
    """``a.b.c`` -> ``["a", "b", "c"]``; empty when not a pure name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return []


def call_keywords(call: ast.Call) -> Dict[str, ast.expr]:
    return {kw.arg: kw.value for kw in call.keywords if kw.arg is not None}


def const_str_tuple(node: ast.expr) -> Optional[Tuple[str, ...]]:
    """A literal tuple/list of string constants, else ``None``."""
    if not isinstance(node, (ast.Tuple, ast.List)):
        return None
    vals = []
    for el in node.elts:
        if not (isinstance(el, ast.Constant) and isinstance(el.value, str)):
            return None
        vals.append(el.value)
    return tuple(vals)


def const_str_dict(node: ast.expr) -> Optional[Dict[str, str]]:
    """A literal ``{str: str}`` dict, else ``None``."""
    if not isinstance(node, ast.Dict):
        return None
    out: Dict[str, str] = {}
    for k, v in zip(node.keys, node.values):
        if not (
            isinstance(k, ast.Constant)
            and isinstance(k.value, str)
            and isinstance(v, ast.Constant)
            and isinstance(v.value, str)
        ):
            return None
        out[k.value] = v.value
    return out


def walk_calls(node: ast.AST) -> Iterator[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            yield sub
