"""LAY3xx — layering invariants across the whole src/repro tree.

Three conventions hold the architecture together:

  * ``core/`` is the closed-form layer — it may depend on nothing above it
    (an import of ``repro.engine`` or ``repro.remote`` from ``core`` would
    let simulator behaviour leak into the formulas it is proven against),
  * ledger mutation is the data plane's monopoly: only the store that owns a
    ledger (``remote/simulator.py``) and the tier router
    (``engine/scheduler.py``) may call its mutators or poke its counters —
    everyone else reads snapshots/deltas, which is what keeps "per-tenant
    shares sum byte-for-byte to the totals" provable,
  * simulator paths (``core/``, ``engine/``, ``remote/``) are deterministic:
    no wall clock, no unseeded randomness — every BENCH_*.json number and
    every ledger-exactness test depends on replayability.  One carve-out:
    ``remote/backend.py`` is the execution backend whose whole job is timing
    real transfers and kernels, so wall-clock reads are allowed *there and
    only there*; its RNG discipline is still checked, and the simulator
    (``remote/simulator.py``) and router (``engine/scheduler.py``) stay
    fully clock-free.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from repro.analysis.base import Finding, Project, attr_chain, rule

# The only files allowed to mutate a TransferLedger in place: the store that
# owns the ledgers and the scheduler that routes rounds into them.
LEDGER_MUTATORS = {
    ("remote", "simulator.py"),
    ("engine", "scheduler.py"),
}

# TransferLedger's mutating methods (reads like snapshot()/delta() are fine).
MUTATING_METHODS = {"read", "write", "pushdown", "merge", "reset"}

# Packages that form the deterministic simulator stack.
DETERMINISTIC_PKGS = ("core", "engine", "remote")

# The one file allowed to read the wall clock: the execution backend, which
# *measures* transfers instead of simulating them.  The exemption covers
# clock calls only — unseeded RNG stays a violation even here, and every
# other deterministic-stack file (simulator.py, scheduler.py included) keeps
# the full check.
WALLCLOCK_EXEMPT = {
    ("remote", "backend.py"),
}

# Wall-clock and unseeded-randomness call patterns (suffix of the dotted
# chain).  ``default_rng`` is handled separately: seeded calls are fine.
NONDET_CALLS = {
    ("time", "time"),
    ("time", "monotonic"),
    ("time", "perf_counter"),
    ("time", "time_ns"),
    ("datetime", "now"),
    ("datetime", "utcnow"),
}


def _imports_random(tree: ast.Module) -> Set[str]:
    """Names under which the stdlib ``random`` module is visible."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "random":
                    names.add(a.asname or "random")
        elif isinstance(node, ast.ImportFrom) and node.module == "random":
            names.update(a.asname or a.name for a in node.names)
    return names


def check_layering(project: Project) -> Iterator[Finding]:
    # LAY301 — core/ imports nothing from the layers above it.
    for path in project.src_files("core"):
        tree = project.tree(path)
        if tree is None:
            continue
        for node in ast.walk(tree):
            mods = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                mods = [node.module]
            for mod in mods:
                if mod.startswith(("repro.engine", "repro.remote")):
                    yield Finding(
                        "LAY301", project.rel(path), node.lineno,
                        f"core/ must not import the layers above it "
                        f"(import of {mod})",
                    )

    # LAY302 + LAY303 — scan every module in the deterministic stack.
    for pkg in DETERMINISTIC_PKGS:
        for path in project.src_files(pkg):
            tree = project.tree(path)
            if tree is None:
                continue
            rel = project.rel(path)
            is_mutator_file = any(
                path == project.src.joinpath(*parts)
                for parts in LEDGER_MUTATORS
            )
            clock_exempt = any(
                path == project.src.joinpath(*parts)
                for parts in WALLCLOCK_EXEMPT
            )
            random_names = _imports_random(tree)
            for node in ast.walk(tree):
                yield from _check_ledger_mutation(
                    node, rel, is_mutator_file
                )
                yield from _check_nondeterminism(
                    node, rel, random_names, clock_exempt
                )


def _check_ledger_mutation(
    node: ast.AST, rel: str, allowed: bool
) -> Iterator[Finding]:
    if allowed:
        return
    # ``<expr>.ledger.read(...)`` and friends.
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        if node.func.attr in MUTATING_METHODS:
            base = node.func.value
            if isinstance(base, ast.Attribute) and base.attr == "ledger":
                yield Finding(
                    "LAY302", rel, node.lineno,
                    f"direct ledger mutation "
                    f"(.ledger.{node.func.attr}(...)) outside the data "
                    f"plane — route it through TransferScheduler",
                )
    # ``<expr>.ledger.c_read += 1`` / ``= 0`` style counter pokes.
    targets = []
    if isinstance(node, ast.Assign):
        targets = node.targets
    elif isinstance(node, ast.AugAssign):
        targets = [node.target]
    for t in targets:
        if isinstance(t, ast.Attribute):
            base = t.value
            if isinstance(base, ast.Attribute) and base.attr == "ledger":
                yield Finding(
                    "LAY302", rel, t.lineno,
                    f"direct ledger counter assignment "
                    f"(.ledger.{t.attr}) outside the data plane",
                )


def _check_nondeterminism(
    node: ast.AST, rel: str, random_names: Set[str],
    clock_exempt: bool = False,
) -> Iterator[Finding]:
    if not isinstance(node, ast.Call):
        return
    chain = attr_chain(node.func)
    if len(chain) < 2:
        return
    tail = tuple(chain[-2:])
    if tail in NONDET_CALLS:
        if clock_exempt:
            return  # the backend's job is timing; RNG checks still apply
        yield Finding(
            "LAY303", rel, node.lineno,
            f"nondeterministic call {'.'.join(chain)}() in a simulator "
            f"path — thread explicit inputs instead",
        )
        return
    # Unseeded numpy Generator / legacy global RNG draws.
    if "random" in chain[:-1] and chain[0] not in random_names:
        fn = chain[-1]
        if fn == "default_rng":
            if not node.args and not node.keywords:
                yield Finding(
                    "LAY303", rel, node.lineno,
                    f"{'.'.join(chain)}() without a seed in a simulator "
                    f"path — pass an explicit seed",
                )
        elif fn == "seed":
            pass  # explicit seeding is the fix, not the bug
        else:
            yield Finding(
                "LAY303", rel, node.lineno,
                f"global-RNG draw {'.'.join(chain)}() in a simulator path "
                f"— use a seeded default_rng(...)",
            )
        return
    # stdlib ``random`` module calls (any draw off the global RNG).
    if chain[0] in random_names and len(chain) == 2 and chain[1] != "seed":
        yield Finding(
            "LAY303", rel, node.lineno,
            f"stdlib random call {'.'.join(chain)}() in a simulator path "
            f"— use a seeded numpy Generator",
        )


_SUMMARIES = {
    "LAY301": "core/ must not import repro.engine or repro.remote",
    "LAY302": "only simulator.py and scheduler.py may mutate ledgers",
    "LAY303": "simulator paths must stay deterministic (no clock/global RNG; remote/backend.py alone may read the clock)",
}
for _code, _summary in _SUMMARIES.items():
    rule(_code, _summary)(check_layering)
