"""Static contract analysis: the invariants CI used to trust to convention.

REMOP's "ledger-exact closed forms" claim only holds while every counter on
the :class:`repro.core.cost_model.TransferLedger` is threaded through every
snapshot/delta/merge/reset/serialization path, every operator honors its
registry contract, and the layering (closed forms below, engine above, one
ledger mutator) stays intact.  Those invariants are purely structural — so
this package checks them *statically*, from the AST, without importing the
code under analysis:

  * ``LED1xx`` — ledger-field completeness: a counter added to the ledger
    must reach every carry site (``rules_ledger``),
  * ``OPS2xx`` — operator contracts: module declarations, registry wiring,
    run signatures, pushdown plumbing (``rules_operators``),
  * ``LAY3xx`` — layering: core imports nothing above it, only the data
    plane mutates ledgers, simulator paths stay deterministic
    (``rules_layering``),
  * ``PAR4xx`` — parity coverage: every public closed form keeps a test
    witness (``rules_parity``).

Run it with ``python -m repro.analysis`` (text or ``--format json``); see
``--list-rules`` for the catalog and ``base.SUPPRESS_RE`` for the
``# lint: ignore[CODE]`` suppression syntax.
"""

from repro.analysis.base import (
    Finding,
    Project,
    Rule,
    all_rules,
    run_analysis,
)
# Importing the rule modules registers them with the rule registry.
from repro.analysis import (  # noqa: F401  (registration side effect)
    rules_layering,
    rules_ledger,
    rules_operators,
    rules_parity,
)

__all__ = [
    "Finding",
    "Project",
    "Rule",
    "all_rules",
    "run_analysis",
]
