"""OPS2xx — operator contracts (engine/registry.py <-> remote/*.py).

Every spill operator is one :class:`OperatorSpec` registration plus a data
plane module; the session API, the arbiter, and the plan frontend all trust
that the two agree: the module's declared ``INPUTS``/``INPUT_STATS``/
``STREAMS`` are what the registration wires, the run function's signature
binds those inputs positionally, and the pushdown hooks emit kwargs the run
function actually accepts.  Each of those used to be checked only by the
first integration test that happened to exercise the operator; these rules
check the contract itself.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.base import (
    Finding,
    Project,
    attr_chain,
    call_keywords,
    class_def,
    const_str_dict,
    const_str_tuple,
    dataclass_fields,
    func_def,
    rule,
    walk_calls,
)

REGISTRY = ("engine", "registry.py")


def _module_aliases(fn: ast.FunctionDef) -> Dict[str, str]:
    """``bnlj_mod = importlib.import_module("repro.remote.bnlj")`` bindings."""
    out: Dict[str, str] = {}
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        tgt = node.targets[0]
        if not isinstance(tgt, ast.Name):
            continue
        val = node.value
        if not isinstance(val, ast.Call):
            continue
        chain = attr_chain(val.func)
        if chain[-1:] == ["import_module"] and val.args:
            arg = val.args[0]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                out[tgt.id] = arg.value
    return out


def _registrations(tree: ast.Module) -> List[ast.Call]:
    """Every ``register(OperatorSpec(...))`` call's inner OperatorSpec call."""
    specs: List[ast.Call] = []
    for call in walk_calls(tree):
        chain = attr_chain(call.func)
        if chain[-1:] != ["register"] or not call.args:
            continue
        inner = call.args[0]
        if isinstance(inner, ast.Call) and attr_chain(inner.func)[-1:] == [
            "OperatorSpec"
        ]:
            specs.append(inner)
    return specs


def _module_const(tree: ast.Module, name: str) -> Tuple[Optional[ast.expr], int]:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return node.value, node.lineno
        if isinstance(node, ast.AnnAssign) and isinstance(
            node.target, ast.Name
        ) and node.target.id == name:
            return node.value, node.lineno
    return None, 0


def _return_dict_keys(fn: ast.FunctionDef) -> Optional[Set[str]]:
    """String keys of dict literals returned by ``fn`` (None if opaque)."""
    keys: Set[str] = set()
    found = False
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and node.value is not None:
            if isinstance(node.value, ast.Dict):
                found = True
                for k in node.value.keys:
                    if isinstance(k, ast.Constant) and isinstance(
                        k.value, str
                    ):
                        keys.add(k.value)
            else:
                return None  # opaque return: can't verify statically
    return keys if found else None


def check_operators(project: Project) -> Iterator[Finding]:
    reg_path = project.src.joinpath(*REGISTRY)
    reg_tree = project.tree(reg_path)
    if reg_tree is None:
        return
    reg_rel = project.rel(reg_path)

    stats_fields = {
        n for n, _ in dataclass_fields(class_def(reg_tree, "WorkloadStats"))
    }
    ensure = func_def(reg_tree.body, "_ensure_builtin")
    aliases = _module_aliases(ensure) if ensure is not None else {}

    for spec in _registrations(reg_tree):
        kw = call_keywords(spec)
        name_node = kw.get("name")
        op = (
            name_node.value
            if isinstance(name_node, ast.Constant)
            and isinstance(name_node.value, str)
            else "<?>"
        )

        # Which data-plane module does this spec register?  Follow ``run=``.
        run_chain = attr_chain(kw.get("run", ast.Name(id="", ctx=ast.Load())))
        mod_alias = run_chain[0] if len(run_chain) == 2 else None
        dotted = aliases.get(mod_alias or "")
        if dotted is None:
            yield Finding(
                "OPS203", reg_rel, spec.lineno,
                f"operator {op!r}: run= must reference a data-plane module "
                f"imported in _ensure_builtin (got "
                f"{'.'.join(run_chain) or 'nothing'})",
            )
            continue
        mod_path = project.module_path(dotted)
        mod_tree = project.tree(mod_path)
        if mod_tree is None:
            yield Finding(
                "OPS201", reg_rel, spec.lineno,
                f"operator {op!r}: data-plane module {dotted} not found",
            )
            continue
        mod_rel = project.rel(mod_path)

        # OPS201 — module-level contract declarations.
        inputs_node, inputs_line = _module_const(mod_tree, "INPUTS")
        stats_node, stats_line = _module_const(mod_tree, "INPUT_STATS")
        streams_node, streams_line = _module_const(mod_tree, "STREAMS")
        inputs = const_str_tuple(inputs_node) if inputs_node else None
        input_stats = const_str_dict(stats_node) if stats_node else None
        streams = const_str_tuple(streams_node) if streams_node else None
        for decl, node, val in (
            ("INPUTS", inputs_node, inputs),
            ("INPUT_STATS", stats_node, input_stats),
            ("STREAMS", streams_node, streams),
        ):
            if node is None:
                yield Finding(
                    "OPS201", mod_rel, 1,
                    f"operator module {dotted} does not declare {decl}",
                )
            elif val is None:
                yield Finding(
                    "OPS201", mod_rel, node.lineno,
                    f"operator module {dotted}: {decl} must be a literal of "
                    f"string constants (statically checkable)",
                )

        # OPS202 — INPUT_STATS maps exactly the INPUTS onto WorkloadStats.
        if inputs is not None and input_stats is not None:
            extra = sorted(set(input_stats) - set(inputs))
            missing = [i for i in inputs if i not in input_stats]
            if extra or missing:
                yield Finding(
                    "OPS202", mod_rel, stats_line,
                    f"operator {op!r}: INPUT_STATS keys must equal INPUTS "
                    f"(missing {missing}, unknown {extra})",
                )
            if stats_fields:
                bad = sorted(
                    v for v in input_stats.values() if v not in stats_fields
                )
                if bad:
                    yield Finding(
                        "OPS202", mod_rel, stats_line,
                        f"operator {op!r}: INPUT_STATS values {bad} are not "
                        f"WorkloadStats fields",
                    )

        # OPS203 — the registration must wire the module's own declarations.
        for spec_kw, decl in (
            ("inputs", "INPUTS"),
            ("input_stats", "INPUT_STATS"),
            ("streams", "STREAMS"),
        ):
            node = kw.get(spec_kw)
            chain = attr_chain(node) if node is not None else []
            if chain != [mod_alias, decl]:
                got = ".".join(chain) if chain else (
                    "nothing" if node is None else "a non-reference"
                )
                yield Finding(
                    "OPS203", reg_rel, spec.lineno,
                    f"operator {op!r}: {spec_kw}= must wire "
                    f"{mod_alias}.{decl} (got {got})",
                )

        # OPS204 — run signature binds INPUTS positionally after the store.
        run_fn = (
            func_def(mod_tree.body, run_chain[1])
            if len(run_chain) == 2
            else None
        )
        if run_fn is None:
            yield Finding(
                "OPS204", mod_rel, 1,
                f"operator {op!r}: run function "
                f"{run_chain[-1] if run_chain else '<?>'} not found in "
                f"{dotted}",
            )
        elif inputs is not None:
            pos = [a.arg for a in run_fn.args.posonlyargs + run_fn.args.args]
            got = tuple(pos[1 : 1 + len(inputs)])
            if len(pos) < 1 + len(inputs) or got != inputs:
                yield Finding(
                    "OPS204", mod_rel, run_fn.lineno,
                    f"operator {op!r}: {run_fn.name}() must take INPUTS "
                    f"{list(inputs)} positionally after the store "
                    f"(signature has {list(got)})",
                )

        # OPS205 — pushdown pricing and its data-plane kwargs come in pairs.
        has_pd = "pushdown" in kw
        has_pdkw = "pushdown_kwargs" in kw
        if has_pd != has_pdkw:
            present, absent = (
                ("pushdown", "pushdown_kwargs")
                if has_pd
                else ("pushdown_kwargs", "pushdown")
            )
            yield Finding(
                "OPS205", reg_rel, spec.lineno,
                f"operator {op!r}: {present}= without {absent}= — a priced "
                f"verdict the data plane can't realize (or kwargs with no "
                f"pricing)",
            )

        # OPS206 — pushdown kwargs must be accepted by the run function.
        pdkw_node = kw.get("pushdown_kwargs")
        if pdkw_node is not None and run_fn is not None:
            pdkw_chain = attr_chain(pdkw_node)
            pdkw_fn = (
                func_def(reg_tree.body, pdkw_chain[-1]) if pdkw_chain else None
            )
            if pdkw_fn is not None:
                keys = _return_dict_keys(pdkw_fn)
                if keys is not None:
                    accepted = {
                        a.arg
                        for a in run_fn.args.args + run_fn.args.kwonlyargs
                    }
                    bad = sorted(keys - accepted)
                    if bad:
                        yield Finding(
                            "OPS206", reg_rel, pdkw_fn.lineno,
                            f"operator {op!r}: pushdown kwargs {bad} are not "
                            f"parameters of {run_fn.name}()",
                        )

        # OPS207 — stream footprint decomposition covers exactly STREAMS.
        sfp_node = kw.get("stream_footprints")
        if sfp_node is not None and streams is not None:
            sfp_chain = attr_chain(sfp_node)
            sfp_fn = (
                func_def(reg_tree.body, sfp_chain[-1]) if sfp_chain else None
            )
            if sfp_fn is not None:
                keys = _return_dict_keys(sfp_fn)
                if keys is not None and keys != set(streams):
                    yield Finding(
                        "OPS207", reg_rel, sfp_fn.lineno,
                        f"operator {op!r}: stream_footprints keys "
                        f"{sorted(keys)} must equal declared STREAMS "
                        f"{list(streams)}",
                    )

        # OPS208 — the cost-model hooks the arbiter/explain need, together.
        if "model" in kw and "costs" not in kw:
            yield Finding(
                "OPS208", reg_rel, spec.lineno,
                f"operator {op!r}: model= without costs= — explain() cannot "
                f"decompose L = D + tau*C",
            )
        if streams and "stream_footprints" not in kw:
            yield Finding(
                "OPS208", reg_rel, spec.lineno,
                f"operator {op!r}: declares spill streams {list(streams)} "
                f"but wires no stream_footprints=",
            )


_SUMMARIES = {
    "OPS201": "operator modules must declare literal INPUTS/INPUT_STATS/STREAMS",
    "OPS202": "INPUT_STATS must map exactly INPUTS onto WorkloadStats fields",
    "OPS203": "registrations must wire the module's own declarations",
    "OPS204": "run signatures must bind INPUTS positionally after the store",
    "OPS205": "pushdown pricing and pushdown kwargs must be paired",
    "OPS206": "pushdown kwargs must be parameters of the run function",
    "OPS207": "stream_footprints must decompose exactly the declared STREAMS",
    "OPS208": "cost-model hooks (model/costs, streams/footprints) pair up",
}
for _code, _summary in _SUMMARIES.items():
    rule(_code, _summary)(check_operators)
