"""LED1xx — ledger-field completeness (core/cost_model.py).

The transfer ledger is the repo's ground truth: closed forms are proven
*against* it, so a counter that exists on :class:`TransferLedger` but is
dropped by one carry site (snapshot, delta, merge, reset, the snapshot
mirror, ``__add__``, ``_sum_snapshots``, the :class:`HierarchySnapshot`
aggregate, ``to_dict``, or the hidden-round terms of ``latency_seconds``)
silently under-counts — exactly the hand-edit drift PRs 6 and 9 risked when
they added ``c_migration_hidden`` and the pushdown counters by touching
five sites apiece.  These rules make every carry site mechanical.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from repro.analysis.base import (
    Finding,
    Project,
    attr_chain,
    call_keywords,
    class_def,
    dataclass_fields,
    func_def,
    rule,
    walk_calls,
)

COST_MODEL = ("core", "cost_model.py")


def _snapshot_ctor_kwargs(fn: Optional[ast.FunctionDef], ctor: str) -> Optional[Set[str]]:
    """Keyword names of the ``ctor(...)`` call(s) inside ``fn``."""
    if fn is None:
        return None
    names: Set[str] = set()
    found = False
    for call in walk_calls(fn):
        chain = attr_chain(call.func)
        if chain and chain[-1] == ctor:
            found = True
            names |= set(call_keywords(call))
    return names if found else None


def _self_attr_targets(fn: Optional[ast.FunctionDef]) -> Set[str]:
    """Attributes of ``self`` assigned (plain or augmented) inside ``fn``."""
    if fn is None:
        return set()
    out: Set[str] = set()
    for node in ast.walk(fn):
        targets: List[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        for t in targets:
            chain = attr_chain(t)
            if len(chain) == 2 and chain[0] == "self":
                out.add(chain[1])
    return out


def _dict_keys(fn: Optional[ast.FunctionDef]) -> Optional[Set[str]]:
    """String keys of every dict literal inside ``fn`` (None if no dict)."""
    if fn is None:
        return None
    keys: Set[str] = set()
    found = False
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            found = True
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    keys.add(k.value)
    return keys if found else None


def _attrs_read(fn: Optional[ast.FunctionDef]) -> Set[str]:
    if fn is None:
        return set()
    return {n.attr for n in ast.walk(fn) if isinstance(n, ast.Attribute)}


def _missing(fields: List[str], carried: Optional[Set[str]]) -> List[str]:
    if carried is None:
        return list(fields)
    return [f for f in fields if f not in carried]


def check_ledger(project: Project) -> Iterator[Finding]:
    path = project.src.joinpath(*COST_MODEL)
    tree = project.tree(path)
    if tree is None:
        return
    rel = project.rel(path)

    ledger = class_def(tree, "TransferLedger")
    snap = class_def(tree, "LedgerSnapshot")
    hier = class_def(tree, "HierarchySnapshot")
    if ledger is None or snap is None:
        return

    lfields = [n for n, _ in dataclass_fields(ledger)]
    sfields = [n for n, _ in dataclass_fields(snap)]

    # LED101 — the snapshot must mirror the ledger field-for-field.
    for name, line in dataclass_fields(ledger):
        if name not in sfields:
            yield Finding(
                "LED101", rel, line,
                f"TransferLedger.{name} has no LedgerSnapshot mirror field",
            )
    for name, line in dataclass_fields(snap):
        if name not in lfields:
            yield Finding(
                "LED101", rel, line,
                f"LedgerSnapshot.{name} has no TransferLedger counter "
                f"backing it",
            )

    def site(cls: ast.ClassDef, meth: str) -> Optional[ast.FunctionDef]:
        return func_def(cls.body, meth)

    def report(code: str, fn: Optional[ast.FunctionDef], owner: str,
               meth: str, missing: List[str], what: str) -> Iterator[Finding]:
        line = fn.lineno if fn is not None else (
            ledger.lineno if owner == "TransferLedger" else snap.lineno
        )
        if fn is None:
            yield Finding(
                code, rel, line,
                f"{owner} has no {meth}() carry site",
            )
        elif missing:
            yield Finding(
                code, rel, line,
                f"{owner}.{meth} drops counter(s) {missing} ({what})",
            )

    # LED102/103 — snapshot()/delta() must construct a complete snapshot.
    for code, meth in (("LED102", "snapshot"), ("LED103", "delta")):
        fn = site(ledger, meth)
        carried = _snapshot_ctor_kwargs(fn, "LedgerSnapshot")
        yield from report(code, fn, "TransferLedger", meth,
                          _missing(lfields, carried),
                          "LedgerSnapshot(...) keyword per counter")

    # LED104 — merge() must accumulate every counter.
    fn = site(ledger, "merge")
    yield from report("LED104", fn, "TransferLedger", "merge",
                      _missing(lfields, _self_attr_targets(fn)),
                      "self.<counter> += other.<counter>")

    # LED105 — reset() must zero every counter.
    fn = site(ledger, "reset")
    yield from report("LED105", fn, "TransferLedger", "reset",
                      _missing(lfields, _self_attr_targets(fn)),
                      "assignment per counter")

    # LED106 — LedgerSnapshot.__add__ must carry every field.
    fn = site(snap, "__add__")
    yield from report("LED106", fn, "LedgerSnapshot", "__add__",
                      _missing(sfields,
                               _snapshot_ctor_kwargs(fn, "LedgerSnapshot")),
                      "LedgerSnapshot(...) keyword per field")

    # LED107 — _sum_snapshots (the HierarchySnapshot aggregate seed).
    fn = func_def(tree.body, "_sum_snapshots")
    if fn is not None:
        missing = _missing(sfields, _snapshot_ctor_kwargs(fn, "LedgerSnapshot"))
        if missing:
            yield Finding(
                "LED107", rel, fn.lineno,
                f"_sum_snapshots drops counter(s) {missing}",
            )

    # LED108 — HierarchySnapshot must mirror every field as an aggregate.
    if hier is not None:
        have = {n.name for n in hier.body if isinstance(n, ast.FunctionDef)}
        for name in sfields:
            if name not in have:
                yield Finding(
                    "LED108", rel, hier.lineno,
                    f"HierarchySnapshot has no aggregate property for "
                    f"ledger counter {name!r}",
                )

    # LED109 — to_dict() serialization must carry every counter.
    fn = site(snap, "to_dict")
    yield from report("LED109", fn, "LedgerSnapshot", "to_dict",
                      _missing(sfields, _dict_keys(fn)),
                      "dict key per counter")

    # LED110 — hidden-round counters must enter the latency_seconds round
    # accounting (they exist precisely to be subtracted from paying rounds).
    hidden = [f for f in lfields if f.startswith("c_") and f.endswith("_hidden")]
    for owner, cls in (("TransferLedger", ledger), ("HierarchySnapshot", hier)):
        if cls is None:
            continue
        fn = site(cls, "latency_seconds")
        if fn is None:
            yield Finding(
                "LED110", rel, cls.lineno,
                f"{owner} has no latency_seconds() round accounting",
            )
            continue
        read = _attrs_read(fn)
        for f in hidden:
            if f not in read:
                yield Finding(
                    "LED110", rel, fn.lineno,
                    f"{owner}.latency_seconds never discounts hidden "
                    f"round counter {f!r}",
                )


_SUMMARIES = {
    "LED101": "TransferLedger and LedgerSnapshot fields must mirror 1:1",
    "LED102": "TransferLedger.snapshot() must carry every counter",
    "LED103": "TransferLedger.delta() must carry every counter",
    "LED104": "TransferLedger.merge() must accumulate every counter",
    "LED105": "TransferLedger.reset() must zero every counter",
    "LED106": "LedgerSnapshot.__add__ must carry every field",
    "LED107": "_sum_snapshots must sum every field",
    "LED108": "HierarchySnapshot must aggregate every ledger counter",
    "LED109": "LedgerSnapshot.to_dict must serialize every counter",
    "LED110": "hidden-round counters must enter latency_seconds accounting",
}
for _code, _summary in _SUMMARIES.items():
    rule(_code, _summary)(check_ledger)
