"""PAR4xx — parity coverage (core/policies.py <-> tests/).

The paper's claim is not "we have formulas", it is "the formulas match the
simulator ledger-for-ledger".  That claim is only as strong as the parity
suite: a public closed form in ``core/policies.py`` that no test references
is an unproven formula, and nothing today notices when a refactor or a new
policy quietly drops its witness.  PAR401 requires every public top-level
name in ``policies.py`` to be referenced by at least one test file —
imported, attribute-accessed, or named.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from repro.analysis.base import Finding, Project, rule

POLICIES = ("core", "policies.py")


def _public_toplevel(tree: ast.Module) -> Iterator[tuple]:
    """(name, line) for every public top-level def/class/constant."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            if not node.name.startswith("_"):
                yield node.name, node.lineno
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and not t.id.startswith("_"):
                    yield t.id, node.lineno
        elif isinstance(node, ast.AnnAssign) and isinstance(
            node.target, ast.Name
        ):
            if not node.target.id.startswith("_"):
                yield node.target.id, node.lineno


def _names_used(tree: ast.Module) -> Set[str]:
    """Every identifier a test file could be referencing a policy by:
    bare names, attribute accesses (``policies.bnlj_costs``), and the
    original names of ``from ... import x as y`` aliases."""
    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            used.add(node.attr)
        elif isinstance(node, ast.ImportFrom):
            used.update(a.name for a in node.names)
    return used


def check_parity(project: Project) -> Iterator[Finding]:
    path = project.src.joinpath(*POLICIES)
    tree = project.tree(path)
    if tree is None:
        return
    rel = project.rel(path)

    used: Set[str] = set()
    for tpath in project.test_files():
        ttree = project.tree(tpath)
        if ttree is not None:
            used |= _names_used(ttree)

    seen: Set[str] = set()
    for name, line in _public_toplevel(tree):
        if name in seen:
            continue
        seen.add(name)
        if name not in used:
            yield Finding(
                "PAR401", rel, line,
                f"public closed form {name!r} has no test witness — nothing "
                f"proves it against the simulator ledger",
            )


rule(
    "PAR401",
    "every public name in core/policies.py must be referenced by a test",
)(check_parity)
