"""Concurrent multi-tenant serving: many queries on one shared hierarchy.

A :class:`Server` owns one :class:`repro.remote.simulator.MemoryHierarchy`
and admits many :class:`repro.engine.session.Session` pipelines concurrently.
It generalizes the single-query machinery to the fleet:

  * **Cross-query arbitration** — on every admission and finish event the
    server re-arbitrates budgets *and* tier placements across all in-flight
    queries' pending operators through the same
    :func:`repro.core.arbiter.arbitrate_hierarchy` descent the session replan
    loop uses, with ``occupied=`` fed from the live hierarchy.  A finishing
    query is a capacity-release event: its held budget returns to the pool and
    its pages are freed.
  * **Admission control** — a request is admitted only when the joint
    arbitration over (its operators + every pending operator) is feasible
    under the remaining budget and capacities; otherwise it queues FIFO, with
    the closed-form admissibility check being the arbiter's own feasibility
    test (budget floors + capacity-feasible placement).
  * **Priority and preemptive demotion** — per-tenant ``priority`` weights
    scale each query's modeled latency inside the arbiter's marginal-cost
    descent, so contested quanta and fast tiers go to high-priority queries;
    at admission the server additionally *preempts* lower-priority tenants'
    resident pages off the tiers the new query was granted, demoting them via
    the hierarchy in background batches (``c_migration_hidden`` rounds,
    accounted to the admitted query).
  * **Event-driven simulated clock** — each executed task's measured ledger
    delta decomposes into per-tier work (Eq. (1) seconds per tier); every
    tier is a processor-shared resource among the tenants currently demanding
    it, and the server advances a simulated clock between chunk boundaries
    and arrivals.  A query's tiers are consumed serially, so a *single*
    admitted query reproduces the standalone session's simulated latency —
    while concurrent queries overlap different tiers, which is exactly where
    serving throughput beats FIFO-one-at-a-time.

All ledger-touching work on behalf of a query (its operators, the demotions
its admission triggered) is wrapped in checkpoints, so per-tenant
:class:`repro.core.cost_model.HierarchySnapshot` deltas sum **byte-for-byte**
to the hierarchy totals (``tests/test_hierarchy_invariants.py``).

The request/slot surface follows ``repro.runtime.serve_loop``'s continuous
batching shape: requests queue up, at most ``slots`` run concurrently, and a
finishing query frees its slot for the queue head.

Serving modes (``benchmarks/bench_serving.py`` compares all three):

``"arbitrated"``
  The full system: cross-query arbitration + priorities + preemption.
``"fifo"``
  One query at a time (``slots=1``) with the full single-query machinery —
  the strongest serial baseline.
``"even"``
  Static even-split sharing: every admitted query plans against
  ``budget/slots`` pages and ``capacity/slots`` per tier, with no
  cross-query re-arbitration and no preemption.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import (
    Any,
    Callable,
    Deque,
    Dict,
    List,
    Optional,
    Sequence,
    Set,
    Tuple,
    Union,
)

from repro.core.cost_model import (
    HierarchySnapshot,
    HierarchySpec,
    LedgerSnapshot,
    TierLevel,
)
from repro.engine.session import OperatorTask, Session, TaskRun, delta_chunks

_EPS = 1e-9


# --------------------------------------------------------------------------
# The continuous-batching primitive
# --------------------------------------------------------------------------


class SlotLoop:
    """Continuous batching over an arbitrary per-item engine.

    The slot discipline both serving surfaces share: at most ``slots`` items
    are active, free slots refill FIFO from the pending queue, every active
    item advances one quantum per iteration, and a finishing item releases
    its slot immediately for the queue head.  ``start(item)`` admits an item
    into a slot and returns its slot state; ``step(item, state)`` advances
    it one quantum and returns ``True`` when it finished.

    :class:`Server` interleaves this discipline with its simulated event
    clock; ``repro.runtime.serve_loop.ServeEngine`` (LM decode) delegates
    its batching loop here verbatim — one quantum is one decoded token.
    """

    def __init__(
        self,
        slots: int,
        start: Callable[[Any], Any],
        step: Callable[[Any, Any], bool],
    ) -> None:
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        self.slots = int(slots)
        self.start = start
        self.step = step

    def run(self, items: Sequence[Any]) -> List[Any]:
        """Drive every item to completion; returns them in finish order."""
        pending = list(items)
        active: List[Tuple[Any, Any]] = []
        finished: List[Any] = []
        while pending or active:
            while pending and len(active) < self.slots:
                item = pending.pop(0)
                active.append((item, self.start(item)))
            for entry in list(active):
                if self.step(entry[0], entry[1]):
                    active.remove(entry)
                    finished.append(entry[0])
        return finished


# --------------------------------------------------------------------------
# Requests and reports
# --------------------------------------------------------------------------


@dataclasses.dataclass
class QueryRequest:
    """One tenant's query: the serving analogue of ``serve_loop.Request``.

    ``tasks_of`` is called with a :class:`Session` over the server's shared
    hierarchy when the request is admitted; it seeds the query's input data
    into the hierarchy and returns the typed task pipeline.  It must be
    deterministic — the server also calls it against a scratch hierarchy at
    submit time to learn the pipeline's shape for the admissibility check.

    ``priority`` biases the cross-query arbiter (higher wins contested budget
    and fast tiers) and makes lower-priority tenants preemptible by this one.
    ``done`` flips when the query completes (continuous-batching shape).
    """

    rid: int
    tasks_of: Callable[[Session], Sequence[OperatorTask]]
    arrival: float = 0.0
    priority: float = 1.0
    label: str = ""
    done: bool = False


@dataclasses.dataclass(frozen=True)
class PreemptionEvent:
    """One reclaim-for-admission demotion batch, per victim query."""

    time: float
    rid: int  # the admitted query that triggered the reclaim
    victim_rid: int  # the lower-priority query whose pages were demoted
    tier: str  # the tier the pages were demoted off
    pages: int


@dataclasses.dataclass
class QueryReport:
    """One served query: timing, its ledger share, and its task runs."""

    rid: int
    label: str
    priority: float
    arrival: float
    admitted: float
    finished: float
    ledger: HierarchySnapshot  # this tenant's exact share of the totals
    tasks: List[TaskRun]
    preempted_pages: int = 0  # this query's pages demoted by others' arrivals

    @property
    def latency(self) -> float:
        """Simulated seconds from arrival to completion (incl. queueing)."""
        return self.finished - self.arrival

    @property
    def wait(self) -> float:
        """Simulated seconds spent queued before admission."""
        return self.admitted - self.arrival

    def to_dict(self) -> Dict[str, Any]:
        return {
            "rid": self.rid,
            "label": self.label,
            "priority": self.priority,
            "arrival": self.arrival,
            "admitted": self.admitted,
            "finished": self.finished,
            "latency": self.latency,
            "wait": self.wait,
            "preempted_pages": self.preempted_pages,
        }


@dataclasses.dataclass
class ServerReport:
    """One ``Server.run()``: per-query reports plus fleet-level metrics."""

    mode: str
    queries: List[QueryReport]  # completion order
    total: HierarchySnapshot  # hierarchy-wide delta over the whole run
    makespan: float  # simulated seconds, first arrival handled to last finish
    preemptions: List[PreemptionEvent]
    rearbitrations: int

    def query(self, rid: int) -> QueryReport:
        for q in self.queries:
            if q.rid == rid:
                return q
        raise KeyError(f"no query rid={rid} in report")

    @property
    def throughput(self) -> float:
        """Sustained queries/second over the makespan."""
        if self.makespan <= 0.0:
            return math.inf if self.queries else 0.0
        return len(self.queries) / self.makespan

    def latency_percentile(self, pct: float) -> float:
        """Nearest-rank percentile of per-query simulated latency."""
        if not self.queries:
            return 0.0
        lats = sorted(q.latency for q in self.queries)
        rank = max(int(math.ceil(pct / 100.0 * len(lats))), 1)
        return lats[rank - 1]

    @property
    def p50_latency(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p99_latency(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def tenant_total(self) -> HierarchySnapshot:
        """Sum of per-query ledgers — equals ``total`` byte-for-byte."""
        acc = HierarchySnapshot(tiers=tuple(
            (n, LedgerSnapshot()) for n, _ in self.total.tiers
        ))
        for q in self.queries:
            acc = acc + q.ledger
        return acc

    def to_dict(self) -> Dict[str, Any]:
        return {
            "mode": self.mode,
            "queries": [q.to_dict() for q in self.queries],
            "makespan": self.makespan,
            "throughput": self.throughput,
            "p50_latency": self.p50_latency,
            "p99_latency": self.p99_latency,
            "preempted_pages": sum(e.pages for e in self.preemptions),
            "rearbitrations": self.rearbitrations,
        }

    def __str__(self) -> str:
        lines = [
            f"serving: mode={self.mode} queries={len(self.queries)} "
            f"makespan={self.makespan:.4g}s "
            f"throughput={self.throughput:.4g} q/s "
            f"p50={self.p50_latency:.4g}s p99={self.p99_latency:.4g}s"
        ]
        for q in self.queries:
            mark = f" preempted={q.preempted_pages}p" if q.preempted_pages else ""
            lines.append(
                f"  q{q.rid} {q.label or '-'} prio={q.priority:g} "
                f"wait={q.wait:.4g}s latency={q.latency:.4g}s{mark}"
            )
        if self.preemptions:
            for e in self.preemptions:
                lines.append(
                    f"  preempt t={e.time:.4g}s q{e.rid} demoted {e.pages}p "
                    f"of q{e.victim_rid} off {e.tier}"
                )
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Internal per-tenant state
# --------------------------------------------------------------------------


class _Tenant:
    """One admitted query: its session, grants, playback and ledger share."""

    def __init__(
        self,
        request: QueryRequest,
        session: Session,
        tasks: Sequence[OperatorTask],
        spec: HierarchySpec,
    ) -> None:
        self.request = request
        self.session = session
        self.tasks = list(tasks)
        self.grants: List[Any] = [None] * len(self.tasks)  # OperatorBudget
        self.cur_stats = [t.stats for t in self.tasks]
        self.outputs: Dict[int, Any] = {}
        self.started = 0  # tasks executed so far (grants below are held)
        self.runs: List[TaskRun] = []
        self.ledger = HierarchySnapshot.zero(spec)
        self.owned: Set[int] = set()  # page ids attributed to this query
        self.admitted = 0.0
        self.preempted_pages = 0
        # Simulated playback of the running task: [tier_index, seconds_left]
        # chunks consumed in order, each at the tier's processor-shared rate.
        self.chunks: Deque[List[float]] = deque()

    @property
    def held_pages(self) -> float:
        """Budget held by started tasks (released when the query finishes)."""
        return sum(self.grants[j].m_pages for j in range(self.started))


# --------------------------------------------------------------------------
# The server
# --------------------------------------------------------------------------


class Server:
    """Admit many session pipelines concurrently on one shared hierarchy.

    ``target`` must resolve to a memory hierarchy (spec, level list, or live
    :class:`MemoryHierarchy`); ``budget`` is the fleet-wide page budget the
    cross-query arbiter splits.  ``slots`` caps concurrently admitted queries
    (the continuous-batching slot count); ``eviction`` attaches the
    hierarchy's background evictor (``None`` disables both background
    demotion and preemption).  See the module docstring for ``mode``.
    """

    def __init__(
        self,
        target: Any,
        budget: float,
        *,
        policy: str = "remop",
        mode: str = "arbitrated",
        slots: int = 4,
        step: float = 1.0,
        eviction: Any = "lru",
        overlap_migration: bool = True,
        headroom: float = 0.0,
    ) -> None:
        if mode not in ("arbitrated", "even", "fifo"):
            raise ValueError(
                f"mode must be 'arbitrated', 'even' or 'fifo', got {mode!r}"
            )
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        # The bootstrap session materializes the hierarchy, attaches the
        # evictor, and doubles as the planner the arbitration calls run on.
        self._planner = Session(
            target, budget=budget, policy=policy, step=step,
            eviction=eviction, overlap_migration=overlap_migration,
            headroom=headroom,
        )
        if not self._planner.is_hierarchy:
            raise ValueError(
                "a Server needs a memory hierarchy target; multi-tenant "
                "placement has no meaning on a single tier"
            )
        self.remote = self._planner.remote
        self.spec: HierarchySpec = self._planner.hierarchy
        self.evictor = self._planner.evictor
        self.overlap = self._planner.overlap_migration
        self.budget = float(budget)
        self.policy = policy
        self.step = step
        self.mode = mode
        self.slots = 1 if mode == "fifo" else int(slots)
        self._sched = self._planner.scheduler
        self.active: List[_Tenant] = []
        self._pending: List[QueryRequest] = []
        self._probes: Dict[int, List[OperatorTask]] = {}
        self.preemptions: List[PreemptionEvent] = []
        self.rearbitrations = 0

    # -- submission ----------------------------------------------------------

    def submit(
        self, requests: Union[QueryRequest, Sequence[QueryRequest]]
    ) -> "Server":
        """Enqueue requests for the next :meth:`run` (chainable)."""
        if isinstance(requests, QueryRequest):
            requests = [requests]
        for req in requests:
            if req.rid in self._probes:
                raise ValueError(f"duplicate request rid={req.rid}")
            if req.priority <= 0:
                raise ValueError(
                    f"request rid={req.rid}: priority must be > 0, "
                    f"got {req.priority}"
                )
            if req.arrival < 0:
                raise ValueError(
                    f"request rid={req.rid}: arrival must be >= 0, "
                    f"got {req.arrival}"
                )
            self._probes[req.rid] = self._probe(req)
            self._pending.append(req)
        return self

    def _probe(self, req: QueryRequest) -> List[OperatorTask]:
        """Learn the request's pipeline shape against a scratch hierarchy.

        The scratch session shares nothing with the live hierarchy, so the
        admissibility check (which needs every operator's spec and stats)
        never seeds data — or spends ledger rounds — before admission.
        """
        scratch = Session(
            self.spec, budget=self.budget, policy=self.policy, step=self.step
        )
        tasks = list(req.tasks_of(scratch))
        if not tasks:
            raise ValueError(f"request rid={req.rid}: tasks_of returned no tasks")
        return tasks

    # -- cross-query arbitration ----------------------------------------------

    def _held_budget(self) -> float:
        return sum(ten.held_pages for ten in self.active)

    def _pinned(
        self, participants: Sequence["_Tenant"]
    ) -> Optional[List[float]]:
        """Per-tier residency that this arbitration must not reallocate.

        A tenant *participating* in the arbitration (it still has pending
        operators) has its resident pages represented as soft ``occupied``
        capacity — the descent may plan around displacing its own cold
        pages, exactly like a standalone ``Session``.  A tenant that is
        fully started but still draining its simulated chunks is outside
        the descent's control: its pages are in active use and must be
        subtracted from the capacities outright, or the joint arbitration
        over-commits fast tiers and locks churn-heavy placements in at
        task start.  Preemptive demotion is the pressure valve that turns
        a low-priority tenant's pinned fast-tier residency back into
        capacity.  Solo admission pins nothing, which is what makes
        single-tenant admission reproduce the standalone ``Session`` plan
        byte-for-byte.
        """
        part = set(id(t) for t in participants)
        drainers = [t for t in self.active if id(t) not in part]
        if not drainers:
            return None
        pinned = [0.0] * len(self.spec)
        for ten in drainers:
            for p in ten.owned:
                try:
                    pinned[self.spec.index(self.remote.tier_of(p))] += 1.0
                except KeyError:
                    continue  # freed behind our back; nothing to pin
        return pinned

    def _arbitrate_pending(
        self,
        extra: Optional[Sequence[OperatorTask]] = None,
        extra_priority: float = 1.0,
    ) -> List[Any]:
        """Re-split the unheld budget over every pending operator.

        Pending = not-yet-executed tasks of in-flight queries, plus (for an
        admission trial) a candidate's probe tasks.  Started tasks keep their
        grants until their query finishes — a finishing query is the
        capacity-release event.  Commits new grants to in-flight tenants and
        returns the candidate's grants; raises ``ValueError`` when infeasible
        (nothing is committed in that case).
        """
        tasks: List[OperatorTask] = []
        stats: List[Any] = []
        weights: List[float] = []
        owners: List[Tuple[_Tenant, int]] = []
        participants: List[_Tenant] = []
        for ten in self.active:
            w = ten.request.priority
            if ten.started < len(ten.tasks):
                participants.append(ten)
            for j in range(ten.started, len(ten.tasks)):
                tasks.append(ten.tasks[j])
                stats.append(ten.cur_stats[j])
                weights.append(w)
                owners.append((ten, j))
        n_own = len(tasks)
        if extra is not None:
            for t in extra:
                tasks.append(t)
                stats.append(t.stats)
                weights.append(extra_priority)
        if not tasks:
            return []
        budget_avail = self.budget - self._held_budget()
        grants = self._planner._arbitrate_tail(
            tasks, stats, budget_avail, weights=weights,
            pinned=self._pinned(participants),
        )
        for (ten, j), ob in zip(owners, grants[:n_own]):
            ten.grants[j] = ob
        self.rearbitrations += 1
        return grants[n_own:]

    def _rearbitrate(self) -> bool:
        """Global re-arbitration; keeps current grants when infeasible."""
        try:
            self._arbitrate_pending()
            return True
        except ValueError:
            return False

    def _even_plan(self, tasks: Sequence[OperatorTask]) -> List[Any]:
        """Static even-split baseline: 1/slots of budget and capacities."""
        from repro.engine.pipeline import _plan_pipeline

        scaled = HierarchySpec(tuple(
            TierLevel(
                lv.tier,
                lv.capacity_pages if math.isinf(lv.capacity_pages)
                else max(lv.capacity_pages / self.slots, 1.0),
            )
            for lv in self.spec.levels
        ))
        plan = _plan_pipeline(
            [t.op for t in tasks], [t.stats for t in tasks],
            scaled, self.budget / self.slots, self.policy, self.step,
            eviction=self.evictor is not None,
        )
        return list(plan.ops)

    # -- admission -----------------------------------------------------------

    def _try_admit(self, req: QueryRequest, now: float) -> bool:
        """Admit ``req`` if the joint arbitration is feasible right now."""
        probe = self._probes[req.rid]
        if self.mode == "even":
            try:
                self._even_plan(probe)
            except ValueError:
                return False
        else:
            try:
                self._arbitrate_pending(extra=probe, extra_priority=req.priority)
            except ValueError:
                return False  # stays queued; nothing was committed
        session = Session(
            self.remote, budget=self.budget, policy=self.policy, step=self.step
        )
        before = set(self.remote.resident_ids())
        tasks = list(req.tasks_of(session))
        seeded = set(self.remote.resident_ids()) - before
        if [t.op for t in tasks] != [t.op for t in probe]:
            raise RuntimeError(
                f"request rid={req.rid}: tasks_of is not deterministic "
                f"(probe saw {[t.op for t in probe]}, admission got "
                f"{[t.op for t in tasks]})"
            )
        ten = _Tenant(req, session, tasks, self.spec)
        ten.owned |= seeded
        ten.admitted = now
        self.active.append(ten)
        if self.mode == "even":
            ten.grants = self._even_plan(tasks)
        else:
            try:
                self._arbitrate_pending()
            except ValueError:
                raise RuntimeError(
                    f"request rid={req.rid}: admission trial was feasible "
                    f"but the commit arbitration is not — tasks_of seeded "
                    f"data onto a finite tier?"
                ) from None
            before = len(self.preemptions)
            self._reclaim_for(ten, now)
            if len(self.preemptions) > before:
                # The reclaim unpinned fast-tier capacity; let every grant
                # (including the admitted query's) see it before executing.
                self._rearbitrate()
        self._exec_next(ten)
        return True

    def _reclaim_for(self, ten: _Tenant, now: float) -> None:
        """Preemptive demotion: clear lower-priority pages off granted tiers.

        For every non-bottom tier the new query's grants place spill on, the
        granted *buffer* pages beyond the tier's free capacity are reclaimed
        by demoting the coldest resident pages *owned by strictly
        lower-priority tenants* (active scan windows spared) one tier down,
        as background migration batches.  The rounds are accounted to the
        admitted query.

        Only the working buffers are reclaimed eagerly — not the full
        modeled footprint.  Run files and outputs stream through the tier
        and are better displaced lazily by the evictor as the operator
        actually touches them; reclaiming the whole footprint up front
        demotes a low-priority sort's still-warm runs wholesale and forces
        it to re-read them from the slow tier during its merge.
        """
        if self.evictor is None:
            return
        prio = ten.request.priority
        owner: Dict[int, _Tenant] = {}
        for other in self.active:
            if other is ten or other.request.priority >= prio:
                continue
            for p in other.owned:
                owner[p] = other
        if not owner:
            return
        need: Dict[int, float] = {}
        for _task, ob in zip(ten.tasks, ten.grants):
            if ob is None or ob.placement is None:
                continue
            ti = self.spec.index(ob.placement)
            if ti >= len(self.spec) - 1:
                continue
            # Tasks run serially, so the peak single-task buffer demand is
            # the residency the tier must absorb at any one time.
            need[ti] = max(need.get(ti, 0.0), float(ob.m_pages))
        if not need:
            return
        protected = self.evictor.scan_pages()
        label = f"srv-preempt-q{ten.request.rid}"
        self._sched.checkpoint(label)
        try:
            for ti in sorted(need):
                deficit = int(math.ceil(need[ti] - self.remote.capacity_left(ti)))
                if deficit <= 0:
                    continue
                cands = [
                    p for p in self.remote.pages_on(ti)
                    if p in owner and p not in protected
                ]
                cands.sort(key=lambda p: (self.remote.last_access(p), p))
                victims = cands[:deficit]
                if not victims:
                    continue
                self.evictor.make_room(ti + 1, len(victims))
                room = self.remote.capacity_left(ti + 1)
                if not math.isinf(room):
                    victims = victims[: max(int(room), 0)]
                if not victims:
                    continue
                self.remote.demote(victims, background=self.overlap)
                per: Dict[int, int] = {}
                for p in victims:
                    victim = owner[p]
                    victim.preempted_pages += 1
                    per[victim.request.rid] = per.get(victim.request.rid, 0) + 1
                for vrid, n in sorted(per.items()):
                    self.preemptions.append(PreemptionEvent(
                        time=now, rid=ten.request.rid, victim_rid=vrid,
                        tier=self.spec.names[ti], pages=n,
                    ))
            delta = self._sched.since(label)
        finally:
            self._sched.drop_checkpoint(label)
        ten.ledger = ten.ledger + delta
        # The reclaim precedes the first task in this query's playback.
        ten.chunks.extend(self._chunks_of(delta))

    # -- execution -----------------------------------------------------------

    def _exec_next(self, ten: _Tenant) -> None:
        """Execute the tenant's next task and queue its per-tier playback."""
        i = ten.started
        task, ob = ten.tasks[i], ten.grants[i]
        if ob is None:
            raise RuntimeError(
                f"query rid={ten.request.rid} task {i} has no grant"
            )
        before = set(self.remote.resident_ids())
        tr = ten.session.exec_task(
            task, ob, outputs=ten.outputs, stats=ten.cur_stats[i],
            label=f"srv-q{ten.request.rid}-t{i}",
        )
        after = set(self.remote.resident_ids())
        ten.owned = (ten.owned & after) | (after - before)
        ten.cur_stats[i] = tr.measured
        ten.runs.append(tr)
        ten.ledger = ten.ledger + tr.delta
        ten.started = i + 1
        Session.propagate_measured(ten.tasks, ten.cur_stats, ten.outputs, i)
        ten.chunks.extend(self._chunks_of(tr.delta))

    def _chunks_of(self, delta: HierarchySnapshot) -> List[List[float]]:
        """Decompose a ledger delta into per-tier Eq.-(1) seconds, top first."""
        return [
            [float(ti), secs]
            for ti, secs in delta_chunks(
                delta, self.spec, None, overlap_migration=self.overlap
            )
        ]

    def _advance_tenant(
        self, ten: _Tenant, now: float, reports: List[QueryReport]
    ) -> None:
        """Drained playback: start the next task or finish the query."""
        while not ten.chunks:
            if ten.started < len(ten.tasks):
                if self.mode != "even":
                    # Task boundaries re-arbitrate too: measured stats and
                    # consumed capacity feed every in-flight query's grants.
                    self._rearbitrate()
                self._exec_next(ten)
            else:
                self._finish_query(ten, now, reports)
                return

    def _finish_query(
        self, ten: _Tenant, now: float, reports: List[QueryReport]
    ) -> None:
        """Capacity-release event: free pages, report, re-arbitrate."""
        self.active.remove(ten)
        req = ten.request
        req.done = True
        resident = set(self.remote.resident_ids())
        to_free = sorted(ten.owned & resident)
        if to_free:
            # Releasing a finished query's pages is allocation bookkeeping,
            # not a transfer: no rounds, like the seeding that created them.
            self.remote.free(to_free)
        reports.append(QueryReport(
            rid=req.rid, label=req.label, priority=req.priority,
            arrival=req.arrival, admitted=ten.admitted, finished=now,
            ledger=ten.ledger, tasks=ten.runs,
            preempted_pages=ten.preempted_pages,
        ))
        if self.mode != "even":
            self._rearbitrate()

    # -- the event loop --------------------------------------------------------

    def run(self) -> ServerReport:
        """Serve every submitted request to completion (simulated clock)."""
        arrivals = sorted(self._pending, key=lambda r: (r.arrival, r.rid))
        self._pending = []
        queue: List[QueryRequest] = []
        reports: List[QueryReport] = []
        now = 0.0
        base = self._sched.snapshot()
        while arrivals or queue or self.active:
            while arrivals and arrivals[0].arrival <= now + _EPS:
                queue.append(arrivals.pop(0))
            # Priority-ordered admission, FIFO within a priority class; the
            # highest-priority waiter admits or blocks the queue (no
            # backfill past it, so one admission check never starves it).
            queue.sort(key=lambda r: (-r.priority, r.arrival, r.rid))
            while queue and len(self.active) < self.slots:
                if not self._try_admit(queue[0], now):
                    break
                queue.pop(0)
            if not self.active:
                if arrivals:
                    now = max(now, arrivals[0].arrival)
                    continue
                if queue:
                    head = queue[0]
                    raise RuntimeError(
                        f"request rid={head.rid} is inadmissible on an idle "
                        f"server (pipeline floors exceed budget "
                        f"{self.budget:g}?)"
                    )
                break
            # Processor sharing per tier: k tenants demanding one tier each
            # progress at rate 1/k; the next event is the earliest chunk
            # boundary or the next arrival.
            demand = [0] * len(self.spec)
            for ten in self.active:
                demand[int(ten.chunks[0][0])] += 1
            dt = math.inf
            for ten in self.active:
                ti = int(ten.chunks[0][0])
                dt = min(dt, ten.chunks[0][1] * demand[ti])
            if arrivals:
                dt = min(dt, max(arrivals[0].arrival - now, 0.0))
            dt = max(dt, 0.0)
            for ten in self.active:
                ti = int(ten.chunks[0][0])
                ten.chunks[0][1] -= dt / demand[ti]
            now += dt
            for ten in list(self.active):
                while ten.chunks and ten.chunks[0][1] <= _EPS:
                    ten.chunks.popleft()
                if not ten.chunks:
                    self._advance_tenant(ten, now, reports)
        total = self._sched.delta(base)
        return ServerReport(
            mode=self.mode, queries=reports, total=total, makespan=now,
            preemptions=list(self.preemptions),
            rearbitrations=self.rearbitrations,
        )
