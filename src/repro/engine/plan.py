"""Logical-plan frontend: relational trees compiled to Session task DAGs.

The paper's headline numbers are end-to-end TPC-H/TPC-DS queries, not single
operators — multi-join plans whose *shape* (join order, bushy vs. left-deep)
decides how much intermediate state competes for the page budget.  This
module closes the gap between hand-wired ``session.task(...)`` lists and
those queries:

``LogicalPlan``
  A tree of relational nodes — ``scan`` / ``filter`` / ``join`` /
  ``aggregate`` / ``sort`` — annotated with table statistics (sizes in
  pages).  Filters scale the estimated pages flowing upward; a filter
  chain feeding a BNLJ probe side additionally compiles *physically* — the
  join task carries ``pushdown_sel`` (and the predicate, when given), so
  the arbiter can ship the filtered scan to a compute-capable tier.  All
  other filters remain stats annotations (pushdown-at-scan assumption);
  ``CompiledPlan.pushed_filters`` / ``annotation_filters`` record which is
  which.

``compile_plan(session, plan)``
  Lowers the tree to a dependency-ordered task DAG over the registered
  spill operators — joins to EHJ (or BNLJ), ``aggregate`` to EAGG, ``sort``
  to EMS — chaining intermediate results by ``task.output`` references, so
  ``session.run(tasks, schedule="dag")`` executes producers before
  consumers, overlaps independent subtrees, and places every intermediate
  spill stream through ``arbitrate_hierarchy`` like any other.

Join-order choice is *enumerate-and-cost over a bounded candidate set*
priced with the same closed forms (``core/policies.py`` via
``OperatorSpec.model``) the arbiter already trusts: the hand-written tree
(the left-deep baseline), every left-deep permutation for small clusters, a
greedy smallest-first order, and a smallest-pair bushy tree.  Ties keep the
hand-written order, so a compiled plan is never modeled worse than the
hand-wired chain.  Intermediate cardinalities follow the classic
independent-selectivity estimate: each source join contributes a page
selectivity ``phi = out / (|L| * |S|)`` applied once both its sides are
joined.

Skeleton assumption (documented, asserted nowhere): every join in a cluster
equi-joins on the shared key column 0 — the convention of the synthetic
relations (``make_relation``) and of operator outputs (``_block_join``
keeps the key in column 0) — which is what makes reordering semantically
valid.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.engine.registry import WorkloadStats, get
from repro.engine.session import OperatorTask, Session, TaskOutput

# --------------------------------------------------------------------------
# Logical nodes
# --------------------------------------------------------------------------

_KINDS = ("scan", "filter", "join", "aggregate", "sort")


@dataclasses.dataclass(eq=False)
class Node:
    """One relational node; compare by identity (trees share subtrees)."""

    kind: str
    name: str
    children: Tuple["Node", ...] = ()
    relation: Any = None  # scan only: Relation / page-id list
    rows_per_page: int = 8  # scan only
    selectivity: float = 1.0  # filter only
    out_pages: Optional[float] = None  # join/aggregate estimate override
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def pages(self) -> float:
        """Estimated output pages of this subtree."""
        if self.kind == "scan":
            return max(float(_relation_pages(self.relation)), 1.0)
        if self.kind == "filter":
            return max(self.children[0].pages * self.selectivity, 1.0)
        if self.kind == "sort":
            return self.children[0].pages
        if self.kind == "aggregate":
            if self.out_pages is not None:
                return max(float(self.out_pages), 1.0)
            return max(self.children[0].pages / 8.0, 1.0)
        # join: explicit estimate, else the FK-join default |larger side|
        if self.out_pages is not None:
            return max(float(self.out_pages), 1.0)
        return max(self.children[0].pages, self.children[1].pages)


def _relation_pages(relation: Any) -> int:
    if relation is None:
        return 0
    if hasattr(relation, "page_ids"):
        return len(relation.page_ids)
    return len(relation)


class LogicalPlan:
    """Builder for a relational tree; the last node built is the root.

    >>> lp = LogicalPlan("q3")
    >>> o = lp.scan("orders", orders_rel)
    >>> li = lp.scan("lineitem", lineitem_rel)
    >>> j = lp.join(lp.filter(o, 0.5), li, out_pages=30.0)
    >>> lp.aggregate(j, out_pages=4.0)
    >>> tasks = compile_plan(session, lp).tasks
    """

    def __init__(self, name: str = "query"):
        self.name = name
        self.root: Optional[Node] = None
        self._seq = 0
        self.nodes: List[Node] = []

    def _add(self, node: Node) -> Node:
        self.nodes.append(node)
        self.root = node
        return node

    def _name(self, kind: str, name: Optional[str]) -> str:
        if name is not None:
            return name
        self._seq += 1
        return f"{self.name}.{kind}{self._seq}"

    def scan(self, name: str, relation: Any, rows_per_page: int = 8) -> Node:
        """A base table: a live ``Relation`` or page-id list."""
        if _relation_pages(relation) == 0:
            raise ValueError(f"scan {name!r}: relation has no pages")
        return self._add(Node(
            kind="scan", name=name, relation=relation,
            rows_per_page=rows_per_page,
        ))

    def filter(self, child: Node, selectivity: float,
               name: Optional[str] = None,
               predicate: Optional[Callable[..., bool]] = None) -> Node:
        """Scale the child's estimated pages by ``selectivity`` (0, 1].

        ``selectivity`` must be finite — ``nan``/``inf`` raise here instead
        of corrupting every upstream estimate.  ``predicate(page) -> bool``
        optionally carries the *actual* page predicate; when the filter is
        compiled physically (BNLJ probe side), the predicate executes at the
        data plane — at a compute-capable tier when the arbiter pushes it —
        while ``selectivity`` stays the planning estimate.
        """
        selectivity = float(selectivity)
        if not math.isfinite(selectivity) or not 0.0 < selectivity <= 1.0:
            raise ValueError(
                f"filter selectivity must be finite and in (0, 1], "
                f"got {selectivity}"
            )
        options: Dict[str, Any] = {}
        if predicate is not None:
            if not callable(predicate):
                raise TypeError(
                    f"filter predicate must be callable, got "
                    f"{type(predicate).__name__}"
                )
            options["predicate"] = predicate
        return self._add(Node(
            kind="filter", name=self._name("filter", name),
            children=(self._node(child),), selectivity=selectivity,
            options=options,
        ))

    def join(self, left: Node, right: Node,
             out_pages: Optional[float] = None,
             name: Optional[str] = None, **options: Any) -> Node:
        """Equijoin on the shared key column; ``options`` reach the task."""
        return self._add(Node(
            kind="join", name=self._name("join", name),
            children=(self._node(left), self._node(right)),
            out_pages=out_pages, options=dict(options),
        ))

    def aggregate(self, child: Node, out_pages: Optional[float] = None,
                  name: Optional[str] = None, **options: Any) -> Node:
        """Group-by on the key column, lowered to EAGG."""
        return self._add(Node(
            kind="aggregate", name=self._name("agg", name),
            children=(self._node(child),), out_pages=out_pages,
            options=dict(options),
        ))

    def sort(self, child: Node, name: Optional[str] = None,
             **options: Any) -> Node:
        """Order-by, lowered to EMS."""
        return self._add(Node(
            kind="sort", name=self._name("sort", name),
            children=(self._node(child),), options=dict(options),
        ))

    @staticmethod
    def _node(value: Any) -> Node:
        if not isinstance(value, Node):
            raise TypeError(
                f"expected a plan Node, got {type(value).__name__} "
                f"(wrap base tables with plan.scan(...))"
            )
        return value


# --------------------------------------------------------------------------
# Join-order optimization: enumerate-and-cost over a bounded candidate set
# --------------------------------------------------------------------------

# Full left-deep permutation enumeration up to this many cluster leaves;
# larger clusters fall back to the greedy + bushy candidates only.
_ENUM_LEAVES = 4


@dataclasses.dataclass(frozen=True)
class JoinChoice:
    """One join cluster's costed candidates, for inspection/benchmarks."""

    cluster: str  # the cluster's original top join node name
    chosen: str  # description of the winning shape
    chosen_cost: float  # modeled L of the winning shape
    left_deep_cost: float  # modeled L of the hand-written tree
    candidates: Tuple[Tuple[str, float], ...]  # (description, modeled L)
    # Filter nodes this cluster compiled physically onto a BNLJ probe side
    # (the operator executes them — candidates for tier pushdown) rather
    # than leaving them as pure stats annotations.
    pushed_filters: Tuple[str, ...] = ()


class _Cluster:
    """A maximal join-only subtree: leaves + pairwise page selectivities."""

    def __init__(self, session: Session, join_op: str, policy: str):
        self.leaves: List[Node] = []
        self.est: Dict[frozenset, float] = {}
        self.preds: List[Tuple[frozenset, frozenset, float]] = []
        self.tau = session.tier.tau_pages
        self.spec = get(join_op)
        self.policy = policy
        self.budget = session.budget

    def collect(self, node: Node) -> frozenset:
        """Flatten ``node``'s join subtree into leaves + predicates."""
        if node.kind != "join":
            idx = len(self.leaves)
            self.leaves.append(node)
            s = frozenset([idx])
            self.est[s] = max(node.pages, 1.0)
            return s
        ls = self.collect(node.children[0])
        rs = self.collect(node.children[1])
        out = node.pages if node.out_pages is not None else max(
            self.est[ls], self.est[rs]
        )
        phi = out / max(self.est[ls] * self.est[rs], 1e-12)
        self.preds.append((ls, rs, phi))
        s = ls | rs
        self.est[s] = max(out, 1.0)
        return s

    def size_of(self, s: frozenset) -> float:
        """Estimated pages of the join over leaf set ``s``.

        Independent-selectivity estimate: the product of leaf sizes times
        every source predicate whose two sides are both inside ``s``.
        """
        pages = 1.0
        for i in s:
            pages *= self.est[frozenset([i])]
        for a, b, phi in self.preds:
            if (a | b) <= s:
                pages *= phi
        return max(pages, 1.0)

    def cost_tree(self, tree: Any) -> float:
        """Modeled L of a candidate tree under a nominal even budget split.

        ``tree`` is a leaf index or a nested ``(left, right)`` pair.  Each
        join is priced with the operator's closed-form model at
        ``budget / (#joins)`` — the plan-level analogue of the arbiter's
        even-split starting point.
        """
        n_joins = max(len(self.leaves) - 1, 1)
        m_nom = max(self.budget / n_joins, self.spec.min_pages)
        total = 0.0

        def walk(t) -> frozenset:
            nonlocal total
            if isinstance(t, int):
                return frozenset([t])
            ls, rs = walk(t[0]), walk(t[1])
            s = ls | rs
            stats = WorkloadStats(
                size_r=self.size_of(ls), size_s=self.size_of(rs),
                out=self.size_of(s),
            )
            total += self.spec.model(stats, self.tau, m_nom, self.policy)
            return s

        walk(tree)
        return total

    # -- candidate shapes ---------------------------------------------------

    def _left_deep(self, order: Sequence[int]) -> Any:
        tree: Any = order[0]
        for i in order[1:]:
            tree = (tree, i)
        return tree

    def _bushy_smallest_pair(self) -> Any:
        """Repeatedly join the two smallest current subtrees (by est pages)."""
        forest: List[Tuple[frozenset, Any]] = [
            (frozenset([i]), i) for i in range(len(self.leaves))
        ]
        while len(forest) > 1:
            forest.sort(key=lambda e: (self.size_of(e[0]), min(e[0])))
            (sa, ta), (sb, tb) = forest[0], forest[1]
            forest = forest[2:] + [(sa | sb, (ta, tb))]
        return forest[0][1]

    def candidates(self) -> List[Tuple[str, Any]]:
        n = len(self.leaves)
        given = list(range(n))
        out: List[Tuple[str, Any]] = [
            ("left-deep (as written)", self._left_deep(given))
        ]
        if n <= _ENUM_LEAVES:
            for perm in itertools.permutations(given):
                if list(perm) == given:
                    continue
                names = ">".join(self.leaves[i].name for i in perm)
                out.append((f"left-deep {names}", self._left_deep(perm)))
        else:
            by_size = sorted(
                given, key=lambda i: self.est[frozenset([i])]
            )
            names = ">".join(self.leaves[i].name for i in by_size)
            out.append((f"left-deep smallest-first {names}",
                        self._left_deep(by_size)))
        out.append(("bushy smallest-pair", self._bushy_smallest_pair()))
        return out

    def best(self, cluster_name: str) -> Tuple[Any, JoinChoice]:
        """Cost every candidate; ties keep the hand-written order."""
        scored = [
            (desc, tree, self.cost_tree(tree))
            for desc, tree in self.candidates()
        ]
        left_deep_cost = scored[0][2]
        best_desc, best_tree, best_cost = min(
            scored, key=lambda e: (e[2], e[0] != "left-deep (as written)")
        )
        if best_cost >= left_deep_cost - 1e-12:
            best_desc, best_tree, best_cost = scored[0]
        return best_tree, JoinChoice(
            cluster=cluster_name, chosen=best_desc, chosen_cost=best_cost,
            left_deep_cost=left_deep_cost,
            candidates=tuple((d, c) for d, _, c in scored),
        )


# --------------------------------------------------------------------------
# compile_plan
# --------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledPlan:
    """A logical plan lowered to a Session task DAG.

    ``tasks`` is dependency-ordered (producers first) and runs with
    ``session.run(tasks, schedule="dag")``; ``root`` is the plan's final
    task.  ``join_choices`` records each join cluster's costed candidate
    set — the evidence behind the chosen shape.
    """

    tasks: List[OperatorTask]
    root: OperatorTask
    plan: LogicalPlan
    join_choices: List[JoinChoice]
    # Filter disposition across the whole plan: physically compiled onto a
    # BNLJ probe side (arbiter decides ship vs. tier pushdown at plan time)
    # vs. left as pure estimate annotations (ehj, build sides, non-leaf
    # filters).  Names are logical-plan node names.
    pushed_filters: List[str] = dataclasses.field(default_factory=list)
    annotation_filters: List[str] = dataclasses.field(default_factory=list)

    def run(self, session: Session, **kwargs: Any):
        kwargs.setdefault("schedule", "dag")
        return session.run(self.tasks, **kwargs)

    def explain(self, session: Session):
        return session.explain(self.tasks, dag=True)

    @property
    def output(self) -> TaskOutput:
        return self.root.output


def compile_plan(
    session: Session,
    plan: LogicalPlan,
    root: Optional[Node] = None,
    *,
    join_op: str = "ehj",
    optimize: bool = True,
    prefetch: bool = False,
) -> CompiledPlan:
    """Compile ``plan`` (rooted at ``root`` or ``plan.root``) into tasks.

    ``join_op`` selects the join operator (``"ehj"`` or ``"bnlj"``);
    ``optimize=False`` keeps the hand-written join order (the left-deep
    baseline the benchmark compares against).  Node ``options`` pass
    through to ``session.task`` (e.g. ``placement=...``, ``sigma=...``).
    """
    root = root if root is not None else plan.root
    if root is None:
        raise ValueError(f"plan {plan.name!r} is empty: build nodes first")
    if join_op not in ("ehj", "bnlj"):
        raise ValueError(f"join_op must be 'ehj' or 'bnlj', got {join_op!r}")
    tasks: List[OperatorTask] = []
    choices: List[JoinChoice] = []
    pushed_filters: List[str] = []

    def stats_options(node: Node) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Split node options into WorkloadStats fields vs. task options."""
        stat_fields = {"sigma", "partitions", "selectivity", "k_cap"}
        stats_kw = {k: v for k, v in node.options.items() if k in stat_fields}
        task_kw = {k: v for k, v in node.options.items()
                   if k not in stat_fields}
        return stats_kw, task_kw

    def leaf_rpp(node: Node) -> int:
        """rows_per_page flowing up from the subtree's first scan."""
        if node.kind == "scan":
            return node.rows_per_page
        return leaf_rpp(node.children[0])

    def lower(node: Node) -> Tuple[Any, float]:
        """Returns (data-plane value or TaskOutput, estimated pages)."""
        if node.kind == "scan":
            return node.relation, node.pages
        if node.kind == "filter":
            value, _ = lower(node.children[0])
            return value, node.pages
        if node.kind == "join":
            return lower_join_cluster(node)
        if node.kind == "aggregate":
            value, in_pages = lower(node.children[0])
            stats_kw, task_kw = stats_options(node)
            task_kw.setdefault("rows_per_page", leaf_rpp(node))
            task = session.task(
                "eagg",
                WorkloadStats(size_r=in_pages, out=node.pages, **stats_kw),
                inputs={"rel": value}, label=node.name, **task_kw,
            )
            tasks.append(task)
            return task.output, node.pages
        if node.kind == "sort":
            value, in_pages = lower(node.children[0])
            stats_kw, task_kw = stats_options(node)
            task_kw.setdefault("rows_per_page", leaf_rpp(node))
            task = session.task(
                "ems",
                WorkloadStats(size_r=in_pages, out=node.pages, **stats_kw),
                inputs={"page_ids": value}, label=node.name, **task_kw,
            )
            tasks.append(task)
            return task.output, node.pages
        raise ValueError(f"unknown plan node kind {node.kind!r}")

    def probe_filter(leaf: Node):
        """(combined sel, predicate, raw pages, names) for a physicalizable
        filter chain leaf, else None (the chain stays an annotation)."""
        if leaf.kind != "filter":
            return None
        sel, names, preds = 1.0, [], []
        n = leaf
        while n.kind == "filter":
            sel *= n.selectivity
            if n.options.get("predicate") is not None:
                preds.append(n.options["predicate"])
            names.append(n.name)
            n = n.children[0]
        if preds and (len(preds) > 1 or len(names) > 1):
            # Callables don't compose with each other or with scalar
            # estimates; a mixed chain stays a stats annotation.
            return None
        return sel, (preds[0] if preds else None), max(n.pages, 1.0), names

    def lower_join_cluster(node: Node) -> Tuple[Any, float]:
        """Flatten a maximal join subtree, pick a shape, emit join tasks."""
        cluster = _Cluster(session, join_op, session.policy)
        cluster.collect(node)
        choice: Optional[JoinChoice] = None
        if optimize and len(cluster.leaves) > 2:
            tree, choice = cluster.best(node.name)
        else:
            tree = cluster._left_deep(range(len(cluster.leaves)))
        lowered = [lower(leaf) for leaf in cluster.leaves]
        # Task options/rows_per_page follow the original top join node.
        stats_kw, task_kw = stats_options(node)
        rpp = leaf_rpp(node)
        seq = [0]
        cluster_pushed: List[str] = []

        def emit(t) -> Tuple[Any, frozenset]:
            if isinstance(t, int):
                return lowered[t][0], frozenset([t])
            lv, ls = emit(t[0])
            rv, rs = emit(t[1])
            s = ls | rs
            stats = WorkloadStats(
                size_r=cluster.size_of(ls), size_s=cluster.size_of(rs),
                out=cluster.size_of(s), **stats_kw,
            )
            seq[0] += 1
            label = node.name if s == frozenset(range(len(cluster.leaves))) \
                else f"{node.name}/{seq[0]}"
            kw = dict(task_kw)
            if join_op == "ehj":
                inputs = {"build": lv, "probe": rv}
                kw.setdefault("rows_per_page", rpp)
            else:
                inputs = {"outer": lv, "inner": rv}
                # A filter chain feeding the probe (inner) side compiles
                # physically: the operator scans the *raw* inner pages and
                # applies the filter itself, so the arbiter can ship the
                # scan to a compute-capable tier and return only survivors.
                pf = probe_filter(cluster.leaves[t[1]]) \
                    if isinstance(t[1], int) else None
                if pf is not None:
                    sel, pred, raw_pages, names = pf
                    stats = dataclasses.replace(
                        stats, size_s=raw_pages, pushdown_sel=sel,
                    )
                    if pred is not None:
                        kw.setdefault("inner_filter", pred)
                    cluster_pushed.extend(names)
            if prefetch:
                kw.setdefault("prefetch", True)
            task = session.task(
                join_op, stats, inputs=inputs, label=label, **kw,
            )
            tasks.append(task)
            return task.output, s

        value, s = emit(tree)
        if choice is not None:
            choices.append(dataclasses.replace(
                choice, pushed_filters=tuple(cluster_pushed),
            ))
        pushed_filters.extend(cluster_pushed)
        return value, cluster.size_of(s)

    value, _ = lower(root)
    if not tasks:
        raise ValueError(
            f"plan {plan.name!r} lowers to no operator tasks (scans and "
            f"filters alone are not executable)"
        )
    if not isinstance(value, TaskOutput) or value.task is not tasks[-1]:
        raise AssertionError("lowering must end at the root task")

    def filter_names(node: Node, acc: List[str]) -> None:
        if node.kind == "filter" and node.name not in acc:
            acc.append(node.name)
        for child in node.children:
            filter_names(child, acc)

    all_filters: List[str] = []
    filter_names(root, all_filters)
    annotation_filters = [n for n in all_filters if n not in set(pushed_filters)]
    return CompiledPlan(
        tasks=tasks, root=tasks[-1], plan=plan, join_choices=choices,
        pushed_filters=pushed_filters, annotation_filters=annotation_filters,
    )
