"""Unified spill-engine layer shared by every remote-memory operator.

All three REMOP operators (BNLJ, EMS, EHJ) — and any operator added later —
move data across the remote tier exclusively through this layer:

  * :class:`TransferScheduler` (``engine.scheduler``) is the tier router and
    owner of the :class:`repro.core.TransferLedger` stack: every batched
    read/write it issues is one transfer round per tier touched (its target
    is a single ``RemoteMemory`` or a whole ``MemoryHierarchy``, with writes
    named to a placement tier and reads placement-resolved), it records
    §IV-E prefetch hiding in one place, supports ledger
    ``snapshot()``/``delta()`` for per-region accounting (per-tier ledgers
    summing to hierarchy-wide D/C on a hierarchy), and can coalesce adjacent
    read rounds.
  * :class:`BufferPool` (``engine.buffers``) is the write side: a pool of
    ``capacity`` pages sliced across ``n_streams`` output streams, flushing
    one slice per batched write round when a slice fills.
  * :class:`PageCursor` (``engine.buffers``) is the read side: a page stream
    through a fixed-size buffer, one refill per read round, with an optional
    double-buffer prefetch and sorted-run merge helpers.
  * ``engine.registry`` maps operator names to :class:`OperatorSpec` bundles
    (plan type, buffer policies, runner, oracle, latency model, min_pages);
    :func:`plan_operator` is the single planning entry point used by the
    benchmark harness.
  * ``engine.session`` is the query-facing surface: a :class:`Session` owns
    the remote target, the scheduler, the policy, and the global budget, and
    exposes typed ``session.task(op, stats, inputs=...)`` construction,
    ``session.plan``/``session.explain`` (structured plan reports), and
    ``session.run`` with optional measured-feedback re-planning
    (``replan="measured"``).
  * ``engine.pipeline`` holds the shared plan dataclasses and the deprecated
    ``plan_pipeline``/``run_pipeline`` shims (ledger-exact over the session).

The accounting contract (paper §II, Definitions 1–3)
----------------------------------------------------

Latency on a remote tier is Eq. (1): ``D/BW + C*RTT``, normalized to the
dimensionless latency cost

    ``L = D + tau * C``,   ``tau = BW * RTT / page_bytes``

where ``D`` counts transferred *pages* and ``C`` counts *transfer rounds*.
The engine guarantees, for any operator built on it:

  1. **One call, one round.** Every ``TransferScheduler.read``/``write`` (and
     hence every ``PageCursor`` refill and every ``BufferPool`` slice flush)
     increments ``C`` by exactly 1 and ``D`` by the batch's page count —
     rounds are never double-counted and never split.
  2. **Ceil semantics.** Streaming ``V`` pages through a ``c``-page cursor or
     pool slice costs exactly ``ceil(V/c)`` rounds (capacity-triggered
     flushes plus one forced flush for a partial remainder), matching the
     closed forms in §III that the tests compare against.
  3. **Prefetch hiding.** With prefetch enabled, every round after a read
     stream's first is overlapped by the double buffer and recorded in
     ``c_prefetch_hidden``; the first round of a stream is never hidden.
     ``TransferLedger.latency_seconds(tier, prefetch=True)`` then charges RTT
     only for ``C - c_prefetch_hidden`` rounds.
  4. **Delta reporting.** Operators report per-call D/C as
     ``ledger.delta(snapshot)`` — immutable snapshots, no ledger copies — so
     nested/sequenced operators compose on one shared ledger.
"""

from repro.engine.buffers import BufferPool, PageCursor
from repro.engine.eviction import (
    ClockPolicy,
    DeadAfterFlushPolicy,
    EvictionPolicy,
    Evictor,
    LRUPolicy,
    make_policy,
)
from repro.engine.scheduler import TransferScheduler
from repro.engine import registry
from repro.engine.registry import (
    OperatorPlan,
    OperatorSpec,
    WorkloadStats,
    model_costs,
    model_latency,
    plan_operator,
    resolve_hierarchy,
    resolve_tier,
)
from repro.engine.pipeline import (
    OperatorBudget,
    PipelinePlan,
    PipelineRunResult,
    plan_pipeline,
    run_pipeline,
)
from repro.engine.server import (
    PreemptionEvent,
    QueryReport,
    QueryRequest,
    Server,
    ServerReport,
)
from repro.engine.session import (
    OperatorTask,
    PlanReport,
    ReplanEvent,
    Session,
    SessionRunResult,
    TaskExplain,
    TaskOutput,
    TaskRun,
)

__all__ = [
    "Server",
    "QueryRequest",
    "QueryReport",
    "ServerReport",
    "PreemptionEvent",
    "Session",
    "OperatorTask",
    "TaskOutput",
    "TaskRun",
    "TaskExplain",
    "PlanReport",
    "ReplanEvent",
    "SessionRunResult",
    "BufferPool",
    "PageCursor",
    "TransferScheduler",
    "EvictionPolicy",
    "Evictor",
    "LRUPolicy",
    "ClockPolicy",
    "DeadAfterFlushPolicy",
    "make_policy",
    "OperatorPlan",
    "OperatorSpec",
    "WorkloadStats",
    "model_costs",
    "model_latency",
    "plan_operator",
    "resolve_hierarchy",
    "resolve_tier",
    "registry",
    "OperatorBudget",
    "PipelinePlan",
    "PipelineRunResult",
    "plan_pipeline",
    "run_pipeline",
]
