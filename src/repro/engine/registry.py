"""Operator/plan registry: one entry point for planning every spill operator.

Benchmarks, examples, and future query layers plan through

    plan_operator("bnlj" | "ems" | "ehj", stats, tier, m_pages, policy=...)

instead of importing per-operator constructors.  Each registered
:class:`OperatorSpec` bundles the plan type, the available buffer policies
(REMOP optimum plus the paper's baselines), the data-plane runner, and the
correctness oracle, so adding an operator (external aggregation, a new tier
stack) is one ``register()`` call.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, Union, runtime_checkable

import math

from repro.core.cost_model import (
    HierarchySpec,
    TierSpec,
    hierarchy_spec,
    resolve_tier_name,
)
from repro.core.policies import (
    BNLJPlan,
    EAggPlan,
    EHJPlan,
    EMSPlan,
    bnlj_conventional,
    bnlj_latency,
    bnlj_plan,
    eagg_latency,
    eagg_plan,
    eagg_starved,
    ehj_latency,
    ehj_plan,
    ehj_starved,
    ems_conventional,
    ems_costs,
    ems_duckdb,
    ems_passes,
    ems_plan,
)


@runtime_checkable
class OperatorPlan(Protocol):
    """A buffer plan for one spill operator; ``op`` names its registry entry."""

    op: str


@dataclasses.dataclass(frozen=True)
class WorkloadStats:
    """Operator-independent workload description; all sizes in pages.

    ``size_r`` is the primary input (BNLJ outer, EMS sort input, EHJ build),
    ``size_s`` the secondary (inner / probe), ``out`` the output estimate.
    ``selectivity`` is the BNLJ join selectivity ``f`` (beta = f*M);
    ``partitions``/``sigma`` are the EHJ radix count and spilled fraction;
    ``k_cap`` optionally caps the EMS merge fan-in.
    """

    size_r: float = 0.0
    size_s: float = 0.0
    out: float = 0.0
    selectivity: float = 0.0
    partitions: int = 16
    sigma: float = 0.5
    k_cap: Optional[int] = None


Planner = Callable[[WorkloadStats, float, float, str], OperatorPlan]
# Modeled latency cost L(stats, tau, m_pages, policy) — the arbiter's
# marginal-cost hook (repro.core.arbiter consumes L as a function of m).
LatencyModel = Callable[[WorkloadStats, float, float, str], float]
# Estimated remote spill footprint F(stats, tau, m_pages) in pages — what a
# tier's capacity constrains when the hierarchy arbiter places an operator.
# tau matters because the plan itself is tau-dependent (e.g. the EMS merge
# fan-in, hence pass count, changes with the placement tier).
Footprint = Callable[[WorkloadStats, float, float], float]


@dataclasses.dataclass(frozen=True)
class OperatorSpec:
    """Everything the engine knows about one spill operator."""

    name: str
    plan_type: type
    policies: Tuple[str, ...]  # first entry is the default ("remop")
    planner: Planner
    run: Callable[..., Any]  # data-plane executor over a RemoteMemory/hierarchy
    oracle: Callable[..., Any]  # accounting-free correctness reference
    model: Optional[LatencyModel] = None  # modeled L for pipeline arbitration
    min_pages: float = 3.0  # smallest plannable budget (pages)
    footprint: Optional[Footprint] = None  # spill pages parked on the tier


_REGISTRY: Dict[str, OperatorSpec] = {}
_builtin_registered = False


def register(spec: OperatorSpec) -> OperatorSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"operator {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> OperatorSpec:
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown operator {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> Tuple[str, ...]:
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))


def resolve_tier(tier: Union[TierSpec, str]) -> TierSpec:
    """Accept a TierSpec or a tier name from Table I / TESTBED / TPU tiers."""
    return resolve_tier_name(tier)


def resolve_hierarchy(hierarchy: Any) -> HierarchySpec:
    """Normalize a hierarchy argument to a :class:`HierarchySpec`.

    Accepts a spec, a live :class:`repro.remote.simulator.MemoryHierarchy`,
    or a sequence of levels where each level is a tier (TierSpec or name from
    the known tables) or a ``(tier, capacity_pages)`` pair — e.g.
    ``[("dram", 64), ("rdma", 256), "ssd"]``.
    """
    if isinstance(hierarchy, HierarchySpec):
        return hierarchy
    if getattr(hierarchy, "is_hierarchy", False):
        return hierarchy.spec
    return hierarchy_spec(*hierarchy)


def plan_operator(
    op: str,
    stats: WorkloadStats,
    tier: Union[TierSpec, str],
    m_pages: float,
    policy: str = "remop",
) -> OperatorPlan:
    """Plan ``op``'s buffers for a workload on a tier under one policy.

    ``m_pages`` is the operator's local budget M (the EHJ I/O pool M_B); tau
    comes from the tier's ``tau_pages``.  ``policy`` selects the REMOP optimum
    or one of the paper's baselines (see ``get(op).policies``).
    """
    spec = get(op)
    if policy not in spec.policies:
        raise ValueError(
            f"operator {op!r} has no policy {policy!r}; available: {spec.policies}"
        )
    if m_pages < spec.min_pages:
        raise ValueError(
            f"operator {op!r} needs m_pages >= {spec.min_pages} "
            f"(one page per buffer pool at minimum), got {m_pages}"
        )
    return spec.planner(stats, resolve_tier(tier).tau_pages, float(m_pages), policy)


def model_latency(
    op: str,
    stats: WorkloadStats,
    tier: Union[TierSpec, str],
    m_pages: float,
    policy: str = "remop",
) -> float:
    """Modeled latency cost L = D + tau*C for ``op`` planned with ``m_pages``.

    This is the objective the query-level memory arbiter minimizes when it
    splits one global budget across a pipeline (see ``engine.pipeline``).
    """
    spec = get(op)
    if spec.model is None:
        raise ValueError(f"operator {op!r} has no latency model")
    return spec.model(stats, resolve_tier(tier).tau_pages, float(m_pages), policy)


# --------------------------------------------------------------------------
# Built-in operators
# --------------------------------------------------------------------------


def _plan_bnlj(stats: WorkloadStats, tau: float, m: float, policy: str) -> BNLJPlan:
    if policy == "conventional":
        return bnlj_conventional(m)
    return bnlj_plan(m, tau, selectivity=stats.selectivity)


def _plan_ems(stats: WorkloadStats, tau: float, m: float, policy: str) -> EMSPlan:
    if policy == "conventional":
        return ems_conventional(m)
    if policy == "duckdb":
        return ems_duckdb(m)
    return ems_plan(stats.size_r, m, tau, k_cap=stats.k_cap)


def _plan_ehj(stats: WorkloadStats, tau: float, m: float, policy: str) -> EHJPlan:
    if policy == "conventional":
        return ehj_starved(m, stats.partitions, stats.sigma)
    return ehj_plan(
        stats.size_r, stats.size_s, stats.out, m, stats.partitions, stats.sigma
    )


def _plan_eagg(stats: WorkloadStats, tau: float, m: float, policy: str) -> EAggPlan:
    if policy == "conventional":
        return eagg_starved(m, stats.partitions, stats.sigma)
    return eagg_plan(stats.size_r, stats.out, m, stats.partitions, stats.sigma)


# Latency models: closed-form L = D + tau*C of the policy's plan at budget m.
# Each is (weakly) decreasing in m, which is what the arbiter's greedy
# marginal-cost descent assumes.


def _model_bnlj(stats: WorkloadStats, tau: float, m: float, policy: str) -> float:
    plan = _plan_bnlj(stats, tau, m, policy)
    return bnlj_latency(stats.size_r, stats.size_s, stats.out, plan, tau)


def _model_ems(stats: WorkloadStats, tau: float, m: float, policy: str) -> float:
    plan = _plan_ems(stats, tau, m, policy)
    d, c, _ = ems_costs(stats.size_r, m, plan)
    # Run formation (§III-B a): one read + one write round per M-page chunk.
    chunks = math.ceil(stats.size_r / max(m, 1.0))
    return (d + 2.0 * stats.size_r) + tau * (c + 2.0 * chunks)


def _model_ehj(stats: WorkloadStats, tau: float, m: float, policy: str) -> float:
    plan = _plan_ehj(stats, tau, m, policy)
    return ehj_latency(stats.size_r, stats.size_s, stats.out, plan, tau)


def _model_eagg(stats: WorkloadStats, tau: float, m: float, policy: str) -> float:
    plan = _plan_eagg(stats, tau, m, policy)
    return eagg_latency(stats.size_r, stats.out, plan, tau)


# Spill footprints: pages an operator parks on its placement tier over a run
# (nothing is freed mid-operator, so this is also the peak residency the
# hierarchy arbiter must fit under the tier's capacity).  Evaluated at the
# placement tier's tau, because the plan the operator executes is itself
# tau-dependent.


def _fp_bnlj(stats: WorkloadStats, tau: float, m: float) -> float:
    # Only the join output is written back.
    return stats.out


def _fp_ems(stats: WorkloadStats, tau: float, m: float) -> float:
    # Run formation writes N pages of runs; every merge pass writes N more,
    # with the pass count set by the fan-in this tier's tau selects.
    plan = _plan_ems(stats, tau, m, "remop")
    return stats.size_r * (1.0 + ems_passes(stats.size_r, m, plan.k))


def _fp_ehj(stats: WorkloadStats, tau: float, m: float) -> float:
    # Spilled build + probe partitions, plus the join output.
    return stats.sigma * (stats.size_r + stats.size_s) + stats.out


def _fp_eagg(stats: WorkloadStats, tau: float, m: float) -> float:
    # Spilled raw partitions, plus the group output.
    return stats.sigma * stats.size_r + stats.out


def _ensure_builtin() -> None:
    """Register the built-in operators on first lookup.

    Deferred (rather than at import) because the operator modules themselves
    import the engine's buffers/scheduler — eager registration would re-enter
    a partially-imported module.
    """
    global _builtin_registered
    if _builtin_registered:
        return

    # The flag is only set once registration succeeds, so a failed deferred
    # import resurfaces as the real ImportError on the next lookup instead of
    # a misleading "unknown operator" KeyError.
    from repro.remote.bnlj import bnlj, bnlj_oracle
    from repro.remote.eagg import eagg, eagg_oracle
    from repro.remote.ehj import ehj, ehj_oracle
    from repro.remote.ems import ems_oracle, ems_sort

    register(OperatorSpec(
        name="bnlj", plan_type=BNLJPlan,
        policies=("remop", "conventional"),
        planner=_plan_bnlj, run=bnlj, oracle=bnlj_oracle,
        model=_model_bnlj, footprint=_fp_bnlj,
    ))
    register(OperatorSpec(
        name="ems", plan_type=EMSPlan,
        policies=("remop", "conventional", "duckdb"),
        planner=_plan_ems, run=ems_sort, oracle=ems_oracle,
        model=_model_ems, footprint=_fp_ems,
    ))
    register(OperatorSpec(
        name="ehj", plan_type=EHJPlan,
        policies=("remop", "conventional"),
        planner=_plan_ehj, run=ehj, oracle=ehj_oracle,
        model=_model_ehj, footprint=_fp_ehj,
    ))
    register(OperatorSpec(
        name="eagg", plan_type=EAggPlan,
        policies=("remop", "conventional"),
        planner=_plan_eagg, run=eagg, oracle=eagg_oracle,
        model=_model_eagg, footprint=_fp_eagg,
    ))
    _builtin_registered = True
