"""Operator/plan registry: one entry point for planning every spill operator.

Benchmarks, examples, and future query layers plan through

    plan_operator("bnlj" | "ems" | "ehj", stats, tier, m_pages, policy=...)

instead of importing per-operator constructors.  Each registered
:class:`OperatorSpec` bundles the plan type, the available buffer policies
(REMOP optimum plus the paper's baselines), the data-plane runner, and the
correctness oracle, so adding an operator (external aggregation, a new tier
stack) is one ``register()`` call.
"""

from __future__ import annotations

import dataclasses
import math
from typing import (
    Any,
    Callable,
    Dict,
    Mapping,
    Optional,
    Protocol,
    Tuple,
    Union,
    runtime_checkable,
)

from repro.core.cost_model import (
    HierarchySpec,
    TierLevel,
    TierSpec,
    hierarchy_spec,
    resolve_tier_name,
)
from repro.core.policies import (
    BNLJPlan,
    PushdownChoice,
    pushdown_or_ship,
    EAggPlan,
    EHJPlan,
    EMSPlan,
    bnlj_conventional,
    bnlj_costs,
    bnlj_plan,
    eagg_data_costs,
    eagg_plan,
    eagg_round_costs,
    eagg_starved,
    ehj_data_costs,
    ehj_plan,
    ehj_round_costs,
    ehj_starved,
    ems_conventional,
    ems_duckdb,
    ems_passes,
    ems_plan,
    ems_total_costs,
)


@runtime_checkable
class OperatorPlan(Protocol):
    """A buffer plan for one spill operator; ``op`` names its registry entry."""

    op: str


@dataclasses.dataclass(frozen=True)
class WorkloadStats:
    """Operator-independent workload description; all sizes in pages.

    ``size_r`` is the primary input (BNLJ outer, EMS sort input, EHJ build),
    ``size_s`` the secondary (inner / probe), ``out`` the output estimate.
    ``selectivity`` is the BNLJ join selectivity ``f`` (beta = f*M);
    ``partitions``/``sigma`` are the EHJ radix count and spilled fraction;
    ``k_cap`` optionally caps the EMS merge fan-in.  ``pushdown_sel`` is the
    estimated surviving fraction of a probe-side *filter* annotation on the
    secondary input (BNLJ inner) — ``None`` means no filter; a set value
    makes the filter physical and lets the arbiter price executing it at a
    compute-capable tier (``OperatorSpec.pushdown``).
    """

    size_r: float = 0.0
    size_s: float = 0.0
    out: float = 0.0
    selectivity: float = 0.0
    partitions: int = 16
    sigma: float = 0.5
    k_cap: Optional[int] = None
    pushdown_sel: Optional[float] = None


Planner = Callable[[WorkloadStats, float, float, str], OperatorPlan]
# Modeled latency cost L(stats, tau, m_pages, policy) — the arbiter's
# marginal-cost hook (repro.core.arbiter consumes L as a function of m).
LatencyModel = Callable[[WorkloadStats, float, float, str], float]
# Modeled (D, C) of the policy's plan at budget m — the structured form the
# session ``explain()`` report decomposes L = D + tau*C from.
CostModel = Callable[[WorkloadStats, float, float, str], Tuple[float, float]]
# Estimated remote spill footprint F(stats, tau, m_pages) in pages — what a
# tier's capacity constrains when the hierarchy arbiter places an operator.
# tau matters because the plan itself is tau-dependent (e.g. the EMS merge
# fan-in, hence pass count, changes with the placement tier).
Footprint = Callable[[WorkloadStats, float, float], float]
# Measured-feedback hook: (estimated stats, run result) -> stats with the
# *measured* output cardinality, for mid-pipeline re-planning.
MeasuredStats = Callable[[WorkloadStats, Any], WorkloadStats]
# Output-stats hook: estimated output size (pages) of the operator at plan
# time — the planning-time analogue of ``MeasuredStats``.  A query frontend
# uses it to feed one task's estimated output into the downstream task's
# input stats (``input_stats``) before anything has run.
OutputPages = Callable[[WorkloadStats], float]
# Per-stream footprint decomposition: the same pages ``Footprint`` reports,
# attributed to the operator's named spill streams (``OperatorSpec.streams``)
# — what fractional placement splits across tiers and ``explain()`` renders.
StreamFootprints = Callable[[WorkloadStats, float, float], Dict[str, float]]
# Ship-pages vs. ship-compute arbitration hook: given the workload, the
# placement tier's full TierLevel (capabilities included), the budget m, and
# the policy, return the priced PushdownChoice — or None when the operator
# has nothing to push (no filter annotation, no spilled partitions).  The
# choice's l_delta (<= 0) is added to the operator's modeled L during
# arbitration, so a slower-tau tier with compute can win placement.
Pushdown = Callable[
    [WorkloadStats, TierLevel, float, str], Optional[PushdownChoice]
]
# Data-plane kwargs realizing a PushdownChoice (e.g. BNLJ's
# ``inner_filter``/``pushdown``); applied with setdefault so explicit task
# options always win.
PushdownKwargs = Callable[[WorkloadStats, PushdownChoice], Dict[str, Any]]


@dataclasses.dataclass(frozen=True)
class OperatorSpec:
    """Everything the engine knows about one spill operator."""

    name: str
    plan_type: type
    policies: Tuple[str, ...]  # first entry is the default ("remop")
    planner: Planner
    run: Callable[..., Any]  # data-plane executor over a RemoteMemory/hierarchy
    oracle: Callable[..., Any]  # accounting-free correctness reference
    model: Optional[LatencyModel] = None  # modeled L for pipeline arbitration
    min_pages: float = 3.0  # smallest plannable budget (pages)
    footprint: Optional[Footprint] = None  # spill pages parked on the tier
    costs: Optional[CostModel] = None  # modeled (D, C) behind ``model``
    # Typed input signature (session API): ordered names of the data-plane
    # inputs ``run`` takes positionally, and the WorkloadStats field each one
    # sizes (so a re-planner can refresh an estimate from a measured input).
    inputs: Tuple[str, ...] = ()
    input_stats: Mapping[str, str] = dataclasses.field(default_factory=dict)
    measured_stats: Optional[MeasuredStats] = None  # replan feedback hook
    output_of: Optional[Callable[[Any], Any]] = None  # run result -> output pages
    # Estimated output pages at plan time (feeds downstream input stats).
    output_pages: Optional[OutputPages] = None
    # Named spill streams, in the order the data plane's ``tier=`` mapping
    # (and ``session.task(..., placement=[...])`` lists) bind to; empty for
    # operators without per-stream routing.
    streams: Tuple[str, ...] = ()
    # ``footprint`` decomposed per stream (keys ⊆ ``streams``).
    stream_footprints: Optional[StreamFootprints] = None
    # Ship-vs-push arbitration hook and the data-plane kwargs realizing its
    # verdict; None for operators with nothing to execute at the tier.
    pushdown: Optional[Pushdown] = None
    pushdown_kwargs: Optional[PushdownKwargs] = None

    def bind_inputs(self, inputs: Mapping[str, Any]) -> Tuple[Any, ...]:
        """Resolve named inputs to ``run``'s positional argument order.

        Raises ``ValueError`` naming the expected signature when an input is
        missing or unknown — the typed replacement for the legacy positional
        ``(args, kwargs)`` workload tuples.
        """
        unknown = sorted(set(inputs) - set(self.inputs))
        missing = [name for name in self.inputs if name not in inputs]
        if unknown or missing:
            problems = []
            if missing:
                problems.append(f"missing {missing}")
            if unknown:
                problems.append(f"unknown {unknown}")
            raise ValueError(
                f"operator {self.name!r} takes inputs {list(self.inputs)}: "
                + ", ".join(problems)
            )
        return tuple(inputs[name] for name in self.inputs)


_REGISTRY: Dict[str, OperatorSpec] = {}
_builtin_registered = False


def register(spec: OperatorSpec) -> OperatorSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"operator {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> OperatorSpec:
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown operator {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> Tuple[str, ...]:
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))


def resolve_tier(tier: Union[TierSpec, str]) -> TierSpec:
    """Accept a TierSpec or a tier name from Table I / TESTBED / TPU tiers."""
    return resolve_tier_name(tier)


def resolve_hierarchy(hierarchy: Any) -> HierarchySpec:
    """Normalize a hierarchy argument to a :class:`HierarchySpec`.

    Accepts a spec, a live :class:`repro.remote.simulator.MemoryHierarchy`,
    or a sequence of levels where each level is a tier (TierSpec or name from
    the known tables) or a ``(tier, capacity_pages)`` pair — e.g.
    ``[("dram", 64), ("rdma", 256), "ssd"]``.
    """
    if isinstance(hierarchy, HierarchySpec):
        return hierarchy
    if getattr(hierarchy, "is_hierarchy", False):
        return hierarchy.spec
    return hierarchy_spec(*hierarchy)


def plan_operator(
    op: str,
    stats: WorkloadStats,
    tier: Union[TierSpec, str],
    m_pages: float,
    policy: str = "remop",
) -> OperatorPlan:
    """Plan ``op``'s buffers for a workload on a tier under one policy.

    ``m_pages`` is the operator's local budget M (the EHJ I/O pool M_B); tau
    comes from the tier's ``tau_pages``.  ``policy`` selects the REMOP optimum
    or one of the paper's baselines (see ``get(op).policies``).
    """
    spec = get(op)
    if policy not in spec.policies:
        raise ValueError(
            f"operator {op!r} has no policy {policy!r}; available: {spec.policies}"
        )
    if m_pages < spec.min_pages:
        raise ValueError(
            f"operator {op!r} needs m_pages >= {spec.min_pages} "
            f"(one page per buffer pool at minimum), got {m_pages}"
        )
    return spec.planner(stats, resolve_tier(tier).tau_pages, float(m_pages), policy)


def model_latency(
    op: str,
    stats: WorkloadStats,
    tier: Union[TierSpec, str],
    m_pages: float,
    policy: str = "remop",
) -> float:
    """Modeled latency cost L = D + tau*C for ``op`` planned with ``m_pages``.

    This is the objective the query-level memory arbiter minimizes when it
    splits one global budget across a pipeline (see ``engine.pipeline``).
    """
    spec = get(op)
    if spec.model is None:
        raise ValueError(f"operator {op!r} has no latency model")
    return spec.model(stats, resolve_tier(tier).tau_pages, float(m_pages), policy)


def model_costs(
    op: str,
    stats: WorkloadStats,
    tier: Union[TierSpec, str],
    m_pages: float,
    policy: str = "remop",
) -> Tuple[float, float]:
    """Modeled (D, C) for ``op`` planned with ``m_pages`` on ``tier``.

    The structured decomposition behind :func:`model_latency`
    (L = D + tau*C) — what ``Session.explain`` reports per operator.
    """
    spec = get(op)
    if spec.costs is None:
        raise ValueError(f"operator {op!r} has no cost model")
    return spec.costs(stats, resolve_tier(tier).tau_pages, float(m_pages), policy)


# --------------------------------------------------------------------------
# Built-in operators
# --------------------------------------------------------------------------


def _plan_bnlj(stats: WorkloadStats, tau: float, m: float, policy: str) -> BNLJPlan:
    if policy == "conventional":
        return bnlj_conventional(m)
    return bnlj_plan(m, tau, selectivity=stats.selectivity)


def _plan_ems(stats: WorkloadStats, tau: float, m: float, policy: str) -> EMSPlan:
    if policy == "conventional":
        return ems_conventional(m)
    if policy == "duckdb":
        return ems_duckdb(m)
    return ems_plan(stats.size_r, m, tau, k_cap=stats.k_cap)


def _plan_ehj(stats: WorkloadStats, tau: float, m: float, policy: str) -> EHJPlan:
    if policy == "conventional":
        return ehj_starved(m, stats.partitions, stats.sigma)
    return ehj_plan(
        stats.size_r, stats.size_s, stats.out, m, stats.partitions, stats.sigma
    )


def _plan_eagg(stats: WorkloadStats, tau: float, m: float, policy: str) -> EAggPlan:
    if policy == "conventional":
        return eagg_starved(m, stats.partitions, stats.sigma)
    return eagg_plan(stats.size_r, stats.out, m, stats.partitions, stats.sigma)


# Cost models: closed-form (D, C) of the policy's plan at budget m; the
# latency models below collapse them to L = D + tau*C.  Each L is (weakly)
# decreasing in m, which is what the arbiter's greedy marginal-cost descent
# assumes; the (D, C) split is what ``Session.explain`` reports per operator.


def _costs_bnlj(
    stats: WorkloadStats, tau: float, m: float, policy: str
) -> Tuple[float, float]:
    plan = _plan_bnlj(stats, tau, m, policy)
    return bnlj_costs(stats.size_r, stats.size_s, stats.out, plan)


def _costs_ems(
    stats: WorkloadStats, tau: float, m: float, policy: str
) -> Tuple[float, float]:
    # Run formation + merge passes, one shared closed form (core.policies).
    plan = _plan_ems(stats, tau, m, policy)
    return ems_total_costs(stats.size_r, m, plan)


def _costs_ehj(
    stats: WorkloadStats, tau: float, m: float, policy: str
) -> Tuple[float, float]:
    plan = _plan_ehj(stats, tau, m, policy)
    d = sum(ehj_data_costs(stats.size_r, stats.size_s, stats.out, plan.sigma))
    c = sum(ehj_round_costs(stats.size_r, stats.size_s, stats.out, plan))
    return d, c


def _costs_eagg(
    stats: WorkloadStats, tau: float, m: float, policy: str
) -> Tuple[float, float]:
    plan = _plan_eagg(stats, tau, m, policy)
    d = sum(eagg_data_costs(stats.size_r, stats.out, plan.sigma))
    c = sum(eagg_round_costs(stats.size_r, stats.out, plan))
    return d, c


def _model_from(costs: CostModel) -> LatencyModel:
    def model(stats: WorkloadStats, tau: float, m: float, policy: str) -> float:
        d, c = costs(stats, tau, m, policy)
        return d + tau * c

    return model


_model_bnlj = _model_from(_costs_bnlj)
_model_ems = _model_from(_costs_ems)
_model_ehj = _model_from(_costs_ehj)
_model_eagg = _model_from(_costs_eagg)


# Spill footprints: pages an operator parks on its placement tier over a run
# (nothing is freed mid-operator, so this is also the peak residency the
# hierarchy arbiter must fit under the tier's capacity).  Evaluated at the
# placement tier's tau, because the plan the operator executes is itself
# tau-dependent.


def _fp_bnlj(stats: WorkloadStats, tau: float, m: float) -> float:
    # Only the join output is written back.
    return stats.out


def _fp_ems(stats: WorkloadStats, tau: float, m: float) -> float:
    # Run formation writes N pages of runs; every merge pass writes N more,
    # with the pass count set by the fan-in this tier's tau selects.
    plan = _plan_ems(stats, tau, m, "remop")
    return stats.size_r * (1.0 + ems_passes(stats.size_r, m, plan.k))


def _fp_ehj(stats: WorkloadStats, tau: float, m: float) -> float:
    # Spilled build + probe partitions, plus the join output.
    return stats.sigma * (stats.size_r + stats.size_s) + stats.out


def _fp_eagg(stats: WorkloadStats, tau: float, m: float) -> float:
    # Spilled raw partitions, plus the group output.
    return stats.sigma * stats.size_r + stats.out


# Per-stream decompositions of the footprints above (same totals).  The
# stream names match the ``tier=`` mapping each operator's data plane takes,
# so fractional placement can route e.g. EHJ build partitions to DRAM while
# the staged probe spills to SSD.


def _sfp_bnlj(stats: WorkloadStats, tau: float, m: float) -> Dict[str, float]:
    return {"output": stats.out}


def _sfp_ems(stats: WorkloadStats, tau: float, m: float) -> Dict[str, float]:
    plan = _plan_ems(stats, tau, m, "remop")
    passes = ems_passes(stats.size_r, m, plan.k)
    return {"runs": stats.size_r * passes, "output": stats.size_r}


def _sfp_ehj(stats: WorkloadStats, tau: float, m: float) -> Dict[str, float]:
    return {
        "build": stats.sigma * stats.size_r,
        "stage": stats.sigma * stats.size_s,
        "output": stats.out,
    }


def _sfp_eagg(stats: WorkloadStats, tau: float, m: float) -> Dict[str, float]:
    return {"partitions": stats.sigma * stats.size_r, "output": stats.out}


# Ship-pages vs. ship-compute hooks: price the operator's pushable stream at
# the candidate placement tier with the closed forms (core.policies) and
# return the verdict.  The l_delta (<= 0) folds into the arbiter's modeled L.


def _scale_choice(ch: PushdownChoice, k: int) -> PushdownChoice:
    """Scale a per-pass/per-partition verdict to ``k`` repetitions."""
    if k == 1:
        return ch
    return dataclasses.replace(
        ch, l_ship=ch.l_ship * k, l_push=ch.l_push * k,
        d_saved=ch.d_saved * k, c_pushdown=ch.c_pushdown * k,
        scanned=ch.scanned * k,
    )


def _pushdown_bnlj(
    stats: WorkloadStats, level: TierLevel, m: float, policy: str
) -> Optional[PushdownChoice]:
    # The probe-side filter annotation: every outer pass re-reads the inner
    # stream in p_s-page rounds; the per-pass verdict scales by the pass
    # count (the decision itself is pass-invariant).
    if stats.pushdown_sel is None:
        return None
    plan = _plan_bnlj(stats, level.tier.tau_pages, m, policy)
    p_r = max(1, int(round(plan.outer_pages)))
    p_s = max(1, int(round(plan.inner_pages)))
    n = max(int(round(stats.size_s)), 0)
    passes = max(math.ceil(stats.size_r / p_r), 1)
    ch = pushdown_or_ship(
        n, stats.pushdown_sel, level, level.tier.tau_pages, batch_pages=p_s
    )
    return _scale_choice(ch, passes)


def _pushdown_eagg(
    stats: WorkloadStats, level: TierLevel, m: float, policy: str
) -> Optional[PushdownChoice]:
    # P2 re-reads each spilled partition (~size_r/P raw pages); a pushed
    # partial aggregation ships ~out/P group pages in one round instead.
    plan = _plan_eagg(stats, level.tier.tau_pages, m, policy)
    n_spilled = int(round(plan.sigma * plan.partitions))
    if n_spilled <= 0:
        return None
    n_q = max(int(round(stats.size_r / plan.partitions)), 0)
    if n_q <= 0:
        return None
    out_q = stats.out / plan.partitions
    r_r2 = max(int(round(plan.p2[0])), 1) if plan.p2 else 1
    ch = pushdown_or_ship(
        n_q, 1.0, level, level.tier.tau_pages, batch_pages=r_r2,
        op="reduce", out_pages=out_q,
    )
    return _scale_choice(ch, n_spilled)


def _pdkw_bnlj(stats: WorkloadStats, ch: PushdownChoice) -> Dict[str, Any]:
    return {"inner_filter": stats.pushdown_sel, "pushdown": ch.push}


def _pdkw_eagg(stats: WorkloadStats, ch: PushdownChoice) -> Dict[str, Any]:
    return {"pushdown": ch.push}


# Estimated output pages at plan time: what the operator's result stream is
# expected to occupy, per its WorkloadStats — the planning-time mirror of the
# ``measured_stats`` feedback hooks above.


def _out_pages_from_out(stats: WorkloadStats) -> float:
    return stats.out


def _out_pages_ems(stats: WorkloadStats) -> float:
    # A sort permutes its input: the final run is the input's size.
    return stats.size_r


def _ensure_builtin() -> None:
    """Register the built-in operators on first lookup.

    Deferred (rather than at import) because the operator modules themselves
    import the engine's buffers/scheduler — eager registration would re-enter
    a partially-imported module.
    """
    global _builtin_registered
    if _builtin_registered:
        return

    # The flag is only set once registration succeeds, so a failed deferred
    # import resurfaces as the real ImportError on the next lookup instead of
    # a misleading "unknown operator" KeyError.
    # importlib lookups: the ``repro.remote`` package re-exports the runner
    # *functions* under the same names as the submodules, so plain
    # ``import repro.remote.bnlj as m`` would bind the function instead.
    import importlib

    bnlj_mod = importlib.import_module("repro.remote.bnlj")
    eagg_mod = importlib.import_module("repro.remote.eagg")
    ehj_mod = importlib.import_module("repro.remote.ehj")
    ems_mod = importlib.import_module("repro.remote.ems")

    register(OperatorSpec(
        name="bnlj", plan_type=BNLJPlan,
        policies=("remop", "conventional"),
        planner=_plan_bnlj, run=bnlj_mod.bnlj, oracle=bnlj_mod.bnlj_oracle,
        model=_model_bnlj, footprint=_fp_bnlj, costs=_costs_bnlj,
        inputs=bnlj_mod.INPUTS, input_stats=bnlj_mod.INPUT_STATS,
        measured_stats=bnlj_mod.bnlj_measured, output_of=bnlj_mod.bnlj_output,
        output_pages=_out_pages_from_out,
        streams=bnlj_mod.STREAMS, stream_footprints=_sfp_bnlj,
        pushdown=_pushdown_bnlj, pushdown_kwargs=_pdkw_bnlj,
    ))
    register(OperatorSpec(
        name="ems", plan_type=EMSPlan,
        policies=("remop", "conventional", "duckdb"),
        planner=_plan_ems, run=ems_mod.ems_sort, oracle=ems_mod.ems_oracle,
        model=_model_ems, footprint=_fp_ems, costs=_costs_ems,
        inputs=ems_mod.INPUTS, input_stats=ems_mod.INPUT_STATS,
        measured_stats=ems_mod.ems_measured, output_of=ems_mod.ems_output,
        output_pages=_out_pages_ems,
        streams=ems_mod.STREAMS, stream_footprints=_sfp_ems,
    ))
    register(OperatorSpec(
        name="ehj", plan_type=EHJPlan,
        policies=("remop", "conventional"),
        planner=_plan_ehj, run=ehj_mod.ehj, oracle=ehj_mod.ehj_oracle,
        model=_model_ehj, footprint=_fp_ehj, costs=_costs_ehj,
        inputs=ehj_mod.INPUTS, input_stats=ehj_mod.INPUT_STATS,
        measured_stats=ehj_mod.ehj_measured, output_of=ehj_mod.ehj_output,
        output_pages=_out_pages_from_out,
        streams=ehj_mod.STREAMS, stream_footprints=_sfp_ehj,
    ))
    register(OperatorSpec(
        name="eagg", plan_type=EAggPlan,
        policies=("remop", "conventional"),
        planner=_plan_eagg, run=eagg_mod.eagg, oracle=eagg_mod.eagg_oracle,
        model=_model_eagg, footprint=_fp_eagg, costs=_costs_eagg,
        inputs=eagg_mod.INPUTS, input_stats=eagg_mod.INPUT_STATS,
        measured_stats=eagg_mod.eagg_measured, output_of=eagg_mod.eagg_output,
        output_pages=_out_pages_from_out,
        streams=eagg_mod.STREAMS, stream_footprints=_sfp_eagg,
        pushdown=_pushdown_eagg, pushdown_kwargs=_pdkw_eagg,
    ))
    _builtin_registered = True
