"""Pipeline planning and execution: many operators, one budget, one memory stack.

``plan_pipeline`` is the query-level entry point.  On a single tier it wraps
each registered operator's latency model (``OperatorSpec.model``) as an
:class:`repro.core.arbiter.ArbiterItem`, lets the arbiter split the global
page budget M, and then plans every operator at its awarded budget through
the normal ``plan_operator`` path — so a single-operator pipeline degenerates
to exactly the standalone plan.  On a **memory hierarchy** (a
:class:`repro.core.cost_model.HierarchySpec`, a live
:class:`repro.remote.simulator.MemoryHierarchy`, or a level list such as
``[("dram", 64), ("rdma", 256), "ssd"]``) it instead builds
:class:`repro.core.arbiter.HierarchyItem`\\ s — each operator's modeled cost
as a function of (pages, tier) plus its spill footprint — and the
hierarchy-wide arbiter jointly assigns every operator a budget *and* a tier
placement under the per-tier capacities, never worse than the best
single-tier placement.

``run_pipeline`` executes a planned pipeline against *one shared* remote
target: all operators account on the same ledger stack, and per-operator D/C
come back as snapshot deltas (engine contract rule 4), so pipeline totals are
measured, not summed estimates.  On a hierarchy each operator's spill writes
are routed to its planned placement tier.

.. deprecated::
    ``plan_pipeline`` and ``run_pipeline`` are thin shims over the
    session-centric API (:class:`repro.engine.session.Session`): build typed
    tasks with ``session.task(op, stats, inputs=...)`` and use
    ``session.plan`` / ``session.run`` / ``session.explain`` instead.  The
    shims stay ledger-exact with ``Session.run`` (tests/test_session.py).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.arbiter import ArbiterItem, HierarchyItem, arbitrate, arbitrate_hierarchy
from repro.core.cost_model import HierarchySpec, TierLevel, TierSpec
from repro.core.policies import PushdownChoice
from repro.engine.registry import (
    OperatorPlan,
    WorkloadStats,
    get,
    plan_operator,
    resolve_hierarchy,
    resolve_tier,
)


@dataclasses.dataclass(frozen=True)
class OperatorBudget:
    """One pipeline member's share: awarded pages, plan, and modeled cost.

    ``placement`` names the hierarchy tier the operator's spill is routed to
    (``None`` on a single-tier pipeline, where the pipeline tier applies).
    ``pushdown`` is the arbiter's ship-pages vs. ship-compute verdict for
    the operator's pushable stream at its awarded (pages, tier) — ``None``
    when the operator has nothing to push.  ``modeled_latency`` includes the
    verdict's ``l_delta`` so plan totals match the arbitration objective.
    """

    op: str
    stats: WorkloadStats
    m_pages: float
    plan: OperatorPlan
    modeled_latency: float
    placement: Optional[str] = None
    pushdown: Optional[PushdownChoice] = None


def pushdown_choice(
    spec, stats: WorkloadStats, level: TierLevel, m: float, policy: str
) -> Optional[PushdownChoice]:
    """The operator's priced ship-vs-push verdict at one (pages, tier) point.

    ``None`` when the operator declares no pushdown hook or has nothing to
    push.  On a plain (single) tier, wrap the tier in a capability-free
    ``TierLevel(tier=...)`` — the verdict is then always ship, but the
    data-plane kwargs (e.g. BNLJ's ``inner_filter``) still apply, so a
    filter annotation stays *semantically* physical everywhere.
    """
    if spec.pushdown is None:
        return None
    return spec.pushdown(stats, level, m, policy)


def _modeled_latency(
    spec, stats: WorkloadStats, level: TierLevel, m: float, policy: str
) -> float:
    """Modeled L = D + tau*C plus the pushdown verdict's l_delta (<= 0)."""
    base = spec.model(stats, level.tier.tau_pages, m, policy)
    ch = pushdown_choice(spec, stats, level, m, policy)
    return base + (ch.l_delta if ch is not None else 0.0)


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """An arbitrated pipeline: per-operator budgets summing to ``m_total``.

    ``hierarchy`` is set when the pipeline was planned against a memory
    hierarchy; ``tier`` then holds the hierarchy's top tier for the legacy
    single-tier accessors.
    """

    tier: TierSpec
    m_total: float
    policy: str
    ops: Tuple[OperatorBudget, ...]
    hierarchy: Optional[HierarchySpec] = None

    @property
    def budgets(self) -> Tuple[float, ...]:
        return tuple(ob.m_pages for ob in self.ops)

    @property
    def placements(self) -> Tuple[Optional[str], ...]:
        return tuple(ob.placement for ob in self.ops)

    @property
    def total_modeled_latency(self) -> float:
        return sum(ob.modeled_latency for ob in self.ops)


def _broadcast_stats(
    ops: Sequence[str], stats: Union[WorkloadStats, Sequence[WorkloadStats]]
) -> List[WorkloadStats]:
    if isinstance(stats, WorkloadStats):
        return [stats] * len(ops)
    stats = list(stats)
    if len(stats) != len(ops):
        raise ValueError(
            f"got {len(stats)} WorkloadStats for {len(ops)} operators"
        )
    return stats


def _is_hierarchy(tier: Any) -> bool:
    return (
        isinstance(tier, HierarchySpec)
        or getattr(tier, "is_hierarchy", False)
        or isinstance(tier, (list, tuple))
    )


def _warn_deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use the session API instead "
        f"(repro.engine.Session: {new})",
        DeprecationWarning,
        stacklevel=3,
    )


def plan_pipeline(
    ops: Sequence[str],
    stats: Union[WorkloadStats, Sequence[WorkloadStats]],
    tier: Any,
    m_pages: float,
    policy: str = "remop",
    step: float = 1.0,
) -> PipelinePlan:
    """Deprecated shim over ``Session.plan``: split ``m_pages`` across ``ops``.

    ``stats`` is one :class:`WorkloadStats` per operator (or a single one
    broadcast to all).  ``tier`` is a single tier (TierSpec or name) or a
    memory hierarchy (spec, live ``MemoryHierarchy``, or level list); on a
    hierarchy the arbiter jointly assigns budgets and tier placements.
    Budgets sum to exactly ``m_pages`` and each respects the operator's
    ``min_pages``; infeasible budgets raise ``ValueError``.
    """
    _warn_deprecated("plan_pipeline", "session.plan(tasks)")
    return _plan_pipeline(ops, stats, tier, m_pages, policy, step)


def _plan_pipeline(
    ops: Sequence[str],
    stats: Union[WorkloadStats, Sequence[WorkloadStats]],
    tier: Any,
    m_pages: float,
    policy: str = "remop",
    step: float = 1.0,
    eviction: bool = False,
    pinned: Optional[Sequence[Optional[int]]] = None,
) -> PipelinePlan:
    """The shared planning core behind ``Session.plan`` and the legacy shim.

    ``eviction=True`` plans for a hierarchy with a background evictor:
    tier capacities are soft and placement costs blend per-tier taus by
    where each footprint comes to rest (see
    :func:`repro.core.arbiter.arbitrate_hierarchy`).  ``pinned`` (hierarchy
    targets only; one tier index or ``None`` per operator) fixes operators
    with an explicit ``placement=`` on their pinned tier while the arbiter
    still grants them budget.
    """
    if not list(ops):
        raise ValueError(
            "empty pipeline: plan_pipeline needs at least one operator "
            "(got ops=[])"
        )
    if _is_hierarchy(tier):
        return _plan_pipeline_hierarchy(
            ops, stats, resolve_hierarchy(tier), m_pages, policy, step,
            eviction=eviction, pinned=pinned,
        )
    tier_spec = resolve_tier(tier)
    tau = tier_spec.tau_pages
    # Capability-free level: the ship-vs-push verdict on a single tier is
    # always ship, but it still carries the filter annotation to the data
    # plane (OperatorSpec.pushdown_kwargs).
    level = TierLevel(tier=tier_spec)
    all_stats = _broadcast_stats(ops, stats)
    items = []
    for op, st in zip(ops, all_stats):
        spec = get(op)  # raises ValueError for unknown operators
        if spec.model is None:
            raise ValueError(f"operator {op!r} has no latency model")
        items.append(ArbiterItem(
            name=op,
            min_pages=spec.min_pages,
            latency_of=lambda m, spec=spec, st=st: spec.model(st, tau, m, policy),
        ))
    alloc, _ = arbitrate(items, float(m_pages), step=step)
    budgets = tuple(
        OperatorBudget(
            op=op,
            stats=st,
            m_pages=m,
            plan=plan_operator(op, st, tier_spec, m, policy=policy),
            modeled_latency=get(op).model(st, tau, m, policy),
            pushdown=pushdown_choice(get(op), st, level, m, policy),
        )
        for op, st, m in zip(ops, all_stats, alloc)
    )
    return PipelinePlan(tier=tier_spec, m_total=float(m_pages), policy=policy,
                        ops=budgets)


def _plan_pipeline_hierarchy(
    ops: Sequence[str],
    stats: Union[WorkloadStats, Sequence[WorkloadStats]],
    hspec: HierarchySpec,
    m_pages: float,
    policy: str,
    step: float,
    eviction: bool = False,
    pinned: Optional[Sequence[Optional[int]]] = None,
) -> PipelinePlan:
    """Joint (pages, tier) assignment over a hierarchy's taus and capacities."""
    taus = hspec.taus
    all_stats = _broadcast_stats(ops, stats)
    items = []
    for op, st in zip(ops, all_stats):
        spec = get(op)  # raises ValueError for unknown operators
        if spec.model is None:
            raise ValueError(f"operator {op!r} has no latency model")
        footprint = spec.footprint or (lambda st_, tau_, m_: 0.0)
        items.append(HierarchyItem(
            name=op,
            min_pages=spec.min_pages,
            # Pushdown-aware placement cost: a compute-capable tier's
            # l_delta (<= 0) can beat a faster dumb tier.
            latency_of=lambda m, t, spec=spec, st=st: _modeled_latency(
                spec, st, hspec.levels[t], m, policy
            ),
            footprint_of=lambda m, t, fp=footprint, st=st: fp(st, taus[t], m),
        ))
    alloc, placement, _ = arbitrate_hierarchy(
        items, float(m_pages), hspec.capacities, step=step, eviction=eviction,
        pinned_tiers=pinned,
    )
    budgets = tuple(
        OperatorBudget(
            op=op,
            stats=st,
            m_pages=m,
            plan=plan_operator(op, st, hspec.levels[t].tier, m, policy=policy),
            modeled_latency=_modeled_latency(
                get(op), st, hspec.levels[t], m, policy
            ),
            placement=hspec.names[t],
            pushdown=pushdown_choice(get(op), st, hspec.levels[t], m, policy),
        )
        for op, st, m, t in zip(ops, all_stats, alloc, placement)
    )
    return PipelinePlan(tier=hspec.levels[0].tier, m_total=float(m_pages),
                        policy=policy, ops=budgets, hierarchy=hspec)


@dataclasses.dataclass
class PipelineRunResult:
    """Measured per-operator and total D/C of one shared-target execution.

    ``total`` (and each per-op delta) is a ``LedgerSnapshot`` for a
    single-tier run and a ``HierarchySnapshot`` — per-tier ledgers summing to
    the hierarchy-wide D/C — for a hierarchy run.
    """

    per_op: List[Tuple[str, Any, Any]]  # (op, run result, snapshot delta)
    total: Any

    def latency_seconds(self, tier) -> float:
        """Eq.-(1) wall latency of the run.

        ``tier`` is the run's ``TierSpec`` for a single-tier execution, or
        the ``HierarchySpec`` (e.g. ``pplan.hierarchy``) for a hierarchy
        execution — pricing a multi-tier run's aggregate rounds with one
        tier's constants would be silently wrong, so that combination raises.
        """
        is_hier_run = hasattr(self.total, "tiers")
        if isinstance(tier, HierarchySpec):
            if not is_hier_run:
                raise TypeError(
                    "single-tier run: pass the run's TierSpec, not a "
                    "HierarchySpec (the plan's placements were not routed)"
                )
            return self.total.latency_seconds(tier)
        if is_hier_run:
            raise TypeError(
                "hierarchy run: pass the HierarchySpec (e.g. pplan.hierarchy)"
                " so each tier's rounds are priced with its own (BW, RTT)"
            )
        return tier.latency_seconds(self.total.d_total, self.total.c_total)

    def latency_cost(self, tau) -> float:
        """L of the whole run; ``tau`` is a scalar or a ``HierarchySpec``."""
        return self.total.latency_cost(tau)


def run_pipeline(
    remote,
    pplan: PipelinePlan,
    workloads: Sequence[Tuple[Sequence[Any], Optional[Dict[str, Any]]]],
) -> PipelineRunResult:
    """Deprecated shim over ``Session.run``: execute ``pplan`` on ``remote``.

    ``workloads[i]`` is the legacy positional ``(args, kwargs)`` tuple for
    operator ``i``'s data plane — the args are bound to the operator's typed
    input signature in declaration order and handed to a one-shot
    :class:`repro.engine.session.Session`, so the shim is ledger-exact with
    ``session.run(tasks)``.  All operators share ``remote``'s ledger stack;
    per-operator D/C are snapshot deltas.  When ``remote`` is a
    :class:`MemoryHierarchy` and the plan carries placements, each operator's
    spill writes target its planned tier.
    """
    _warn_deprecated("run_pipeline", "session.run(tasks)")
    from repro.engine.session import Session

    if len(workloads) != len(pplan.ops):
        raise ValueError(
            f"got {len(workloads)} workloads for {len(pplan.ops)} operators"
        )
    session = Session(remote, budget=pplan.m_total, policy=pplan.policy)
    tasks = []
    for ob, (args, kwargs) in zip(pplan.ops, workloads):
        spec = get(ob.op)
        if len(args) != len(spec.inputs):
            raise ValueError(
                f"operator {ob.op!r} takes {len(spec.inputs)} data-plane "
                f"inputs {list(spec.inputs)}; got {len(args)} positional "
                f"values"
            )
        tasks.append(session.task(
            ob.op, ob.stats, inputs=dict(zip(spec.inputs, args)),
            **(kwargs or {}),
        ))
    res = session.run(tasks, plan=pplan)
    return PipelineRunResult(per_op=res.per_op, total=res.total)
