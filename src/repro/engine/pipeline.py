"""Pipeline planning and execution: many operators, one budget, one memory stack.

``plan_pipeline`` is the query-level entry point.  On a single tier it wraps
each registered operator's latency model (``OperatorSpec.model``) as an
:class:`repro.core.arbiter.ArbiterItem`, lets the arbiter split the global
page budget M, and then plans every operator at its awarded budget through
the normal ``plan_operator`` path — so a single-operator pipeline degenerates
to exactly the standalone plan.  On a **memory hierarchy** (a
:class:`repro.core.cost_model.HierarchySpec`, a live
:class:`repro.remote.simulator.MemoryHierarchy`, or a level list such as
``[("dram", 64), ("rdma", 256), "ssd"]``) it instead builds
:class:`repro.core.arbiter.HierarchyItem`\\ s — each operator's modeled cost
as a function of (pages, tier) plus its spill footprint — and the
hierarchy-wide arbiter jointly assigns every operator a budget *and* a tier
placement under the per-tier capacities, never worse than the best
single-tier placement.

``run_pipeline`` executes a planned pipeline against *one shared* remote
target: all operators account on the same ledger stack, and per-operator D/C
come back as snapshot deltas (engine contract rule 4), so pipeline totals are
measured, not summed estimates.  On a hierarchy each operator's spill writes
are routed to its planned placement tier.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.arbiter import ArbiterItem, HierarchyItem, arbitrate, arbitrate_hierarchy
from repro.core.cost_model import HierarchySpec, TierSpec
from repro.engine.registry import (
    OperatorPlan,
    WorkloadStats,
    get,
    plan_operator,
    resolve_hierarchy,
    resolve_tier,
)
from repro.engine.scheduler import TransferScheduler


@dataclasses.dataclass(frozen=True)
class OperatorBudget:
    """One pipeline member's share: awarded pages, plan, and modeled cost.

    ``placement`` names the hierarchy tier the operator's spill is routed to
    (``None`` on a single-tier pipeline, where the pipeline tier applies).
    """

    op: str
    stats: WorkloadStats
    m_pages: float
    plan: OperatorPlan
    modeled_latency: float
    placement: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """An arbitrated pipeline: per-operator budgets summing to ``m_total``.

    ``hierarchy`` is set when the pipeline was planned against a memory
    hierarchy; ``tier`` then holds the hierarchy's top tier for the legacy
    single-tier accessors.
    """

    tier: TierSpec
    m_total: float
    policy: str
    ops: Tuple[OperatorBudget, ...]
    hierarchy: Optional[HierarchySpec] = None

    @property
    def budgets(self) -> Tuple[float, ...]:
        return tuple(ob.m_pages for ob in self.ops)

    @property
    def placements(self) -> Tuple[Optional[str], ...]:
        return tuple(ob.placement for ob in self.ops)

    @property
    def total_modeled_latency(self) -> float:
        return sum(ob.modeled_latency for ob in self.ops)


def _broadcast_stats(
    ops: Sequence[str], stats: Union[WorkloadStats, Sequence[WorkloadStats]]
) -> List[WorkloadStats]:
    if isinstance(stats, WorkloadStats):
        return [stats] * len(ops)
    stats = list(stats)
    if len(stats) != len(ops):
        raise ValueError(
            f"got {len(stats)} WorkloadStats for {len(ops)} operators"
        )
    return stats


def _is_hierarchy(tier: Any) -> bool:
    return (
        isinstance(tier, HierarchySpec)
        or getattr(tier, "is_hierarchy", False)
        or isinstance(tier, (list, tuple))
    )


def plan_pipeline(
    ops: Sequence[str],
    stats: Union[WorkloadStats, Sequence[WorkloadStats]],
    tier: Any,
    m_pages: float,
    policy: str = "remop",
    step: float = 1.0,
) -> PipelinePlan:
    """Split ``m_pages`` across ``ops`` minimizing total modeled latency.

    ``stats`` is one :class:`WorkloadStats` per operator (or a single one
    broadcast to all).  ``tier`` is a single tier (TierSpec or name) or a
    memory hierarchy (spec, live ``MemoryHierarchy``, or level list); on a
    hierarchy the arbiter jointly assigns budgets and tier placements.
    Budgets sum to exactly ``m_pages`` and each respects the operator's
    ``min_pages``; infeasible budgets raise ``ValueError``.
    """
    if _is_hierarchy(tier):
        return _plan_pipeline_hierarchy(
            ops, stats, resolve_hierarchy(tier), m_pages, policy, step
        )
    tier_spec = resolve_tier(tier)
    tau = tier_spec.tau_pages
    all_stats = _broadcast_stats(ops, stats)
    items = []
    for op, st in zip(ops, all_stats):
        spec = get(op)  # raises ValueError for unknown operators
        if spec.model is None:
            raise ValueError(f"operator {op!r} has no latency model")
        items.append(ArbiterItem(
            name=op,
            min_pages=spec.min_pages,
            latency_of=lambda m, spec=spec, st=st: spec.model(st, tau, m, policy),
        ))
    alloc, _ = arbitrate(items, float(m_pages), step=step)
    budgets = tuple(
        OperatorBudget(
            op=op,
            stats=st,
            m_pages=m,
            plan=plan_operator(op, st, tier_spec, m, policy=policy),
            modeled_latency=get(op).model(st, tau, m, policy),
        )
        for op, st, m in zip(ops, all_stats, alloc)
    )
    return PipelinePlan(tier=tier_spec, m_total=float(m_pages), policy=policy,
                        ops=budgets)


def _plan_pipeline_hierarchy(
    ops: Sequence[str],
    stats: Union[WorkloadStats, Sequence[WorkloadStats]],
    hspec: HierarchySpec,
    m_pages: float,
    policy: str,
    step: float,
) -> PipelinePlan:
    """Joint (pages, tier) assignment over a hierarchy's taus and capacities."""
    taus = hspec.taus
    all_stats = _broadcast_stats(ops, stats)
    items = []
    for op, st in zip(ops, all_stats):
        spec = get(op)  # raises ValueError for unknown operators
        if spec.model is None:
            raise ValueError(f"operator {op!r} has no latency model")
        footprint = spec.footprint or (lambda st_, tau_, m_: 0.0)
        items.append(HierarchyItem(
            name=op,
            min_pages=spec.min_pages,
            latency_of=lambda m, t, spec=spec, st=st: spec.model(
                st, taus[t], m, policy
            ),
            footprint_of=lambda m, t, fp=footprint, st=st: fp(st, taus[t], m),
        ))
    alloc, placement, _ = arbitrate_hierarchy(
        items, float(m_pages), hspec.capacities, step=step
    )
    budgets = tuple(
        OperatorBudget(
            op=op,
            stats=st,
            m_pages=m,
            plan=plan_operator(op, st, hspec.levels[t].tier, m, policy=policy),
            modeled_latency=get(op).model(st, taus[t], m, policy),
            placement=hspec.names[t],
        )
        for op, st, m, t in zip(ops, all_stats, alloc, placement)
    )
    return PipelinePlan(tier=hspec.levels[0].tier, m_total=float(m_pages),
                        policy=policy, ops=budgets, hierarchy=hspec)


@dataclasses.dataclass
class PipelineRunResult:
    """Measured per-operator and total D/C of one shared-target execution.

    ``total`` (and each per-op delta) is a ``LedgerSnapshot`` for a
    single-tier run and a ``HierarchySnapshot`` — per-tier ledgers summing to
    the hierarchy-wide D/C — for a hierarchy run.
    """

    per_op: List[Tuple[str, Any, Any]]  # (op, run result, snapshot delta)
    total: Any

    def latency_seconds(self, tier) -> float:
        """Eq.-(1) wall latency of the run.

        ``tier`` is the run's ``TierSpec`` for a single-tier execution, or
        the ``HierarchySpec`` (e.g. ``pplan.hierarchy``) for a hierarchy
        execution — pricing a multi-tier run's aggregate rounds with one
        tier's constants would be silently wrong, so that combination raises.
        """
        is_hier_run = hasattr(self.total, "tiers")
        if isinstance(tier, HierarchySpec):
            if not is_hier_run:
                raise TypeError(
                    "single-tier run: pass the run's TierSpec, not a "
                    "HierarchySpec (the plan's placements were not routed)"
                )
            return self.total.latency_seconds(tier)
        if is_hier_run:
            raise TypeError(
                "hierarchy run: pass the HierarchySpec (e.g. pplan.hierarchy)"
                " so each tier's rounds are priced with its own (BW, RTT)"
            )
        return tier.latency_seconds(self.total.d_total, self.total.c_total)

    def latency_cost(self, tau) -> float:
        """L of the whole run; ``tau`` is a scalar or a ``HierarchySpec``."""
        return self.total.latency_cost(tau)


def run_pipeline(
    remote,
    pplan: PipelinePlan,
    workloads: Sequence[Tuple[Sequence[Any], Optional[Dict[str, Any]]]],
) -> PipelineRunResult:
    """Run every operator of ``pplan`` in order against one remote target.

    ``workloads[i]`` is ``(args, kwargs)`` for operator ``i``'s data plane:
    ``spec.run(remote, *args, plan, **kwargs)`` — e.g. ``((outer, inner), {})``
    for BNLJ or ``((page_ids,), {"rows_per_page": 8})`` for EMS.  All
    operators share ``remote``'s ledger stack; per-operator D/C are snapshot
    deltas.  When ``remote`` is a :class:`MemoryHierarchy` and the plan
    carries placements, each operator's spill writes target its planned tier.
    """
    if len(workloads) != len(pplan.ops):
        raise ValueError(
            f"got {len(workloads)} workloads for {len(pplan.ops)} operators"
        )
    sched = TransferScheduler(remote)
    route_tiers = bool(getattr(remote, "is_hierarchy", False))
    before = sched.snapshot()
    per_op: List[Tuple[str, Any, Any]] = []
    for ob, (args, kwargs) in zip(pplan.ops, workloads):
        t0 = sched.snapshot()
        call_kwargs = dict(kwargs or {})
        if route_tiers and ob.placement is not None:
            call_kwargs.setdefault("tier", ob.placement)
        result = get(ob.op).run(remote, *args, ob.plan, **call_kwargs)
        per_op.append((ob.op, result, sched.delta(t0)))
    return PipelineRunResult(per_op=per_op, total=sched.delta(before))
