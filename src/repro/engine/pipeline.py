"""Pipeline planning and execution: many operators, one budget, one tier.

``plan_pipeline`` is the query-level entry point: it wraps each registered
operator's latency model (``OperatorSpec.model``) as an
:class:`repro.core.arbiter.ArbiterItem`, lets the arbiter split the global
page budget M, and then plans every operator at its awarded budget through
the normal ``plan_operator`` path — so a single-operator pipeline degenerates
to exactly the standalone plan.

``run_pipeline`` executes a planned pipeline against *one shared*
:class:`repro.remote.simulator.RemoteMemory`: all operators account on the
same ledger, and per-operator D/C come back as snapshot deltas (engine
contract rule 4), so pipeline totals are measured, not summed estimates.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.arbiter import ArbiterItem, arbitrate
from repro.core.cost_model import LedgerSnapshot, TierSpec
from repro.engine.registry import (
    OperatorPlan,
    WorkloadStats,
    get,
    plan_operator,
    resolve_tier,
)
from repro.engine.scheduler import TransferScheduler


@dataclasses.dataclass(frozen=True)
class OperatorBudget:
    """One pipeline member's share: awarded pages, plan, and modeled cost."""

    op: str
    stats: WorkloadStats
    m_pages: float
    plan: OperatorPlan
    modeled_latency: float


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """An arbitrated pipeline: per-operator budgets summing to ``m_total``."""

    tier: TierSpec
    m_total: float
    policy: str
    ops: Tuple[OperatorBudget, ...]

    @property
    def budgets(self) -> Tuple[float, ...]:
        return tuple(ob.m_pages for ob in self.ops)

    @property
    def total_modeled_latency(self) -> float:
        return sum(ob.modeled_latency for ob in self.ops)


def _broadcast_stats(
    ops: Sequence[str], stats: Union[WorkloadStats, Sequence[WorkloadStats]]
) -> List[WorkloadStats]:
    if isinstance(stats, WorkloadStats):
        return [stats] * len(ops)
    stats = list(stats)
    if len(stats) != len(ops):
        raise ValueError(
            f"got {len(stats)} WorkloadStats for {len(ops)} operators"
        )
    return stats


def plan_pipeline(
    ops: Sequence[str],
    stats: Union[WorkloadStats, Sequence[WorkloadStats]],
    tier: Union[TierSpec, str],
    m_pages: float,
    policy: str = "remop",
    step: float = 1.0,
) -> PipelinePlan:
    """Split ``m_pages`` across ``ops`` minimizing total modeled latency.

    ``stats`` is one :class:`WorkloadStats` per operator (or a single one
    broadcast to all).  Budgets sum to exactly ``m_pages`` and each respects
    the operator's ``min_pages``; infeasible budgets raise ``ValueError``.
    """
    tier_spec = resolve_tier(tier)
    tau = tier_spec.tau_pages
    all_stats = _broadcast_stats(ops, stats)
    items = []
    for op, st in zip(ops, all_stats):
        spec = get(op)  # raises ValueError for unknown operators
        if spec.model is None:
            raise ValueError(f"operator {op!r} has no latency model")
        items.append(ArbiterItem(
            name=op,
            min_pages=spec.min_pages,
            latency_of=lambda m, spec=spec, st=st: spec.model(st, tau, m, policy),
        ))
    alloc, _ = arbitrate(items, float(m_pages), step=step)
    budgets = tuple(
        OperatorBudget(
            op=op,
            stats=st,
            m_pages=m,
            plan=plan_operator(op, st, tier_spec, m, policy=policy),
            modeled_latency=get(op).model(st, tau, m, policy),
        )
        for op, st, m in zip(ops, all_stats, alloc)
    )
    return PipelinePlan(tier=tier_spec, m_total=float(m_pages), policy=policy,
                        ops=budgets)


@dataclasses.dataclass
class PipelineRunResult:
    """Measured per-operator and total D/C of one shared-tier execution."""

    per_op: List[Tuple[str, Any, LedgerSnapshot]]  # (op, run result, delta)
    total: LedgerSnapshot

    def latency_seconds(self, tier: TierSpec) -> float:
        return tier.latency_seconds(self.total.d_total, self.total.c_total)

    def latency_cost(self, tau: float) -> float:
        return self.total.latency_cost(tau)


def run_pipeline(
    remote,
    pplan: PipelinePlan,
    workloads: Sequence[Tuple[Sequence[Any], Optional[Dict[str, Any]]]],
) -> PipelineRunResult:
    """Run every operator of ``pplan`` in order against one RemoteMemory.

    ``workloads[i]`` is ``(args, kwargs)`` for operator ``i``'s data plane:
    ``spec.run(remote, *args, plan, **kwargs)`` — e.g. ``((outer, inner), {})``
    for BNLJ or ``((page_ids,), {"rows_per_page": 8})`` for EMS.  All
    operators share ``remote``'s ledger; per-operator D/C are snapshot deltas.
    """
    if len(workloads) != len(pplan.ops):
        raise ValueError(
            f"got {len(workloads)} workloads for {len(pplan.ops)} operators"
        )
    sched = TransferScheduler(remote)
    before = sched.snapshot()
    per_op: List[Tuple[str, Any, LedgerSnapshot]] = []
    for ob, (args, kwargs) in zip(pplan.ops, workloads):
        t0 = sched.snapshot()
        result = get(ob.op).run(remote, *args, ob.plan, **(kwargs or {}))
        per_op.append((ob.op, result, sched.delta(t0)))
    return PipelineRunResult(per_op=per_op, total=sched.delta(before))
