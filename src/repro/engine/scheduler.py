"""Transfer scheduler: the tier router owning all round accounting.

Every batched read/write an operator issues flows through one
:class:`TransferScheduler`, which

  * routes it to its target — a single
    :class:`repro.remote.simulator.RemoteMemory` tier or a whole
    :class:`repro.remote.simulator.MemoryHierarchy` — as exactly one transfer
    round per tier touched (Definition 2).  On a hierarchy, writes name a
    tier (falling back to the scheduler's default placement) and reads
    resolve each page's tier from the hierarchy's placement map,
  * records §IV-E prefetch hiding in one place: a round issued with
    ``prefetch=True`` models the double buffer fetching one batch ahead, so
    its RTT is hidden (``ledger.c_prefetch_hidden``).  Stream consumers
    (:class:`repro.engine.buffers.PageCursor`) enforce the rule that a
    stream's *first* round is never marked,
  * exposes ledger ``snapshot()`` / ``delta()`` so callers report per-region
    D/C counts without copying the mutable ledger — a
    :class:`repro.core.cost_model.LedgerSnapshot` for a single tier, a
    :class:`repro.core.cost_model.HierarchySnapshot` (per-tier ledgers that
    sum to the hierarchy-wide D/C) for a hierarchy, and
  * can *coalesce* adjacent read batches into fewer rounds
    (:meth:`read_coalesced`) when a caller trades buffer space for rounds.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.cost_model import HierarchySnapshot, LedgerSnapshot, TransferLedger

Snapshot = Union[LedgerSnapshot, HierarchySnapshot]

TierSpec = Union[int, str, None]


def stream_tiers(
    tier: Union[TierSpec, Dict[str, TierSpec], Sequence[TierSpec]],
    streams: Sequence[str],
) -> Dict[str, TierSpec]:
    """Normalize an operator ``tier=`` spec into a ``{stream: tier}`` map.

    Operators declare their spill streams (``OperatorSpec.streams``) and
    accept ``tier=`` as either

      * a scalar (index / name / ``None``) — every stream on that tier, the
        pre-fractional behaviour,
      * a dict keyed by stream name — missing streams fall back to ``None``
        (the scheduler's default placement); unknown keys raise, or
      * a sequence aligned with ``streams`` — one entry per stream.

    The result always has exactly one entry per declared stream.
    """
    if isinstance(tier, dict):
        unknown = sorted(set(tier) - set(streams))
        if unknown:
            raise ValueError(
                f"unknown stream(s) {unknown} in tier spec; "
                f"operator streams are {list(streams)}"
            )
        return {s: tier.get(s) for s in streams}
    if isinstance(tier, (list, tuple)):
        if len(tier) != len(streams):
            raise ValueError(
                f"tier list has {len(tier)} entries for {len(streams)} "
                f"stream(s) {list(streams)}"
            )
        return dict(zip(streams, tier))
    return {s: tier for s in streams}


class TransferScheduler:
    """Schedules batched transfer rounds against one remote target.

    ``target`` is a single ``RemoteMemory`` tier or a ``MemoryHierarchy``;
    ``tier`` names the default placement for writes on a hierarchy (index or
    tier name; ignored for a single-tier target).  A single-tier hierarchy
    behaves exactly like the bare tier: same rounds, same ledgers.
    """

    def __init__(self, target, tier: Union[int, str, None] = None):
        self.remote = target
        self.is_hierarchy: bool = bool(getattr(target, "is_hierarchy", False))
        self.default_tier: Union[int, str, None] = tier
        self._checkpoints: Dict[str, Snapshot] = {}
        if self.is_hierarchy:
            # Resolve early so a bad placement fails at construction.
            self.default_tier = target.tier_index(tier)

    # -- ledger accounting ---------------------------------------------------

    @property
    def ledger(self) -> TransferLedger:
        """The single tier's ledger (default-placement tier on a hierarchy)."""
        if self.is_hierarchy:
            return self.remote.tiers[self.default_tier].ledger
        return self.remote.ledger

    def snapshot(self) -> Snapshot:
        if self.is_hierarchy:
            return self.remote.snapshot()
        return self.remote.ledger.snapshot()

    def delta(self, since: Snapshot) -> Snapshot:
        if self.is_hierarchy:
            return self.remote.delta(since)
        return self.remote.ledger.delta(since)

    # -- named checkpoints ---------------------------------------------------
    #
    # Per-task bookkeeping for the session executor: a checkpoint freezes the
    # ledger state under a label so the per-task delta (and a mid-pipeline
    # re-planner's "what has this task cost so far") can be read back without
    # the caller threading snapshot objects through its control flow.

    def checkpoint(self, label: str) -> Snapshot:
        """Freeze the current ledger state under ``label`` (overwriting)."""
        snap = self.snapshot()
        self._checkpoints[label] = snap
        return snap

    def restore(self, label: str) -> Snapshot:
        """Return the snapshot frozen under ``label``."""
        try:
            return self._checkpoints[label]
        except KeyError:
            raise ValueError(
                f"no checkpoint {label!r}; have {sorted(self._checkpoints)}"
            ) from None

    def since(self, label: str) -> Snapshot:
        """Ledger delta accumulated since ``checkpoint(label)``."""
        return self.delta(self.restore(label))

    def drop_checkpoint(self, label: str) -> None:
        """Forget ``label`` (missing labels are ignored)."""
        self._checkpoints.pop(label, None)

    # -- execution-backend surface -------------------------------------------
    #
    # A target may be an execution backend (repro.remote.backend): pages then
    # mirror as device arrays, transfers are timed host<->device copies, and
    # operator compute can run Pallas kernels.  The scheduler routes those
    # capabilities exactly like it routes transfer rounds — operators ask the
    # scheduler, never the store — and degrades to the deterministic numpy
    # reference on simulator targets.  Nothing here reads a clock: the
    # scheduler stays on the LAY303-deterministic side of the boundary.

    @property
    def wall(self):
        """The target's measured wall clock, or ``None`` on a simulator."""
        return getattr(self.remote, "wall", None)

    def sort_keys(self, keys: np.ndarray) -> np.ndarray:
        """Sort a 1-D key block: the backend's kernel hook, else numpy.

        Both paths return byte-identical sorted keys (bare keys carry no
        payload); only wall-clock accounting differs.
        """
        fn = getattr(self.remote, "sort_keys", None)
        if fn is not None:
            return fn(keys)
        return np.sort(keys, kind="stable")

    def partitions(self, rows: np.ndarray, parts: np.ndarray):
        """Group a row block by partition id, ascending, stable within groups.

        Returns ``[(q, rows_of_q), ...]`` — on a backend via the dispatch
        kernels, else the numpy reference; outputs are byte-identical.
        """
        fn = getattr(self.remote, "partition_rows", None)
        if fn is not None:
            return fn(rows, parts)
        return [(int(q), rows[parts == q]) for q in np.unique(parts)]

    # -- transfer rounds -----------------------------------------------------

    def read(
        self,
        page_ids: Sequence[int],
        *,
        prefetch: bool = False,
    ) -> List[np.ndarray]:
        """One swap-in round (per tier touched, on a hierarchy).

        ``prefetch=True`` marks the round as overlapped by the double buffer
        (its RTT is hidden).  A stream's first round can never be hidden —
        there is nothing to overlap it with — so stream consumers pass
        ``prefetch`` only from the second round on (see ``PageCursor``).
        """
        if not len(page_ids):
            return []
        return self.remote.read_batch(page_ids, prefetched=prefetch)

    def read_coalesced(
        self,
        id_batches: Sequence[Sequence[int]],
        *,
        max_pages: Optional[int] = None,
        prefetch: bool = False,
    ) -> List[np.ndarray]:
        """Merge adjacent read batches into as few rounds as possible.

        Consecutive batches are fused into rounds of at most ``max_pages``
        pages (unbounded when ``None``) — batches larger than the bound are
        split, so a caller can size its local buffer to ``max_pages`` —
        trading local buffer space for rounds, the engine-level version of
        REMON's batched fetch.  Returns all pages in the original order.
        """
        if max_pages is not None and max_pages < 1:
            raise ValueError(
                f"read_coalesced needs max_pages >= 1 (or None for unbounded "
                f"rounds), got {max_pages}"
            )
        pages: List[np.ndarray] = []
        pending: List[int] = []
        issued = 0

        def flush(ids: List[int]) -> None:
            nonlocal issued
            pages.extend(self.read(ids, prefetch=prefetch and issued > 0))
            issued += 1

        for batch in id_batches:
            pending.extend(batch)
            if max_pages is not None:
                while len(pending) >= max_pages:
                    flush(pending[:max_pages])
                    pending = pending[max_pages:]
        if pending:
            flush(pending)
        return pages

    def read_filtered(
        self,
        page_ids: Sequence[int],
        *,
        selectivity: Optional[float] = None,
        predicate=None,
        batch_pages: Optional[int] = None,
        pushdown: bool = True,
    ) -> List[np.ndarray]:
        """Filtered stream read: push the filter to capable tiers, else ship.

        The keep decision is made *globally* — a scalar ``selectivity`` uses
        the deterministic positional rule over the whole ``page_ids`` list
        (``repro.remote.simulator.pushdown_keep``), a ``predicate(page)`` is
        evaluated per page — so the surviving pages are identical whatever
        tier each page happens to sit on.  The stream is processed in
        ``batch_pages`` chunks (default: one chunk); per chunk, each tier's
        pages cost one round:

          * a tier capable of the ``"filter"`` op (and ``pushdown=True``)
            executes the filter in place and ships only survivors — a
            ``c_pushdown`` round with ``d_pushdown_saved`` accounting;
          * any other tier ships the whole group (a plain read round) and
            the filter runs locally.

        With ``pushdown=False``, or when no tier is capable (e.g. a bare
        single tier), the rounds and volumes are byte-for-byte identical to
        reading the stream plain in the same chunks — pushdown degrades to
        the ship path, never changes results.
        """
        from repro.remote.simulator import _check_selectivity, pushdown_keep

        ids = [int(i) for i in page_ids]
        if not ids:
            return []
        if (selectivity is None) == (predicate is None):
            raise ValueError(
                "read_filtered needs exactly one of selectivity=, predicate="
            )
        batch = len(ids) if batch_pages is None else int(batch_pages)
        if batch <= 0:
            raise ValueError(f"batch_pages must be > 0, got {batch_pages}")
        keep = None
        if selectivity is not None:
            sel = _check_selectivity(selectivity)
            keep = frozenset(
                i for pos, i in enumerate(ids) if pushdown_keep(pos, sel)
            )
        kept: Dict[int, np.ndarray] = {}
        for start in range(0, len(ids), batch):
            chunk = ids[start : start + batch]
            if not self.is_hierarchy:
                for i, page in zip(chunk, self.remote.read_batch(chunk)):
                    if predicate(page) if predicate is not None else i in keep:
                        kept[i] = page
                continue
            by_tier: Dict[str, List[int]] = {}
            for i in chunk:
                by_tier.setdefault(self.remote.tier_of(i), []).append(i)
            for name in sorted(by_tier, key=self.remote.spec.index):
                group = by_tier[name]
                if pushdown and self.remote.spec.level(name).can_push("filter"):
                    if predicate is not None:
                        kids, kpages = self.remote.scan_filtered(
                            name, group, predicate=predicate
                        )
                    else:
                        kids, kpages = self.remote.scan_filtered(
                            name, group, keep_ids=keep
                        )
                    kept.update(zip(kids, kpages))
                else:
                    for i, page in zip(group, self.remote.read_batch(group)):
                        if predicate(page) if predicate is not None \
                                else i in keep:
                            kept[i] = page
        return [kept[i] for i in ids if i in kept]

    def stream_flushed(self, page_ids: Sequence[int]) -> None:
        """Hint: a spill stream owning ``page_ids`` is fully flushed.

        Forwarded to the hierarchy's attached evictor (if any) so
        spill-stream-aware eviction policies (``dead``) can mark the pages
        as first-choice demotion victims.  A no-op on bare tiers and on
        hierarchies without an evictor.
        """
        evictor = getattr(self.remote, "evictor", None)
        if evictor is not None and len(page_ids):
            evictor.stream_flushed(list(page_ids))

    def scan_hint(self, key, page_ids: Sequence[int]) -> None:
        """Hint: a sequential scan ``key`` has ``page_ids`` left to read.

        Forwarded to the hierarchy's attached evictor so victim selection
        spares pages an active scan is about to read (scan resistance —
        pure LRU would demote exactly the merge-run pages whose last access
        was the flush that wrote them).  A no-op without an evictor.
        """
        evictor = getattr(self.remote, "evictor", None)
        if evictor is not None:
            evictor.scan_hint(key, page_ids)

    def scan_done(self, key) -> None:
        """Drop a scan window previously declared via :meth:`scan_hint`."""
        evictor = getattr(self.remote, "evictor", None)
        if evictor is not None:
            evictor.scan_done(key)

    def write(
        self,
        pages: Sequence[np.ndarray],
        *,
        tier: Union[int, str, None] = None,
    ) -> List[int]:
        """One flush-out round; returns the new remote page ids.

        On a hierarchy the batch targets ``tier`` (default: the scheduler's
        placement tier), waterfalling overflow to lower tiers — each tier
        receiving pages accounts one round.
        """
        if self.is_hierarchy:
            return self.remote.write_batch(
                pages, tier=self.default_tier if tier is None else tier
            )
        return self.remote.write_batch(pages)
