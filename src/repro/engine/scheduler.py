"""Transfer scheduler: the single owner of a remote tier's round accounting.

Every batched read/write an operator issues flows through one
:class:`TransferScheduler`, which

  * forwards it to the :class:`repro.remote.simulator.RemoteMemory` store as
    exactly one transfer round (Definition 2),
  * records §IV-E prefetch hiding in one place: a round issued with
    ``prefetch=True`` models the double buffer fetching one batch ahead, so
    its RTT is hidden (``ledger.c_prefetch_hidden``).  Stream consumers
    (:class:`repro.engine.buffers.PageCursor`) enforce the rule that a
    stream's *first* round is never marked,
  * exposes ledger ``snapshot()`` / ``delta()`` so callers report per-region
    D/C counts without copying the mutable ledger, and
  * can *coalesce* adjacent read batches into fewer rounds
    (:meth:`read_coalesced`) when a caller trades buffer space for rounds.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.cost_model import LedgerSnapshot, TransferLedger


class TransferScheduler:
    """Schedules batched transfer rounds against one remote tier."""

    def __init__(self, remote):
        self.remote = remote

    # -- ledger accounting ---------------------------------------------------

    @property
    def ledger(self) -> TransferLedger:
        return self.remote.ledger

    def snapshot(self) -> LedgerSnapshot:
        return self.remote.ledger.snapshot()

    def delta(self, since: LedgerSnapshot) -> LedgerSnapshot:
        return self.remote.ledger.delta(since)

    # -- transfer rounds -----------------------------------------------------

    def read(
        self,
        page_ids: Sequence[int],
        *,
        prefetch: bool = False,
    ) -> List[np.ndarray]:
        """One swap-in round.

        ``prefetch=True`` marks the round as overlapped by the double buffer
        (its RTT is hidden).  A stream's first round can never be hidden —
        there is nothing to overlap it with — so stream consumers pass
        ``prefetch`` only from the second round on (see ``PageCursor``).
        """
        if not len(page_ids):
            return []
        return self.remote.read_batch(page_ids, prefetched=prefetch)

    def read_coalesced(
        self,
        id_batches: Sequence[Sequence[int]],
        *,
        max_pages: Optional[int] = None,
        prefetch: bool = False,
    ) -> List[np.ndarray]:
        """Merge adjacent read batches into as few rounds as possible.

        Consecutive batches are fused into rounds of at most ``max_pages``
        pages (unbounded when ``None``) — batches larger than the bound are
        split, so a caller can size its local buffer to ``max_pages`` —
        trading local buffer space for rounds, the engine-level version of
        REMON's batched fetch.  Returns all pages in the original order.
        """
        pages: List[np.ndarray] = []
        pending: List[int] = []
        issued = 0

        def flush(ids: List[int]) -> None:
            nonlocal issued
            pages.extend(self.read(ids, prefetch=prefetch and issued > 0))
            issued += 1

        for batch in id_batches:
            pending.extend(batch)
            if max_pages is not None:
                while len(pending) >= max_pages:
                    flush(pending[:max_pages])
                    pending = pending[max_pages:]
        if pending:
            flush(pending)
        return pages

    def write(self, pages: Sequence[np.ndarray]) -> List[int]:
        """One flush-out round; returns the new remote page ids."""
        return self.remote.write_batch(pages)
