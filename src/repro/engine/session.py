"""Session-centric execution API: typed tasks, ``explain()``, adaptive replan.

A :class:`Session` owns everything one spilling query needs — the remote
target (a single :class:`repro.remote.simulator.RemoteMemory` tier or a whole
:class:`repro.remote.simulator.MemoryHierarchy`), the
:class:`repro.engine.scheduler.TransferScheduler` routing every transfer
round, the buffer policy, and the global page budget — and exposes the
planning loop as one object:

  * ``session.task(op, stats, inputs=...)`` builds a typed
    :class:`OperatorTask`: named data-plane inputs validated against the
    operator's declared signature (``OperatorSpec.inputs``) instead of the
    legacy positional ``(args, kwargs)`` tuples, with ``task.output`` usable
    as a downstream task's input so pipelines chain by reference.
  * ``session.plan(tasks)`` arbitrates the global budget (and, on a
    hierarchy, the tier placements) across the tasks — the same arbitration
    the legacy ``plan_pipeline`` performed.
  * ``session.explain(tasks)`` returns a structured :class:`PlanReport`:
    per-operator budget, placement, modeled D/C/L, and spill footprint
    against tier capacity — the plan, inspectable before a single page moves.
  * ``session.run(tasks)`` executes against the session's one shared ledger
    stack; ``session.run(tasks, replan="measured")`` additionally feeds each
    finished operator's *measured* output cardinality (via the operator's
    ``measured_stats`` hook) and the live hierarchy's consumed capacity back
    into the arbiter, re-planning the remaining operators' budgets and tier
    placements mid-pipeline — the capacity-aware re-planning loop the
    ROADMAP calls for (the EHJ output estimate can be ~8x off; see
    ``benchmarks/bench_session.py``).

The legacy ``plan_pipeline``/``run_pipeline`` entry points remain as thin
deprecated shims over this module with exact-ledger parity
(``tests/test_session.py``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.arbiter import (
    ArbiterItem,
    HierarchyItem,
    arbitrate,
    arbitrate_hierarchy,
)
from repro.core.cost_model import HierarchySpec, TierSpec
from repro.engine.registry import (
    WorkloadStats,
    get,
    plan_operator,
    resolve_hierarchy,
    resolve_tier,
)
from repro.engine.scheduler import TransferScheduler, stream_tiers

# --------------------------------------------------------------------------
# Typed tasks
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class OperatorTask:
    """One typed pipeline member: an operator, its stats, and named inputs.

    ``inputs`` maps the operator's declared input names (see
    ``OperatorSpec.inputs``) to data-plane values — a ``Relation``, a page-id
    list, or another task's :class:`TaskOutput` (``task.output``), resolved
    when the producing task has run.  A ``TaskOutput`` input is also a DAG
    edge: ``session.run(tasks, schedule="dag")`` executes producers before
    consumers and overlaps independent subtrees.  ``options`` carries the
    remaining run keywords (``rows_per_page``, ``prefetch``, ...).  Tasks
    compare by identity so the same task object can be referenced from
    several places.
    """

    op: str
    stats: WorkloadStats
    inputs: Mapping[str, Any]
    options: Mapping[str, Any]
    label: str
    # Per-task eviction policy override: a resolved EvictionPolicy instance
    # (session.task() resolves names once, so stateful policies keep their
    # hints across runs); None uses the session's policy.
    eviction: Any = None
    # Fractional placement: {stream: tier-name-or-None} over the operator's
    # declared spill streams (``OperatorSpec.streams``); None-valued streams
    # follow the arbiter's placement.  Built by ``session.task(placement=)``.
    placement: Optional[Mapping[str, Optional[str]]] = None

    @property
    def output(self) -> "TaskOutput":
        """A reference to this task's output pages, bindable downstream."""
        return TaskOutput(self)


@dataclasses.dataclass(frozen=True, eq=False)
class TaskOutput:
    """Marker binding a downstream input to an earlier task's output pages."""

    task: OperatorTask


# --------------------------------------------------------------------------
# explain(): the structured plan report
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TaskExplain:
    """One operator's row of the plan report."""

    op: str
    label: str
    m_pages: float
    placement: str  # tier name the spill is routed to
    tau: float
    modeled_d: float
    modeled_c: float
    modeled_latency: float  # L = D + tau*C
    footprint: float  # estimated spill pages parked on the placement tier
    capacity: float  # the placement tier's total capacity (inf = unbounded)
    min_pages: float
    # Eviction plan (None when the session has no evictor): the effective
    # policy, the estimated pages the evictor must demote off the placement
    # tier to fit the footprint, and the coarse background-round estimate
    # (one demotion batch per overflowing write round of ~M_i pages).
    eviction: Optional[str] = None
    eviction_pages: float = 0.0
    eviction_rounds: float = 0.0
    # Fractional placement: (stream, tier, estimated pages) per declared
    # stream — only populated when the task carries a per-stream placement.
    streams: Tuple[Tuple[str, str, float], ...] = ()
    # Ship-vs-push verdict for the operator's pushable stream (None when the
    # operator has nothing to push): the repro.core.policies.PushdownChoice
    # the arbiter priced at this task's (pages, tier).
    pushdown: Optional[Any] = None

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["capacity"] = None if math.isinf(self.capacity) else self.capacity
        d["streams"] = [
            {"stream": s, "tier": t, "footprint": fp} for s, t, fp in self.streams
        ]
        ch = self.pushdown
        d["pushdown"] = None if ch is None else {
            "op": ch.op, "mode": ch.mode, "l_ship": ch.l_ship,
            "l_push": None if math.isinf(ch.l_push) else ch.l_push,
            "l_delta": ch.l_delta, "d_saved": ch.d_saved,
            "c_pushdown": ch.c_pushdown, "scanned": ch.scanned,
        }
        return d


@dataclasses.dataclass(frozen=True)
class PlanReport:
    """``session.explain(tasks)``: the arbitrated plan, decomposed.

    ``tasks`` holds one :class:`TaskExplain` per operator;
    ``tier_footprints`` aggregates the estimated spill residency per tier
    against its capacity.  ``str(report)`` renders an aligned table.
    """

    policy: str
    m_total: float
    target: str  # tier name, or "dram->rdma->ssd" for a hierarchy
    tasks: Tuple[TaskExplain, ...]
    tier_footprints: Tuple[Tuple[str, float, float], ...]  # (tier, fp, cap)
    # Session eviction setup, e.g. "lru+overlap"; None when disabled.
    eviction: Optional[str] = None

    @property
    def total_modeled_latency(self) -> float:
        return sum(t.modeled_latency for t in self.tasks)

    @property
    def total_eviction_rounds(self) -> float:
        """Estimated background demotion batches across the whole plan."""
        return sum(t.eviction_rounds for t in self.tasks)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "policy": self.policy,
            "m_total": self.m_total,
            "target": self.target,
            "eviction": self.eviction,
            "total_modeled_latency": self.total_modeled_latency,
            "total_eviction_rounds": self.total_eviction_rounds,
            "tasks": [t.to_dict() for t in self.tasks],
            "tier_footprints": [
                {"tier": name, "footprint": fp,
                 "capacity": None if math.isinf(cap) else cap}
                for name, fp, cap in self.tier_footprints
            ],
        }

    def __str__(self) -> str:
        header = (f"plan: policy={self.policy} M={self.m_total:g} "
                  f"target={self.target}")
        if self.eviction is not None:
            header += f" eviction={self.eviction}"
        cols = ("op", "label", "M_i", "tier", "D", "C", "L", "footprint/cap")
        if self.eviction is not None:
            cols = cols + ("evict",)
        rows = [cols]
        for t in self.tasks:
            cap = "inf" if math.isinf(t.capacity) else f"{t.capacity:g}"
            row = (
                t.op, t.label, f"{t.m_pages:g}", t.placement,
                f"{t.modeled_d:.1f}", f"{t.modeled_c:.1f}",
                f"{t.modeled_latency:.1f}", f"{t.footprint:g}/{cap}",
            )
            if self.eviction is not None:
                row = row + (
                    f"{t.eviction_pages:g}p/{t.eviction_rounds:g}r",
                )
            rows.append(row)
        widths = [max(len(r[i]) for r in rows) for i in range(len(cols))]
        lines = [header] + [
            "  ".join(cell.ljust(w) for cell, w in zip(row, widths)).rstrip()
            for row in rows
        ]
        for t in self.tasks:
            if t.streams:
                split = " ".join(
                    f"{s}->{tn}({fp:g}p)" for s, tn, fp in t.streams
                )
                lines.append(f"  {t.label} streams: {split}")
        for t in self.tasks:
            ch = t.pushdown
            if ch is None:
                continue
            if ch.push:
                lines.append(
                    f"  {t.label} pushdown: push({ch.op})@{t.placement} "
                    f"D-saved={ch.d_saved:g} c_pushdown={ch.c_pushdown:g} "
                    f"L{ch.l_delta:+.1f}"
                )
            else:
                why = ("tier cannot execute it" if math.isinf(ch.l_push)
                       else "compute too slow to pay for the trip")
                lines.append(
                    f"  {t.label} pushdown: ship({ch.op}) — {why}"
                )
        lines.append(f"total modeled latency L = {self.total_modeled_latency:.1f}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# run(): results and replan events
# --------------------------------------------------------------------------


@dataclasses.dataclass
class TaskRun:
    """One executed task: the plan it ran under and its measured ledger."""

    task: OperatorTask
    op: str
    label: str
    m_pages: float
    placement: Optional[str]
    stats: WorkloadStats  # stats the executed plan was built from
    measured: WorkloadStats  # stats with the measured output fed back
    result: Any  # the operator's run result
    delta: Any  # LedgerSnapshot / HierarchySnapshot for this task
    replanned: bool = False  # True when a mid-run replan changed this task
    # Measured eviction effort during this task (0 without an evictor).
    eviction_pages: int = 0
    eviction_rounds: int = 0  # background demotion batches


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """One mid-pipeline re-arbitration, after ``after_label`` finished."""

    after_index: int
    after_label: str
    measured_out: float  # the finished operator's measured output pages
    budgets_before: Tuple[float, ...]  # remaining tasks, pipeline order
    budgets_after: Tuple[float, ...]
    placements_before: Tuple[Optional[str], ...]
    placements_after: Tuple[Optional[str], ...]
    modeled_before: float  # remaining tasks' modeled L under the old split
    modeled_after: float
    # Measured eviction effort up to this replan boundary (cumulative over
    # the run so far, 0 without an evictor): background demotion batches and
    # the pages they moved.
    eviction_rounds: int = 0
    eviction_pages: int = 0


@dataclasses.dataclass
class SessionRunResult:
    """Measured per-task and total D/C of one session execution."""

    per_task: List[TaskRun]
    total: Any  # LedgerSnapshot / HierarchySnapshot
    plan: Any  # the initial PipelinePlan the run started from
    replan_events: List[ReplanEvent]
    tier: TierSpec
    hierarchy: Optional[HierarchySpec]
    # True when the session ran background demotions overlapped with compute
    # (hidden migration rounds then pay no RTT in latency_seconds()).
    overlap_migration: bool = False
    # "serial" (list order) or "dag" (dependency order, ready tasks overlap).
    schedule: str = "serial"
    # DAG runs only: Eq.-(1) wall clock with ready tasks from independent
    # subtrees overlapped under per-tier processor sharing — never more than
    # the serial ``latency_seconds()``; equal for a linear chain.
    makespan_seconds: Optional[float] = None
    # Execution-backend targets only: measured wall-clock seconds of the real
    # host<->device transfers + Pallas kernel time this run spent (read off
    # the backend's WallClock — the session itself never touches a clock).
    # ``None`` on simulator targets; never regression-gated in CI.
    wall_seconds: Optional[float] = None

    @property
    def per_op(self) -> List[Tuple[str, Any, Any]]:
        """Legacy ``(op, result, delta)`` triples, pipeline order."""
        return [(tr.op, tr.result, tr.delta) for tr in self.per_task]

    def latency_seconds(self) -> float:
        """Eq.-(1) wall latency of the whole run on the session's target."""
        if self.hierarchy is not None:
            return self.total.latency_seconds(
                self.hierarchy, overlap_migration=self.overlap_migration
            )
        return self.tier.latency_seconds(self.total.d_total, self.total.c_total)

    def latency_cost(self) -> float:
        """L of the whole run against the session's tau(s)."""
        if self.hierarchy is not None:
            return self.total.latency_cost(self.hierarchy)
        return self.total.latency_cost(self.tier.tau_pages)


# --------------------------------------------------------------------------
# Simulated concurrency: chunk decomposition + processor-shared playback
# --------------------------------------------------------------------------

_EPS = 1e-12


def delta_chunks(delta, hierarchy, tier, overlap_migration=False):
    """Decompose one task's ledger delta into ``[tier_index, seconds]`` work.

    Each chunk is the Eq.-(1) seconds the task spends on one tier (hidden
    migration rounds pay no RTT when ``overlap_migration``).  The chunks are
    the currency of :func:`playback_dag` and the server's event clock: tasks
    demanding the same tier at the same simulated time share its bandwidth.
    """
    if hierarchy is None:
        secs = tier.latency_seconds(delta.d_total, delta.c_total)
        return [[0, float(secs)]] if secs > 0 else []
    chunks = []
    for ti, (name, lv) in enumerate(zip(hierarchy.names, hierarchy.levels)):
        snap = delta.tier(name)
        c = snap.c_total
        if overlap_migration:
            c -= snap.c_migration_hidden
        secs = lv.tier.latency_seconds(snap.d_total, max(c, 0))
        if secs > 0:
            chunks.append([ti, float(secs)])
    return chunks


def playback_dag(chunks, deps) -> float:
    """Makespan of per-task chunk lists under dependency-gated sharing.

    ``chunks[i]`` is task *i*'s ``[tier, seconds]`` list (``None`` treated as
    empty); ``deps[i]`` the set of task indices it waits on.  A task starts
    the instant its last dependency finishes; concurrently-running tasks
    demanding the same tier split its bandwidth evenly (processor sharing),
    so per-tier work is conserved and the makespan never exceeds the serial
    sum — a linear chain reproduces it exactly.
    """
    n = len(chunks)
    remaining = [[list(c) for c in (chunks[i] or [])] for i in range(n)]
    finished = [False] * n
    running: set = set()
    clock = 0.0

    def admit() -> None:
        moved = True
        while moved:
            moved = False
            for i in range(n):
                if (not finished[i] and i not in running
                        and all(finished[d] for d in deps[i])):
                    if remaining[i]:
                        running.add(i)
                    else:
                        finished[i] = True  # zero-work task: instant
                    moved = True

    admit()
    while running:
        demand: Dict[int, int] = {}
        for i in running:
            ti = remaining[i][0][0]
            demand[ti] = demand.get(ti, 0) + 1
        dt = min(
            remaining[i][0][1] * demand[remaining[i][0][0]] for i in running
        )
        clock += dt
        for i in list(running):
            ti = remaining[i][0][0]
            remaining[i][0][1] -= dt / demand[ti]
            while remaining[i] and remaining[i][0][1] <= _EPS:
                remaining[i].pop(0)
            if not remaining[i]:
                running.discard(i)
                finished[i] = True
        admit()
    return clock


# --------------------------------------------------------------------------
# The session
# --------------------------------------------------------------------------


class Session:
    """One spilling query's execution context: target + budget + policy.

    ``target`` is a live ``RemoteMemory``/``MemoryHierarchy`` or anything
    that resolves to one — a tier name/``TierSpec`` (a fresh simulated tier
    is created), a ``HierarchySpec``, or a level list such as
    ``[("dram", 64), ("rdma", 256), "ssd"]``.  ``budget`` is the global page
    budget M split across every task of a pipeline.

    ``eviction`` enables proactive background demotion on a hierarchy
    target: a policy name (``"lru"``/``"clock"``/``"dead"``) or an
    :class:`repro.engine.eviction.EvictionPolicy` instance attaches an
    :class:`repro.engine.eviction.Evictor` to the hierarchy, so cold pages
    are demoted out of hot spill streams' way instead of the streams
    waterfalling downward.  ``overlap_migration`` (default ``True``) issues
    those demotions overlapped with operator compute — their rounds pay no
    RTT in the session's measured latency.  ``headroom`` keeps that many
    pages free on every non-bottom tier after each write.  Individual tasks
    can select a different policy via ``session.task(..., eviction=...)``.
    """

    def __init__(self, target: Any, budget: float, policy: str = "remop",
                 step: float = 1.0, eviction: Any = None,
                 overlap_migration: bool = True, headroom: float = 0.0):
        if budget <= 0:
            raise ValueError(f"session budget must be > 0 pages, got {budget}")
        self.budget = float(budget)
        self.policy = policy
        self.step = step
        self.remote = self._materialize(target)
        self.scheduler = TransferScheduler(self.remote)
        self.is_hierarchy = bool(getattr(self.remote, "is_hierarchy", False))
        self.hierarchy: Optional[HierarchySpec] = (
            self.remote.spec if self.is_hierarchy else None
        )
        self.tier: TierSpec = (
            self.hierarchy.levels[0].tier if self.is_hierarchy
            else self.remote.tier
        )
        self.evictor = None
        self.overlap_migration = False
        if eviction is not None:
            if not self.is_hierarchy:
                raise ValueError(
                    "eviction needs a memory hierarchy target; a single "
                    "tier has nowhere to demote cold pages to"
                )
            from repro.engine.eviction import Evictor

            self.evictor = Evictor(
                self.remote, eviction, overlap=overlap_migration,
                headroom=headroom,
            )
            self.remote.evictor = self.evictor
            self.overlap_migration = bool(overlap_migration)
        elif getattr(self.remote, "evictor", None) is not None:
            # A live hierarchy handed in with an evictor already attached
            # (e.g. by a Server sharing one hierarchy across tenants) keeps
            # its eviction semantics: adopt it instead of silently planning
            # without eviction-aware capacities.
            self.evictor = self.remote.evictor
            self.overlap_migration = bool(self.evictor.overlap)
        self._task_seq = 0
        self._run_seq = 0
        self._exec_seq = 0

    @staticmethod
    def _materialize(target: Any):
        """Resolve ``target`` to a live store, creating one from a spec."""
        from repro.remote.simulator import MemoryHierarchy, RemoteMemory

        if isinstance(target, (RemoteMemory, MemoryHierarchy)):
            return target
        if getattr(target, "is_hierarchy", False):  # duck-typed live hierarchy
            return target
        if isinstance(target, (HierarchySpec, list, tuple)):
            return MemoryHierarchy(resolve_hierarchy(target))
        return RemoteMemory(resolve_tier(target))

    @property
    def target_name(self) -> str:
        if self.hierarchy is not None:
            return "->".join(self.hierarchy.names)
        return self.tier.name

    def _placement_tau(self, placement: Optional[str]) -> float:
        """tau of a plan's placement tier (the session tier when single)."""
        if self.hierarchy is not None and placement is not None:
            return self.hierarchy.level(placement).tier.tau_pages
        return self.tier.tau_pages

    def _placement_level(self, placement: Optional[str]):
        """The placement tier's full TierLevel, capabilities included.

        A single-tier session gets a capability-free wrapper level, so
        pushdown verdicts degrade to ship there.
        """
        from repro.core.cost_model import TierLevel

        if self.hierarchy is not None and placement is not None:
            return self.hierarchy.level(placement)
        return TierLevel(tier=self.tier)

    @property
    def eviction_name(self) -> Optional[str]:
        """Human-readable eviction setup, e.g. ``"lru+overlap"``."""
        if self.evictor is None:
            return None
        name = self.evictor.policy.name
        return f"{name}+overlap" if self.overlap_migration else name

    # -- task construction ---------------------------------------------------

    def task(
        self,
        op: str,
        stats: WorkloadStats,
        *,
        inputs: Optional[Mapping[str, Any]] = None,
        label: Optional[str] = None,
        eviction: Any = None,
        placement: Any = None,
        **options: Any,
    ) -> OperatorTask:
        """Build a typed task; input names are validated against the operator.

        ``inputs`` values may be live data (relations, page-id lists) or an
        earlier task's ``.output`` reference; ``options`` are passed through
        to the operator's data plane (``rows_per_page``, ``prefetch``, ...).
        ``eviction`` selects a different eviction policy for this task only
        (the session's evictor must be enabled; validated eagerly).

        ``placement`` routes the operator's spill *streams* to explicit
        hierarchy tiers (fractional placement): a list aligned with the
        operator's ``OperatorSpec.streams`` declaration, or a dict keyed by
        stream name — e.g. EHJ ``placement={"build": "dram", "stage":
        "ssd"}`` keeps spilled build partitions hot while staging probes
        cold.  ``None`` entries follow the arbiter's placement; tier names
        are validated eagerly against the session's hierarchy.
        """
        spec = get(op)  # raises ValueError for unknown operators
        if self.policy not in spec.policies:
            raise ValueError(
                f"operator {op!r} has no policy {self.policy!r}; "
                f"available: {spec.policies}"
            )
        if eviction is not None:
            if self.evictor is None:
                raise ValueError(
                    f"task {op!r} selects eviction policy {eviction!r} but "
                    f"the session has no evictor (pass eviction=... to "
                    f"Session)"
                )
            from repro.engine.eviction import make_policy

            # Resolve once (failing fast on unknown names) and keep the
            # instance on the task, so a stateful policy ("dead", "clock")
            # retains its hints/sweep state across runs of the same task.
            eviction = make_policy(eviction)
        # Unknown names fail fast here; *missing* inputs only fail at run
        # time (bind_inputs), so plan()/explain() work on data-free tasks.
        unknown = sorted(set(inputs or {}) - set(spec.inputs))
        if unknown:
            raise ValueError(
                f"operator {op!r} takes inputs {list(spec.inputs)}: "
                f"unknown {unknown}"
            )
        if placement is not None:
            if not self.is_hierarchy:
                raise ValueError(
                    f"task {op!r} placement needs a memory hierarchy target; "
                    f"a single tier has no placement choice"
                )
            if not spec.streams:
                raise ValueError(
                    f"operator {op!r} declares no spill streams; per-stream "
                    f"placement is not supported"
                )
            norm = stream_tiers(placement, spec.streams)
            # Resolve names/indices eagerly so bad tiers fail at task build.
            try:
                placement = {
                    s: (None if v is None
                        else self.hierarchy.names[self.remote.tier_index(v)])
                    for s, v in norm.items()
                }
            except KeyError as e:
                raise ValueError(
                    f"task {op!r} placement: {e.args[0]}"
                ) from None
        self._task_seq += 1
        return OperatorTask(
            op=op,
            stats=stats,
            inputs=dict(inputs or {}),
            options=dict(options),
            label=label or f"{op}#{self._task_seq}",
            eviction=eviction,
            placement=placement,
        )

    def _check_tasks(
        self, tasks: Sequence[OperatorTask], dag: bool = False
    ) -> List[OperatorTask]:
        tasks = list(tasks)
        if not tasks:
            raise ValueError(
                "empty pipeline: session.plan/run/explain need at least one "
                "task (build them with session.task(op, stats, inputs=...))"
            )
        for i, task in enumerate(tasks):
            if not isinstance(task, OperatorTask):
                raise TypeError(
                    f"tasks[{i}] is {type(task).__name__}, expected an "
                    f"OperatorTask from session.task(...)"
                )
            if not dag:
                for name, value in task.inputs.items():
                    if isinstance(value, TaskOutput):
                        if not any(value.task is t for t in tasks[:i]):
                            raise ValueError(
                                f"task {task.label!r} input {name!r} "
                                f"references a task output that does not run "
                                f"earlier in this pipeline"
                            )
        if dag:
            self._check_dag(tasks)
        return tasks

    @staticmethod
    def _dag_deps(tasks: Sequence[OperatorTask]) -> List[set]:
        """Per-task dependency sets (list indices) from ``TaskOutput`` edges."""
        index = {id(t): i for i, t in enumerate(tasks)}
        return [
            {
                index[id(v.task)]
                for v in t.inputs.values()
                if isinstance(v, TaskOutput)
            }
            for t in tasks
        ]

    def _check_dag(self, tasks: Sequence[OperatorTask]) -> None:
        """Fail fast on DAG wiring errors, naming the offending task.

        Duplicate task objects or labels, ``inputs=`` referencing a task not
        part of this run, and dependency cycles each raise ``ValueError``.
        """
        seen_labels: Dict[str, int] = {}
        for i, t in enumerate(tasks):
            if any(t is u for u in tasks[:i]):
                raise ValueError(
                    f"duplicate task {t.label!r}: the same task object "
                    f"appears twice in this run"
                )
            if t.label in seen_labels:
                raise ValueError(
                    f"duplicate task name {t.label!r}: labels must be unique "
                    f"in a DAG run"
                )
            seen_labels[t.label] = i
        index = {id(t): i for i, t in enumerate(tasks)}
        for t in tasks:
            for name, value in t.inputs.items():
                if isinstance(value, TaskOutput) and id(value.task) not in index:
                    raise ValueError(
                        f"task {t.label!r} input {name!r} references task "
                        f"{value.task.label!r}, which is not part of this run"
                    )
        # Kahn's algorithm: anything left unordered sits on a cycle.
        deps = self._dag_deps(tasks)
        pending = {i: set(d) for i, d in enumerate(deps)}
        while True:
            ready = [i for i, d in pending.items() if not d]
            if not ready:
                break
            for i in ready:
                del pending[i]
            for d in pending.values():
                d.difference_update(ready)
        if pending:
            offender = tasks[min(pending)]
            raise ValueError(
                f"cyclic inputs=: task {offender.label!r} participates in a "
                f"dependency cycle"
            )

    # -- planning ------------------------------------------------------------

    def _primary_pin(self, task: OperatorTask) -> Optional[int]:
        """Arbiter tier pin for a fractionally-placed task (else ``None``).

        The arbiter assigns one (pages, tier) pair per task; a per-stream
        placement pins that choice to the *primary* stream's tier — the
        explicitly-placed stream with the largest estimated footprint — so
        the joint descent prices the task where most of its spill lands
        while the data plane routes each stream to its own tier.
        """
        if task.placement is None or self.hierarchy is None:
            return None
        explicit = {s: v for s, v in task.placement.items() if v is not None}
        if not explicit:
            return None
        spec = get(task.op)
        primary = next(iter(explicit))
        if spec.stream_footprints is not None and len(explicit) > 1:
            m0 = max(self.budget / 4.0, spec.min_pages)
            tau0 = self.tier.tau_pages
            fps = spec.stream_footprints(task.stats, tau0, m0)
            primary = max(explicit, key=lambda s: (fps.get(s, 0.0), s))
        return self.remote.tier_index(explicit[primary])

    def _task_pins(
        self, tasks: Sequence[OperatorTask]
    ) -> Optional[List[Optional[int]]]:
        if self.hierarchy is None:
            return None
        pins = [self._primary_pin(t) for t in tasks]
        return pins if any(p is not None for p in pins) else None

    def plan(self, tasks: Sequence[OperatorTask], dag: bool = False):
        """Arbitrate the session budget (and placements) across ``tasks``.

        ``dag=True`` validates the tasks as a DAG (any topological wiring)
        instead of requiring list order to be execution order.
        """
        from repro.engine.pipeline import _plan_pipeline

        tasks = self._check_tasks(tasks, dag=dag)
        target = self.hierarchy if self.hierarchy is not None else self.tier
        return _plan_pipeline(
            [t.op for t in tasks], [t.stats for t in tasks],
            target, self.budget, self.policy, self.step,
            eviction=self.evictor is not None,
            pinned=self._task_pins(tasks),
        )

    @staticmethod
    def _check_plan_matches(pplan, tasks: Sequence[OperatorTask]) -> None:
        if len(pplan.ops) != len(tasks):
            raise ValueError(
                f"plan has {len(pplan.ops)} operators for {len(tasks)} tasks"
            )
        for ob, task in zip(pplan.ops, tasks):
            if ob.op != task.op:
                raise ValueError(
                    f"plan/task mismatch: plan expects {ob.op!r}, task is "
                    f"{task.op!r} ({task.label})"
                )

    def explain(
        self, tasks: Sequence[OperatorTask], plan=None, dag: bool = False
    ) -> PlanReport:
        """The structured plan report: budgets, placements, D/C/L, footprints."""
        tasks = self._check_tasks(tasks, dag=dag)
        pplan = plan if plan is not None else self.plan(tasks, dag=dag)
        self._check_plan_matches(pplan, tasks)
        rows: List[TaskExplain] = []
        usage: Dict[str, float] = {}
        for task, ob in zip(tasks, pplan.ops):
            spec = get(ob.op)
            if self.hierarchy is not None and ob.placement is not None:
                level = self.hierarchy.level(ob.placement)
                tier_name, tau = level.tier.name, level.tier.tau_pages
                capacity = level.capacity_pages
            else:
                tier_name, tau = self.tier.name, self.tier.tau_pages
                capacity = math.inf
            d, c = (spec.costs(ob.stats, tau, ob.m_pages, self.policy)
                    if spec.costs else (math.nan, math.nan))
            fp = (spec.footprint(ob.stats, tau, ob.m_pages)
                  if spec.footprint else 0.0)
            # Fractional placement: decompose the footprint per stream and
            # attribute each stream's pages to *its* tier.
            stream_rows: Tuple[Tuple[str, str, float], ...] = ()
            if task.placement is not None and spec.streams:
                sf = (spec.stream_footprints(ob.stats, tau, ob.m_pages)
                      if spec.stream_footprints else {})
                stream_rows = tuple(
                    (s, task.placement.get(s) or tier_name,
                     float(sf.get(s, 0.0)))
                    for s in spec.streams
                )
            if stream_rows:
                for _s, s_tier, s_fp in stream_rows:
                    usage[s_tier] = usage.get(s_tier, 0.0) + s_fp
            else:
                usage[tier_name] = usage.get(tier_name, 0.0) + fp
            ev_name, ev_pages, ev_rounds = None, 0.0, 0.0
            if self.evictor is not None:
                ev_name = (task.eviction.name if task.eviction is not None
                           else self.evictor.policy.name)
                # Footprint beyond the placement tier's free capacity is
                # what the evictor must demote; the round estimate assumes
                # one background batch per overflowing ~M_i-page write.
                free = capacity
                if not math.isinf(free):
                    free = max(capacity - float(
                        self.remote.tier_resident(tier_name)), 0.0)
                    ev_pages = max(fp - free, 0.0)
                    ev_rounds = math.ceil(
                        ev_pages / max(ob.m_pages, 1.0)) if ev_pages else 0.0
            rows.append(TaskExplain(
                op=ob.op, label=task.label, m_pages=ob.m_pages,
                placement=tier_name, tau=tau, modeled_d=d, modeled_c=c,
                modeled_latency=ob.modeled_latency, footprint=fp,
                capacity=capacity, min_pages=spec.min_pages,
                eviction=ev_name, eviction_pages=ev_pages,
                eviction_rounds=ev_rounds, streams=stream_rows,
                pushdown=getattr(ob, "pushdown", None),
            ))
        if self.hierarchy is not None:
            footprints = tuple(
                (name, usage.get(name, 0.0), level.capacity_pages)
                for name, level in zip(self.hierarchy.names,
                                       self.hierarchy.levels)
            )
        else:
            footprints = ((self.tier.name, usage.get(self.tier.name, 0.0),
                           math.inf),)
        return PlanReport(
            policy=self.policy, m_total=self.budget, target=self.target_name,
            tasks=tuple(rows), tier_footprints=footprints,
            eviction=self.eviction_name,
        )

    # -- execution -----------------------------------------------------------

    def exec_task(
        self,
        task: OperatorTask,
        ob: Any,
        *,
        outputs: Optional[Dict[int, Any]] = None,
        stats: Optional[WorkloadStats] = None,
        label: Optional[str] = None,
        replanned: bool = False,
    ) -> TaskRun:
        """Execute one planned task against the session's shared ledger.

        ``ob`` is the task's :class:`~repro.engine.pipeline.OperatorBudget`;
        ``outputs`` maps ``id(task)`` to resolved output pages — it resolves
        this task's :class:`TaskOutput` inputs and receives its own output.
        ``stats`` overrides the stats handed to the ``measured_stats`` hook
        (defaults to ``ob.stats``).  This is the single execution path shared
        by :meth:`run` and the multi-tenant ``Server``, so both produce
        identical ledger deltas for the same plan.
        """
        spec = get(task.op)
        if outputs is None:
            outputs = {}
        base_stats = stats if stats is not None else ob.stats
        resolved = {
            name: outputs[id(value.task)]
            if isinstance(value, TaskOutput) else value
            for name, value in task.inputs.items()
        }
        args = spec.bind_inputs(resolved)
        kwargs = dict(task.options)
        # Realize the arbiter's ship-vs-push verdict as data-plane kwargs
        # (e.g. BNLJ's inner_filter/pushdown); explicit task options win.
        choice = getattr(ob, "pushdown", None)
        if choice is not None and spec.pushdown_kwargs is not None:
            for key, value in spec.pushdown_kwargs(base_stats, choice).items():
                kwargs.setdefault(key, value)
        if self.is_hierarchy:
            if task.placement is not None and spec.streams:
                # Fractional placement: every stream to its explicit tier,
                # unplaced streams follow the arbiter's placement.
                kwargs.setdefault("tier", {
                    s: (task.placement.get(s) or ob.placement)
                    for s in spec.streams
                })
            elif ob.placement is not None:
                kwargs.setdefault("tier", ob.placement)
        if label is None:
            self._exec_seq += 1
            label = f"session-exec{self._exec_seq}"
        sched = self.scheduler
        sched.checkpoint(label)
        ev_before = self.evictor.counters() if self.evictor else None
        saved_policy = None
        if self.evictor is not None and task.eviction is not None:
            saved_policy = self.evictor.policy
            self.evictor.policy = task.eviction
        try:
            result = spec.run(self.remote, *args, ob.plan, **kwargs)
            delta = sched.since(label)
        finally:
            sched.drop_checkpoint(label)
            if saved_policy is not None:
                self.evictor.policy = saved_policy
        ev_pages = ev_rounds = 0
        if ev_before is not None:
            after = self.evictor.counters()
            ev_pages = after["pages_demoted"] - ev_before["pages_demoted"]
            ev_rounds = after["demote_batches"] - ev_before["demote_batches"]
        if spec.output_of is not None:
            outputs[id(task)] = spec.output_of(result)
        measured = (spec.measured_stats(base_stats, result)
                    if spec.measured_stats else base_stats)
        return TaskRun(
            task=task, op=task.op, label=task.label,
            m_pages=ob.m_pages, placement=ob.placement,
            stats=ob.stats, measured=measured, result=result,
            delta=delta, replanned=replanned,
            eviction_pages=ev_pages, eviction_rounds=ev_rounds,
        )

    @staticmethod
    def estimate_error(planned: WorkloadStats, measured: WorkloadStats) -> float:
        """Relative cardinality error of a plan's estimate vs measurement."""
        est, got = float(planned.out), float(measured.out)
        return abs(got - est) / max(abs(est), 1.0)

    def run(
        self,
        tasks: Sequence[OperatorTask],
        replan: Optional[str] = None,
        plan=None,
        replan_threshold: Optional[float] = None,
        schedule: str = "serial",
    ) -> SessionRunResult:
        """Execute ``tasks`` in order against the session's shared ledger.

        ``replan=None`` executes the arbitrated plan as-is (ledger-exact with
        the legacy ``run_pipeline``).  ``replan="measured"`` re-arbitrates
        after each operator finishes: its measured output cardinality updates
        the downstream stats (both the finished operator's ``out`` and any
        task input bound to its ``.output``), and the remaining operators'
        budgets and tier placements are re-planned against the measured
        remaining capacity.  ``replan_threshold`` (only with
        ``replan="measured"``) skips the re-arbitration while the finished
        operator's relative cardinality error ``|measured - estimated| /
        max(estimated, 1)`` stays at or below the threshold — measured stats
        still propagate downstream, but an accurately-estimated pipeline
        records zero :class:`ReplanEvent`\\ s.  ``None`` keeps the legacy
        behaviour of re-arbitrating after every task.  ``plan`` optionally
        supplies a precomputed :class:`~repro.engine.pipeline.PipelinePlan`.

        ``schedule="dag"`` treats ``TaskOutput`` inputs as DAG edges instead
        of requiring list order: tasks execute in dependency order (lowest
        list index first among ready tasks), wiring errors fail fast
        (cycles, duplicates, foreign references), ``replan="measured"``
        re-arbitrates the *remaining frontier* after each finish, and the
        result carries ``makespan_seconds`` — the Eq.-(1) wall clock with
        independent subtrees overlapped under per-tier processor sharing.
        A linear chain reproduces the serial schedule's ledgers exactly.
        """
        if replan not in (None, "measured"):
            raise ValueError(
                f"replan must be None or 'measured', got {replan!r}"
            )
        if replan_threshold is not None:
            if replan != "measured":
                raise ValueError(
                    "replan_threshold requires replan='measured'"
                )
            if replan_threshold < 0:
                raise ValueError(
                    f"replan_threshold must be >= 0, got {replan_threshold}"
                )
        if schedule not in ("serial", "dag"):
            raise ValueError(
                f"schedule must be 'serial' or 'dag', got {schedule!r}"
            )
        if schedule == "dag":
            return self._run_dag(
                tasks, replan=replan, plan=plan,
                replan_threshold=replan_threshold,
            )
        tasks = self._check_tasks(tasks)
        pplan = plan if plan is not None else self.plan(tasks)
        self._check_plan_matches(pplan, tasks)
        budgets = list(pplan.ops)  # OperatorBudget per task; replan swaps tails
        cur_stats = [ob.stats for ob in budgets]
        replanned = [False] * len(tasks)
        outputs: Dict[int, Any] = {}  # id(task) -> resolved output pages
        events: List[ReplanEvent] = []
        per_task: List[TaskRun] = []

        self._run_seq += 1
        run_label = f"session-run{self._run_seq}"
        sched = self.scheduler
        wall0 = None if sched.wall is None else sched.wall.total_seconds
        sched.checkpoint(run_label)
        try:
            for i, task in enumerate(tasks):
                ob = budgets[i]
                tr = self.exec_task(
                    task, ob, outputs=outputs, stats=cur_stats[i],
                    label=f"{run_label}/{i}", replanned=replanned[i],
                )
                measured = tr.measured
                cur_stats[i] = measured
                per_task.append(tr)
                if replan == "measured" and i + 1 < len(tasks):
                    self.propagate_measured(tasks, cur_stats, outputs, i)
                    if (replan_threshold is not None
                            and self.estimate_error(ob.stats, measured)
                            <= replan_threshold):
                        continue
                    event = self._replan_remaining(
                        tasks, budgets, cur_stats, i, measured
                    )
                    if event is not None:
                        events.append(event)
                        for j in range(i + 1, len(tasks)):
                            replanned[j] = True
            total = sched.since(run_label)
        finally:
            sched.drop_checkpoint(run_label)
        return SessionRunResult(
            per_task=per_task, total=total, plan=pplan, replan_events=events,
            tier=self.tier, hierarchy=self.hierarchy,
            overlap_migration=self.overlap_migration,
            wall_seconds=(
                None if wall0 is None else sched.wall.total_seconds - wall0
            ),
        )

    def _run_dag(
        self,
        tasks: Sequence[OperatorTask],
        replan: Optional[str],
        plan,
        replan_threshold: Optional[float],
    ) -> SessionRunResult:
        """DAG scheduler: dependency-ordered execution + overlapped makespan.

        Tasks execute one at a time against the shared ledger (the simulator
        is single-threaded), picking the lowest-index ready task — so a
        linear chain is byte-identical to the serial path, labels included.
        Concurrency is *modeled*: each task's ledger delta decomposes into
        per-tier work chunks (:func:`delta_chunks`) and
        :func:`playback_dag` replays them with ready tasks from independent
        subtrees sharing each tier's bandwidth — the same event clock the
        multi-tenant ``Server`` uses cross-query, re-used intra-query.
        """
        tasks = self._check_tasks(tasks, dag=True)
        pplan = plan if plan is not None else self.plan(tasks, dag=True)
        self._check_plan_matches(pplan, tasks)
        deps = self._dag_deps(tasks)
        n = len(tasks)
        budgets = list(pplan.ops)
        cur_stats = [ob.stats for ob in budgets]
        replanned = [False] * n
        outputs: Dict[int, Any] = {}
        events: List[ReplanEvent] = []
        per_task: List[TaskRun] = []
        chunks: List[Any] = [None] * n
        done = [False] * n

        self._run_seq += 1
        run_label = f"session-run{self._run_seq}"
        sched = self.scheduler
        wall0 = None if sched.wall is None else sched.wall.total_seconds
        sched.checkpoint(run_label)
        try:
            for _ in range(n):
                i = next(
                    j for j in range(n)
                    if not done[j] and all(done[d] for d in deps[j])
                )
                task, ob = tasks[i], budgets[i]
                tr = self.exec_task(
                    task, ob, outputs=outputs, stats=cur_stats[i],
                    label=f"{run_label}/{i}", replanned=replanned[i],
                )
                measured = tr.measured
                cur_stats[i] = measured
                per_task.append(tr)
                chunks[i] = delta_chunks(
                    tr.delta, self.hierarchy, self.tier,
                    overlap_migration=self.overlap_migration,
                )
                done[i] = True
                remaining = [j for j in range(n) if not done[j]]
                if replan == "measured" and remaining:
                    self.propagate_measured(
                        tasks, cur_stats, outputs, i, targets=remaining
                    )
                    if (replan_threshold is not None
                            and self.estimate_error(ob.stats, measured)
                            <= replan_threshold):
                        continue
                    budget_rem = self.budget - sum(
                        budgets[k].m_pages for k in range(n) if done[k]
                    )
                    event = self._replan_indices(
                        tasks, budgets, cur_stats, remaining, budget_rem,
                        i, measured,
                    )
                    if event is not None:
                        events.append(event)
                        for j in remaining:
                            replanned[j] = True
            total = sched.since(run_label)
        finally:
            sched.drop_checkpoint(run_label)
        return SessionRunResult(
            per_task=per_task, total=total, plan=pplan, replan_events=events,
            tier=self.tier, hierarchy=self.hierarchy,
            overlap_migration=self.overlap_migration,
            schedule="dag", makespan_seconds=playback_dag(chunks, deps),
            wall_seconds=(
                None if wall0 is None else sched.wall.total_seconds - wall0
            ),
        )

    # -- mid-pipeline re-arbitration ------------------------------------------

    @staticmethod
    def propagate_measured(
        tasks: Sequence[OperatorTask],
        cur_stats: List[WorkloadStats],
        outputs: Mapping[int, Any],
        done: int,
        targets: Optional[Sequence[int]] = None,
    ) -> None:
        """Feed task ``done``'s measured output sizes into downstream stats.

        Updates ``cur_stats`` in place for every later task whose input binds
        to the finished task's output (the operator's ``input_stats`` mapping
        names the stats field the input sizes).  ``targets`` restricts the
        update to specific task indices (the DAG scheduler passes its
        unfinished frontier; the default is every later list position).
        Pure stats bookkeeping — no arbitration — so callers can propagate
        measurements even when a replan threshold suppresses the re-split
        itself.
        """
        finished_task = tasks[done]
        measured_sel = cur_stats[done].pushdown_sel
        if targets is None:
            targets = range(done + 1, len(tasks))
        for j in targets:
            spec_j = get(tasks[j].op)
            for name, value in tasks[j].inputs.items():
                if not (isinstance(value, TaskOutput)
                        and value.task is finished_task):
                    continue
                field = spec_j.input_stats.get(name)
                resolved = outputs.get(id(finished_task))
                if field is None or resolved is None:
                    continue
                cur_stats[j] = dataclasses.replace(
                    cur_stats[j], **{field: float(len(resolved))}
                )
                # A downstream task filtering the same annotated chain
                # refines its selectivity estimate from the measured one,
                # so the next re-arbitration re-decides ship-vs-push.
                if (measured_sel is not None
                        and cur_stats[j].pushdown_sel is not None):
                    cur_stats[j] = dataclasses.replace(
                        cur_stats[j], pushdown_sel=float(measured_sel)
                    )

    def _replan_remaining(
        self,
        tasks: Sequence[OperatorTask],
        budgets: List[Any],
        cur_stats: List[WorkloadStats],
        done: int,
        measured: WorkloadStats,
    ) -> Optional[ReplanEvent]:
        """Re-split the remaining budget after task ``done`` finished.

        Re-arbitrates the remaining budget over tasks ``done+1..`` at their
        current (measured-updated) stats — on a hierarchy, against the
        *measured* per-tier residency (``occupied``), so placements react to
        capacity actually consumed.  Returns a :class:`ReplanEvent` when the
        split changed, ``None`` when the re-arbitration confirmed the current
        plan (or was infeasible, in which case the current plan is kept).
        """
        remaining = list(range(done + 1, len(tasks)))
        budget_rem = self.budget - sum(budgets[k].m_pages
                                       for k in range(done + 1))
        return self._replan_indices(
            tasks, budgets, cur_stats, remaining, budget_rem, done, measured
        )

    def _replan_indices(
        self,
        tasks: Sequence[OperatorTask],
        budgets: List[Any],
        cur_stats: List[WorkloadStats],
        remaining: Sequence[int],
        budget_rem: float,
        done: int,
        measured: WorkloadStats,
    ) -> Optional[ReplanEvent]:
        """Re-arbitrate ``budget_rem`` over the ``remaining`` task indices.

        The index-list generalization shared by the serial tail replan and
        the DAG scheduler's frontier replan (the frontier is not a list
        suffix once independent subtrees interleave).
        """
        from repro.engine.pipeline import _modeled_latency

        finished_task = tasks[done]
        before_m = tuple(budgets[j].m_pages for j in remaining)
        before_p = tuple(budgets[j].placement for j in remaining)
        # Price the *old* split at the *updated* stats, so before/after in the
        # event measure what the re-split itself bought (pushdown verdicts
        # re-derived at the measured selectivity, symmetric with the re-split).
        before_l = sum(
            _modeled_latency(
                get(tasks[j].op), cur_stats[j],
                self._placement_level(budgets[j].placement),
                budgets[j].m_pages, self.policy,
            )
            for j in remaining
        )
        try:
            new_budgets = self._arbitrate_tail(
                [tasks[j] for j in remaining],
                [cur_stats[j] for j in remaining],
                budget_rem,
            )
        except ValueError:
            # No feasible re-split (e.g. measured residency ate the capacity
            # the estimate assumed): keep the current plan rather than fail a
            # query the static path would have completed.
            return None
        changed = any(
            abs(nb.m_pages - budgets[j].m_pages) > 1e-9
            or nb.placement != budgets[j].placement
            or nb.plan != budgets[j].plan
            or nb.pushdown != getattr(budgets[j], "pushdown", None)
            for j, nb in zip(remaining, new_budgets)
        )
        if not changed:
            return None
        for j, nb in zip(remaining, new_budgets):
            budgets[j] = nb
        ev = (self.evictor.counters() if self.evictor is not None
              else {"demote_batches": 0, "pages_demoted": 0})
        return ReplanEvent(
            after_index=done,
            after_label=finished_task.label,
            measured_out=measured.out,
            budgets_before=before_m,
            budgets_after=tuple(nb.m_pages for nb in new_budgets),
            placements_before=before_p,
            placements_after=tuple(nb.placement for nb in new_budgets),
            modeled_before=before_l,
            modeled_after=sum(nb.modeled_latency for nb in new_budgets),
            eviction_rounds=ev["demote_batches"],
            eviction_pages=ev["pages_demoted"],
        )

    def _arbitrate_tail(
        self,
        tasks: Sequence[OperatorTask],
        stats: Sequence[WorkloadStats],
        budget: float,
        weights: Optional[Sequence[float]] = None,
        pinned: Optional[Sequence[float]] = None,
    ) -> List[Any]:
        """Arbitrate ``budget`` over the remaining tasks with updated stats.

        ``weights`` (one per task, default all 1.0) scale each task's modeled
        latency inside the arbiter's marginal-cost descent — the multi-tenant
        ``Server`` passes per-tenant priorities here so high-priority queries
        win the contested budget quanta and fast-tier placements.  Reported
        ``modeled_latency`` stays unweighted.

        ``pinned`` (per-tier page counts, hierarchy targets only) marks
        residency that must NOT be treated as evictable: those pages are
        subtracted from both the tier capacities and the soft ``occupied``
        residency before arbitration.  A single query's own cold pages are
        legitimately evictable (the standalone semantics), but another
        in-flight query's pages are about to be read again — planning spill
        on top of them causes demotion thrash, so the ``Server`` pins every
        admitted tenant's residency whenever two or more queries share the
        hierarchy.
        """
        from repro.core.cost_model import TierLevel
        from repro.engine.pipeline import (
            OperatorBudget,
            _modeled_latency,
            pushdown_choice,
        )

        policy = self.policy
        if weights is None:
            weights = [1.0] * len(tasks)
        if len(weights) != len(tasks):
            raise ValueError(
                f"{len(weights)} weights for {len(tasks)} tasks"
            )
        if self.hierarchy is None:
            tau = self.tier.tau_pages
            level = TierLevel(tier=self.tier)  # capability-free: always ship
            items = [
                ArbiterItem(
                    name=t.op, min_pages=get(t.op).min_pages,
                    latency_of=lambda m, s=get(t.op), st=st, w=w: w * s.model(
                        st, tau, m, policy
                    ),
                )
                for t, st, w in zip(tasks, stats, weights)
            ]
            alloc, _ = arbitrate(items, budget, step=self.step)
            return [
                OperatorBudget(
                    op=t.op, stats=st, m_pages=m,
                    plan=plan_operator(t.op, st, self.tier, m, policy=policy),
                    modeled_latency=get(t.op).model(st, tau, m, policy),
                    pushdown=pushdown_choice(get(t.op), st, level, m, policy),
                )
                for t, st, m in zip(tasks, stats, alloc)
            ]
        hspec = self.hierarchy
        taus = hspec.taus
        occupied = [
            float(self.remote.tier_resident(t)) for t in range(len(hspec))
        ]
        capacities = list(hspec.capacities)
        if pinned is not None:
            if len(pinned) != len(hspec):
                raise ValueError(
                    f"{len(pinned)} pinned counts for {len(hspec)} tiers"
                )
            occupied = [max(o - p, 0.0) for o, p in zip(occupied, pinned)]
            capacities = [
                c if math.isinf(c) else max(c - p, 0.0)
                for c, p in zip(capacities, pinned)
            ]
        items = []
        for t, st, w in zip(tasks, stats, weights):
            spec = get(t.op)
            footprint = spec.footprint or (lambda st_, tau_, m_: 0.0)
            items.append(HierarchyItem(
                name=t.op, min_pages=spec.min_pages,
                latency_of=lambda m, ti, s=spec, st=st, w=w: w * _modeled_latency(
                    s, st, hspec.levels[ti], m, policy
                ),
                footprint_of=lambda m, ti, fp=footprint, st=st: fp(
                    st, taus[ti], m
                ),
            ))
        alloc, placement, _ = arbitrate_hierarchy(
            items, budget, capacities, step=self.step, occupied=occupied,
            eviction=self.evictor is not None,
            pinned_tiers=self._task_pins(tasks),
        )
        return [
            OperatorBudget(
                op=t.op, stats=st, m_pages=m,
                plan=plan_operator(t.op, st, hspec.levels[ti].tier, m,
                                   policy=policy),
                modeled_latency=_modeled_latency(
                    get(t.op), st, hspec.levels[ti], m, policy
                ),
                placement=hspec.names[ti],
                pushdown=pushdown_choice(
                    get(t.op), st, hspec.levels[ti], m, policy
                ),
            )
            for t, st, m, ti in zip(tasks, stats, alloc, placement)
        ]
