"""Proactive eviction: demote cold pages to keep fast-tier headroom.

PR 4's :class:`repro.remote.simulator.MemoryHierarchy` only *waterfalls* on
overflow: when a spill stream outgrows its target tier, the *new* (hot) pages
cascade to slower tiers and pay those tiers' rounds synchronously — the worst
pages go to the worst place at the worst time.  The eviction subsystem
inverts that: an :class:`Evictor` attached to the hierarchy demotes *cold*
pages out of the way in **background migration rounds** (RTT hidden via
``c_migration_hidden``, the §IV-E prefetch model applied to demotion), so hot
spill streams land — and are re-read — on the fast tier.

Three policies over the recency the hierarchy tracks per page:

``LRUPolicy``
  Coldest-first by last batched access (writes and reads tick a shared
  clock; migration never refreshes recency).

``ClockPolicy``
  Second-chance clock: a circular hand sweeps resident pages; a page
  accessed since the hand last passed is spared once, otherwise evicted.

``DeadAfterFlushPolicy``
  Spill-stream aware: :class:`repro.engine.buffers.BufferPool` hints when a
  stream is fully flushed, marking its pages *dead* — complete, not being
  appended to, and not read since the flush.  Dead pages are first-choice
  victims; anything else falls back to LRU order.  A page read after its
  flush hint sheds the dead mark (recency moved past the hint).

The :class:`Evictor` is the mechanism: ``make_room(tier, need)`` runs before
every hierarchy write, demoting one victim batch per overflowing write (and
recursively making room below), so the write's own pages never cascade while
cold pages exist above.  The closed-form counterpart is
:func:`repro.core.policies.eviction_waterfall_io`.
"""

from __future__ import annotations

import math
from typing import (
    Dict,
    FrozenSet,
    Hashable,
    List,
    Optional,
    Protocol,
    Sequence,
    Union,
    runtime_checkable,
)


@runtime_checkable
class EvictionPolicy(Protocol):
    """Victim selection over one hierarchy tier's resident pages."""

    name: str

    def victims(self, hierarchy, tier_index: int, n_pages: int) -> List[int]:
        """Up to ``n_pages`` page ids resident on ``tier_index``, coldest
        first.  May return fewer (nothing evictable); never pages from
        another tier."""
        ...

    def stream_flushed(self, hierarchy, page_ids: Sequence[int]) -> None:
        """Hint: a spill stream owning ``page_ids`` is fully flushed."""
        ...


class LRUPolicy:
    """Least-recently-used: rank by the hierarchy's batched access clock."""

    name = "lru"

    def victims(self, hierarchy, tier_index: int, n_pages: int) -> List[int]:
        if n_pages <= 0:
            return []
        resident = hierarchy.pages_on(tier_index)
        resident.sort(key=lambda i: (hierarchy.last_access(i), i))
        return resident[:n_pages]

    def stream_flushed(self, hierarchy, page_ids: Sequence[int]) -> None:
        pass


class ClockPolicy:
    """Second-chance clock over page access recency.

    The hand sweeps resident page ids in circular order; a page whose last
    access is newer than when the hand last passed it gets a second chance
    (its reference state refreshes), otherwise it is evicted.  Equivalent to
    the classic one-bit clock with the hierarchy's access clock standing in
    for the reference bit.
    """

    name = "clock"

    def __init__(self) -> None:
        self._seen: Dict[int, int] = {}
        self._hand: int = -1

    def victims(self, hierarchy, tier_index: int, n_pages: int) -> List[int]:
        if n_pages <= 0:
            return []
        # Drop sweep state for pages freed since the last call, so the
        # dict tracks live pages rather than every id ever seen.
        self._seen = {
            i: v for i, v in self._seen.items() if hierarchy.is_resident(i)
        }
        resident = hierarchy.pages_on(tier_index)
        if not resident:
            return []
        # Rotate so the sweep resumes just past the hand's last position.
        start = 0
        for pos, i in enumerate(resident):
            if i > self._hand:
                start = pos
                break
        order = resident[start:] + resident[:start]
        chosen: List[int] = []
        # Two full sweeps suffice: the first clears every reference, the
        # second must find victims.
        for i in order * 2:
            if len(chosen) >= n_pages:
                break
            if i in chosen:
                continue
            last = hierarchy.last_access(i)
            if last > self._seen.get(i, -1):
                self._seen[i] = last  # second chance: clear the reference
            else:
                chosen.append(i)
            self._hand = i
        return chosen

    def stream_flushed(self, hierarchy, page_ids: Sequence[int]) -> None:
        pass


class DeadAfterFlushPolicy:
    """Prefer pages of fully-flushed spill streams; fall back to LRU.

    ``BufferPool`` reports each stream's pages when the stream is force-
    flushed (complete); those pages are dead weight on the fast tier until
    something reads them again — a read after the hint revives the page.
    """

    name = "dead"

    def __init__(self, fallback: Optional[EvictionPolicy] = None) -> None:
        # flush-time access clock per hinted page: dead iff not read since.
        self._flushed_at: Dict[int, int] = {}
        self._fallback = fallback or LRUPolicy()

    def victims(self, hierarchy, tier_index: int, n_pages: int) -> List[int]:
        if n_pages <= 0:
            return []
        # Forget hints for pages freed since the last call (bounds the dict
        # by live pages, not pages ever hinted).
        self._flushed_at = {
            i: v for i, v in self._flushed_at.items()
            if hierarchy.is_resident(i)
        }
        dead = [
            i for i in hierarchy.pages_on(tier_index)
            if i in self._flushed_at
            and hierarchy.last_access(i) <= self._flushed_at[i]
        ]
        dead.sort(key=lambda i: (hierarchy.last_access(i), i))
        chosen = dead[:n_pages]
        if len(chosen) < n_pages:
            taken = set(chosen)
            for i in self._fallback.victims(hierarchy, tier_index, n_pages):
                if i not in taken:
                    chosen.append(i)
                    if len(chosen) >= n_pages:
                        break
        return chosen

    def stream_flushed(self, hierarchy, page_ids: Sequence[int]) -> None:
        clock = hierarchy.access_clock
        for i in page_ids:
            self._flushed_at[i] = clock


_POLICIES = {
    "lru": LRUPolicy,
    "clock": ClockPolicy,
    "dead": DeadAfterFlushPolicy,
}


def make_policy(policy: Union[str, EvictionPolicy]) -> EvictionPolicy:
    """Resolve a policy name (``lru``/``clock``/``dead``) or pass through."""
    if isinstance(policy, str):
        try:
            return _POLICIES[policy]()
        except KeyError:
            raise ValueError(
                f"unknown eviction policy {policy!r}; "
                f"known: {sorted(_POLICIES)}"
            ) from None
    if not isinstance(policy, EvictionPolicy):
        raise TypeError(
            f"eviction policy must be a name or an EvictionPolicy, "
            f"got {type(policy).__name__}"
        )
    return policy


class Evictor:
    """Background demotion engine attached to one :class:`MemoryHierarchy`.

    ``make_room(tier, need)`` runs before every hierarchy write targeting
    ``tier``: while the tier lacks ``need`` free pages, the policy's coldest
    victims are demoted one tier down as **one background migration batch**
    (recursively making room below first), so the incoming hot batch lands on
    its target.  ``headroom`` additionally keeps that many pages free on
    every non-bottom tier after each write (``maintain``), pre-paying
    demotions before the next burst instead of on its critical path.

    ``overlap=True`` (the default) issues demotions as background migrations:
    their rounds are recorded in ``c_migration_hidden`` and pay no RTT under
    ``latency_seconds(overlap_migration=True)``.  Counters ``pages_demoted``
    and ``demote_batches`` expose the measured eviction effort (each batch is
    one migration round on each ledger it crosses).

    ``promote`` enables the inverse flow for *re-hot* pages: each
    ``maintain`` sweep moves up to that many pages per tier — pages a slower
    tier holds that have been accessed more recently than the coldest
    resident of the tier above — one tier up as one background migration
    batch (same ``c_migration_hidden`` accounting as demotion).  Promotion
    makes room above through the same scan-resistant victim selection, so it
    can never evict a page an active scan window protects.  Counters
    ``pages_promoted`` and ``promote_batches`` expose the effort.
    """

    def __init__(
        self,
        hierarchy,
        policy: Union[str, EvictionPolicy] = "lru",
        *,
        overlap: bool = True,
        headroom: float = 0.0,
        promote: float = 0.0,
    ) -> None:
        if not getattr(hierarchy, "is_hierarchy", False):
            raise ValueError(
                "an Evictor needs a MemoryHierarchy; single-tier stores "
                "have nowhere to demote to"
            )
        if headroom < 0:
            raise ValueError(f"headroom must be >= 0 pages, got {headroom}")
        if promote < 0:
            raise ValueError(f"promote must be >= 0 pages, got {promote}")
        self.hierarchy = hierarchy
        self.policy = make_policy(policy)
        self.overlap = bool(overlap)
        self.headroom = float(headroom)
        self.promote = float(promote)
        self.pages_demoted = 0
        self.demote_batches = 0
        self.scan_spared = 0
        self.pages_promoted = 0
        self.promote_batches = 0
        # Active sequential-scan windows, keyed per cursor: pages a consumer
        # is about to read.  Victim selection skips them (scan resistance).
        self._scan_windows: Dict[Hashable, FrozenSet[int]] = {}

    def counters(self) -> Dict[str, int]:
        """Measured eviction effort so far (monotone)."""
        return {
            "pages_demoted": self.pages_demoted,
            "demote_batches": self.demote_batches,
            "scan_spared": self.scan_spared,
            "pages_promoted": self.pages_promoted,
            "promote_batches": self.promote_batches,
        }

    # -- scan resistance -----------------------------------------------------

    def scan_hint(self, key: Hashable, page_ids: Sequence[int]) -> None:
        """Declare the pages a sequential scan (``key``) has yet to read.

        Pure LRU demotes exactly the run pages an EMS merge is about to read
        next — their last access was the flush that wrote them, so they rank
        coldest right when they are hottest.  While a window is active its
        pages are skipped by victim selection; the consumer re-hints with the
        shrinking remainder after each read round and an empty window (or
        :meth:`scan_done`) lifts the protection.
        """
        ids = frozenset(int(i) for i in page_ids)
        if ids:
            self._scan_windows[key] = ids
        else:
            self._scan_windows.pop(key, None)

    def scan_done(self, key: Hashable) -> None:
        """Drop a scan window (missing keys are ignored)."""
        self._scan_windows.pop(key, None)

    def scan_pages(self) -> FrozenSet[int]:
        """Union of all active scan windows (the currently unevictable set)."""
        if not self._scan_windows:
            return frozenset()
        return frozenset().union(*self._scan_windows.values())

    def _select_victims(self, tier_index: int, deficit: int) -> List[int]:
        """Policy victims minus active scan windows, still ``deficit`` deep.

        Asks the policy for enough extra candidates to cover the protected
        pages it may rank first, so sparing a scan never shrinks the demotion
        batch while colder unprotected pages exist.
        """
        protected = self.scan_pages()
        if not protected:
            return self.policy.victims(self.hierarchy, tier_index, deficit)
        on_tier = self.hierarchy.pages_on(tier_index)
        n_protected = sum(1 for i in on_tier if i in protected)
        ranked = self.policy.victims(
            self.hierarchy, tier_index, deficit + n_protected
        )
        victims = [i for i in ranked if i not in protected][:deficit]
        self.scan_spared += sum(1 for i in ranked[:deficit] if i in protected)
        return victims

    def make_room(self, tier_index: int, need: float) -> None:
        """Demote cold victims until ``tier_index`` has ``need`` free pages.

        The bottom tier is the backstop (nothing below to demote to); a
        policy that returns no victims leaves the residual overflow to the
        hierarchy's normal waterfall.
        """
        h = self.hierarchy
        if tier_index >= len(h.tiers) - 1:
            return
        free = h.capacity_left(tier_index)
        if math.isinf(free) or free >= need:
            return
        deficit = int(math.ceil(need - free))
        victims = self._select_victims(tier_index, deficit)
        if not victims:
            return
        self.make_room(tier_index + 1, len(victims))
        room_below = h.capacity_left(tier_index + 1)
        if not math.isinf(room_below):
            # The tier below could not clear enough (no victims of its own):
            # demote only what fits; the residual overflow waterfalls.
            victims = victims[: max(int(room_below), 0)]
        if not victims:
            return
        h.demote(victims, background=self.overlap)
        self.pages_demoted += len(victims)
        self.demote_batches += 1

    def maintain(self) -> None:
        """Restore ``headroom`` free pages on every non-bottom tier, then
        promote re-hot pages back up (when ``promote`` is enabled)."""
        if self.headroom > 0:
            for t in range(len(self.hierarchy.tiers) - 1):
                self.make_room(t, self.headroom)
        self.promote_hot()

    # -- re-hot promotion ----------------------------------------------------

    def _promote_candidates(self, tier_index: int, limit: int) -> List[int]:
        """Hottest pages on ``tier_index`` that outrank the tier above.

        A page qualifies when its last batched access is strictly newer than
        the coldest resident of the tier above (swapping the two improves
        recency locality); on an empty upper tier, any accessed page does.
        """
        h = self.hierarchy
        below = h.pages_on(tier_index)
        if not below:
            return []
        above = h.pages_on(tier_index - 1)
        floor = min((h.last_access(i) for i in above), default=0)
        hot = [i for i in below if h.last_access(i) > floor]
        hot.sort(key=lambda i: (-h.last_access(i), i))
        return hot[:limit]

    def promote_hot(self) -> None:
        """One promotion sweep: re-hot pages move one tier up per call.

        Room above is made through :meth:`make_room` — the same
        scan-resistant victim selection as demotion — so a promotion can
        displace cold pages but never a scan-protected one; when the upper
        tier cannot clear enough space the batch is truncated to what fits.
        """
        if self.promote <= 0:
            return
        h = self.hierarchy
        for t in range(len(h.tiers) - 1, 0, -1):
            batch = self._promote_candidates(t, int(self.promote))
            if not batch:
                continue
            self.make_room(t - 1, len(batch))
            # Room-making may itself have cascaded demotions through tier
            # ``t`` (clock/dead policies don't rank by recency), displacing
            # some candidates: promote only pages still resident here.
            batch = [i for i in batch
                     if h.is_resident(i) and h.tier_of(i) == h.spec.names[t]]
            free = h.capacity_left(t - 1)
            if not math.isinf(free):
                batch = batch[: max(int(free), 0)]
            if not batch:
                continue
            h.promote(batch, background=self.overlap)
            self.pages_promoted += len(batch)
            self.promote_batches += 1

    def stream_flushed(self, page_ids: Sequence[int]) -> None:
        """Forward a BufferPool fully-flushed-stream hint to the policy."""
        self.policy.stream_flushed(self.hierarchy, page_ids)
