"""Shared spill buffers: capacity-triggered write pools and streamed read cursors.

These two classes carry *all* of the operators' round accounting:

``BufferPool``
  A write pool of ``capacity_pages`` shared by ``n_streams`` output streams
  (partitions, runs, the single result stream).  Each stream owns a slice of
  ``floor(capacity/n_streams)`` pages; whenever a slice fills, exactly one
  slice worth of rows is flushed in one batched write round, so a stream of
  ``V`` pages costs ``ceil(V / slice)`` write rounds — the ``|stream|/R``
  terms in the paper's C formulas (§III).  ``flush_all`` force-flushes the
  partial remainders, one round per non-empty stream.

``PageCursor``
  Streams a page-id list through a fixed-size read buffer; each refill is one
  read round, so a ``V``-page stream through a ``c``-page buffer costs
  ``ceil(V/c)`` read rounds.  With ``prefetch=True`` the cursor models the
  §IV-E double buffer: every refill after the first is issued one batch ahead
  and its RTT is hidden (accounted by the scheduler).  Sorted-run helpers
  (``safe_bound`` / ``take_upto``) support merge consumers (EMS).
"""

from __future__ import annotations

from typing import Dict, Hashable, Iterator, List, Optional, Sequence

import numpy as np

from repro.engine.scheduler import TransferScheduler


class BufferPool:
    """Per-stream sliced write pool with batched, capacity-triggered flushes.

    On a hierarchy target, ``tier`` names the placement tier for this pool's
    flush rounds (``None`` falls through to the scheduler's default tier) —
    the hook fractional placement uses to route one operator's streams to
    different tiers.
    """

    def __init__(
        self,
        sched: TransferScheduler,
        capacity_pages: float,
        rows_per_page: int,
        n_streams: int = 1,
        tier=None,
    ):
        self.sched = sched
        self.tier = tier
        self.slice_pages = max(1, int(capacity_pages / max(n_streams, 1)))
        self.slice_rows = self.slice_pages * rows_per_page
        self.rows_per_page = rows_per_page
        self._bufs: Dict[Hashable, List[np.ndarray]] = {}
        self._counts: Dict[Hashable, int] = {}
        self._pages: Dict[Hashable, List[int]] = {}
        self.flushes = 0
        self.rows_flushed = 0

    def add(self, rows: np.ndarray, stream: Hashable = 0) -> None:
        """Buffer rows on a stream; flush full slices as batched write rounds."""
        if not len(rows):
            return
        self._bufs.setdefault(stream, []).append(rows)
        self._counts[stream] = self._counts.get(stream, 0) + len(rows)
        if self._counts[stream] >= self.slice_rows:
            self._drain(stream, force=False)

    def _drain(self, stream: Hashable, force: bool) -> None:
        bufs = self._bufs.get(stream, [])
        data = bufs[0] if len(bufs) == 1 else np.concatenate(bufs, axis=0)
        while len(data) >= self.slice_rows:
            self._write_round(stream, data[: self.slice_rows])
            data = data[self.slice_rows :]
        if force and len(data):
            self._write_round(stream, data)
            data = data[:0]
        self._bufs[stream] = [data] if len(data) else []
        self._counts[stream] = len(data)

    def _write_round(self, stream: Hashable, chunk: np.ndarray) -> None:
        pages = [
            chunk[i : i + self.rows_per_page]
            for i in range(0, len(chunk), self.rows_per_page)
        ]
        self._pages.setdefault(stream, []).extend(
            self.sched.write(pages, tier=self.tier)
        )
        self.flushes += 1
        self.rows_flushed += len(chunk)

    def flush_all(self) -> None:
        """Force-flush every stream's remainder: one write round per stream.

        A force-flush means the stream is complete, so each stream's pages
        are reported to the scheduler as a fully-flushed spill stream — the
        "dead after flush" hint eviction policies use to pick first-choice
        demotion victims.
        """
        for stream in list(self._bufs):
            if self._counts.get(stream, 0):
                self._drain(stream, force=True)
        for page_ids in self._pages.values():
            self.sched.stream_flushed(page_ids)

    def buffered_rows(self, stream: Hashable = 0) -> int:
        return self._counts.get(stream, 0)

    def pages(self, stream: Hashable = 0) -> List[int]:
        """Remote page ids flushed for a stream, in flush order."""
        return self._pages.get(stream, [])


class PageCursor:
    """Streamed reads of a page-id list through a fixed-size buffer."""

    def __init__(
        self,
        sched: TransferScheduler,
        page_ids: Sequence[int],
        batch_pages: float,
        *,
        prefetch: bool = False,
        ravel: bool = False,
    ):
        self.sched = sched
        self.page_ids = list(page_ids)
        self.batch_pages = max(1, int(batch_pages))
        self.prefetch = prefetch
        self.ravel = ravel
        self.pos = 0
        self.refills = 0
        self._buf: Optional[np.ndarray] = None
        # Scan resistance: declare the unread window so an attached evictor
        # never demotes pages this cursor is about to read (the EMS merge
        # pattern — run pages rank LRU-coldest exactly when they are next).
        self._scan_key = f"cursor-{id(self)}"
        self.sched.scan_hint(self._scan_key, self.page_ids)

    # -- buffered streaming (merge consumers) --------------------------------

    @property
    def buffered(self) -> int:
        """Rows (or keys, in ravel mode) currently buffered."""
        return 0 if self._buf is None else len(self._buf)

    @property
    def exhausted(self) -> bool:
        return self.pos >= len(self.page_ids) and self.buffered == 0

    def refill(self) -> bool:
        """One read round: load the next batch into the (empty) buffer."""
        if self.buffered > 0 or self.pos >= len(self.page_ids):
            return self.buffered > 0
        self._buf = self._concat(self._read_next())
        return True

    def safe_bound(self) -> Optional[int]:
        """Largest key below which this stream cannot produce unseen elements.

        ``None`` when nothing is buffered, or when the stream is fully
        buffered (no bound needed).  Assumes a sorted (run) stream.
        """
        if self.buffered == 0 or self.pos >= len(self.page_ids):
            return None
        return int(self._buf[-1])

    def take_upto(self, bound: Optional[int]) -> np.ndarray:
        """Consume buffered elements ``<= bound`` (all of them when ``None``).

        The empty result keeps the buffered dtype when one is known — an
        execution backend streams real (possibly non-int64) pages through
        the same cursors, and a dtype-mismatched empty would poison the
        consumer's concatenation.
        """
        if self.buffered == 0:
            dtype = np.int64 if self._buf is None else self._buf.dtype
            return np.empty((0,), dtype=dtype)
        if bound is None:
            out, self._buf = self._buf, self._buf[:0]
            return out
        idx = int(np.searchsorted(self._buf, bound, side="right"))
        out, self._buf = self._buf[:idx], self._buf[idx:]
        return out

    # -- block streaming (scan consumers) ------------------------------------

    def blocks(self) -> Iterator[np.ndarray]:
        """Yield one concatenated block per read round until exhausted.

        Rows already buffered by ``refill()`` (whose round was already
        charged) are drained first, so mixing the buffered and block APIs
        never drops data.
        """
        if self.buffered:
            buf, self._buf = self._buf, None
            yield buf
        while self.pos < len(self.page_ids):
            yield self._concat(self._read_next())

    def read_all(self) -> np.ndarray:
        """Stream the remaining pages (one round per batch) into one array."""
        return np.concatenate(list(self.blocks()), axis=0)

    def _concat(self, pages: List[np.ndarray]) -> np.ndarray:
        if self.ravel:
            return np.concatenate([p.ravel() for p in pages])
        return pages[0] if len(pages) == 1 else np.concatenate(pages, axis=0)

    def _read_next(self) -> List[np.ndarray]:
        ids = self.page_ids[self.pos : self.pos + self.batch_pages]
        # A stream's first round is never hidden: nothing overlaps it.
        pages = self.sched.read(ids, prefetch=self.prefetch and self.refills > 0)
        self.pos += len(ids)
        self.refills += 1
        # Shrink the protected window to what is still unread; exhausting
        # the stream lifts the protection entirely.
        if self.pos >= len(self.page_ids):
            self.sched.scan_done(self._scan_key)
        else:
            self.sched.scan_hint(self._scan_key, self.page_ids[self.pos:])
        return pages
