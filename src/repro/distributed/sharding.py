"""Logical-axis sharding: one set of model code, any mesh.

Model code annotates activations with *logical* names (``batch``, ``seq``,
``heads``, ``ff``, ``vocab``, ``expert``...).  A :class:`Sharder` installed by
the launcher maps logical names to mesh axes and applies
``with_sharding_constraint``; with no sharder installed (unit tests, smoke
tests on one CPU device) the annotations are no-ops.

Parameter shardings are produced by path-pattern rules over the params
pytree (``param_specs``), giving TP on the ``model`` axis, EP for expert
stacks, and replication elsewhere; ZeRO-1 additionally shards optimizer
state over the ``data`` axis (``zero1_specs``).
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


# Logical axis name -> mesh axis (or tuple of mesh axes).
# ``seq`` maps to the model axis between blocks: Megatron-style sequence
# parallelism, which shards the residual stream and turns the TP all-reduce
# into all-gather + reduce-scatter pairs (same volume, less activation memory).
DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "d_model": None,
    "state": ("model",),
    # decode KV-cache sequence dim: unsharded by default; the "kv_seq"
    # hillclimb variant maps it to the model axis (flash-decoding style
    # sharded-KV attention) for MQA archs whose single KV head cannot be
    # head-sharded.
    "kv_seq": None,
}


class Sharder:
    def __init__(self, mesh: Mesh, rules: Dict[str, Optional[Tuple[str, ...]]] | None = None,
                 sequence_parallel: bool = True):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        if sequence_parallel:
            self.rules["seq"] = self.rules.get("seq_sp", ("model",))
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec(self, logical: Sequence[Optional[str]], shape: Sequence[int] | None = None) -> P:
        axes = []
        used = set()
        for i, name in enumerate(logical):
            if name is None:
                axes.append(None)
                continue
            mesh_axes = self.rules.get(name)
            if mesh_axes is None:
                axes.append(None)
                continue
            mesh_axes = tuple(a for a in mesh_axes if a in self.axis_sizes and a not in used)
            if not mesh_axes:
                axes.append(None)
                continue
            if shape is not None:
                # Only shard divisible dims: avoids GSPMD padding blowups on
                # head counts like 24 or 10 that don't divide the model axis.
                total = 1
                for a in mesh_axes:
                    total *= self.axis_sizes[a]
                if shape[i] % total != 0:
                    axes.append(None)
                    continue
            used.update(mesh_axes)
            axes.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        return P(*axes)

    def constrain(self, x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
        if len(logical) != x.ndim:
            raise ValueError(f"logical axes {logical} vs rank {x.ndim}")
        spec = self.spec(logical, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def sharding(self, logical: Sequence[Optional[str]], shape: Sequence[int] | None = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


def current_sharder() -> Optional[Sharder]:
    return getattr(_state, "sharder", None)


@contextlib.contextmanager
def use_sharder(sharder: Optional[Sharder]):
    prev = getattr(_state, "sharder", None)
    _state.sharder = sharder
    try:
        yield
    finally:
        _state.sharder = prev


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Annotate activations with logical axes (no-op without a sharder)."""
    s = current_sharder()
    if s is None:
        return x
    return s.constrain(x, logical)


# ---------------------------------------------------------------------------
# Parameter sharding rules (path-pattern based)
# ---------------------------------------------------------------------------

# Patterns are matched against "/"-joined param paths; the FIRST match wins.
# Specs are logical names per dim, resolved through the sharder rules; a
# leading "layer" dim (stacked scan params) is always unsharded.
PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed/table", ("vocab", None)),
    (r"(frontend|proj_in)/.*w", (None, None)),
    # attention projections (2-D, layer-stacked to 3-D handled generically)
    (r"attn/wq/w", (None, "heads_flat")),
    (r"attn/wk/w", (None, "kv_flat")),
    (r"attn/wv/w", (None, "kv_flat")),
    (r"attn/wo/w", ("heads_flat", None)),
    # MLA
    (r"attn/w_dq/w", (None, None)),
    (r"attn/w_uq/w", (None, "heads_flat")),
    (r"attn/w_dkv/w", (None, None)),
    (r"attn/w_uk/w", (None, "heads_flat")),
    (r"attn/w_uv/w", (None, "heads_flat")),
    (r"attn/w_kr/w", (None, None)),
    # dense mlp
    (r"mlp/w_(gate|up)/w", (None, "ff")),
    (r"mlp/w_down/w", ("ff", None)),
    # MoE experts: [E, d, ff] / [E, ff, d] — expert-parallel on the model axis
    (r"moe/experts/w_(gate|up)", ("expert", None, None)),
    (r"moe/experts/w_down", ("expert", None, None)),
    (r"moe/router/w", (None, None)),
    (r"moe/shared/w_(gate|up)/w", (None, "ff")),
    (r"moe/shared/w_down/w", ("ff", None)),
    # mamba2 / SSD
    (r"ssm/w_in/w", (None, "ff")),
    (r"ssm/w_out/w", ("ff", None)),
    (r"ssm/(a_log|dt_bias|d_skip)", ("state_heads",)),
    (r"ssm/conv/w", (None, "ff")),
    # RG-LRU
    (r"rec/w_(x|gate)/w", (None, "ff")),
    (r"rec/w_out/w", ("ff", None)),
    (r"rec/(a_param|a_gate|x_gate)", ("ff",)) ,
    (r"rec/conv/w", (None, "ff")),
    # norms / biases / scalars: replicate
    (r".*", None),
)

_LOGICAL_FALLBACK = {
    "heads_flat": ("model",),
    "kv_flat": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "state_heads": ("model",),
}


def _resolve_logical(name: Optional[str], sharder: Sharder) -> Optional[Tuple[str, ...]]:
    if name is None:
        return None
    if name in sharder.rules:
        return sharder.rules[name]
    return _LOGICAL_FALLBACK.get(name)


def param_specs(params, sharder: Sharder):
    """PartitionSpec pytree for a params pytree (TP/EP on the model axis)."""

    def spec_for(path: str, shape: Tuple[int, ...]) -> P:
        for pattern, logical in PARAM_RULES:
            if re.search(pattern, path):
                if logical is None:
                    return P()
                # Right-align logical names to trailing dims (stacked layer
                # dims on the left stay unsharded).
                names: list = [None] * len(shape)
                for off, nm in enumerate(reversed(logical)):
                    idx = len(shape) - 1 - off
                    if idx < 0:
                        continue
                    mesh_axes = _resolve_logical(nm, sharder)
                    if mesh_axes is None:
                        continue
                    total = 1
                    for a in mesh_axes:
                        total *= sharder.axis_sizes.get(a, 1)
                    if shape[idx] % total == 0 and total > 1:
                        names[idx] = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
                return P(*names)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        path_str = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        specs.append(spec_for(path_str, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


def zero1_specs(param_spec_tree, sharder: Sharder):
    """Optimizer-state specs: params' TP sharding + ZeRO-1 over 'data'.

    Each m/v leaf adds the data axis on the first dimension the param spec
    leaves unsharded and whose size divides the data-axis size.
    """
    data_axes = tuple(a for a in ("data",) if a in sharder.axis_sizes)
    if not data_axes:
        return param_spec_tree

    def add_data(spec: P, shape: Tuple[int, ...]) -> P:
        names = list(spec) + [None] * (len(shape) - len(spec))
        dsize = sharder.axis_sizes["data"]
        for i, (nm, dim) in enumerate(zip(names, shape)):
            if nm is None and dim % dsize == 0 and dim >= dsize:
                names[i] = "data"
                return P(*names)
        return P(*names)

    # We need shapes: caller zips specs with params via tree_map.
    return add_data  # used via tree_map(lambda spec, p: add_data(spec, p.shape))


def named_sharding_tree(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
