"""Round-aware bucketed collectives (the ICI tier of the REMOP model).

Each collective launch pays a fixed cost (the "RTT" of the ICI tier), so the
number of collective *rounds* is a first-order term exactly as in Eq. (1).
``bucketed_psum`` coalesces a gradient pytree into ~equal-byte buckets sized
by ``core.planner.plan_grad_buckets`` (fewer rounds), while keeping enough
buckets that the backward pass can overlap them (the §IV-E prefetch trade).

Under pjit, XLA already fuses same-shape all-reduces; this module is for the
explicit shard_map/manual paths and for the cross-pod hop where we also
compress (``optim.compression``) before reducing.
"""

from __future__ import annotations

from typing import Any, List

import jax
import jax.numpy as jnp

from repro.core.planner import BucketPlan, plan_grad_buckets


def partition_buckets(tree, n_buckets: int) -> List[List[int]]:
    """Greedy partition of leaf indices into ~equal-byte buckets."""
    leaves = jax.tree.leaves(tree)
    sizes = [(i, l.size * l.dtype.itemsize) for i, l in enumerate(leaves)]
    sizes.sort(key=lambda t: -t[1])
    buckets: List[List[int]] = [[] for _ in range(max(1, n_buckets))]
    loads = [0] * len(buckets)
    for i, b in sizes:
        j = loads.index(min(loads))
        buckets[j].append(i)
        loads[j] += b
    return [b for b in buckets if b]


def bucketed_psum(tree, axis_name: str, plan: BucketPlan | None = None,
                  backward_seconds: float = 0.05, group_size: int = 16):
    """psum a pytree in REMOP-planned buckets (inside shard_map).

    Each bucket is flattened into one f32 vector => one all-reduce round.
    """
    leaves, treedef = jax.tree.flatten(tree)
    total = sum(l.size * 4 for l in leaves)
    if plan is None:
        plan = plan_grad_buckets(total, backward_seconds, group_size)
    buckets = partition_buckets(tree, plan.n_buckets)
    out: List[Any] = [None] * len(leaves)
    for idx in buckets:
        flat = jnp.concatenate(
            [leaves[i].astype(jnp.float32).reshape(-1) for i in idx])
        flat = jax.lax.psum(flat, axis_name)  # 1 round
        off = 0
        for i in idx:
            n = leaves[i].size
            out[i] = flat[off:off + n].reshape(leaves[i].shape).astype(
                leaves[i].dtype)
            off += n
    return jax.tree.unflatten(treedef, out)


def hierarchical_grad_reduce(tree, intra_axis: str, inter_axis: str | None):
    """Reduce-scatter intra-pod, all-reduce across pods, all-gather intra-pod.

    The canonical multi-pod schedule: the slow inter-pod hop moves only
    1/pod_size of the bytes.  Usable inside shard_map with both axes manual.
    """
    def one(g):
        g = g.astype(jnp.float32)
        flat = g.reshape(-1)
        # psum of a literal 1 is the canonical static axis-size idiom (the
        # pinned jax has no lax.axis_size).
        n = jax.lax.psum(1, intra_axis)
        pad = (-flat.size) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        shard = jax.lax.psum_scatter(flat.reshape(n, -1), intra_axis,
                                     scatter_dimension=0, tiled=False)
        if inter_axis is not None:
            shard = jax.lax.psum(shard, inter_axis)
        full = jax.lax.all_gather(shard, intra_axis, tiled=False).reshape(-1)
        if pad:
            full = full[:-pad]
        return full.reshape(g.shape)

    return jax.tree.map(one, tree)
