"""Host-DRAM offload with REMOP-planned chunking (the PCIe tier).

Host memory is the third "remote" tier (DESIGN.md §3): each transfer pays a
descriptor/launch overhead (~20 us) on top of ~16 GB/s PCIe bandwidth, so
chunk count is a round count.  ``plan_offload_chunks`` picks the chunk size
minimizing L = D + tau_pcie * C subject to a pinned-staging budget;
``HostOffloader`` applies it to activation/KV pytrees with double-buffered
(async dispatch) device->host copies.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax

from repro.core.cost_model import TPU_TIERS


@dataclasses.dataclass(frozen=True)
class OffloadPlan:
    chunk_bytes: int
    n_chunks: int
    d_bytes: float
    c_rounds: float
    l_cost: float


def plan_offload_chunks(total_bytes: int, staging_budget: int = 256 << 20,
                        min_chunk: int = 1 << 20) -> OffloadPlan:
    """Chunk size for one offloaded tensor set: fewest rounds that fit staging.

    D is fixed (= total bytes); only C moves, so the optimum is the largest
    chunk the pinned staging buffer allows — the min-C-subject-to-budget shape
    of Property 5 (double buffering halves the usable staging).
    """
    tier = TPU_TIERS["pcie_host"]
    usable = max(staging_budget // 2, min_chunk)  # double buffer
    chunk = min(usable, total_bytes) or min_chunk
    n = max(1, math.ceil(total_bytes / chunk))
    d = float(total_bytes)
    c = float(n)
    return OffloadPlan(chunk_bytes=int(chunk), n_chunks=n, d_bytes=d,
                       c_rounds=c, l_cost=d + tier.tau_bytes * c)


class HostOffloader:
    """Move pytrees to host and back in planned chunks.

    On CPU-only containers this degrades to host<->host copies but preserves
    the exact call structure (device_put with donation, per-chunk rounds) so
    the policy and bookkeeping are testable.
    """

    def __init__(self, staging_budget: int = 256 << 20):
        self.staging_budget = staging_budget
        self.rounds = 0
        self.bytes_moved = 0
        self._store: dict[int, Any] = {}
        self._next = 0

    def offload(self, tree) -> int:
        """Device -> host. Returns a handle."""
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = []
        for leaf in leaves:
            nbytes = leaf.size * leaf.dtype.itemsize
            plan = plan_offload_chunks(nbytes, self.staging_budget)
            self.rounds += plan.n_chunks
            self.bytes_moved += nbytes
            host_leaves.append(jax.device_get(leaf))
        handle = self._next
        self._store[handle] = (treedef, host_leaves)
        self._next += 1
        return handle

    def restore(self, handle: int, device=None):
        """Host -> device (frees the host copy)."""
        treedef, host_leaves = self._store.pop(handle)
        dev_leaves = []
        for leaf in host_leaves:
            nbytes = leaf.size * leaf.dtype.itemsize
            plan = plan_offload_chunks(nbytes, self.staging_budget)
            self.rounds += plan.n_chunks
            self.bytes_moved += nbytes
            dev_leaves.append(jax.device_put(leaf, device))
        return jax.tree.unflatten(treedef, dev_leaves)
