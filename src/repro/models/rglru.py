"""RG-LRU recurrent block (RecurrentGemma / Griffin).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t), with a_t = a^(c * r_t),
a = sigmoid(Λ) a learned per-channel constant, r_t/i_t input-dependent gates.
The full-sequence form uses an associative scan (parallel prefix) — linear
recurrences compose associatively — so prefill is O(S log S) parallel work
instead of a length-S sequential loop.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import init_dense, dense, truncated_normal

_C = 8.0  # temperature from the Griffin paper


def init_rglru(key, cfg: ModelConfig) -> Dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    # Λ init so that a = sigmoid(Λ) ∈ [0.9, 0.999] as in the paper.
    u = jax.random.uniform(ks[0], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(u ** (1 / _C)) - jnp.log1p(-(u ** (1 / _C)))
    return {
        "w_x": init_dense(ks[1], d, w),
        "w_gate": init_dense(ks[2], d, w),
        "conv": {"w": truncated_normal(ks[3], (cfg.conv_width, w), 0.1)},
        "a_param": lam,
        "a_gate": {"w": truncated_normal(ks[4], (w, w), 1.0 / math.sqrt(w))},
        "x_gate": {"w": truncated_normal(ks[5], (w, w), 1.0 / math.sqrt(w))},
        "w_out": init_dense(ks[0], w, d),
    }


def _gates(p: Dict, xb: jnp.ndarray):
    r = jax.nn.sigmoid(xb @ p["a_gate"]["w"].astype(xb.dtype))
    i = jax.nn.sigmoid(xb @ p["x_gate"]["w"].astype(xb.dtype))
    log_a = -_C * jax.nn.softplus(-p["a_param"].astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i.astype(jnp.float32) * xb.astype(jnp.float32)
    )
    return a, gated


def _conv(p, x, state=None):
    w = p["conv"]["w"]
    width = w.shape[0]
    pad = (jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(width))
    return y, xp[:, -(width - 1):]


def rglru_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                  initial_h: jnp.ndarray | None = None,
                  return_state: bool = False):
    """Full-sequence RG-LRU block. x: [B,S,d]."""
    xb = dense(p["w_x"], x)
    gate_branch = jax.nn.gelu(dense(p["w_gate"], x), approximate=True)
    xb, conv_state = _conv(p, xb)
    xb = constrain(xb, ("batch", None, "ff"))
    a, gated = _gates(p, xb)

    if initial_h is not None:
        # Fold h0 in as a virtual step 0 with a=1 for position 0 handled below.
        gated = gated.at[:, 0].add(a[:, 0] * initial_h.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b2 + a2 * b1

    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = (h.astype(x.dtype) * gate_branch)
    out = dense(p["w_out"], y)
    if return_state:
        return out, (conv_state, h[:, -1])
    return out


def rglru_decode(p: Dict, cfg: ModelConfig, x_t: jnp.ndarray,
                 cache: Tuple[jnp.ndarray, jnp.ndarray]):
    """One-token step. cache = (conv_state [B,W-1,w], h [B,w])."""
    conv_state, h = cache
    xb = dense(p["w_x"], x_t)
    gate_branch = jax.nn.gelu(dense(p["w_gate"], x_t), approximate=True)
    xb, conv_state = _conv(p, xb, conv_state)
    a, gated = _gates(p, xb)
    h_new = a[:, 0] * h.astype(jnp.float32) + gated[:, 0]
    y = h_new[:, None, :].astype(x_t.dtype) * gate_branch
    return dense(p["w_out"], y), (conv_state, h_new)


def rglru_cache_shapes(cfg: ModelConfig, batch: int):
    return (batch, cfg.conv_width - 1, cfg.lru_width), (batch, cfg.lru_width)
