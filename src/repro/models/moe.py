"""Mixture-of-Experts layer (top-k routing, capacity-based dispatch).

The dispatch is the EHJ analogue (DESIGN.md §3): tokens are radix-partitioned
across experts; tokens routed to experts on other chips are the "spilled
partitions" that must be staged and moved by all-to-all.  The staging-pool
sizing lives in ``core/planner.plan_dispatch`` and the TPU-native kernel in
``kernels/dispatch``; here the dense-math dispatch uses static capacity so the
layer shards cleanly under GSPMD (experts on the ``model``/EP axis).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

# jax >= 0.5 exposes shard_map at the top level with a ``check_vma`` kwarg;
# 0.4.x has it under jax.experimental with the same check named ``check_rep``.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_NOCHECK = {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_NOCHECK = {"check_rep": False}

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain, current_sharder
from repro.models.layers import init_mlp, mlp, truncated_normal

# MoE execution strategy:
#   "gspmd"        — batch-grouped dispatch under the SPMD partitioner
#                    (baseline; GSPMD re-gathers the expert dim around the
#                    dispatch scatter — measured in §Perf).
#   "ep_shard_map" — manual expert parallelism: each model-axis shard keeps
#                    its E/ep local experts, routes ALL local tokens against
#                    them (mask + local scatter), and partial outputs are
#                    psum-combined.  No expert-dim resharding ever happens;
#                    the cross-shard traffic is one activation-sized psum per
#                    layer — the EHJ "spilled partitions join locally, ship
#                    results once" schedule.
_MOE_IMPL = "gspmd"


def set_moe_impl(name: str) -> None:
    global _MOE_IMPL
    assert name in ("gspmd", "ep_shard_map")
    _MOE_IMPL = name


def init_moe(key, cfg: ModelConfig) -> Dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": truncated_normal(ks[0], (d, e), 1.0 / math.sqrt(d))},
        "experts": {
            "w_gate": truncated_normal(ks[1], (e, d, ff), 1.0 / math.sqrt(d)),
            "w_up": truncated_normal(ks[2], (e, d, ff), 1.0 / math.sqrt(d)),
            "w_down": truncated_normal(ks[3], (e, ff, d), 1.0 / math.sqrt(ff)),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared_experts * ff, "swiglu")
    return p


def topk_route(router_logits: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (weights [T,k], expert_ids [T,k], aux_loss scalar)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss: E * sum_e f_e * P_e.
    e = router_logits.shape[-1]
    f = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=1), axis=0
    )
    p_mean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * p_mean)
    return weights.astype(jnp.bfloat16), ids, aux


def moe_apply(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
              capacity_factor: float | None = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,S,d] -> (y, aux_loss).  Batch-grouped static-capacity dispatch.

    Capacity is per sequence (GShard-style group-local dropping), so the
    dispatch scatter is batch-local: under pjit the batch dim stays on the
    data axis and experts on the model (EP) axis, the expert matmuls contract
    the unsharded d_ff dim, and the only cross-device movement is the
    expert_in/out resharding — the EHJ "spilled partition" all-to-all
    (DESIGN.md §3), whose staging budget core.planner.plan_dispatch sizes.
    """
    capacity_factor = capacity_factor or cfg.capacity_factor
    sharder = current_sharder()
    if (_MOE_IMPL == "ep_shard_map" and sharder is not None
            and "model" in sharder.axis_sizes
            and cfg.n_experts % sharder.axis_sizes["model"] == 0):
        return _moe_apply_ep(p, cfg, x, capacity_factor, sharder)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    # SP exit: gather the sequence locally (batch stays on the data axis) so
    # routing cumsums and the dispatch scatter are device-local — otherwise
    # GSPMD replicates the [B, S*k, E] position tensors across the mesh.
    x = constrain(x, ("batch", None, None))
    logits = x @ p["router"]["w"].astype(x.dtype)  # [B,S,E]
    logits = constrain(logits, ("batch", None, None))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, k)  # [B,S,k]
    weights = (weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
               ).astype(x.dtype)
    # Switch-style load-balance aux loss over the global batch.
    f_frac = jnp.mean(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=(0, 1, 2))
    aux = e * jnp.sum(f_frac * jnp.mean(probs, axis=(0, 1)))

    capacity = max(1, int(capacity_factor * s * k / e))
    a_r = s * k
    flat_ids = ids.reshape(b, a_r)  # token-major, choice-minor
    one_hot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)  # [B, A, E]
    pos = jnp.cumsum(one_hot, axis=1) - 1
    pos_in_e = jnp.take_along_axis(pos, flat_ids[..., None], axis=2)[..., 0]
    keep = pos_in_e < capacity
    safe_pos = jnp.where(keep, pos_in_e, capacity - 1)

    tok_idx = jnp.repeat(jnp.arange(s), k)  # [A], same for every row
    updates = jnp.where(keep[..., None], x[:, tok_idx, :], 0)
    updates = constrain(updates, ("batch", None, None))

    def scatter_row(ids_r, pos_r, upd_r):
        return jnp.zeros((e, capacity, d), x.dtype).at[ids_r, pos_r].add(upd_r)

    expert_in = jax.vmap(scatter_row)(flat_ids, safe_pos, updates)
    expert_in = constrain(expert_in, ("batch", "expert", None, None))

    w_g = p["experts"]["w_gate"].astype(x.dtype)
    w_u = p["experts"]["w_up"].astype(x.dtype)
    w_d = p["experts"]["w_down"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in, w_g))
    h = h * jnp.einsum("becd,edf->becf", expert_in, w_u)
    h = constrain(h, ("batch", "expert", None, None))
    expert_out = jnp.einsum("becf,efd->becd", h, w_d)
    expert_out = constrain(expert_out, ("batch", "expert", None, None))

    def gather_row(out_r, ids_r, pos_r):
        return out_r[ids_r, pos_r]

    gathered = jax.vmap(gather_row)(expert_out, flat_ids, safe_pos)  # [B,A,d]
    gathered = jnp.where(keep[..., None], gathered, 0)
    gathered = constrain(gathered, ("batch", None, None))
    # Combine: assignments are (token-major, choice-minor) => pure reshape.
    y = (gathered.reshape(b, s, k, d)
         * weights[..., None]).sum(axis=2)

    if "shared" in p:
        y = y + mlp(p["shared"], x, "swiglu")
    return y, aux.astype(jnp.float32)


def _route(x2d, router_w, k):
    """Shared routing math: returns (weights [T,k], ids [T,k], aux scalar)."""
    logits = x2d @ router_w.astype(x2d.dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = (weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
               ).astype(x2d.dtype)
    e = probs.shape[-1]
    f_frac = jnp.mean(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(f_frac * jnp.mean(probs, axis=0))
    return weights, ids, aux


def _moe_apply_ep(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                  capacity_factor: float, sharder) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Manual-EP MoE: local experts per model shard + psum combine."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    mesh = sharder.mesh
    ep = sharder.axis_sizes["model"]
    e_loc = e // ep
    capacity = max(1, int(capacity_factor * s * k / e))
    x = constrain(x, ("batch", None, None))  # SP exit; model-replicated

    w_g, w_u, w_d = (p["experts"]["w_gate"], p["experts"]["w_up"],
                     p["experts"]["w_down"])
    router_w = p["router"]["w"]

    batch_axes = tuple(a for a in ("pod", "data") if a in sharder.axis_sizes)

    def local(xb, rw, wgb, wub, wdb):
        # Full-manual: xb is this shard's [B_loc, S, d] batch slice (replicated
        # across model); expert weights are this shard's [e_loc, ...] slice.
        # (Partial-manual shard_map triggers an XLA-CPU crash in
        # AllReducePromotion via a copy-combiner all-reduce; full manual is
        # the mature path and costs nothing here.)
        my = jax.lax.axis_index("model")
        bb, ss, dd = xb.shape
        wgt, ids, aux = _route(xb.reshape(bb * ss, dd), rw, k)
        wgt = wgt.reshape(bb, ss * k)
        ids_loc = ids.reshape(bb, ss * k) - my * e_loc
        mask = (ids_loc >= 0) & (ids_loc < e_loc)
        safe_ids = jnp.where(mask, ids_loc, 0)
        one_hot = jax.nn.one_hot(safe_ids, e_loc, dtype=jnp.int32)
        one_hot = one_hot * mask[..., None].astype(jnp.int32)
        pos = jnp.cumsum(one_hot, axis=1) - 1
        pos_in = jnp.take_along_axis(pos, safe_ids[..., None], axis=2)[..., 0]
        keep = mask & (pos_in < capacity)
        safe_pos = jnp.where(keep, pos_in, capacity - 1)
        tok = jnp.repeat(jnp.arange(ss), k)
        upd = jnp.where(keep[..., None], xb[:, tok, :], 0)

        def scatter_row(ids_r, pos_r, upd_r):
            return jnp.zeros((e_loc, capacity, dd), xb.dtype).at[
                ids_r, pos_r].add(upd_r)

        expert_in = jax.vmap(scatter_row)(safe_ids, safe_pos, upd)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in,
                                   wgb.astype(xb.dtype)))
        h = h * jnp.einsum("becd,edf->becf", expert_in, wub.astype(xb.dtype))
        out = jnp.einsum("becf,efd->becd", h, wdb.astype(xb.dtype))

        def gather_row(out_r, ids_r, pos_r):
            return out_r[ids_r, pos_r]

        rows = jax.vmap(gather_row)(out, safe_ids, safe_pos)
        rows = jnp.where(keep[..., None], rows, 0) * wgt[..., None]
        y = rows.reshape(bb, ss, k, dd).sum(axis=2)
        # Return f32 from the manual region: XLA CPU's AllReducePromotion
        # pass crashes cloning the bf16 copy-combiner all-reduce that GSPMD
        # emits at the shard_map exit; f32 outputs sidestep the pass (and the
        # f32 psum avoids precision loss in the combine anyway).
        y = jax.lax.psum(y.astype(jnp.float32), "model")
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return y, aux

    y, aux = _shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_axes if batch_axes else None, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(batch_axes if batch_axes else None, None, None), P()),
        **_SHARD_MAP_NOCHECK,
    )(x, router_w, w_g, w_u, w_d)
    if batch_axes:
        aux = aux  # identical across batch shards (same formula per shard mean)
    y = y.astype(x.dtype)
    if "shared" in p:
        y = y + mlp(p["shared"], x, "swiglu")
    return y, aux.astype(jnp.float32)
