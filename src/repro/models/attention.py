"""Attention variants: GQA/MQA (optionally windowed, qk-norm) and MLA.

Long sequences stream KV in chunks with an online softmax (the EMS-style
"merge of sorted runs" becomes a merge of partial softmax statistics); this
bounds activation memory to O(S * chunk) and is the pure-jnp oracle shape for
the Pallas flash/paged kernels in ``repro.kernels``.

Decode paths:
  * GQA: ring/linear KV cache [B, W_or_S, KV, hd], positions tracked modulo
    the window for local attention.
  * MLA: compressed cache (c_kv, k_rope) with the absorbed-weight trick —
    scores and context are computed in the kv_lora space, so the per-step
    cost is O(S * kv_lora) instead of materializing per-head K/V.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import apply_rope, init_dense, init_rmsnorm, dense, rmsnorm, rope_tables

NEG_INF = -1e30
_CHUNK_THRESHOLD = 8192
_KV_CHUNK = 1024

# int8 KV-cache quantization (decode): cache = (k_q, v_q, k_scale, v_scale)
# with per-(token, head) scales.  Halves cache residency + read bandwidth —
# the REMOP D-term lever once the round count is already minimal.
KV_QUANT = False


def set_kv_quant(flag: bool) -> None:
    global KV_QUANT
    KV_QUANT = flag


def quantize_kv(x):
    """x: [..., hd] -> (int8 values, bf16 scale[..., 1])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# shared attention math (grouped heads, causal + window masking)
# ---------------------------------------------------------------------------


def _mask(q_pos, kv_pos, window: int):
    m = q_pos[..., :, None] >= kv_pos[..., None, :]
    if window:
        m &= (q_pos[..., :, None] - kv_pos[..., None, :]) < window
    return m


def full_attention(q, k, v, q_pos, kv_pos, window: int = 0,
                   softcap: float = 0.0) -> jnp.ndarray:
    """q: [B,S,KV,G,hd]; k/v: [B,T,KV,hd] -> [B,S,KV,G,hd]."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = _mask(q_pos, kv_pos, window)[:, None, None, :, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)


def chunked_attention(q, k, v, q_pos, kv_pos, window: int = 0,
                      softcap: float = 0.0, chunk: int = _KV_CHUNK) -> jnp.ndarray:
    """Online-softmax attention streaming KV in chunks (flash-style oracle).

    K and V may have different head dims (MLA: 192-d keys, 128-d values).
    """
    b, s_len, kv_h, g, hd_k = q.shape
    hd_v = v.shape[-1]
    t = k.shape[1]
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=10 ** 9)
    k = k.reshape(b, n_chunks, chunk, kv_h, hd_k).transpose(1, 0, 2, 3, 4)
    v = v.reshape(b, n_chunks, chunk, kv_h, hd_v).transpose(1, 0, 2, 3, 4)
    kv_pos = kv_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    scale = 1.0 / math.sqrt(hd_k)

    m0 = jnp.full((b, kv_h, g, s_len), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv_h, g, s_len), jnp.float32)
    a0 = jnp.zeros((b, s_len, kv_h, g, hd_v), jnp.float32)

    def step(carry, xs):
        m, l, acc = carry
        kc, vc, pc = xs
        s = jnp.einsum("bskgh,bckh->bkgsc", q, kc).astype(jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = _mask(q_pos, pc, window)[:, None, None, :, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
            "bkgsc,bckh->bskgh", p, vc.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (k, v, kv_pos))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.astype(q.dtype)


def grouped_attention(q, k, v, q_pos, kv_pos, window: int = 0,
                      softcap: float = 0.0) -> jnp.ndarray:
    if k.shape[1] > _CHUNK_THRESHOLD:
        return chunked_attention(q, k, v, q_pos, kv_pos, window, softcap)
    return full_attention(q, k, v, q_pos, kv_pos, window, softcap)


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, window: int = 0) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, h * hd),
        "wk": init_dense(ks[1], d, kv * hd),
        "wv": init_dense(ks[2], d, kv * hd),
        "wo": init_dense(ks[3], h * hd, d, scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _gqa_qkv(p: Dict, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(b, s, h, hd)
    k = dense(p["wk"], x).reshape(b, s, kv, hd)
    v = dense(p["wv"], x).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_forward(
    p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray,
    window: int = 0, mask_pos: Optional[jnp.ndarray] = None,
    xa: Optional[jnp.ndarray] = None, return_kv: bool = False,
):
    """Full-sequence attention; optionally cross-attention over ``xa``.

    ``positions`` drive RoPE; ``mask_pos`` (default = positions) drives the
    causal mask — decoupling them implements prefix-LM (VLM) and bidirectional
    (encoder) masking with the same kernel.
    """
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if xa is None:
        q, k, v = _gqa_qkv(p, cfg, x, positions)
        q_pos = kv_pos = positions if mask_pos is None else mask_pos
    else:  # cross-attention: keys/values from encoder output, no causal mask
        q = dense(p["wq"], x).reshape(b, s, h, hd)
        k = dense(p["wk"], xa).reshape(b, xa.shape[1], kv, hd)
        v = dense(p["wv"], xa).reshape(b, xa.shape[1], kv, hd)
        q_pos = jnp.full((b, s), 10 ** 9, jnp.int32)
        kv_pos = jnp.zeros((b, xa.shape[1]), jnp.int32)
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    qg = constrain(qg, ("batch", None, "heads", None, None))
    out = grouped_attention(qg, k, v, q_pos, kv_pos, window, cfg.attn_softcap)
    out = out.reshape(b, s, h * hd)
    out = dense(p["wo"], out)
    if return_kv:
        return out, (k, v)
    return out


def gqa_decode(
    p: Dict, cfg: ModelConfig, x: jnp.ndarray, cache: Tuple[jnp.ndarray, jnp.ndarray],
    pos: jnp.ndarray, window: int = 0,
):
    """One-token decode with a (possibly ring) KV cache.

    cache: (k, v) of shape [B, Scache, KV, hd]; for windowed attention Scache
    is the window and writes wrap (ring buffer).  ``pos`` is a scalar step.
    """
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    q, k_t, v_t = _gqa_qkv(p, cfg, x, positions)
    quantized = len(cache) == 4
    if quantized:
        ckq, cvq, cks, cvs = cache
        s_cache = ckq.shape[1]
        slot = (pos % s_cache) if window else jnp.minimum(pos, s_cache - 1)
        kq, ks_t = quantize_kv(k_t)
        vq, vs_t = quantize_kv(v_t)
        ckq = jax.lax.dynamic_update_slice(ckq, kq, (0, slot, 0, 0))
        cvq = jax.lax.dynamic_update_slice(cvq, vq, (0, slot, 0, 0))
        cks = jax.lax.dynamic_update_slice(cks, ks_t, (0, slot, 0, 0))
        cvs = jax.lax.dynamic_update_slice(cvs, vs_t, (0, slot, 0, 0))
        ck = dequantize_kv(ckq, cks, x.dtype)
        cv = dequantize_kv(cvq, cvs, x.dtype)
        new_cache = (ckq, cvq, cks, cvs)
    else:
        ck, cv = cache
        s_cache = ck.shape[1]
        slot = (pos % s_cache) if window else jnp.minimum(pos, s_cache - 1)
        ck = jax.lax.dynamic_update_slice(ck, k_t.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v_t.astype(cv.dtype), (0, slot, 0, 0))
        new_cache = None
    idx = jnp.arange(s_cache)
    if window:
        slot_pos = pos - ((pos - idx) % s_cache)  # position held by each slot
        valid = slot_pos >= 0
        kv_pos = jnp.where(valid, slot_pos, 10 ** 9)  # future => masked
    else:
        kv_pos = jnp.where(idx <= pos, idx, 10 ** 9)  # future => masked
    kv_pos = jnp.broadcast_to(kv_pos[None], (b, s_cache))
    qg = q.reshape(b, 1, kv, h // kv, hd)
    out = full_attention(qg, ck, cv, positions, kv_pos, window, cfg.attn_softcap)
    out = out.reshape(b, 1, h * hd)
    return dense(p["wo"], out), (new_cache if quantized else (ck, cv))


def gqa_cache_shape(cfg: ModelConfig, batch: int, seq: int, window: int = 0):
    # Ring caches are always window-sized (slots = pos % window).
    s = window if window else seq
    return (batch, s, cfg.n_kv_heads, cfg.head_dim)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    nope, rope_d, v_hd, lora = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq": init_dense(ks[0], d, h * (nope + rope_d)),
        "w_dkv": init_dense(ks[1], d, lora),
        "kv_norm": init_rmsnorm(lora),
        "w_uk": init_dense(ks[2], lora, h * nope),
        "w_uv": init_dense(ks[3], lora, h * v_hd),
        "w_kr": init_dense(ks[4], d, rope_d),
        "wo": init_dense(ks[5], h * v_hd, d, scale=1.0 / math.sqrt(h * v_hd)),
    }


def _mla_q(p, cfg, x, positions):
    b, s, _ = x.shape
    h, nope, rope_d = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    q = dense(p["wq"], x).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_tables(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_ckv(p, cfg, x, positions):
    c_kv = rmsnorm(p["kv_norm"], dense(p["w_dkv"], x), cfg.norm_eps)
    k_rope = dense(p["w_kr"], x)[:, :, None, :]  # single shared rope head
    cos, sin = rope_tables(positions, cfg.rope_head_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray,
                return_cache: bool = False):
    b, s, _ = x.shape
    h, nope, rope_d, v_hd = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_ckv(p, cfg, x, positions)
    k_nope = dense(p["w_uk"], c_kv).reshape(b, s, h, nope)
    v = dense(p["w_uv"], c_kv).reshape(b, s, h, v_hd)
    # Pack rope part into the per-head K (shared across heads) and attend.
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rope_d))], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    qg = q_full.reshape(b, s, h, 1, nope + rope_d)
    qg = constrain(qg, ("batch", None, "heads", None, None))
    out = grouped_attention(qg, k_full, v, positions, positions, 0, cfg.attn_softcap)
    out = out.reshape(b, s, h * v_hd)
    out = dense(p["wo"], out)
    if return_cache:
        return out, (c_kv, k_rope)
    return out


def mla_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
               cache: Tuple[jnp.ndarray, jnp.ndarray], pos: jnp.ndarray):
    """Absorbed-weight decode over the compressed cache.

    cache: (c_kv [B,S,lora], k_rope [B,S,rope_d]).  Cost per step is
    O(S * (lora + rope_d)) per head — the MLA selling point.
    """
    b = x.shape[0]
    h, nope, rope_d, v_hd, lora = (cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim,
                                   cfg.v_head_dim, cfg.kv_lora_rank)
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_t, kr_t = _mla_ckv(p, cfg, x, positions)
    c_cache, r_cache = cache
    s_cache = c_cache.shape[1]
    c_cache = jax.lax.dynamic_update_slice(c_cache, c_t.astype(c_cache.dtype), (0, pos, 0))
    r_cache = jax.lax.dynamic_update_slice(r_cache, kr_t.astype(r_cache.dtype), (0, pos, 0))
    # Absorb W_uk into q: q_abs[b,h,l] = sum_n q_nope[b,h,n] W_uk[l,(h,n)].
    w_uk = p["w_uk"]["w"].astype(x.dtype).reshape(lora, h, nope)
    q_abs = jnp.einsum("bthn,lhn->bthl", q_nope, w_uk)
    scale = 1.0 / math.sqrt(nope + rope_d)
    s_lat = jnp.einsum("bthl,bsl->bhts", q_abs, c_cache.astype(x.dtype))
    s_rope = jnp.einsum("bthr,bsr->bhts", q_rope, r_cache.astype(x.dtype))
    scores = (s_lat + s_rope).astype(jnp.float32) * scale
    idx = jnp.arange(s_cache)
    mask = (idx <= pos)[None, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhts,bsl->bthl", attn, c_cache.astype(x.dtype))
    w_uv = p["w_uv"]["w"].astype(x.dtype).reshape(lora, h, v_hd)
    out = jnp.einsum("bthl,lhv->bthv", ctx, w_uv).reshape(b, 1, h * v_hd)
    return dense(p["wo"], out), (c_cache, r_cache)


def mla_cache_shapes(cfg: ModelConfig, batch: int, seq: int):
    return (batch, seq, cfg.kv_lora_rank), (batch, seq, cfg.rope_head_dim)
