"""Mamba-2 (SSD, state-space duality) block — chunked parallel form + decode.

The chunked SSD algorithm (Dao & Gu, 2024) splits the sequence into Q-length
chunks: intra-chunk terms are dense matmuls (MXU-friendly), inter-chunk terms
pass a [H, P, N] state through a short sequential scan over chunks.  This is
the EMS analogue at the model level: chunk size trades the number of
inter-chunk passes (rounds) against intra-chunk matmul volume.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import init_dense, dense, truncated_normal


def init_ssm(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    d_in = cfg.d_inner_ssm
    n_heads = cfg.n_ssm_heads
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    # in_proj packs [z, x, B, C, dt] like the reference implementation.
    d_proj = 2 * d_in + 2 * n + n_heads
    return {
        "w_in": init_dense(ks[0], d, d_proj),
        "conv": {"w": truncated_normal(ks[1], (cfg.conv_width, d_in + 2 * n), 0.1)},
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        "dt_bias": jnp.zeros((n_heads,)),
        "d_skip": jnp.ones((n_heads,)),
        "w_out": init_dense(ks[5], d_in, d),
    }


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    d_in, n, h = cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads
    z, xbc, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * n], axis=-1)
    return z, xbc, dt  # xbc = [x, B, C] fused for the conv


def _causal_conv(w: jnp.ndarray, x: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv; x [B,S,C], w [W,C]. Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else xp[:, :0]
    return jax.nn.silu(y), new_state


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """segsum(x)[..., i, j] = sum_{j < k <= i} x[..., k] (lower-triangular)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                initial_state: jnp.ndarray | None = None,
                return_state: bool = False):
    """Chunked SSD over the full sequence. x: [B,S,d]."""
    b, s, _ = x.shape
    d_in, n, h_dim = cfg.d_inner_ssm, cfg.ssm_state, cfg.ssm_head_dim
    h = cfg.n_ssm_heads
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"
    nc = s // q

    proj = dense(p["w_in"], x)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(p["conv"]["w"], xbc)
    xc, bmat, cmat = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # [H]
    da = dt * a  # [B,S,H]

    xh = xc.reshape(b, nc, q, h, h_dim)
    xh = constrain(xh, ("batch", None, None, "state", None))
    bm = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cm = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    dac = da.reshape(b, nc, q, h).transpose(0, 1, 3, 2)  # [B,nc,H,Q]
    dtc = dt.reshape(b, nc, q, h)

    # Intra-chunk (diagonal blocks): dense attention-like matmuls.
    l_mat = jnp.exp(_segsum(dac))  # [B,nc,H,Q,Q]
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcsh,bcshp->bclhp",
                        cm, bm, l_mat, dtc, xh.astype(jnp.float32))

    # Chunk-final states.
    a_cum = jnp.cumsum(dac, axis=-1)  # [B,nc,H,Q]
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # [B,nc,H,Q]
    states = jnp.einsum("bcsn,bchs,bcsh,bcshp->bchpn",
                        bm, decay_states, dtc, xh.astype(jnp.float32))

    # Inter-chunk recurrence (sequential over nc chunks).
    chunk_decay = jnp.exp(a_cum[..., -1])  # [B,nc,H]
    s0 = (jnp.zeros((b, h, h_dim, n), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def scan_fn(carry, xs):
        st, dec = xs  # [B,H,P,N], [B,H]
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    final_state, prev_states = jax.lax.scan(
        scan_fn, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    state_decay = jnp.exp(a_cum)  # decay from chunk start to position s
    y_off = jnp.einsum("bcln,bchl,bchpn->bclhp", cm, state_decay, prev_states)

    y = (y_diag + y_off).reshape(b, s, h, h_dim)
    y = y + xh.reshape(b, s, h, h_dim).astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = dense(p["w_out"], y)
    if return_state:
        return out, (conv_state, final_state)
    return out


def ssd_decode(p: Dict, cfg: ModelConfig, x_t: jnp.ndarray,
               cache: Tuple[jnp.ndarray, jnp.ndarray]):
    """Single-token recurrent step. x_t: [B,1,d]; cache=(conv_state, ssm_state)."""
    b = x_t.shape[0]
    d_in, n, h_dim = cfg.d_inner_ssm, cfg.ssm_state, cfg.ssm_head_dim
    h = cfg.n_ssm_heads
    conv_state, ssm_state = cache

    proj = dense(p["w_in"], x_t)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(p["conv"]["w"], xbc, conv_state)
    xc, bmat, cmat = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # [B,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # [B,H]

    xh = xc.reshape(b, h, h_dim).astype(jnp.float32)
    bm = bmat[:, 0].astype(jnp.float32)  # [B,N]
    cm = cmat[:, 0].astype(jnp.float32)
    ssm_state = (ssm_state.astype(jnp.float32) * da[..., None, None]
                 + jnp.einsum("bh,bn,bhp->bhpn", dt, bm, xh))
    y = jnp.einsum("bn,bhpn->bhp", cm, ssm_state)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, d_in).astype(x_t.dtype)
    y = y * jax.nn.silu(z)
    return dense(p["w_out"], y), (conv_state, ssm_state)


def ssm_cache_shapes(cfg: ModelConfig, batch: int):
    conv = (batch, cfg.conv_width - 1, cfg.d_inner_ssm + 2 * cfg.ssm_state)
    state = (batch, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
    return conv, state
