"""Building-block layers (pure-JAX, functional params-as-pytrees).

Conventions:
  * every init_* returns a nested dict of f32 arrays (master weights);
  * every apply casts to the compute dtype of its input;
  * activations are annotated with *logical* axis names through the sharding
    context (``repro.distributed.sharding``) so the same model code runs
    unsharded on one CPU device and fully sharded on the production mesh.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int) -> Dict:
    return {"scale": jnp.zeros((dim,), jnp.float32)}


def rmsnorm(p: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"])).astype(dtype)


# ---------------------------------------------------------------------------
# Dense / embeddings
# ---------------------------------------------------------------------------


def init_dense(key, in_dim: int, out_dim: int, scale: float | None = None) -> Dict:
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return {"w": truncated_normal(key, (in_dim, out_dim), scale)}


def dense(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"].astype(x.dtype)


def init_embedding(key, vocab: int, dim: int) -> Dict:
    # 1/sqrt(dim) so the sqrt(d)-scaled embedding has unit variance and the
    # tied unembedding produces O(1) logits at init.
    return {"table": truncated_normal(key, (vocab, dim), 1.0 / math.sqrt(dim))}


def embed(p: Dict, tokens: jnp.ndarray, scale_by_sqrt_dim: bool = False) -> jnp.ndarray:
    table = p["table"]
    x = jnp.take(table, tokens, axis=0).astype(jnp.bfloat16)
    if scale_by_sqrt_dim:
        x = x * jnp.asarray(math.sqrt(table.shape[-1]), x.dtype)
    return constrain(x, ("batch", "seq", None))


def unembed(p: Dict, x: jnp.ndarray, softcap: float = 0.0) -> jnp.ndarray:
    logits = x @ p["table"].astype(x.dtype).T
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return constrain(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, mlp_type: str = "swiglu") -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": init_dense(k2, d_model, d_ff),
        "w_down": init_dense(k3, d_ff, d_model, scale=1.0 / math.sqrt(d_ff)),
    }
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = init_dense(k1, d_model, d_ff)
    return p


def mlp(p: Dict, x: jnp.ndarray, mlp_type: str = "swiglu") -> jnp.ndarray:
    up = dense(p["w_up"], x)
    t = mlp_type if "w_gate" in p else "gelu"
    if t == "swiglu":
        act = jax.nn.silu(dense(p["w_gate"], x)) * up
    elif t == "geglu":
        act = jax.nn.gelu(dense(p["w_gate"], x), approximate=True) * up
    else:
        act = jax.nn.gelu(up, approximate=True)
    axes = ("batch", "seq", "ff") if act.ndim == 3 else ("batch", "ff")
    act = constrain(act, axes)
    return dense(p["w_down"], act)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_tables(positions: jnp.ndarray, dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given positions; returns (cos, sin) [..., dim/2]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; cos/sin: [..., seq, head_dim/2]."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token-mean cross entropy; stable in f32; vocab may be sharded."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
