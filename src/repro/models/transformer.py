"""Model assembly: block zoo + scan-over-layers LM for every assigned family.

Families:
  dense / moe      — decoder-only transformer (uniform or first-k-dense stacks)
  ssm              — Mamba-2 (SSD) stack, attention-free
  hybrid           — RecurrentGemma pattern (rec, rec, local-attn) repeating
  vlm              — dense decoder over [patch-stub ; text] with prefix mask
  audio_encdec     — encoder (bidirectional) + decoder (self + cross)

All stacks are `lax.scan` over layer-stacked params (fast compiles at 512
devices); training wraps the block in `jax.checkpoint` (full remat).
Caches are layer-stacked pytrees threaded through the decode scan.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rec_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    embed, init_dense, init_embedding, init_mlp, init_rmsnorm, dense, mlp,
    rmsnorm, softmax_xent, unembed,
)

Params = Dict[str, Any]

# When True, segment stacks run as Python loops instead of lax.scan.  Used by
# the dry-run's flop probes: XLA's cost_analysis counts a while-loop body
# ONCE regardless of trip count, so scanned models under-report flops/bytes;
# unrolled shallow probes + linear extrapolation recover the true totals.
_UNROLL = False

# Remat policy for the layer scan: None = full remat (save only carries);
# "dots" = save dot/matmul outputs (less recompute, more activation memory).
_REMAT_POLICY = None


def set_unroll(flag: bool) -> None:
    global _UNROLL
    _UNROLL = flag


def set_remat_policy(name) -> None:
    global _REMAT_POLICY
    _REMAT_POLICY = name


def _checkpoint(fn):
    if _REMAT_POLICY == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ===========================================================================
# Blocks
# ===========================================================================


def init_block(key, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": init_rmsnorm(cfg.d_model)}
    if kind in ("attn", "attn_local", "enc"):
        p["attn"] = attn.init_gqa(ks[0], cfg)
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type)
    elif kind == "mla":
        p["attn"] = attn.init_mla(ks[0], cfg)
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type)
    elif kind in ("moe", "mla_moe"):
        p["attn"] = (attn.init_mla(ks[0], cfg) if kind == "mla_moe"
                     else attn.init_gqa(ks[0], cfg))
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
    elif kind == "rec":
        p["rec"] = rec_mod.init_rglru(ks[0], cfg)
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type)
    elif kind == "cross":
        p["attn"] = attn.init_gqa(ks[0], cfg)
        p["norm_x"] = init_rmsnorm(cfg.d_model)
        p["xattn"] = attn.init_gqa(ks[1], cfg)
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_type)
    else:
        raise ValueError(kind)
    return p


def _window(cfg: ModelConfig, kind: str) -> int:
    return cfg.window if kind == "attn_local" else 0


def block_forward(
    p: Params, cfg: ModelConfig, kind: str, x: jnp.ndarray,
    positions: jnp.ndarray, mask_positions: jnp.ndarray,
    enc_out: Optional[jnp.ndarray] = None,
    want_cache: bool = False,
) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Returns (x_out, cache_or_None, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "attn_local", "moe"):
        out = attn.gqa_forward(p["attn"], cfg, h, positions,
                               window=_window(cfg, kind),
                               mask_pos=mask_positions, return_kv=want_cache)
        if want_cache:
            out, kv = out
            if kind == "attn_local" and cfg.window:
                kv = _ring_pack(kv, positions, cfg.window)
            cache = kv
        x = x + out
    elif kind == "enc":  # bidirectional: all mask positions equal
        out = attn.gqa_forward(p["attn"], cfg, h, positions,
                               mask_pos=jnp.zeros_like(mask_positions))
        x = x + out
    elif kind in ("mla", "mla_moe"):
        out = attn.mla_forward(p["attn"], cfg, h, positions, return_cache=want_cache)
        if want_cache:
            out, cache = out
        x = x + out
    elif kind == "ssm":
        out = ssm_mod.ssd_forward(p["ssm"], cfg, h, return_state=want_cache)
        if want_cache:
            out, state = out
            cache = state
        x = x + out
        return x, cache, aux
    elif kind == "rec":
        out = rec_mod.rglru_forward(p["rec"], cfg, h, return_state=want_cache)
        if want_cache:
            out, hstate = out
            cache = hstate
        x = x + out
    elif kind == "cross":
        out, kv_self = attn.gqa_forward(p["attn"], cfg, h, positions,
                                        mask_pos=mask_positions, return_kv=True)
        x = x + out
        hx = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        xout, kv_cross = attn.gqa_forward(p["xattn"], cfg, hx, positions,
                                          xa=enc_out, return_kv=True)
        x = x + xout
        if want_cache:
            cache = {"self": kv_self, "cross": kv_cross}
    # FFN half.
    if kind in ("moe", "mla_moe"):
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, aux = moe_mod.moe_apply(p["moe"], cfg, h2)
        x = x + y
    elif "mlp" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h2, cfg.mlp_type)
    x = constrain(x, ("batch", "seq", None))
    return x, cache, aux


def _ring_pack(kv, positions, window: int):
    """Pack the last `window` positions of (k, v) into ring-buffer layout."""
    k, v = kv
    s = k.shape[1]
    w = min(window, s)
    last_pos = positions[0, -w:]  # positions are shared across batch
    slots = last_pos % window

    def pack(a):
        ring = jnp.zeros((a.shape[0], window) + a.shape[2:], a.dtype)
        return ring.at[:, slots].set(a[:, -w:])

    return pack(k), pack(v)


def block_decode(
    p: Params, cfg: ModelConfig, kind: str, x: jnp.ndarray, cache: Any,
    pos: jnp.ndarray,
) -> Tuple[jnp.ndarray, Any]:
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "attn_local", "moe"):
        out, cache = attn.gqa_decode(p["attn"], cfg, h, cache, pos,
                                     window=_window(cfg, kind))
        x = x + out
    elif kind in ("mla", "mla_moe"):
        out, cache = attn.mla_decode(p["attn"], cfg, h, cache, pos)
        x = x + out
    elif kind == "ssm":
        out, cache = ssm_mod.ssd_decode(p["ssm"], cfg, h, cache)
        return x + out, cache
    elif kind == "rec":
        out, cache = rec_mod.rglru_decode(p["rec"], cfg, h, cache)
        x = x + out
    elif kind == "cross":
        out, kv_self = attn.gqa_decode(p["attn"], cfg, h, cache["self"], pos)
        x = x + out
        hx = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        ck, cv = cache["cross"]
        b = x.shape[0]
        qx = dense(p["xattn"]["wq"], hx).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        qg = qx.reshape(b, 1, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.head_dim)
        kv_pos = jnp.zeros((b, ck.shape[1]), jnp.int32)
        q_pos = jnp.full((b, 1), 10 ** 9, jnp.int32)
        xo = attn.full_attention(qg, ck, cv, q_pos, kv_pos)
        xo = dense(p["xattn"]["wo"], xo.reshape(b, 1, -1))
        x = x + xo
        cache = {"self": kv_self, "cross": (ck, cv)}
    if kind in ("moe", "mla_moe"):
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, _ = moe_mod.moe_apply(p["moe"], cfg, h2)
        x = x + y
    elif "mlp" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h2, cfg.mlp_type)
    return x, cache


# ===========================================================================
# Stack plans
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class StackSegment:
    kinds: Tuple[str, ...]  # block kinds inside one scan group
    repeats: int  # scan length


def stack_plan(cfg: ModelConfig) -> Tuple[StackSegment, ...]:
    """Decompose the layer list into scannable segments."""
    if cfg.family == "moe":
        kind = "mla_moe" if cfg.attn_type == "mla" else "moe"
        dense_kind = "mla" if cfg.attn_type == "mla" else "attn"
        segs = []
        if cfg.first_k_dense:
            segs.append(StackSegment((dense_kind,), cfg.first_k_dense))
        segs.append(StackSegment((kind,), cfg.n_layers - cfg.first_k_dense))
        return tuple(segs)
    if cfg.family == "ssm":
        return (StackSegment(("ssm",), cfg.n_layers),)
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn_local")
        n_groups, rem = divmod(cfg.n_layers, len(pat))
        segs = [StackSegment(tuple(pat), n_groups)] if n_groups else []
        if rem:
            head = tuple(pat[:rem])
            if len(set(head)) == 1:
                segs.append(StackSegment((head[0],), rem))
            else:
                segs.extend(StackSegment((k,), 1) for k in head)
        return tuple(segs)
    if cfg.attn_type == "mla":
        return (StackSegment(("mla",), cfg.n_layers),)
    # dense / vlm / decoder side of enc-dec
    return (StackSegment(("attn",), cfg.n_layers),)


def init_segment(key, cfg: ModelConfig, seg: StackSegment) -> Params:
    keys = jax.random.split(key, seg.repeats)

    def one(k):
        sub = jax.random.split(k, len(seg.kinds))
        return {f"b{i}_{kind}": init_block(sub[i], cfg, kind)
                for i, kind in enumerate(seg.kinds)}

    return jax.vmap(one)(keys)


def segment_forward(params: Params, cfg: ModelConfig, seg: StackSegment,
                    x, positions, mask_positions, enc_out=None,
                    want_cache=False, remat=False):
    def step(carry, layer_params):
        h, aux_total = carry
        caches = {}
        for i, kind in enumerate(seg.kinds):
            h, cache, aux = block_forward(
                layer_params[f"b{i}_{kind}"], cfg, kind, h, positions,
                mask_positions, enc_out=enc_out, want_cache=want_cache)
            aux_total = aux_total + aux
            caches[f"b{i}_{kind}"] = cache
        return (h, aux_total), (caches if want_cache else None)

    fn = _checkpoint(step) if remat else step
    if _UNROLL:
        carry = (x, jnp.zeros((), jnp.float32))
        cache_list = []
        for l in range(seg.repeats):
            layer_params = jax.tree.map(lambda a, l=l: a[l], params)
            carry, c = fn(carry, layer_params)
            cache_list.append(c)
        (x, aux) = carry
        caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
                  if want_cache else None)
        return x, aux, caches
    (x, aux), caches = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), params)
    return x, aux, caches


def segment_decode(params: Params, cfg: ModelConfig, seg: StackSegment,
                   x, caches, pos):
    def step(h, xs):
        layer_params, layer_cache = xs
        new_caches = {}
        for i, kind in enumerate(seg.kinds):
            name = f"b{i}_{kind}"
            h, c = block_decode(layer_params[name], cfg, kind, h,
                                layer_cache[name], pos)
            new_caches[name] = c
        return h, new_caches

    if _UNROLL:
        cache_list = []
        for l in range(seg.repeats):
            xs_l = jax.tree.map(lambda a, l=l: a[l], (params, caches))
            x, c = step(x, xs_l)
            cache_list.append(c)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
    x, new_caches = jax.lax.scan(step, x, (params, caches))
    return x, new_caches


# ===========================================================================
# Whole-model: init / loss / prefill / decode
# ===========================================================================


def _decoder_segments(cfg: ModelConfig):
    if cfg.n_encoder_layers:
        return (StackSegment(("cross",), cfg.n_layers),)
    return stack_plan(cfg)


def init_params(key, cfg: ModelConfig) -> Params:
    segs = _decoder_segments(cfg)
    ks = jax.random.split(key, len(segs) + 4)
    p: Params = {"embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model),
                 "final_norm": init_rmsnorm(cfg.d_model)}
    for i, seg in enumerate(segs):
        p[f"seg{i}"] = init_segment(ks[i + 1], cfg, seg)
    if cfg.frontend:
        p["frontend"] = {"proj_in": init_dense(ks[-3], cfg.frontend_dim, cfg.d_model)}
    if cfg.n_encoder_layers:
        enc_seg = StackSegment(("enc",), cfg.n_encoder_layers)
        p["encoder"] = init_segment(ks[-2], cfg, enc_seg)
        p["enc_norm"] = init_rmsnorm(cfg.d_model)
    return p


def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Token (+frontend-stub) embedding; returns (x, positions, mask_positions)."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, scale_by_sqrt_dim=True)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    mask_positions = positions
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)  # [B, P, frontend_dim]
        px = dense(params["frontend"]["proj_in"], patches)
        x = jnp.concatenate([px, x], axis=1)
        p_len = patches.shape[1]
        s_tot = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s_tot, dtype=jnp.int32)[None], (b, s_tot))
        # Prefix-LM mask: image prefix is mutually visible.
        mask_positions = jnp.maximum(positions - p_len + 1, 0)
    return x, positions, mask_positions


def _encode(params, cfg: ModelConfig, batch):
    frames = batch["frames"].astype(jnp.bfloat16)  # [B, S_enc, frontend_dim]
    h = dense(params["frontend"]["proj_in"], frames)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    seg = StackSegment(("enc",), cfg.n_encoder_layers)
    h, _, _ = segment_forward(params["encoder"], cfg, seg, h, positions, positions)
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch, want_cache=False, remat=False):
    """Full-sequence forward; returns (logits, aux_loss, caches)."""
    enc_out = _encode(params, cfg, batch) if cfg.n_encoder_layers else None
    x, positions, mask_positions = _embed_inputs(params, cfg, batch)
    caches = []
    aux_total = jnp.zeros((), jnp.float32)
    for i, seg in enumerate(_decoder_segments(cfg)):
        x, aux, cache = segment_forward(
            params[f"seg{i}"], cfg, seg, x, positions, mask_positions,
            enc_out=enc_out, want_cache=want_cache, remat=remat)
        aux_total = aux_total + aux
        caches.append(cache)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.logit_softcap)
    return logits, aux_total, caches


def loss_fn(params, cfg: ModelConfig, batch, remat=True):
    logits, aux, _ = forward(params, cfg, batch, remat=remat)
    if cfg.family == "vlm":  # only text positions carry loss
        p_len = batch["patches"].shape[1]
        logits = logits[:, p_len:]
    loss = softmax_xent(logits[:, :-1], batch["targets"][:, 1:],
                        batch.get("mask", None))
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux": aux}


def prefill(params, cfg: ModelConfig, batch):
    """Returns (last_token_logits, caches) for subsequent decode."""
    logits, _, caches = forward(params, cfg, batch, want_cache=True)
    return logits[:, -1], caches


def decode_step(params, cfg: ModelConfig, caches, token, pos):
    """One decode step. token: [B] int32; pos: scalar int32 step index."""
    x = embed(params["embed"], token[:, None], scale_by_sqrt_dim=True)
    new_caches = []
    for i, seg in enumerate(_decoder_segments(cfg)):
        x, c = segment_decode(params[f"seg{i}"], cfg, seg, x, caches[i], pos)
        new_caches.append(c)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.logit_softcap)
    return logits[:, 0], new_caches


# ===========================================================================
# Cache specs (for dry-run ShapeDtypeStructs and serving allocation)
# ===========================================================================


def cache_struct(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16,
                 enc_len: int | None = None):
    """ShapeDtypeStruct pytree mirroring `prefill`'s cache output."""
    enc_len = enc_len or cfg.frontend_seq or seq

    def seg_cache(seg: StackSegment):
        layer = {}
        for i, kind in enumerate(seg.kinds):
            name = f"b{i}_{kind}"
            if kind in ("attn", "moe", "attn_local"):
                w = cfg.window if kind == "attn_local" else 0
                sh = attn.gqa_cache_shape(cfg, batch, seq, window=w)
                if attn.KV_QUANT:
                    scale_sh = sh[:-1] + (1,)
                    layer[name] = (
                        jax.ShapeDtypeStruct((seg.repeats,) + sh, jnp.int8),
                        jax.ShapeDtypeStruct((seg.repeats,) + sh, jnp.int8),
                        jax.ShapeDtypeStruct((seg.repeats,) + scale_sh, jnp.bfloat16),
                        jax.ShapeDtypeStruct((seg.repeats,) + scale_sh, jnp.bfloat16),
                    )
                else:
                    layer[name] = (jax.ShapeDtypeStruct((seg.repeats,) + sh, dtype),) * 2
            elif kind in ("mla", "mla_moe"):
                c_sh, r_sh = attn.mla_cache_shapes(cfg, batch, seq)
                layer[name] = (
                    jax.ShapeDtypeStruct((seg.repeats,) + c_sh, dtype),
                    jax.ShapeDtypeStruct((seg.repeats,) + r_sh, dtype),
                )
            elif kind == "ssm":
                conv, state = ssm_mod.ssm_cache_shapes(cfg, batch)
                layer[name] = (
                    jax.ShapeDtypeStruct((seg.repeats,) + conv, dtype),
                    jax.ShapeDtypeStruct((seg.repeats,) + state, jnp.float32),
                )
            elif kind == "rec":
                conv, h = rec_mod.rglru_cache_shapes(cfg, batch)
                layer[name] = (
                    jax.ShapeDtypeStruct((seg.repeats,) + conv, dtype),
                    jax.ShapeDtypeStruct((seg.repeats,) + h, jnp.float32),
                )
            elif kind == "cross":
                sh = attn.gqa_cache_shape(cfg, batch, seq)
                enc_sh = attn.gqa_cache_shape(cfg, batch, enc_len)
                layer[name] = {
                    "self": (jax.ShapeDtypeStruct((seg.repeats,) + sh, dtype),) * 2,
                    "cross": (jax.ShapeDtypeStruct((seg.repeats,) + enc_sh, dtype),) * 2,
                }
        return layer

    return [seg_cache(seg) for seg in _decoder_segments(cfg)]


def param_count(params) -> int:
    return int(sum(x.size for x in jax.tree.leaves(params)))


def active_param_count(params, cfg: ModelConfig) -> int:
    """Active params per token (MoE: top-k of routed experts + shared)."""
    total = param_count(params)
    if not cfg.n_experts:
        return total

    def expert_size(p):
        size = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(p)[0]:
            names = "/".join(str(getattr(k, "key", k)) for k in path)
            if "moe/experts" in names or "experts" in names:
                size += leaf.size
        return size

    e_total = expert_size(params)
    active_frac = cfg.experts_per_token / cfg.n_experts
    return int(total - e_total + e_total * active_frac)


def pad_caches(cfg: ModelConfig, caches, target_len: int):
    """Grow attention caches' seq axis to ``target_len`` (for decode headroom).

    KV/MLA caches gain zero padding on the seq axis; ring (windowed), SSM and
    RG-LRU caches are fixed-size and pass through untouched.
    """

    def pad_seq(a, axis):
        if a.shape[axis] >= target_len:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, target_len - a.shape[axis])
        return jnp.pad(a, widths)

    segs = _decoder_segments(cfg)
    out = []
    for seg, seg_cache in zip(segs, caches):
        new_seg = dict(seg_cache)
        for i, kind in enumerate(seg.kinds):
            name = f"b{i}_{kind}"
            c = seg_cache[name]
            if kind in ("attn", "moe"):
                new_seg[name] = tuple(pad_seq(a, 2) for a in c)  # 2- or 4-tuple
            elif kind in ("mla", "mla_moe"):
                new_seg[name] = tuple(pad_seq(a, 2) for a in c)
            elif kind == "cross":
                new_seg[name] = {"self": tuple(pad_seq(a, 2) for a in c["self"]),
                                 "cross": c["cross"]}
        out.append(new_seg)
    return out
