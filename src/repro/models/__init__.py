from repro.models import attention, layers, moe, rglru, ssm, transformer
