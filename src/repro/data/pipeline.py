"""Synthetic sharded data pipeline with a double-buffered host prefetcher.

The prefetch double buffer is the paper's §IV-E mechanism at the input layer:
while the device consumes batch i, the host thread builds and transfers batch
i+1, hiding the host->device "RTT".
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


def synthetic_batches(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0,
                      start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Deterministic per-step synthetic LM batches (resumable by step index)."""
    b, s = shape.global_batch, shape.seq_len
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        tokens = rng.integers(0, cfg.vocab_size, size=(b, s), dtype=np.int32)
        batch = {"tokens": tokens, "targets": tokens}
        if cfg.family == "vlm":
            batch["patches"] = rng.standard_normal(
                (b, cfg.frontend_seq, cfg.frontend_dim), dtype=np.float32)
            batch["tokens"] = tokens[:, : s - cfg.frontend_seq]
            batch["targets"] = batch["tokens"]
        if cfg.family == "audio_encdec":
            batch["frames"] = rng.standard_normal(
                (b, s, cfg.frontend_dim), dtype=np.float32)
        yield batch
        step += 1


class PrefetchingLoader:
    """Double-buffered host->device loader (one worker, depth-2 queue)."""

    def __init__(self, iterator, shardings: Optional[Dict] = None, depth: int = 2):
        self._iter = iterator
        self._shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._shardings is None:
            return jax.tree.map(jnp.asarray, batch)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), batch, self._shardings)

    def _work(self):
        try:
            for batch in self._iter:
                if self._stop.is_set():
                    return
                self._q.put(self._place(batch))
        except Exception as e:  # surface in consumer
            self._q.put(e)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
