"""Simulated remote-memory tier (paper §IV-F, REMON/Infiniswap analogue).

Pages are real numpy arrays held in a remote store; operators move them in
*batched transfer rounds* through a :class:`repro.core.TransferLedger`, so the
paper's D/C accounting is measured, not assumed.  Latency follows Eq. (1)
exactly: ``D/BW + C*RTT`` with the tier's constants (Table I / Table IX).

The store is content-addressed by integer page ids; a relation or run is a
list of page ids.  ``read_batch``/``write_batch`` are the only ways data
crosses the boundary — one call is one transfer round, whatever its size,
mirroring REMON's batched evict/fetch interface.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence

import numpy as np

from repro.core.cost_model import TierSpec, TransferLedger


class RemoteMemory:
    """A remote tier holding pages, with round/volume accounting."""

    def __init__(self, tier: TierSpec):
        self.tier = tier
        self.ledger = TransferLedger()
        self._store: dict[int, np.ndarray] = {}
        self._next_id = 0

    # -- allocation ---------------------------------------------------------

    @property
    def pages_resident(self) -> int:
        """Number of pages currently held by the remote store."""
        return len(self._store)

    def put_local(self, pages: Sequence[np.ndarray]) -> List[int]:
        """Seed the store without accounting (initial data placement)."""
        ids = []
        for p in pages:
            self._store[self._next_id] = np.asarray(p)
            ids.append(self._next_id)
            self._next_id += 1
        return ids

    def peek_batch(self, page_ids: Sequence[int]) -> List[np.ndarray]:
        """Oracle-side reads without accounting (no transfer round)."""
        return [self._store[i] for i in page_ids]

    # -- batched transfer rounds ---------------------------------------------

    def read_batch(self, page_ids: Sequence[int], prefetched: bool = False) -> List[np.ndarray]:
        """One swap-in round: fetch a batch of pages (Definition 2)."""
        if not page_ids:
            return []
        self.ledger.read(float(len(page_ids)))
        if prefetched:
            self.ledger.c_prefetch_hidden += 1
        return [self._store[i] for i in page_ids]

    def write_batch(self, pages: Sequence[np.ndarray]) -> List[int]:
        """One flush-out round: write a batch of pages."""
        if not len(pages):
            return []
        ids = self.put_local(pages)
        self.ledger.write(float(len(pages)))
        return ids

    def free(self, page_ids: Iterable[int]) -> None:
        for i in page_ids:
            self._store.pop(i, None)

    # -- reporting ------------------------------------------------------------

    def latency_seconds(self, prefetch: bool = False) -> float:
        return self.ledger.latency_seconds(self.tier, prefetch=prefetch)

    def latency_cost(self) -> float:
        return self.ledger.latency_cost(self.tier.tau_pages)

    def reset_accounting(self) -> None:
        self.ledger.reset()


@dataclasses.dataclass
class Relation:
    """A paged relation: `pages[i]` is a page id; tuples are (key, payload)."""

    page_ids: List[int]
    rows_per_page: int
    total_rows: int

    def __len__(self) -> int:
        return len(self.page_ids)


def make_relation(
    remote: RemoteMemory,
    n_rows: int,
    rows_per_page: int,
    key_domain: int,
    payload_width: int = 1,
    seed: int = 0,
    sorted_keys: bool = False,
) -> Relation:
    """Materialize a synthetic relation in remote memory (§V-A b workloads).

    Keys are drawn uniformly from [0, key_domain); join selectivity between two
    such relations is ~1/key_domain per tuple pair, matching the paper's
    key-domain-controlled selectivity.
    """
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_domain, size=n_rows, dtype=np.int64)
    if sorted_keys:
        keys = np.sort(keys)
    payload = np.arange(n_rows, dtype=np.int64)[:, None] * np.ones(
        (1, payload_width), dtype=np.int64
    )
    pages = []
    for start in range(0, n_rows, rows_per_page):
        sl = slice(start, min(start + rows_per_page, n_rows))
        pages.append(np.concatenate([keys[sl, None], payload[sl]], axis=1))
    ids = remote.put_local(pages)
    return Relation(page_ids=ids, rows_per_page=rows_per_page, total_rows=n_rows)


def make_key_pages(
    remote: RemoteMemory,
    n_pages: int,
    rows_per_page: int,
    key_domain: int = 1 << 30,
    seed: int = 0,
) -> List[int]:
    """Key-only pages (1-D int64) for sort workloads (§V-B b)."""
    rng = np.random.default_rng(seed)
    pages = [
        rng.integers(0, key_domain, size=rows_per_page, dtype=np.int64)
        for _ in range(n_pages)
    ]
    return remote.put_local(pages)


def relation_rows(remote: RemoteMemory, rel: Relation) -> np.ndarray:
    """Oracle-side full materialization (no accounting): rows as one array."""
    return np.concatenate(remote.peek_batch(rel.page_ids), axis=0)
