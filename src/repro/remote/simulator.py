"""Simulated remote-memory tier (paper §IV-F, REMON/Infiniswap analogue).

Pages are real numpy arrays held in a remote store; operators move them in
*batched transfer rounds* through a :class:`repro.core.TransferLedger`, so the
paper's D/C accounting is measured, not assumed.  Latency follows Eq. (1)
exactly: ``D/BW + C*RTT`` with the tier's constants (Table I / Table IX).

The store is content-addressed by integer page ids; a relation or run is a
list of page ids.  ``read_batch``/``write_batch`` are the only ways data
crosses the boundary — one call is one transfer round, whatever its size,
mirroring REMON's batched evict/fetch interface.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.cost_model import (
    HierarchySnapshot,
    HierarchySpec,
    TierSpec,
    TransferLedger,
)


def pushdown_keep(position: int, selectivity: float) -> bool:
    """Deterministic page-granular filter: keep the page at ``position``.

    Zone-map-style Bresenham rule — keep page ``i`` iff
    ``floor((i+1)*sel) > floor(i*sel)`` — so exactly ``floor(n*sel)`` of any
    ``n`` consecutive positions survive *regardless of batching*.  Both the
    simulator and the closed forms (:func:`repro.core.policies.pushdown_costs`)
    use this rule, which is what makes them exactly comparable.
    """
    return math.floor((position + 1) * selectivity) > math.floor(
        position * selectivity
    )


def _check_selectivity(selectivity) -> float:
    s = float(selectivity)
    if not math.isfinite(s) or not 0.0 < s <= 1.0:
        raise ValueError(
            f"filter selectivity must be finite and in (0, 1], got {selectivity}"
        )
    return s


class RemoteMemory:
    """A remote tier holding pages, with round/volume accounting."""

    def __init__(self, tier: TierSpec, _alloc: Optional[Iterator[int]] = None):
        self.tier = tier
        self.ledger = TransferLedger()
        self._store: dict[int, np.ndarray] = {}
        # Page-id allocator; a MemoryHierarchy passes one shared counter so
        # ids are unique hierarchy-wide and survive tier migration.
        self._alloc = itertools.count() if _alloc is None else _alloc

    # -- allocation ---------------------------------------------------------

    @property
    def pages_resident(self) -> int:
        """Number of pages currently held by the remote store."""
        return len(self._store)

    def put_local(self, pages: Sequence[np.ndarray]) -> List[int]:
        """Seed the store without accounting (initial data placement)."""
        ids = []
        for p in pages:
            i = next(self._alloc)
            self._store[i] = np.asarray(p)
            ids.append(i)
        return ids

    def peek_batch(self, page_ids: Sequence[int]) -> List[np.ndarray]:
        """Oracle-side reads without accounting (no transfer round)."""
        return [self._store[i] for i in page_ids]

    # -- batched transfer rounds ---------------------------------------------

    def read_batch(self, page_ids: Sequence[int], prefetched: bool = False) -> List[np.ndarray]:
        """One swap-in round: fetch a batch of pages (Definition 2)."""
        if not page_ids:
            return []
        self.ledger.read(float(len(page_ids)))
        if prefetched:
            self.ledger.c_prefetch_hidden += 1
        return [self._store[i] for i in page_ids]

    def write_batch(self, pages: Sequence[np.ndarray]) -> List[int]:
        """One flush-out round: write a batch of pages."""
        if not len(pages):
            return []
        ids = self.put_local(pages)
        self.ledger.write(float(len(pages)))
        return ids

    def free(self, page_ids: Iterable[int]) -> None:
        """Drop pages from the store; unknown ids raise ``KeyError``.

        Silently ignoring unknown ids would hide double-free bugs in
        operators, so misuse fails loudly instead.
        """
        ids = list(page_ids)
        missing = [i for i in ids if i not in self._store]
        if missing:
            raise KeyError(
                f"cannot free page ids not resident on {self.tier.name!r}: "
                f"{missing} (double free or wrong tier?)"
            )
        for i in ids:
            del self._store[i]

    # -- reporting ------------------------------------------------------------

    def latency_seconds(
        self, prefetch: bool = False, overlap_migration: bool = False
    ) -> float:
        return self.ledger.latency_seconds(
            self.tier, prefetch=prefetch, overlap_migration=overlap_migration
        )

    def latency_cost(self) -> float:
        return self.ledger.latency_cost(self.tier.tau_pages)

    def reset_accounting(self) -> None:
        self.ledger.reset()


class MemoryHierarchy:
    """An ordered stack of remote tiers with capacities and per-tier ledgers.

    The runtime counterpart of :class:`repro.core.cost_model.HierarchySpec`
    (paper Table I read as a DRAM -> RDMA -> SSD waterfall): each level owns a
    :class:`RemoteMemory` store and its :class:`TransferLedger`; page ids are
    allocated from one shared counter, so a page keeps its id as it migrates
    between tiers and a hierarchy-wide placement map resolves reads.

    Transfer semantics:

      * ``write_batch(pages, tier=t)`` routes the batch to tier ``t``,
        waterfalling overflow to lower tiers when ``t`` is at capacity — each
        tier that receives pages accounts exactly one write round.
      * ``read_batch(ids)`` resolves each page's tier from placement; each
        tier touched accounts exactly one read round.
      * ``migrate(ids, dst)`` moves a batch between tiers in *migration
        rounds*: every adjacent-tier hop is one read round on the ledger it
        leaves and one write round on the ledger it enters (one round on each
        ledger it crosses).

    A single-tier hierarchy therefore reproduces a bare :class:`RemoteMemory`
    ledger exactly: every batch lands on the only tier in one round.
    """

    is_hierarchy = True  # structural marker (avoids import cycles in engine)

    def __init__(self, spec: HierarchySpec):
        self.spec = spec
        self._alloc = itertools.count()
        self.tiers: List[RemoteMemory] = [
            RemoteMemory(lv.tier, _alloc=self._alloc) for lv in spec.levels
        ]
        self._placement: Dict[int, int] = {}
        # Page access recency (one tick per batched access, shared across
        # tiers): the substrate eviction policies rank victims by.  Migration
        # is not an access — a demoted page keeps its coldness.
        self._access_clock = 0
        self._access: Dict[int, int] = {}
        # Pluggable eviction hook (see repro.engine.eviction.Evictor): when
        # set, write_batch asks it to make room on the target tier by
        # demoting cold pages *before* waterfalling new pages downward.
        self.evictor = None

    # -- resolution ----------------------------------------------------------

    def tier_index(self, tier: Union[int, str, None]) -> int:
        return 0 if tier is None else self.spec.index(tier)

    def tier(self, tier: Union[int, str]) -> RemoteMemory:
        return self.tiers[self.spec.index(tier)]

    def tier_of(self, page_id: int) -> str:
        """The tier name currently holding ``page_id``."""
        try:
            return self.spec.names[self._placement[page_id]]
        except KeyError:
            raise KeyError(f"page {page_id} is not resident in the hierarchy") from None

    @property
    def pages_resident(self) -> int:
        return sum(rm.pages_resident for rm in self.tiers)

    def tier_resident(self, tier: Union[int, str]) -> int:
        return self.tier(tier).pages_resident

    def capacity_left(self, tier: Union[int, str]) -> float:
        idx = self.spec.index(tier)
        return self.spec.levels[idx].capacity_pages - self.tiers[idx].pages_resident

    # -- access recency (eviction policy substrate) --------------------------

    def _touch(self, page_ids: Sequence[int]) -> None:
        """Mark a batched access: one clock tick shared by the whole batch."""
        self._access_clock += 1
        for i in page_ids:
            self._access[i] = self._access_clock

    @property
    def access_clock(self) -> int:
        return self._access_clock

    def last_access(self, page_id: int) -> int:
        """Clock tick of the page's last access (0 = never accessed)."""
        return self._access.get(page_id, 0)

    def is_resident(self, page_id: int) -> bool:
        """Whether the page is currently held by any tier."""
        return page_id in self._placement

    def pages_on(self, tier: Union[int, str]) -> List[int]:
        """Resident page ids on a tier, in stable (allocation) order."""
        idx = self.spec.index(tier)
        return sorted(i for i, t in self._placement.items() if t == idx)

    def resident_ids(self) -> List[int]:
        """All resident page ids hierarchy-wide, in allocation order.

        The multi-tenant server diffs this around each task execution to
        attribute page ownership per tenant.
        """
        return sorted(self._placement)

    # -- allocation (no accounting) ------------------------------------------

    def put_local(
        self, pages: Sequence[np.ndarray], tier: Union[int, str, None] = None
    ) -> List[int]:
        """Seed pages on a tier without accounting; default: the bottom tier.

        Seeding models data already resident before the operator runs (input
        relations), so it defaults to the capacity-rich backstop tier and
        leaves upper tiers free for spill placement.  Capacities hold here
        too: overflow waterfalls to lower tiers (no transfer rounds — the
        data never moved), so occupancy can never exceed what the closed
        forms (``tiered_split``/``waterfall_io``) assume.
        """
        idx = len(self.tiers) - 1 if tier is None else self.spec.index(tier)
        ids: List[int] = []
        remaining = list(pages)
        while remaining:
            if idx >= len(self.tiers):
                raise RuntimeError(
                    f"hierarchy full: {len(remaining)} seeded pages overflow "
                    f"the bottom tier {self.spec.names[-1]!r}"
                )
            free = self.spec.levels[idx].capacity_pages - self.tiers[idx].pages_resident
            take = len(remaining) if math.isinf(free) else min(len(remaining), max(int(free), 0))
            if take > 0:
                chunk_ids = self.tiers[idx].put_local(remaining[:take])
                for i in chunk_ids:
                    self._placement[i] = idx
                ids.extend(chunk_ids)
                remaining = remaining[take:]
            idx += 1
        self._touch(ids)
        return ids

    def peek_batch(self, page_ids: Sequence[int]) -> List[np.ndarray]:
        """Oracle-side reads without accounting (no transfer round)."""
        return [
            self.tiers[self._placement[i]]._store[i] for i in page_ids
        ]

    def free(self, page_ids: Iterable[int]) -> None:
        """Drop pages wherever they reside; unknown ids raise ``KeyError``."""
        ids = list(page_ids)
        missing = [i for i in ids if i not in self._placement]
        if missing:
            raise KeyError(
                f"cannot free page ids not resident in the hierarchy: {missing}"
            )
        for i in ids:
            self.tiers[self._placement.pop(i)].free([i])
            self._access.pop(i, None)

    # -- batched transfer rounds ---------------------------------------------

    def read_batch(
        self, page_ids: Sequence[int], prefetched: bool = False
    ) -> List[np.ndarray]:
        """One swap-in round per tier the batch touches, placement-resolved."""
        if not len(page_ids):
            return []
        by_tier: Dict[int, List[int]] = {}
        for i in page_ids:
            if i not in self._placement:
                raise KeyError(f"page {i} is not resident in the hierarchy")
            by_tier.setdefault(self._placement[i], []).append(i)
        fetched: Dict[int, np.ndarray] = {}
        for idx in sorted(by_tier):
            ids = by_tier[idx]
            for i, page in zip(ids, self.tiers[idx].read_batch(ids, prefetched)):
                fetched[i] = page
        self._touch(list(page_ids))
        return [fetched[i] for i in page_ids]

    def write_batch(
        self, pages: Sequence[np.ndarray], tier: Union[int, str, None] = None
    ) -> List[int]:
        """One flush-out round per tier receiving pages, waterfalling overflow.

        The batch targets ``tier`` (default: the top tier); pages beyond the
        target's remaining capacity cascade to the next tier down, each
        receiving tier accounting exactly one write round for its share.
        With an :attr:`evictor` attached, the evictor first demotes cold
        pages off the target tier (background migration rounds), so the hot
        batch lands on its target instead of waterfalling; any residual
        overflow still cascades as before.
        """
        if not len(pages):
            return []
        idx = self.tier_index(tier)
        if self.evictor is not None:
            self.evictor.make_room(idx, len(pages))
        ids: List[int] = []
        remaining = list(pages)
        while remaining:
            if idx >= len(self.tiers):
                raise RuntimeError(
                    f"hierarchy full: {len(remaining)} pages overflow the "
                    f"bottom tier {self.spec.names[-1]!r}"
                )
            free = self.spec.levels[idx].capacity_pages - self.tiers[idx].pages_resident
            take = len(remaining) if math.isinf(free) else min(len(remaining), max(int(free), 0))
            if take > 0:
                chunk_ids = self.tiers[idx].write_batch(remaining[:take])
                for i in chunk_ids:
                    self._placement[i] = idx
                ids.extend(chunk_ids)
                remaining = remaining[take:]
            idx += 1
        self._touch(ids)
        if self.evictor is not None:
            self.evictor.maintain()
        return ids

    # -- migration rounds ----------------------------------------------------

    def migrate(
        self,
        page_ids: Sequence[int],
        dst: Union[int, str],
        background: bool = False,
    ) -> None:
        """Move a batch to ``dst`` in adjacent-tier migration rounds.

        Pages keep their ids.  Every adjacent hop is one read round on the
        ledger it leaves and one write round on the ledger it enters, so a
        two-level demotion crosses three ledgers with the middle one charged
        on both sides.  The destination must have room for the whole batch
        (pass-through tiers need none); short batches raise ``ValueError``.

        ``background=True`` models migration overlapped with operator
        compute (§IV-E applied to demotion): every round of every hop is
        additionally recorded in that ledger's ``c_migration_hidden``, so
        ``latency_seconds(overlap_migration=True)`` charges it no RTT.  The
        volume term still pays in full, and migration never refreshes page
        recency — a demoted page stays as cold as it was.
        """
        if not len(page_ids):
            return
        dst_idx = self.spec.index(dst)
        by_tier: Dict[int, List[int]] = {}
        for i in page_ids:
            if i not in self._placement:
                raise KeyError(f"page {i} is not resident in the hierarchy")
            by_tier.setdefault(self._placement[i], []).append(i)
        incoming = sum(len(v) for t, v in by_tier.items() if t != dst_idx)
        free = self.capacity_left(dst_idx)
        if not math.isinf(free) and incoming > free:
            raise ValueError(
                f"tier {self.spec.names[dst_idx]!r} cannot hold {incoming} "
                f"migrated pages (capacity left: {free})"
            )
        for src_idx in sorted(by_tier):
            if src_idx == dst_idx:
                continue
            ids = by_tier[src_idx]
            step = 1 if dst_idx > src_idx else -1
            cur = src_idx
            while cur != dst_idx:
                nxt = cur + step
                src_rm, dst_rm = self.tiers[cur], self.tiers[nxt]
                pages = [src_rm._store[i] for i in ids]
                src_rm.ledger.read(float(len(ids)))  # one round leaving `cur`
                dst_rm.ledger.write(float(len(ids)))  # one round entering `nxt`
                if background:
                    src_rm.ledger.c_migration_hidden += 1
                    dst_rm.ledger.c_migration_hidden += 1
                for i, page in zip(ids, pages):
                    del src_rm._store[i]
                    dst_rm._store[i] = page
                    self._placement[i] = nxt
                cur = nxt

    # -- operator pushdown (compute-capable tiers) ---------------------------

    def _pushdown_level(self, tier: Union[int, str], op: str):
        """Resolve + capability-check a tier for pushdown op ``op``."""
        idx = self.spec.index(tier)
        level = self.spec.levels[idx]
        if not level.can_push(op):
            raise ValueError(
                f"tier {self.spec.names[idx]!r} cannot execute pushdown op "
                f"{op!r} (compute_pps={level.compute_pps}, "
                f"pushdown_ops={sorted(level.pushdown_ops)})"
            )
        return idx, level

    def _resident_on(self, idx: int, page_ids: Sequence[int]) -> None:
        stray = [i for i in page_ids if self._placement.get(i) != idx]
        if stray:
            raise ValueError(
                f"pushdown needs every page resident on tier "
                f"{self.spec.names[idx]!r}; not there: {stray[:8]}"
                f"{'...' if len(stray) > 8 else ''}"
            )

    def scan_filtered(
        self,
        tier: Union[int, str],
        page_ids: Sequence[int],
        selectivity: Optional[float] = None,
        predicate=None,
        keep_ids: Optional[Iterable[int]] = None,
        batch_pages: Optional[int] = None,
    ) -> Tuple[List[int], List[np.ndarray]]:
        """Execute a filter *at* a compute-capable tier; ship only survivors.

        Every page in ``page_ids`` must be resident on ``tier`` and the tier
        must be capable of the ``"filter"`` op (non-capable tiers raise).
        The selection is one of: a scalar ``selectivity`` applied with the
        deterministic positional rule (:func:`pushdown_keep`, positions
        within ``page_ids``), a ``predicate(page) -> bool``, or an explicit
        ``keep_ids`` set (the placement-aware scheduler fallback uses this to
        preserve a globally consistent keep decision across tiers).

        Accounting: every ``batch_pages`` chunk (default: all pages, one
        round) is one pushdown request round — ``c_read``/``c_pushdown`` +1,
        ``d_read``/``d_pushdown`` += survivors shipped, ``d_pushdown_saved``
        += pages scanned at the tier but never shipped.  All scanned pages
        count as accessed (the tier touched them).
        """
        modes = sum(x is not None for x in (selectivity, predicate, keep_ids))
        if modes != 1:
            raise ValueError(
                "scan_filtered needs exactly one of selectivity=, "
                "predicate=, keep_ids="
            )
        idx, _level = self._pushdown_level(tier, "filter")
        ids = [int(i) for i in page_ids]
        if not ids:
            return [], []
        self._resident_on(idx, ids)
        if selectivity is not None:
            sel = _check_selectivity(selectivity)
        keep_set = None if keep_ids is None else frozenset(int(i) for i in keep_ids)
        batch = len(ids) if batch_pages is None else int(batch_pages)
        if batch <= 0:
            raise ValueError(f"batch_pages must be > 0, got {batch_pages}")
        rm = self.tiers[idx]
        kept_ids: List[int] = []
        kept_pages: List[np.ndarray] = []
        for start in range(0, len(ids), batch):
            chunk = ids[start : start + batch]
            if predicate is not None:
                kept = [i for i in chunk if predicate(rm._store[i])]
            elif keep_set is not None:
                kept = [i for i in chunk if i in keep_set]
            else:
                kept = [
                    i for pos, i in enumerate(chunk, start=start)
                    if pushdown_keep(pos, sel)
                ]
            rm.ledger.pushdown(
                shipped=float(len(kept)), saved=float(len(chunk) - len(kept))
            )
            kept_ids.extend(kept)
            kept_pages.extend(rm._store[i] for i in kept)
        self._touch(ids)
        return kept_ids, kept_pages

    def read_reduced(
        self,
        tier: Union[int, str],
        page_ids: Sequence[int],
        reducer,
        rows_per_page: int,
    ) -> List[np.ndarray]:
        """Execute a partial reduction *at* a compute-capable tier.

        ``reducer(pages) -> rows`` runs over the resident pages at the tier
        (all of ``page_ids`` must live on ``tier``, which must be capable of
        the ``"reduce"`` op); the result rows are packed into
        ``rows_per_page``-row pages and shipped back in **one** pushdown
        round — ``ceil(rows / rows_per_page)`` result pages of ``d_read``
        instead of ``len(page_ids)`` raw ones.  The shipped arrays are
        materialized results, not store pages (the caller owns them).
        """
        idx, _level = self._pushdown_level(tier, "reduce")
        ids = [int(i) for i in page_ids]
        if not ids:
            return []
        if rows_per_page <= 0:
            raise ValueError(f"rows_per_page must be > 0, got {rows_per_page}")
        self._resident_on(idx, ids)
        rm = self.tiers[idx]
        rows = np.asarray(reducer([rm._store[i] for i in ids]))
        out = [
            rows[start : start + rows_per_page]
            for start in range(0, len(rows), rows_per_page)
        ]
        rm.ledger.pushdown(
            shipped=float(len(out)),
            saved=float(max(len(ids) - len(out), 0)),
        )
        self._touch(ids)
        return out

    def demote(self, page_ids: Sequence[int], background: bool = False) -> None:
        """Migrate a batch one tier down (all pages must share a tier)."""
        self._hop(page_ids, +1, background=background)

    def promote(self, page_ids: Sequence[int], background: bool = False) -> None:
        """Migrate a batch one tier up (all pages must share a tier)."""
        self._hop(page_ids, -1, background=background)

    def _hop(
        self, page_ids: Sequence[int], step: int, background: bool = False
    ) -> None:
        if not len(page_ids):
            return
        tiers = {self._placement.get(i) for i in page_ids}
        if None in tiers or len(tiers) != 1:
            raise ValueError(
                "demote/promote needs a batch resident on one tier; got "
                f"placements {sorted('?' if t is None else self.spec.names[t] for t in tiers)}"
            )
        (src_idx,) = tiers
        dst_idx = src_idx + step
        if not 0 <= dst_idx < len(self.tiers):
            raise ValueError(
                f"cannot move {'down' if step > 0 else 'up'} from "
                f"{'bottom' if step > 0 else 'top'} tier {self.spec.names[src_idx]!r}"
            )
        self.migrate(page_ids, dst_idx, background=background)

    # -- reporting ------------------------------------------------------------

    def snapshot(self) -> HierarchySnapshot:
        return HierarchySnapshot(tiers=tuple(
            (name, rm.ledger.snapshot())
            for name, rm in zip(self.spec.names, self.tiers)
        ))

    def delta(self, since: HierarchySnapshot) -> HierarchySnapshot:
        return HierarchySnapshot(tiers=tuple(
            (name, rm.ledger.delta(since.tier(name)))
            for name, rm in zip(self.spec.names, self.tiers)
        ))

    def latency_seconds(
        self, prefetch: bool = False, overlap_migration: bool = False
    ) -> float:
        """Eq. (1) summed over tiers, each with its own (BW, RTT).

        Compute-capable tiers additionally pay their pushdown-scanned pages'
        processing time (``d_pushdown_scanned / compute_pps``).
        """
        return sum(
            rm.ledger.latency_seconds(
                rm.tier, prefetch=prefetch,
                overlap_migration=overlap_migration,
                compute_pps=lv.compute_pps,
            )
            for rm, lv in zip(self.tiers, self.spec.levels)
        )

    def latency_cost(self) -> float:
        """Hierarchy-wide L: per-tier D + tau_t * C summed over tiers.

        Pushdown-scanned pages on compute-capable tiers are priced at that
        tier's ``compute_tau_pages`` each (tier compute in L units).
        """
        total = 0.0
        for rm, lv in zip(self.tiers, self.spec.levels):
            total += rm.latency_cost()
            scanned = rm.ledger.d_pushdown_scanned
            if scanned > 0:
                total += lv.compute_tau_pages * scanned
        return total

    def reset_accounting(self) -> None:
        for rm in self.tiers:
            rm.reset_accounting()


def make_hierarchy(
    *levels: Union[TierSpec, str, Tuple[Union[TierSpec, str], float]],
) -> MemoryHierarchy:
    """Build a :class:`MemoryHierarchy` from tier / ``(tier, cap)`` levels.

    Tiers are ``TierSpec``\\ s or names from Table I / TESTBED / TPU tiers,
    e.g. ``make_hierarchy(("dram", 64), ("rdma", 1024), "ssd")``.
    """
    from repro.core.cost_model import hierarchy_spec

    return MemoryHierarchy(hierarchy_spec(*levels))


@dataclasses.dataclass
class Relation:
    """A paged relation: `pages[i]` is a page id; tuples are (key, payload)."""

    page_ids: List[int]
    rows_per_page: int
    total_rows: int

    def __len__(self) -> int:
        return len(self.page_ids)


def as_relation(remote, value, rows_per_page: Optional[int] = None) -> Relation:
    """Coerce ``value`` (a ``Relation`` or a page-id list) into a ``Relation``.

    Session task DAGs chain operators by page-id lists — a ``TaskOutput``
    resolves to the upstream operator's flushed output pages — while the
    relational operators (BNLJ/EHJ/EAGG) take ``Relation`` inputs.  Row
    geometry is recovered by peeking the pages oracle-side: bookkeeping,
    not a transfer round, so ledgers are unaffected.
    """
    if isinstance(value, Relation):
        return value
    ids = [int(p) for p in value]
    if not ids:
        return Relation(page_ids=[], rows_per_page=rows_per_page or 1, total_rows=0)
    pages = remote.peek_batch(ids)
    total = int(sum(len(p) for p in pages))
    rpp = rows_per_page or max(len(p) for p in pages)
    return Relation(page_ids=ids, rows_per_page=int(rpp), total_rows=total)


def _seed_pages(remote, pages, tier) -> List[int]:
    """Route seeding to a tier when asked (hierarchies only)."""
    if tier is None:
        return remote.put_local(pages)
    if not getattr(remote, "is_hierarchy", False):
        raise ValueError(
            f"tier={tier!r} seeding needs a MemoryHierarchy target; a single "
            f"tier has no placement choice"
        )
    return remote.put_local(pages, tier=tier)


def make_relation(
    remote: RemoteMemory,
    n_rows: int,
    rows_per_page: int,
    key_domain: int,
    payload_width: int = 1,
    seed: int = 0,
    sorted_keys: bool = False,
    tier: Union[int, str, None] = None,
) -> Relation:
    """Materialize a synthetic relation in remote memory (§V-A b workloads).

    Keys are drawn uniformly from [0, key_domain); join selectivity between two
    such relations is ~1/key_domain per tuple pair, matching the paper's
    key-domain-controlled selectivity.

    ``tier`` places the relation on a specific hierarchy tier (a *hot* cached
    table already resident on DRAM/RDMA); the default is the capacity-rich
    bottom tier, the cold-base-table convention of ``put_local``.
    """
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_domain, size=n_rows, dtype=np.int64)
    if sorted_keys:
        keys = np.sort(keys)
    payload = np.arange(n_rows, dtype=np.int64)[:, None] * np.ones(
        (1, payload_width), dtype=np.int64
    )
    pages = []
    for start in range(0, n_rows, rows_per_page):
        sl = slice(start, min(start + rows_per_page, n_rows))
        pages.append(np.concatenate([keys[sl, None], payload[sl]], axis=1))
    ids = _seed_pages(remote, pages, tier)
    return Relation(page_ids=ids, rows_per_page=rows_per_page, total_rows=n_rows)


def make_key_pages(
    remote: RemoteMemory,
    n_pages: int,
    rows_per_page: int,
    key_domain: int = 1 << 30,
    seed: int = 0,
    tier: Union[int, str, None] = None,
) -> List[int]:
    """Key-only pages (1-D int64) for sort workloads (§V-B b)."""
    rng = np.random.default_rng(seed)
    pages = [
        rng.integers(0, key_domain, size=rows_per_page, dtype=np.int64)
        for _ in range(n_pages)
    ]
    return _seed_pages(remote, pages, tier)


def relation_rows(remote: RemoteMemory, rel: Relation) -> np.ndarray:
    """Oracle-side full materialization (no accounting): rows as one array."""
    return np.concatenate(remote.peek_batch(rel.page_ids), axis=0)
