"""External (grace-style) hash aggregation over simulated remote memory.

Two phases under one I/O budget.  P1 scans the input relation through the R_r
read buffer and hash-partitions it into P partitions: resident partitions
aggregate on the fly in local hash tables, spilled partitions (fraction
``sigma``) flush raw tuples through the per-partition-sliced R_w write pool,
and the resident group output flushes through R_o.  P2 re-reads each spilled
partition through R_r, aggregates it in memory (grace assumption: one
partition fits locally), and flushes its groups through R_o.  Every block
read is a :class:`repro.engine.PageCursor` round and every pool flush a
:class:`repro.engine.BufferPool` round, so the measured ledger matches
:func:`repro.core.policies.eagg_costs_exact` exactly (skew included).

Group rows are ``(key, sum(payload), count)`` triples over column 1.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.policies import EAggPlan
from repro.engine.buffers import BufferPool, PageCursor
from repro.engine.scheduler import TransferScheduler, stream_tiers
from repro.remote.simulator import Relation, RemoteMemory, as_relation, relation_rows


# Typed input signature for the session API: ``engine.registry`` binds named
# task inputs to ``eagg``'s positional data-plane arguments through this, and
# maps each input to the WorkloadStats field that estimates its size.
INPUTS = ("rel",)
INPUT_STATS = {"rel": "size_r"}

# Spill streams this operator writes, in declaration order — the unit of
# fractional placement: raw spilled partitions vs. the group output
# (resident P1 groups and external P2 groups share the output stream tier).
STREAMS = ("partitions", "output")


@dataclasses.dataclass
class AggResult:
    output_page_ids: List[int]
    group_rows: int
    sigma: float
    d_read: float
    d_write: float
    c_read: int
    c_write: int
    per_phase_rounds: Dict[str, int]
    # How many spilled partitions were partially aggregated at the memory
    # tier in P2 (0 when pushdown was off or no tier was reduce-capable).
    pushdown_partitions: int = 0


def eagg_output(result: AggResult) -> List[int]:
    """The operator's output pages — what a downstream task's input binds to."""
    return result.output_page_ids


def eagg_measured(stats, result: AggResult):
    """Feed the measured output cardinality back into the workload stats."""
    return dataclasses.replace(stats, out=float(len(result.output_page_ids)))


def _hash_part(keys: np.ndarray, p: int) -> np.ndarray:
    h = keys.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    return ((h >> np.uint64(33)) % np.uint64(p)).astype(np.int64)


def _aggregate(rows: np.ndarray) -> np.ndarray:
    """Group rows by column 0: (key, sum of column 1, count) per group."""
    if not len(rows):
        return np.empty((0, 3), dtype=np.int64)
    keys, inverse = np.unique(rows[:, 0], return_inverse=True)
    sums = np.bincount(inverse, weights=rows[:, 1].astype(np.float64))
    counts = np.bincount(inverse)
    return np.stack([keys, sums.astype(np.int64), counts.astype(np.int64)], axis=1)


def _reduce_partition(pages: List[np.ndarray]) -> np.ndarray:
    """Tier-side reducer for one spilled partition (grace assumption)."""
    return _aggregate(np.concatenate(pages, axis=0))


def eagg(
    remote: RemoteMemory,
    rel: Relation,
    plan: EAggPlan,
    rows_per_page: int | None = None,
    prefetch: bool = False,
    tier=None,
    pushdown: bool = False,
) -> AggResult:
    """Run the two-phase external hash aggregation under ``plan``.

    ``remote`` is a single tier or a :class:`MemoryHierarchy`; on a
    hierarchy, ``tier`` names the placement spilled partitions and group
    output are routed to — a scalar, or a per-stream spec over ``STREAMS``.
    ``rel`` accepts a ``Relation`` or a bare page-id list.

    ``pushdown=True`` lets P2 partially aggregate a spilled partition *at*
    the tier holding it: when every page of the partition is resident on one
    ``"reduce"``-capable tier, a single ``read_reduced`` pushdown round
    ships only the group pages instead of re-reading the raw spill.
    Partitions that waterfalled across tiers, or sit on non-capable tiers,
    fall back to the plain re-read — the group table is identical either
    way (``_aggregate`` is deterministic), only D/C change.
    """
    rel = as_relation(remote, rel)
    tiers = stream_tiers(tier, STREAMS)
    rows_per_page = rows_per_page or rel.rows_per_page
    p = plan.partitions
    n_spilled = int(round(plan.sigma * p))
    spilled = set(range(p - n_spilled, p))  # deterministic spill set
    sched = TransferScheduler(remote, tier=tiers["output"])
    before = sched.snapshot()
    phase_rounds: Dict[str, int] = {}

    # ---- P1: scan, aggregate resident partitions, spill the rest -----------
    t0 = sched.snapshot()
    r_r1, r_w1, r_o1 = plan.p1
    spill_pool = BufferPool(sched, r_w1, rows_per_page,
                            n_streams=max(len(spilled), 1),
                            tier=tiers["partitions"])
    resident: Dict[int, List[np.ndarray]] = {q: [] for q in range(p) if q not in spilled}
    for rows in PageCursor(sched, rel.page_ids, round(r_r1),
                           prefetch=prefetch).blocks():
        parts = _hash_part(rows[:, 0], p)
        for q, sel in sched.partitions(rows, parts):
            if q in spilled:
                spill_pool.add(sel, stream=q)
            else:
                resident[q].append(sel)
    spill_pool.flush_all()
    out_pool = BufferPool(sched, r_o1, rows_per_page, tier=tiers["output"])
    group_rows = 0
    for q in sorted(resident):
        if not resident[q]:
            continue
        groups = _aggregate(np.concatenate(resident[q], axis=0))
        group_rows += len(groups)
        out_pool.add(groups)  # single resident-output stream
    out_pool.flush_all()
    phase_rounds["P1"] = sched.delta(t0).c_total

    # ---- P2: re-read each spilled partition, aggregate, flush groups -------
    t0 = sched.snapshot()
    r_r2, r_o2 = plan.p2
    read_pages = round(r_r2)
    ext_out_pool = BufferPool(sched, r_o2, rows_per_page, tier=tiers["output"])
    pushdown_parts = 0
    for q in sorted(spilled):
        ids = spill_pool.pages(q)
        if not ids:
            continue
        pushed = False
        if pushdown and getattr(remote, "is_hierarchy", False):
            homes = {remote.tier_of(i) for i in ids}
            if len(homes) == 1:
                home = homes.pop()
                if remote.spec.level(home).can_push("reduce"):
                    group_pages = remote.read_reduced(
                        home, ids, _reduce_partition, rows_per_page
                    )
                    groups = (
                        np.concatenate(group_pages, axis=0)
                        if group_pages
                        else np.empty((0, 3), dtype=np.int64)
                    )
                    pushdown_parts += 1
                    pushed = True
        if not pushed:
            part_rows = PageCursor(sched, ids, read_pages, prefetch=prefetch).read_all()
            groups = _aggregate(part_rows)
        group_rows += len(groups)
        ext_out_pool.add(groups)
    ext_out_pool.flush_all()
    phase_rounds["P2"] = sched.delta(t0).c_total

    d = sched.delta(before)
    return AggResult(
        output_page_ids=out_pool.pages() + ext_out_pool.pages(),
        group_rows=group_rows,
        sigma=plan.sigma,
        d_read=d.d_read,
        d_write=d.d_write,
        c_read=d.c_read,
        c_write=d.c_write,
        per_phase_rounds=phase_rounds,
        pushdown_partitions=pushdown_parts,
    )


def eagg_oracle(remote: RemoteMemory, rel: Relation) -> np.ndarray:
    """Oracle group table (key, sum, count), sorted by key (no accounting)."""
    return _aggregate(relation_rows(remote, rel))
