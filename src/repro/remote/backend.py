"""Real Pallas execution backend: pages carry jax arrays, kernels run.

Everywhere else in ``remote/`` the store is a *simulator* — pages are host
numpy arrays, transfers are ledger bookkeeping, and latency comes from
Eq. (1) with assumed Table I constants.  This module is the measured
counterpart: an :class:`ExecutionBackend` is a drop-in
:class:`~repro.remote.simulator.MemoryHierarchy` whose tiers mirror their
pages as device arrays, whose transfer rounds are actual host<->device
copies timed with a wall clock, and whose operator compute hooks run the
repo's Pallas kernels (``kernels/merge_sort`` for EMS merge steps,
``kernels/dispatch`` for EHJ/EAGG partitioning).

Parity is the correctness oracle: every ledger round counts *exactly* as on
the simulator (the overrides delegate to the simulator paths for all D/C
accounting) and every operator output is byte-identical, because

  * device mirrors only hold pages that round-trip losslessly (jax
    canonicalizes 64-bit dtypes to 32-bit with x64 off — flipping
    ``jax_enable_x64`` globally would contaminate every other suite in the
    process, so int64 pages mirror as int32 only when every value fits;
    everything else stays host-pinned and is counted),
  * the kernel hooks fall back to the numpy reference whenever a block is
    not losslessly representable (counted in ``wall.kernel_fallbacks``), and
  * the hooks compute the same functions: sorted keys are sorted keys, and
    a stable partition-id argsort groups rows exactly like per-partition
    boolean masks.

This file is the one sanctioned home of wall-clock reads on a simulator
path (the LAY303 carve-out in ``repro.analysis.rules_layering``); the
determinism contract — no unseeded RNG — still applies here.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cost_model import HierarchySpec, TierSpec
from repro.kernels.dispatch.dispatch import gather_rows
from repro.kernels.merge_sort.ops import argsort_by_key, remop_sort
from repro.kernels.runtime import resolve_interpret
from repro.remote.simulator import MemoryHierarchy, RemoteMemory

_I32_MIN = np.iinfo(np.int32).min
_I32_MAX = np.iinfo(np.int32).max


def _device_page(page: np.ndarray) -> Optional[np.ndarray]:
    """A device-representable view of a host page, or ``None`` when lossy.

    int32/float32 pages mirror as-is; int64 pages mirror as int32 only when
    every value round-trips exactly.  Anything else host-pins — parity with
    the simulator always beats device coverage.
    """
    page = np.asarray(page)
    if page.dtype == np.int64:
        if page.size and (page.min() < _I32_MIN or page.max() > _I32_MAX):
            return None
        return page.astype(np.int32)
    if page.dtype in (np.int32, np.float32):
        return page
    return None


# --------------------------------------------------------------------------
# Wall clock: the measured counterpart of the TransferLedger
# --------------------------------------------------------------------------


@dataclasses.dataclass
class TierWall:
    """Measured host<->device transfer time for one tier."""

    h2d_seconds: float = 0.0
    h2d_rounds: int = 0
    h2d_bytes: int = 0
    d2h_seconds: float = 0.0
    d2h_rounds: int = 0
    d2h_bytes: int = 0

    @property
    def seconds(self) -> float:
        return self.h2d_seconds + self.d2h_seconds

    @property
    def rounds(self) -> int:
        return self.h2d_rounds + self.d2h_rounds

    @property
    def bytes(self) -> int:
        return self.h2d_bytes + self.d2h_bytes

    def to_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d.update(seconds=self.seconds, rounds=self.rounds, bytes=self.bytes)
        return d


class WallClock:
    """Per-tier transfer timings + kernel timings for one backend.

    The wall clock is to measured execution what the
    :class:`~repro.core.cost_model.TransferLedger` is to the simulation —
    but unlike the ledger it is *never* regression-gated in CI
    (``scripts/check_regression.py`` gates only deterministic metrics).
    """

    def __init__(self, tier_names: Sequence[str]):
        self.tiers: Dict[str, TierWall] = {n: TierWall() for n in tier_names}
        self.kernel_seconds = 0.0
        self.kernel_calls = 0
        # Blocks routed back to the numpy reference (lossy int32 round-trip).
        self.kernel_fallbacks = 0
        # Pages never mirrored on device (lossy dtype/range): reads of these
        # serve from the host store, so their rounds have no device timing.
        self.host_pinned_pages = 0

    def record_h2d(self, tier: str, seconds: float, nbytes: int) -> None:
        w = self.tiers[tier]
        w.h2d_seconds += seconds
        w.h2d_rounds += 1
        w.h2d_bytes += nbytes

    def record_d2h(self, tier: str, seconds: float, nbytes: int) -> None:
        w = self.tiers[tier]
        w.d2h_seconds += seconds
        w.d2h_rounds += 1
        w.d2h_bytes += nbytes

    def record_kernel(self, seconds: float) -> None:
        self.kernel_seconds += seconds
        self.kernel_calls += 1

    @property
    def transfer_seconds(self) -> float:
        return sum(w.seconds for w in self.tiers.values())

    @property
    def total_seconds(self) -> float:
        """Measured seconds: all transfers + all kernel invocations."""
        return self.transfer_seconds + self.kernel_seconds

    def to_dict(self) -> Dict[str, object]:
        return {
            "tiers": {n: w.to_dict() for n, w in self.tiers.items()},
            "transfer_seconds": self.transfer_seconds,
            "kernel_seconds": self.kernel_seconds,
            "kernel_calls": self.kernel_calls,
            "kernel_fallbacks": self.kernel_fallbacks,
            "host_pinned_pages": self.host_pinned_pages,
            "wall_seconds": self.total_seconds,
        }


# --------------------------------------------------------------------------
# Backend tiers and the hierarchy
# --------------------------------------------------------------------------


class BackendTier(RemoteMemory):
    """A tier whose pages are mirrored as device arrays.

    Every override delegates to :class:`RemoteMemory` first, so the ledger
    accounting (rounds, volumes, prefetch hiding) is byte-identical to the
    simulator; the device mirror rides along.  ``write_batch`` is a timed
    host->device round, ``read_batch`` a timed device->host round, and the
    pages a read returns are the *device round-trips* (cast back to the host
    dtype), so the data operators consume really crossed the boundary.
    """

    def __init__(self, tier: TierSpec, wall: WallClock, device, _alloc=None):
        super().__init__(tier, _alloc=_alloc)
        self._wall = wall
        self._device = device
        self._dev: Dict[int, jax.Array] = {}
        self._in_write = False

    # -- mirroring -----------------------------------------------------------

    def _mirror(self, page_ids: Sequence[int]) -> None:
        views = []
        for i in page_ids:
            v = _device_page(self._store[i])
            if v is None:
                self._wall.host_pinned_pages += 1
            else:
                views.append((i, v))
        if not views:
            return
        nbytes = sum(v.nbytes for _, v in views)
        t0 = time.perf_counter()
        arrays = jax.device_put([v for _, v in views], self._device)
        jax.block_until_ready(arrays)
        elapsed = time.perf_counter() - t0
        if self._in_write:  # seeding (put_local) is not a transfer round
            self._wall.record_h2d(self.tier.name, elapsed, nbytes)
        for (i, _), arr in zip(views, arrays):
            self._dev[i] = arr

    def put_local(self, pages: Sequence[np.ndarray]) -> List[int]:
        ids = super().put_local(pages)
        self._mirror(ids)
        return ids

    # -- timed transfer rounds ------------------------------------------------

    def write_batch(self, pages: Sequence[np.ndarray]) -> List[int]:
        if not len(pages):
            return []
        self._in_write = True
        try:
            return super().write_batch(pages)  # ledger + put_local -> mirror
        finally:
            self._in_write = False

    def read_batch(self, page_ids: Sequence[int], prefetched: bool = False) -> List[np.ndarray]:
        if not page_ids:
            return []
        host = super().read_batch(page_ids, prefetched)  # identical ledger
        mirrors = [self._dev.get(i) for i in page_ids]
        fetched: List[Optional[np.ndarray]] = [None] * len(page_ids)
        live = [(k, d) for k, d in enumerate(mirrors) if d is not None]
        if live:
            t0 = time.perf_counter()
            pulled = [np.asarray(d) for _, d in live]
            elapsed = time.perf_counter() - t0
            self._wall.record_d2h(
                self.tier.name, elapsed, sum(p.nbytes for p in pulled)
            )
            for (k, _), p in zip(live, pulled):
                fetched[k] = p
        return [
            h if f is None else f.astype(h.dtype, copy=False)
            for h, f in zip(host, fetched)
        ]

    def free(self, page_ids: Iterable[int]) -> None:
        ids = list(page_ids)
        super().free(ids)
        for i in ids:
            self._dev.pop(i, None)


class ExecutionBackend(MemoryHierarchy):
    """A :class:`MemoryHierarchy` executing for real: device pages + kernels.

    Drop-in for every ``MemoryHierarchy`` consumer (``Session``, ``Server``,
    the benchmarks): same placement map, same waterfall, same ledgers — the
    parity tests assert snapshot equality field-for-field — plus a
    :attr:`wall` clock of measured seconds and two compute hooks the
    operators discover through their :class:`~repro.engine.scheduler.
    TransferScheduler` (:meth:`sort_keys`, :meth:`partition_rows`).

    ``interpret=None`` auto-detects the Pallas mode (compiled on TPU/GPU,
    interpreter on CPU); ``device`` defaults to jax's first device.
    """

    is_backend = True  # structural marker (duck-typed like is_hierarchy)

    def __init__(self, spec: HierarchySpec, interpret: Optional[bool] = None,
                 device=None):
        super().__init__(spec)
        self.interpret = resolve_interpret(interpret)
        self.device = jax.devices()[0] if device is None else device
        self.wall = WallClock(spec.names)
        # Re-materialize the levels as backend tiers on the shared allocator
        # (no pages exist yet, so swapping the empty stores is safe).
        self.tiers = [
            BackendTier(lv.tier, wall=self.wall, device=self.device,
                        _alloc=self._alloc)
            for lv in spec.levels
        ]

    # -- migration: move the device mirrors with the pages --------------------

    def migrate(
        self,
        page_ids: Sequence[int],
        dst: Union[int, str],
        background: bool = False,
    ) -> None:
        old = {i: self._placement.get(i) for i in page_ids}
        super().migrate(page_ids, dst, background=background)
        # The base class pokes tier stores directly; re-home the mirrors.
        # All tiers share one device, so this is a reference move, not a
        # timed copy (the ledger already charged the migration rounds).
        for i in page_ids:
            src, cur = old[i], self._placement[i]
            if src is None or src == cur:
                continue
            dev = self.tiers[src]._dev.pop(i, None)
            if dev is not None:
                self.tiers[cur]._dev[i] = dev

    # -- operator compute hooks ------------------------------------------------

    def sort_keys(self, keys: np.ndarray) -> np.ndarray:
        """EMS hook: sort a 1-D key block via the ``merge_sort`` Pallas kernel.

        Byte-identical to ``np.sort(keys, kind="stable")`` — bare keys carry
        no payload, so equal keys are interchangeable.  Blocks that cannot
        round-trip int32 losslessly fall back to numpy (counted).
        """
        keys = np.asarray(keys)
        if keys.ndim != 1 or keys.size < 2:
            return np.sort(keys, kind="stable")
        dev = _device_page(keys) if keys.dtype.kind in "iu" else None
        if dev is None:
            self.wall.kernel_fallbacks += 1
            return np.sort(keys, kind="stable")
        t0 = time.perf_counter()
        out, _ = remop_sort(jnp.asarray(dev), interpret=self.interpret)
        jax.block_until_ready(out)
        self.wall.record_kernel(time.perf_counter() - t0)
        return np.asarray(out).astype(keys.dtype, copy=False)

    def partition_rows(
        self, rows: np.ndarray, parts: np.ndarray
    ) -> List[Tuple[int, np.ndarray]]:
        """EHJ/EAGG hook: group a row block by partition id via ``dispatch``.

        Returns ``[(q, rows_of_q), ...]`` with ``q`` ascending — exactly
        ``[(q, rows[parts == q]) for q in np.unique(parts)]``, because the
        partition-id argsort is *stable* (within-partition row order is
        preserved) and ``gather_rows`` applies the permutation verbatim.
        """
        rows = np.asarray(rows)
        parts = np.asarray(parts)
        if not len(rows):
            return []
        uniq, counts = np.unique(parts, return_counts=True)
        n = len(parts)
        max_part = int(uniq[-1])
        dev_rows = _device_page(rows) if rows.ndim == 2 else None
        eligible = (
            n >= 2
            and dev_rows is not None
            and parts.dtype.kind in "iu"
            and int(uniq[0]) >= 0
            and max_part * n + n < 2**31
        )
        if not eligible:
            self.wall.kernel_fallbacks += 1
            return [(int(q), rows[parts == q]) for q in uniq]
        t0 = time.perf_counter()
        order = argsort_by_key(jnp.asarray(parts.astype(np.int32)),
                               interpret=self.interpret, max_key=max_part)
        gathered = gather_rows(jnp.asarray(dev_rows),
                               order.astype(jnp.int32),
                               interpret=self.interpret)
        jax.block_until_ready(gathered)
        self.wall.record_kernel(time.perf_counter() - t0)
        ordered = np.asarray(gathered).astype(rows.dtype, copy=False)
        out: List[Tuple[int, np.ndarray]] = []
        start = 0
        for q, c in zip(uniq, counts):
            out.append((int(q), ordered[start:start + int(c)]))
            start += int(c)
        return out


def make_backend(
    *levels: Union[TierSpec, str, Tuple[Union[TierSpec, str], float]],
    interpret: Optional[bool] = None,
    device=None,
) -> ExecutionBackend:
    """Build an :class:`ExecutionBackend` from tier / ``(tier, cap)`` levels.

    The backend twin of :func:`repro.remote.simulator.make_hierarchy` —
    same tier resolution, e.g. ``make_backend(("dram", 64), "rdma", "ssd")``.
    """
    from repro.core.cost_model import hierarchy_spec

    return ExecutionBackend(hierarchy_spec(*levels), interpret=interpret,
                            device=device)
