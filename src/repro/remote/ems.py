"""External merge sort over simulated remote memory (Algorithm 2).

Run formation sorts M-page chunks in "local memory" and writes them back as
sorted runs; the merge phase merges groups of ``k`` runs through per-run input
buffers of ``floor(R_in/k)`` pages and an ``R_out``-page output buffer.  Every
refill and every output flush is one transfer round, exactly as analysed in
§III-B (and the §II-C worked example).
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.policies import EMSPlan
from repro.remote.simulator import RemoteMemory


@dataclasses.dataclass
class SortResult:
    run_page_ids: List[int]  # final single sorted run
    passes: int
    d_read: float
    d_write: float
    c_read: int
    c_write: int


class _RunCursor:
    """Streams one sorted run through a per-run input buffer."""

    def __init__(self, remote: RemoteMemory, page_ids: List[int], buf_pages: int,
                 prefetch: bool):
        self.remote = remote
        self.page_ids = page_ids
        self.buf_pages = max(1, buf_pages)
        self.pos = 0
        self.buf = np.empty((0,), dtype=np.int64)
        self.refills = 0
        self.prefetch = prefetch

    @property
    def exhausted(self) -> bool:
        return self.pos >= len(self.page_ids) and len(self.buf) == 0

    def refill(self) -> None:
        """One read round: load the next buf_pages pages of this run."""
        if self.pos >= len(self.page_ids) or len(self.buf) > 0:
            return
        ids = self.page_ids[self.pos : self.pos + self.buf_pages]
        pages = self.remote.read_batch(ids, prefetched=self.prefetch and self.pos > 0)
        self.pos += len(ids)
        self.refills += 1
        self.buf = np.concatenate([p.ravel() for p in pages])

    def safe_bound(self) -> int | None:
        """Largest key below which this run cannot produce unseen elements."""
        if len(self.buf) == 0:
            return None
        if self.pos >= len(self.page_ids):
            return None  # fully buffered: no bound needed
        return int(self.buf[-1])

    def take_upto(self, bound: int | None) -> np.ndarray:
        if len(self.buf) == 0:
            return self.buf
        if bound is None:
            out, self.buf = self.buf, self.buf[:0]
            return out
        idx = np.searchsorted(self.buf, bound, side="right")
        out, self.buf = self.buf[:idx], self.buf[idx:]
        return out


def _merge_group(
    remote: RemoteMemory,
    runs: List[List[int]],
    plan: EMSPlan,
    rows_per_page: int,
    prefetch: bool,
) -> List[int]:
    """Merge up to k runs into one; returns the new run's page ids."""
    per_run = max(1, int(plan.input_pages) // max(len(runs), 1))
    r_out = max(1, int(round(plan.output_pages)))
    cursors = [_RunCursor(remote, r, per_run, prefetch) for r in runs]
    out_ids: List[int] = []
    pending = np.empty((0,), dtype=np.int64)

    def flush(force: bool = False) -> None:
        nonlocal pending
        cap = r_out * rows_per_page
        while len(pending) >= cap or (force and len(pending) > 0):
            take = min(len(pending), cap)
            chunk, pending = pending[:take], pending[take:]
            pages = [chunk[i : i + rows_per_page] for i in range(0, len(chunk), rows_per_page)]
            out_ids.extend(remote.write_batch(pages))  # 1 write round
            if force and len(pending) == 0:
                break

    while True:
        for c in cursors:
            if len(c.buf) == 0 and c.pos < len(c.page_ids):
                c.refill()  # 1 read round per refill
        active = [c for c in cursors if len(c.buf) > 0]
        if not active:
            break
        # Emit everything provably below every active run's buffered horizon
        # (batched tournament: same refill/flush rounds as tuple-at-a-time).
        bounds = [b for c in active if (b := c.safe_bound()) is not None]
        bound = min(bounds) if bounds else None
        taken = [c.take_upto(bound) for c in active]
        merged = np.sort(np.concatenate(taken), kind="stable")
        if len(merged) == 0:
            # Bound excluded everything buffered: force the binding cursor on.
            binding = min(active, key=lambda c: c.safe_bound() or np.iinfo(np.int64).max)
            pending = np.concatenate([pending, np.sort(binding.take_upto(None))])
        else:
            pending = np.concatenate([pending, merged])
        flush()
    flush(force=True)
    return out_ids


def ems_sort(
    remote: RemoteMemory,
    page_ids: List[int],
    plan: EMSPlan,
    rows_per_page: int,
    prefetch: bool = False,
    count_run_formation: bool = True,
) -> SortResult:
    """Full external merge sort of the pages' int64 keys under `plan`."""
    before = dataclasses.replace(remote.ledger)
    m_pages = max(1, int(plan.m))

    # ---- run formation: sort M-page chunks locally (§III-B a) -------------
    runs: List[List[int]] = []
    for start in range(0, len(page_ids), m_pages):
        ids = page_ids[start : start + m_pages]
        if count_run_formation:
            pages = remote.read_batch(ids)  # 1 round
        else:
            pages = [remote._store[i] for i in ids]
        data = np.sort(np.concatenate([p.ravel() for p in pages]), kind="stable")
        out_pages = [data[i : i + rows_per_page] for i in range(0, len(data), rows_per_page)]
        if count_run_formation:
            runs.append(remote.write_batch(out_pages))  # 1 round
        else:
            runs.append(remote.put_local(out_pages))

    # ---- merge passes (Algorithm 2) ----------------------------------------
    passes = 0
    while len(runs) > 1:
        nxt: List[List[int]] = []
        for g in range(0, len(runs), plan.k):
            group = runs[g : g + plan.k]
            if len(group) == 1:
                nxt.append(group[0])
            else:
                nxt.append(_merge_group(remote, group, plan, rows_per_page, prefetch))
        runs = nxt
        passes += 1

    led = remote.ledger
    return SortResult(
        run_page_ids=runs[0] if runs else [],
        passes=passes,
        d_read=led.d_read - before.d_read,
        d_write=led.d_write - before.d_write,
        c_read=led.c_read - before.c_read,
        c_write=led.c_write - before.c_write,
    )


def ems_oracle(remote: RemoteMemory, page_ids: List[int]) -> np.ndarray:
    """Dense oracle: all keys, fully sorted (no accounting)."""
    return np.sort(np.concatenate([remote._store[i].ravel() for i in page_ids]))
