"""External merge sort over simulated remote memory (Algorithm 2).

Run formation sorts M-page chunks in "local memory" and writes them back as
sorted runs; the merge phase merges groups of ``k`` runs through per-run input
buffers of ``floor(R_in/k)`` pages and an ``R_out``-page output buffer.  Each
run streams through a :class:`repro.engine.PageCursor` (one refill = one read
round) and the output region is a :class:`repro.engine.BufferPool` (one slice
flush = one write round), exactly as analysed in §III-B (and the §II-C worked
example).
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.policies import EMSPlan
from repro.engine.buffers import BufferPool, PageCursor
from repro.engine.scheduler import TransferScheduler, stream_tiers
from repro.remote.simulator import RemoteMemory


# Typed input signature for the session API: ``engine.registry`` binds named
# task inputs to ``ems_sort``'s positional data-plane arguments through this,
# and maps each input to the WorkloadStats field that estimates its size.
INPUTS = ("page_ids",)
INPUT_STATS = {"page_ids": "size_r"}

# Spill streams this operator writes, in declaration order — the unit of
# fractional placement: intermediate sorted runs vs. the final merged output.
STREAMS = ("runs", "output")


@dataclasses.dataclass
class SortResult:
    run_page_ids: List[int]  # final single sorted run
    passes: int
    d_read: float
    d_write: float
    c_read: int
    c_write: int


def ems_output(result: SortResult) -> List[int]:
    """The operator's output pages — what a downstream task's input binds to."""
    return result.run_page_ids


def ems_measured(stats, result: SortResult):
    """Feed the measured output cardinality back into the workload stats."""
    return dataclasses.replace(stats, out=float(len(result.run_page_ids)))


def _merge_group(
    sched: TransferScheduler,
    runs: List[List[int]],
    plan: EMSPlan,
    rows_per_page: int,
    prefetch: bool,
    out_tier=None,
) -> List[int]:
    """Merge up to k runs into one; returns the new run's page ids."""
    per_run = max(1, int(plan.input_pages) // max(len(runs), 1))
    r_out = max(1, int(round(plan.output_pages)))
    cursors = [
        PageCursor(sched, r, per_run, prefetch=prefetch, ravel=True) for r in runs
    ]
    out_pool = BufferPool(sched, r_out, rows_per_page, tier=out_tier)

    while True:
        for c in cursors:
            c.refill()  # 1 read round per refill; no-op unless buffer is empty
        active = [c for c in cursors if c.buffered > 0]
        if not active:
            break
        # Emit everything provably below every active run's buffered horizon
        # (batched tournament: same refill/flush rounds as tuple-at-a-time).
        bounds = [b for c in active if (b := c.safe_bound()) is not None]
        bound = min(bounds) if bounds else None
        taken = [c.take_upto(bound) for c in active]
        merged = sched.sort_keys(np.concatenate(taken))
        if len(merged) == 0:
            # Bound excluded everything buffered: force the binding cursor on.
            binding = min(
                active, key=lambda c: c.safe_bound() or np.iinfo(np.int64).max
            )
            out_pool.add(sched.sort_keys(binding.take_upto(None)))
        else:
            out_pool.add(merged)
    out_pool.flush_all()
    return out_pool.pages()


def ems_sort(
    remote: RemoteMemory,
    page_ids: List[int],
    plan: EMSPlan,
    rows_per_page: int,
    prefetch: bool = False,
    count_run_formation: bool = True,
    tier=None,
) -> SortResult:
    """Full external merge sort of the pages' int64 keys under `plan`.

    ``remote`` is a single tier or a :class:`MemoryHierarchy`; on a
    hierarchy, ``tier`` names the placement runs and merge output spill to —
    a scalar, or a per-stream spec over ``STREAMS`` routing intermediate
    runs and the final merged output to different tiers.
    """
    if hasattr(page_ids, "page_ids"):  # accept a Relation (DAG scan output)
        page_ids = list(page_ids.page_ids)
    tiers = stream_tiers(tier, STREAMS)
    sched = TransferScheduler(remote, tier=tiers["output"])
    before = sched.snapshot()
    m_pages = max(1, int(plan.m))

    # ---- run formation: sort M-page chunks locally (§III-B a) -------------
    runs: List[List[int]] = []
    for start in range(0, len(page_ids), m_pages):
        ids = page_ids[start : start + m_pages]
        if count_run_formation:
            pages = sched.read(ids)  # 1 round
        else:
            pages = remote.peek_batch(ids)
        data = sched.sort_keys(np.concatenate([p.ravel() for p in pages]))
        out_pages = [data[i : i + rows_per_page] for i in range(0, len(data), rows_per_page)]
        if count_run_formation:
            runs.append(sched.write(out_pages, tier=tiers["runs"]))  # 1 round
        else:
            runs.append(remote.put_local(out_pages))

    # ---- merge passes (Algorithm 2) ----------------------------------------
    passes = 0
    while len(runs) > 1:
        # The last pass (a single merge group) writes the *output* stream;
        # every earlier pass writes intermediate runs.
        final = len(runs) <= plan.k
        out_tier = tiers["output"] if final else tiers["runs"]
        nxt: List[List[int]] = []
        for g in range(0, len(runs), plan.k):
            group = runs[g : g + plan.k]
            if len(group) == 1:
                nxt.append(group[0])
            else:
                nxt.append(
                    _merge_group(
                        sched, group, plan, rows_per_page, prefetch, out_tier=out_tier
                    )
                )
        runs = nxt
        passes += 1

    d = sched.delta(before)
    return SortResult(
        run_page_ids=runs[0] if runs else [],
        passes=passes,
        d_read=d.d_read,
        d_write=d.d_write,
        c_read=d.c_read,
        c_write=d.c_write,
    )


def ems_oracle(remote: RemoteMemory, page_ids: List[int]) -> np.ndarray:
    """Dense oracle: all keys, fully sorted (no accounting)."""
    return np.sort(np.concatenate([p.ravel() for p in remote.peek_batch(page_ids)]))
