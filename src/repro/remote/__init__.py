"""Faithful REMOP reproduction over a simulated remote-memory tier."""

from repro.remote.simulator import RemoteMemory, Relation, make_relation
from repro.remote.bnlj import bnlj, bnlj_oracle, JoinResult
from repro.remote.ems import ems_sort, ems_oracle, SortResult
from repro.remote.ehj import ehj, ehj_oracle, HashJoinResult
from repro.remote.eagg import eagg, eagg_oracle, AggResult

__all__ = [
    "RemoteMemory", "Relation", "make_relation",
    "bnlj", "bnlj_oracle", "JoinResult",
    "ems_sort", "ems_oracle", "SortResult",
    "ehj", "ehj_oracle", "HashJoinResult",
    "eagg", "eagg_oracle", "AggResult",
]
