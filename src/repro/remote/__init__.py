"""Faithful REMOP reproduction over simulated remote-memory tiers.

A single tier is a :class:`RemoteMemory`; an ordered stack of tiers with
capacities, per-tier ledgers, and migration rounds is a
:class:`MemoryHierarchy` (the runtime of the paper's Table I read as a
DRAM -> RDMA -> SSD waterfall).
"""

from repro.remote.simulator import (
    MemoryHierarchy,
    RemoteMemory,
    Relation,
    make_hierarchy,
    make_relation,
)
from repro.remote.bnlj import bnlj, bnlj_oracle, JoinResult
from repro.remote.ems import ems_sort, ems_oracle, SortResult
from repro.remote.ehj import ehj, ehj_oracle, HashJoinResult
from repro.remote.eagg import eagg, eagg_oracle, AggResult

__all__ = [
    "MemoryHierarchy", "RemoteMemory", "Relation",
    "make_hierarchy", "make_relation",
    "bnlj", "bnlj_oracle", "JoinResult",
    "ems_sort", "ems_oracle", "SortResult",
    "ehj", "ehj_oracle", "HashJoinResult",
    "eagg", "eagg_oracle", "AggResult",
    "ExecutionBackend", "BackendTier", "WallClock", "make_backend",
]

_BACKEND_NAMES = {"ExecutionBackend", "BackendTier", "WallClock", "make_backend"}


def __getattr__(name):
    # The execution backend imports jax + the Pallas kernels; load it lazily
    # so simulator-only consumers never pay (or require) the kernel stack.
    if name in _BACKEND_NAMES:
        from repro.remote import backend

        return getattr(backend, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
