"""Blocked nested-loop join over simulated remote memory (Algorithm 1).

Faithful to §III-A / §IV-B: the budget ``M`` is split into an input region
(``p_R`` of it pinned for the outer block, the rest cycling inner blocks) and
an output region flushed when full.  Every block read and output flush is one
transfer round on the :class:`RemoteMemory` ledger.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.policies import BNLJPlan
from repro.remote.simulator import Relation, RemoteMemory


@dataclasses.dataclass
class JoinResult:
    output_page_ids: List[int]
    output_rows: int
    d_read: float
    d_write: float
    c_read: int
    c_write: int


def _block_join(r_rows: np.ndarray, s_rows: np.ndarray) -> np.ndarray:
    """Equijoin two blocks on column 0; returns (r_key, r_payload, s_payload)."""
    rk, sk = r_rows[:, 0], s_rows[:, 0]
    # Sort-merge inside the block pair (vectorized all-to-all comparison).
    order = np.argsort(sk, kind="stable")
    sk_sorted = sk[order]
    lo = np.searchsorted(sk_sorted, rk, side="left")
    hi = np.searchsorted(sk_sorted, rk, side="right")
    counts = hi - lo
    if counts.sum() == 0:
        return np.empty((0, 3), dtype=np.int64)
    r_idx = np.repeat(np.arange(len(rk)), counts)
    starts = np.repeat(lo, counts)
    within = np.arange(len(r_idx)) - np.repeat(np.cumsum(counts) - counts, counts)
    s_idx = order[starts + within]
    return np.stack(
        [rk[r_idx], r_rows[r_idx, 1], s_rows[s_idx, 1]], axis=1
    ).astype(np.int64)


def bnlj(
    remote: RemoteMemory,
    outer: Relation,
    inner: Relation,
    plan: BNLJPlan,
    prefetch: bool = False,
) -> JoinResult:
    """Run BNLJ with the given buffer plan; returns output + ledger deltas."""
    p_r = max(1, int(round(plan.outer_pages)))
    p_s = max(1, int(round(plan.inner_pages)))
    r_out = max(1, int(round(plan.output_pages)))
    rows_per_page = outer.rows_per_page

    before = dataclasses.replace(remote.ledger)
    out_ids: List[int] = []
    out_rows = 0
    out_buf: List[np.ndarray] = []
    out_buf_rows = 0

    def flush(force: bool = False) -> None:
        nonlocal out_buf, out_buf_rows, out_rows
        while out_buf_rows >= r_out * rows_per_page or (force and out_buf_rows > 0):
            take = min(out_buf_rows, r_out * rows_per_page)
            allrows = np.concatenate(out_buf, axis=0)
            chunk, rest = allrows[:take], allrows[take:]
            pages = [
                chunk[i : i + rows_per_page]
                for i in range(0, len(chunk), rows_per_page)
            ]
            out_ids.extend(remote.write_batch(pages))  # 1 write round
            out_rows += len(chunk)
            out_buf = [rest] if len(rest) else []
            out_buf_rows = len(rest)
            if force and out_buf_rows == 0:
                break

    n_outer_blocks = (len(outer.page_ids) + p_r - 1) // p_r
    for bi in range(n_outer_blocks):
        r_ids = outer.page_ids[bi * p_r : (bi + 1) * p_r]
        r_pages = remote.read_batch(r_ids)  # 1 read round; block stays pinned
        r_block = np.concatenate(r_pages, axis=0)
        n_inner_blocks = (len(inner.page_ids) + p_s - 1) // p_s
        for bj in range(n_inner_blocks):
            s_ids = inner.page_ids[bj * p_s : (bj + 1) * p_s]
            # Inner stream is sequential and predictable: prefetchable (§IV-E).
            s_pages = remote.read_batch(s_ids, prefetched=prefetch and bj > 0)
            s_block = np.concatenate(s_pages, axis=0)
            matched = _block_join(r_block, s_block)
            if len(matched):
                out_buf.append(matched)
                out_buf_rows += len(matched)
                flush()
    flush(force=True)

    led = remote.ledger
    return JoinResult(
        output_page_ids=out_ids,
        output_rows=out_rows,
        d_read=led.d_read - before.d_read,
        d_write=led.d_write - before.d_write,
        c_read=led.c_read - before.c_read,
        c_write=led.c_write - before.c_write,
    )


def bnlj_oracle(remote: RemoteMemory, outer: Relation, inner: Relation) -> np.ndarray:
    """Dense oracle: full equijoin, canonically sorted rows (no accounting)."""
    from repro.remote.simulator import relation_rows

    r = relation_rows(remote, outer)
    s = relation_rows(remote, inner)
    out = _block_join(r, s)
    return out[np.lexsort((out[:, 2], out[:, 1], out[:, 0]))] if len(out) else out
