"""Blocked nested-loop join over simulated remote memory (Algorithm 1).

Faithful to §III-A / §IV-B: the budget ``M`` is split into an input region
(``p_R`` of it pinned for the outer block, the rest cycling inner blocks) and
an output region flushed when full.  All round accounting flows through the
spill engine: block reads are :class:`repro.engine.PageCursor` streams and the
output region is a single-stream :class:`repro.engine.BufferPool`.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Union

import numpy as np

from repro.core.policies import BNLJPlan
from repro.engine.buffers import BufferPool, PageCursor
from repro.engine.scheduler import TransferScheduler, stream_tiers
from repro.remote.simulator import Relation, RemoteMemory, as_relation


# Typed input signature for the session API: ``engine.registry`` binds named
# task inputs to ``bnlj``'s positional data-plane arguments through this, and
# maps each input to the WorkloadStats field that estimates its size.
INPUTS = ("outer", "inner")
INPUT_STATS = {"outer": "size_r", "inner": "size_s"}

# Spill streams this operator writes, in declaration order — the unit of
# fractional placement (``tier=`` may map each to a different tier).
STREAMS = ("output",)


@dataclasses.dataclass
class JoinResult:
    output_page_ids: List[int]
    output_rows: int
    d_read: float
    d_write: float
    c_read: int
    c_write: int
    # Probe-side filter telemetry (None when no inner_filter was applied):
    # measured surviving fraction of the inner stream, for replan="measured".
    inner_sel_measured: Optional[float] = None


def bnlj_output(result: JoinResult) -> List[int]:
    """The operator's output pages — what a downstream task's input binds to."""
    return result.output_page_ids


def bnlj_measured(stats, result: JoinResult):
    """Feed measured output cardinality (and probe selectivity) into stats."""
    stats = dataclasses.replace(stats, out=float(len(result.output_page_ids)))
    if result.inner_sel_measured is not None and hasattr(stats, "pushdown_sel"):
        stats = dataclasses.replace(
            stats, pushdown_sel=float(result.inner_sel_measured)
        )
    return stats


def _block_join(r_rows: np.ndarray, s_rows: np.ndarray) -> np.ndarray:
    """Equijoin two blocks on column 0; returns (r_key, r_payload, s_payload)."""
    rk, sk = r_rows[:, 0], s_rows[:, 0]
    # Sort-merge inside the block pair (vectorized all-to-all comparison).
    order = np.argsort(sk, kind="stable")
    sk_sorted = sk[order]
    lo = np.searchsorted(sk_sorted, rk, side="left")
    hi = np.searchsorted(sk_sorted, rk, side="right")
    counts = hi - lo
    if counts.sum() == 0:
        return np.empty((0, 3), dtype=np.int64)
    r_idx = np.repeat(np.arange(len(rk)), counts)
    starts = np.repeat(lo, counts)
    within = np.arange(len(r_idx)) - np.repeat(np.cumsum(counts) - counts, counts)
    s_idx = order[starts + within]
    return np.stack(
        [rk[r_idx], r_rows[r_idx, 1], s_rows[s_idx, 1]], axis=1
    ).astype(np.int64)


def bnlj(
    remote: RemoteMemory,
    outer: Relation,
    inner: Relation,
    plan: BNLJPlan,
    prefetch: bool = False,
    tier=None,
    inner_filter: Union[float, None, object] = None,
    pushdown: bool = False,
) -> JoinResult:
    """Run BNLJ with the given buffer plan; returns output + ledger deltas.

    ``remote`` is a single tier or a :class:`MemoryHierarchy`; on a
    hierarchy, ``tier`` names the placement the output spill is routed to —
    a scalar, or a per-stream spec over ``STREAMS`` (see ``stream_tiers``).
    ``outer`` / ``inner`` accept a ``Relation`` or a bare page-id list
    (a DAG upstream's output), coerced via ``as_relation``.

    ``inner_filter`` applies a probe-side filter to the inner stream — a
    scalar selectivity in (0, 1] (deterministic positional keep rule) or a
    ``predicate(page) -> bool``.  With ``pushdown=False`` every inner page
    still makes the round trip and is filtered locally; with
    ``pushdown=True`` the filter executes at any capable tier holding inner
    pages and only survivors are shipped (``c_pushdown`` rounds).  The join
    output is identical either way — pushdown changes D, never results.
    """
    outer = as_relation(remote, outer)
    inner = as_relation(remote, inner)
    tiers = stream_tiers(tier, STREAMS)
    p_r = max(1, int(round(plan.outer_pages)))
    p_s = max(1, int(round(plan.inner_pages)))
    r_out = max(1, int(round(plan.output_pages)))

    sched = TransferScheduler(remote, tier=tiers["output"])
    before = sched.snapshot()
    out_pool = BufferPool(sched, r_out, outer.rows_per_page, tier=tiers["output"])

    filt_kw = None
    if inner_filter is not None:
        filt_kw = (
            {"predicate": inner_filter}
            if callable(inner_filter)
            else {"selectivity": float(inner_filter)}
        )
    inner_kept: Optional[int] = None

    for r_block in PageCursor(sched, outer.page_ids, p_r).blocks():
        if filt_kw is None:
            # Inner stream is sequential and predictable: prefetchable
            # (§IV-E); a fresh cursor per outer block, so its first round is
            # never hidden.
            for s_block in PageCursor(sched, inner.page_ids, p_s, prefetch=prefetch).blocks():
                out_pool.add(_block_join(r_block, s_block))
        else:
            # Filtered probe: same ``p_s``-page request rounds as the plain
            # stream; survivors join in one block per request chunk.
            pages = sched.read_filtered(
                inner.page_ids, batch_pages=p_s, pushdown=pushdown, **filt_kw
            )
            inner_kept = len(pages)
            for start in range(0, len(pages), p_s):
                s_rows = np.concatenate(pages[start : start + p_s], axis=0)
                out_pool.add(_block_join(r_block, s_rows))
    out_pool.flush_all()

    d = sched.delta(before)
    return JoinResult(
        output_page_ids=out_pool.pages(),
        output_rows=out_pool.rows_flushed,
        d_read=d.d_read,
        d_write=d.d_write,
        c_read=d.c_read,
        c_write=d.c_write,
        inner_sel_measured=(
            None
            if filt_kw is None or not inner.page_ids
            else (inner_kept or 0) / len(inner.page_ids)
        ),
    )


def bnlj_oracle(remote: RemoteMemory, outer: Relation, inner: Relation) -> np.ndarray:
    """Dense oracle: full equijoin, canonically sorted rows (no accounting)."""
    from repro.remote.simulator import relation_rows

    r = relation_rows(remote, outer)
    s = relation_rows(remote, inner)
    out = _block_join(r, s)
    return out[np.lexsort((out[:, 2], out[:, 1], out[:, 0]))] if len(out) else out
