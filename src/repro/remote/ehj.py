"""External (radix-partitioned) hash join over simulated remote memory.

Algorithm 3 / §III-C: both relations are hash-partitioned into P partitions; a
fraction ``sigma`` of partitions spill.  Phase P1 partitions the build side
(resident partitions become in-memory hash tables, spilled tuples flush
through the R_w write pool); P2 partitions the probe side (resident tuples
probe on the fly, spilled tuples stage through R_s, resident output through
R_o); P3 re-reads each spilled pair and joins it.  Buffer pools obey the plan;
every block read / pool flush is one transfer round.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.policies import EHJPlan
from repro.remote.bnlj import _block_join
from repro.remote.simulator import Relation, RemoteMemory, relation_rows


@dataclasses.dataclass
class HashJoinResult:
    output_rows: int
    sigma: float
    d_read: float
    d_write: float
    c_read: int
    c_write: int
    per_phase_rounds: Dict[str, int]


class _PartitionPool:
    """A write pool divided into per-partition slices (R_w / R_s / R_o).

    §III-C: a pool of ``capacity_pages`` shared by ``n_streams`` partitions
    gives each a slice of ``capacity/n_streams`` pages; when a slice fills it
    is flushed in one batched write round, so a stream of V pages costs
    ~ V / (capacity/n_streams) rounds — the sigma^2*P*|B|/R_w term.
    """

    def __init__(self, remote: RemoteMemory, capacity_pages: float,
                 rows_per_page: int, n_streams: int = 1):
        self.remote = remote
        slice_pages = max(1, int(capacity_pages / max(n_streams, 1)))
        self.slice_rows = slice_pages * rows_per_page
        self.rows_per_page = rows_per_page
        self.buffers: Dict[int, List[np.ndarray]] = {}
        self.buffered: Dict[int, int] = {}
        self.out_pages: Dict[int, List[int]] = {}
        self.flushes = 0

    def add(self, pid: int, rows: np.ndarray) -> None:
        if not len(rows):
            return
        self.buffers.setdefault(pid, []).append(rows)
        self.buffered[pid] = self.buffered.get(pid, 0) + len(rows)
        while self.buffered[pid] >= self.slice_rows:
            self._flush(pid, self.slice_rows)

    def _flush(self, pid: int, take_rows: int | None = None) -> None:
        rows = np.concatenate(self.buffers.pop(pid), axis=0)
        take = len(rows) if take_rows is None else min(take_rows, len(rows))
        chunk, rest = rows[:take], rows[take:]
        self.buffered[pid] = len(rest)
        if len(rest):
            self.buffers[pid] = [rest]
        pages = [chunk[i : i + self.rows_per_page] for i in range(0, len(chunk), self.rows_per_page)]
        self.out_pages.setdefault(pid, []).extend(self.remote.write_batch(pages))
        self.flushes += 1

    def flush_all(self) -> None:
        for pid in list(self.buffers):
            if self.buffered.get(pid, 0):
                self._flush(pid)


def ehj(
    remote: RemoteMemory,
    build: Relation,
    probe: Relation,
    plan: EHJPlan,
    rows_per_page: int | None = None,
    prefetch: bool = False,
) -> HashJoinResult:
    """Run the three-phase external hash join under `plan`."""
    rows_per_page = rows_per_page or build.rows_per_page
    p = plan.partitions
    n_spilled = int(round(plan.sigma * p))
    spilled = set(range(p - n_spilled, p))  # deterministic spill set
    before = dataclasses.replace(remote.ledger)
    phase_rounds: Dict[str, int] = {}

    def snapshot() -> int:
        return remote.ledger.c_total

    def hash_part(keys: np.ndarray) -> np.ndarray:
        h = keys.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
        return ((h >> np.uint64(33)) % np.uint64(p)).astype(np.int64)

    # ---- P1: partition build, build resident tables, spill the rest -------
    t0 = snapshot()
    r_r1, r_w1 = plan.p1
    read_pages = max(1, int(round(r_r1)))
    build_pool = _PartitionPool(remote, r_w1, rows_per_page, n_streams=max(len(spilled), 1))
    resident_build: Dict[int, List[np.ndarray]] = {q: [] for q in range(p) if q not in spilled}
    for start in range(0, len(build.page_ids), read_pages):
        ids = build.page_ids[start : start + read_pages]
        pages = remote.read_batch(ids, prefetched=prefetch and start > 0)
        rows = np.concatenate(pages, axis=0)
        parts = hash_part(rows[:, 0])
        for q in np.unique(parts):
            sel = rows[parts == q]
            if int(q) in spilled:
                build_pool.add(int(q), sel)
            else:
                resident_build[int(q)].append(sel)
    build_pool.flush_all()
    resident_tables = {
        q: (np.concatenate(v, axis=0) if v else np.empty((0, 2), dtype=np.int64))
        for q, v in resident_build.items()
    }
    phase_rounds["P1"] = snapshot() - t0

    # ---- P2: partition probe; probe resident, stage spilled ----------------
    t0 = snapshot()
    r_r2, r_s2, r_o2 = plan.p2
    read_pages = max(1, int(round(r_r2)))
    stage_pool = _PartitionPool(remote, r_s2, rows_per_page, n_streams=max(len(spilled), 1))
    out_pool = _PartitionPool(remote, r_o2, rows_per_page)
    output_rows = 0
    for start in range(0, len(probe.page_ids), read_pages):
        ids = probe.page_ids[start : start + read_pages]
        pages = remote.read_batch(ids, prefetched=prefetch and start > 0)
        rows = np.concatenate(pages, axis=0)
        parts = hash_part(rows[:, 0])
        for q in np.unique(parts):
            sel = rows[parts == q]
            if int(q) in spilled:
                stage_pool.add(int(q), sel)
            else:
                matched = _block_join(resident_tables[int(q)], sel)
                if len(matched):
                    output_rows += len(matched)
                    out_pool.add(p, matched)  # single resident-output stream
    stage_pool.flush_all()
    phase_rounds["P2"] = snapshot() - t0

    # ---- P3: external rounds over spilled pairs ----------------------------
    t0 = snapshot()
    r_r3, r_o3 = plan.p3
    read_pages = max(1, int(round(r_r3)))
    ext_out_pool = _PartitionPool(remote, r_o3, rows_per_page)
    for q in sorted(spilled):
        b_ids = build_pool.out_pages.get(q, [])
        q_ids = stage_pool.out_pages.get(q, [])
        if not b_ids or not q_ids:
            continue
        b_rows_parts = []
        for start in range(0, len(b_ids), read_pages):
            b_rows_parts.extend(
                remote.read_batch(b_ids[start : start + read_pages],
                                  prefetched=prefetch and start > 0)
            )
        b_rows = np.concatenate(b_rows_parts, axis=0)
        for start in range(0, len(q_ids), read_pages):
            q_pages = remote.read_batch(q_ids[start : start + read_pages],
                                        prefetched=prefetch and start > 0)
            matched = _block_join(b_rows, np.concatenate(q_pages, axis=0))
            if len(matched):
                output_rows += len(matched)
                ext_out_pool.add(q, matched)
    out_pool.flush_all()
    ext_out_pool.flush_all()
    phase_rounds["P3"] = snapshot() - t0

    led = remote.ledger
    return HashJoinResult(
        output_rows=output_rows,
        sigma=plan.sigma,
        d_read=led.d_read - before.d_read,
        d_write=led.d_write - before.d_write,
        c_read=led.c_read - before.c_read,
        c_write=led.c_write - before.c_write,
        per_phase_rounds=phase_rounds,
    )


def ehj_oracle(remote: RemoteMemory, build: Relation, probe: Relation) -> int:
    """Oracle row count for the equijoin (no accounting)."""
    b = relation_rows(remote, build)
    q = relation_rows(remote, probe)
    return len(_block_join(b, q))
