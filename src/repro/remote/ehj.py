"""External (radix-partitioned) hash join over simulated remote memory.

Algorithm 3 / §III-C: both relations are hash-partitioned into P partitions; a
fraction ``sigma`` of partitions spill.  Phase P1 partitions the build side
(resident partitions become in-memory hash tables, spilled tuples flush
through the R_w write pool); P2 partitions the probe side (resident tuples
probe on the fly, spilled tuples stage through R_s, resident output through
R_o); P3 re-reads each spilled pair and joins it.  The R_w/R_s/R_o pools are
per-partition-sliced :class:`repro.engine.BufferPool` instances and every
block read is a :class:`repro.engine.PageCursor` round, so the ledger counts
match the Table V terms.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.policies import EHJPlan
from repro.engine.buffers import BufferPool, PageCursor
from repro.engine.scheduler import TransferScheduler, stream_tiers
from repro.remote.bnlj import _block_join
from repro.remote.simulator import Relation, RemoteMemory, as_relation, relation_rows


# Typed input signature for the session API: ``engine.registry`` binds named
# task inputs to ``ehj``'s positional data-plane arguments through this, and
# maps each input to the WorkloadStats field that estimates its size.
INPUTS = ("build", "probe")
INPUT_STATS = {"build": "size_r", "probe": "size_s"}

# Spill streams this operator writes, in declaration order — the unit of
# fractional placement: spilled build partitions, staged probe tuples, and
# the join output (resident + external rounds share the output stream tier).
STREAMS = ("build", "stage", "output")


@dataclasses.dataclass
class HashJoinResult:
    output_rows: int
    sigma: float
    d_read: float
    d_write: float
    c_read: int
    c_write: int
    per_phase_rounds: Dict[str, int]
    output_page_ids: List[int] = dataclasses.field(default_factory=list)


def ehj_output(result: HashJoinResult) -> List[int]:
    """The operator's output pages — what a downstream task's input binds to."""
    return result.output_page_ids


def ehj_measured(stats, result: HashJoinResult):
    """Feed the measured output cardinality back into the workload stats.

    This is the ROADMAP's known misestimation case: the planner's ``out``
    estimate can be ~8x off at high selectivity, and the measured page count
    is what ``Session.run(replan="measured")`` re-arbitrates with.
    """
    return dataclasses.replace(stats, out=float(len(result.output_page_ids)))


def ehj(
    remote: RemoteMemory,
    build: Relation,
    probe: Relation,
    plan: EHJPlan,
    rows_per_page: int | None = None,
    prefetch: bool = False,
    tier=None,
) -> HashJoinResult:
    """Run the three-phase external hash join under `plan`.

    ``remote`` is a single tier or a :class:`MemoryHierarchy`; on a
    hierarchy, ``tier`` names the placement spilled partitions and output
    are routed to — a scalar, or a per-stream spec over ``STREAMS`` (e.g.
    spilled build partitions on DRAM, staged probe tuples on SSD).
    ``build`` / ``probe`` accept a ``Relation`` or a bare page-id list.
    """
    build = as_relation(remote, build)
    probe = as_relation(remote, probe)
    tiers = stream_tiers(tier, STREAMS)
    rows_per_page = rows_per_page or build.rows_per_page
    p = plan.partitions
    n_spilled = int(round(plan.sigma * p))
    spilled = set(range(p - n_spilled, p))  # deterministic spill set
    sched = TransferScheduler(remote, tier=tiers["output"])
    before = sched.snapshot()
    phase_rounds: Dict[str, int] = {}

    def hash_part(keys: np.ndarray) -> np.ndarray:
        h = keys.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
        return ((h >> np.uint64(33)) % np.uint64(p)).astype(np.int64)

    # ---- P1: partition build, build resident tables, spill the rest -------
    t0 = sched.snapshot()
    r_r1, r_w1 = plan.p1
    build_pool = BufferPool(sched, r_w1, rows_per_page,
                            n_streams=max(len(spilled), 1),
                            tier=tiers["build"])
    resident_build: Dict[int, List[np.ndarray]] = {q: [] for q in range(p) if q not in spilled}
    for rows in PageCursor(sched, build.page_ids, round(r_r1),
                           prefetch=prefetch).blocks():
        parts = hash_part(rows[:, 0])
        for q, sel in sched.partitions(rows, parts):
            if q in spilled:
                build_pool.add(sel, stream=q)
            else:
                resident_build[q].append(sel)
    build_pool.flush_all()
    resident_tables = {
        q: (np.concatenate(v, axis=0) if v else np.empty((0, 2), dtype=np.int64))
        for q, v in resident_build.items()
    }
    phase_rounds["P1"] = sched.delta(t0).c_total

    # ---- P2: partition probe; probe resident, stage spilled ----------------
    t0 = sched.snapshot()
    r_r2, r_s2, r_o2 = plan.p2
    stage_pool = BufferPool(sched, r_s2, rows_per_page,
                            n_streams=max(len(spilled), 1),
                            tier=tiers["stage"])
    out_pool = BufferPool(sched, r_o2, rows_per_page, tier=tiers["output"])
    output_rows = 0
    for rows in PageCursor(sched, probe.page_ids, round(r_r2),
                           prefetch=prefetch).blocks():
        parts = hash_part(rows[:, 0])
        for q, sel in sched.partitions(rows, parts):
            if q in spilled:
                stage_pool.add(sel, stream=q)
            else:
                matched = _block_join(resident_tables[q], sel)
                if len(matched):
                    output_rows += len(matched)
                    out_pool.add(matched)  # single resident-output stream
    stage_pool.flush_all()
    phase_rounds["P2"] = sched.delta(t0).c_total

    # ---- P3: external rounds over spilled pairs ----------------------------
    t0 = sched.snapshot()
    r_r3, r_o3 = plan.p3
    read_pages = round(r_r3)
    ext_out_pool = BufferPool(sched, r_o3, rows_per_page, tier=tiers["output"])
    for q in sorted(spilled):
        b_ids = build_pool.pages(q)
        q_ids = stage_pool.pages(q)
        if not b_ids or not q_ids:
            continue
        b_rows = PageCursor(sched, b_ids, read_pages, prefetch=prefetch).read_all()
        for q_rows in PageCursor(sched, q_ids, read_pages,
                                 prefetch=prefetch).blocks():
            matched = _block_join(b_rows, q_rows)
            if len(matched):
                output_rows += len(matched)
                ext_out_pool.add(matched, stream=q)
    out_pool.flush_all()
    ext_out_pool.flush_all()
    phase_rounds["P3"] = sched.delta(t0).c_total

    d = sched.delta(before)
    output_ids = list(out_pool.pages())
    for q in sorted(spilled):
        output_ids.extend(ext_out_pool.pages(q))
    return HashJoinResult(
        output_rows=output_rows,
        sigma=plan.sigma,
        d_read=d.d_read,
        d_write=d.d_write,
        c_read=d.c_read,
        c_write=d.c_write,
        per_phase_rounds=phase_rounds,
        output_page_ids=output_ids,
    )


def ehj_oracle(remote: RemoteMemory, build: Relation, probe: Relation) -> int:
    """Oracle row count for the equijoin (no accounting)."""
    b = relation_rows(remote, build)
    q = relation_rows(remote, probe)
    return len(_block_join(b, q))
