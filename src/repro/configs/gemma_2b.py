"""gemma-2b [arXiv:2403.08295; hf]: 18L d2048 8H MQA(kv=1) d_ff=16384 GeGLU."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    attn_type="gqa",
    mlp_type="geglu",
    sub_quadratic=False,
)
