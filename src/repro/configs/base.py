"""Model / shape configuration dataclasses for the assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture from the assigned pool (verbatim numbers; see DESIGN.md
    §Arch-applicability for recorded spec discrepancies)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio_encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---------------------------------------------------------
    attn_type: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0

    # --- MLA (deepseek) ----------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MLP ----------------------------------------------------------------
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0
    router_aux_coef: float = 0.001
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # --- hybrid (recurrentgemma) ---------------------------------------------
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    window: int = 0
    lru_width: int = 0

    # --- encoder-decoder -----------------------------------------------------
    n_encoder_layers: int = 0
    cross_attention: bool = False

    # --- modality frontend stubs ---------------------------------------------
    frontend: str = ""  # "" | vision_stub | audio_stub
    frontend_seq: int = 0  # stub tokens prepended (vlm) / encoder frames (audio)
    frontend_dim: int = 0

    # --- misc -----------------------------------------------------------------
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    sub_quadratic: bool = False  # supports long_500k decode

    @property
    def qkv_heads_padded(self) -> int:
        return self.n_heads

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim if self.ssm_head_dim else 0


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": ShapeSpec("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeSpec("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": ShapeSpec("long_500k", seq_len=524288, global_batch=1, kind="decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Task rules: long_500k only for sub-quadratic archs; decode needs a decoder."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k context is quadratic — skipped per task spec"
    return True, ""


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else cfg.n_kv_heads,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
    )
    if cfg.attn_type == "mla":
        changes.update(kv_lora_rank=32, q_lora_rank=0, rope_head_dim=8,
                       nope_head_dim=16, v_head_dim=16, head_dim=16)
    if cfg.n_experts:
        # capacity_factor = E ensures no capacity drops in tiny smoke tests,
        # keeping prefill/decode exactly consistent.
        changes.update(n_experts=4, experts_per_token=2, moe_d_ff=64,
                       n_shared_experts=min(cfg.n_shared_experts, 1),
                       capacity_factor=4.0)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.lru_width:
        changes.update(lru_width=64, window=32)
    if cfg.window and not cfg.lru_width:
        changes.update(window=32)
    if cfg.n_encoder_layers:
        changes.update(n_encoder_layers=2)
    if cfg.frontend_seq:
        changes.update(frontend_seq=8, frontend_dim=32)
    if cfg.block_pattern:
        changes.update(n_layers=len(cfg.block_pattern))
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
