"""granite-20b [arXiv:2405.04324; hf]: 52L d6144 48H MQA(kv=1) d_ff=24576."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    attn_type="gqa",
    mlp_type="gelu",  # granite-20b-code is a gpt-bigcode derivative
    sub_quadratic=False,
)
