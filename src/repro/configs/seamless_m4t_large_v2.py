"""seamless-m4t-large-v2 [arXiv:2308.11596; hf]: enc-dec, speech stub.

24 encoder + 24 decoder layers, d1024 16H kv16 d_ff=8192, vocab 256206.
The speech frontend (w2v-BERT) is a STUB: input_specs() provides precomputed
frame embeddings (frontend_dim=1024) consumed by the text-decoder backbone
through cross-attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio_encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    attn_type="gqa",
    mlp_type="gelu",
    n_encoder_layers=24,
    cross_attention=True,
    frontend="audio_stub",
    frontend_seq=4096,   # encoder frames per train_4k cell (= seq_len)
    frontend_dim=1024,
    sub_quadratic=False,
)
