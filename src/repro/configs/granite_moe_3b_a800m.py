"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base].

Assigned spec: 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155,
"MoE 40e top-8 — 32 experts top-8".
DISCREPANCY (recorded in DESIGN.md): headline says 40 experts, bracket note
says 32; we implement the assigned headline: 40 experts, top-8, expert
d_ff=512.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    attn_type="gqa",
    mlp_type="swiglu",
    n_experts=40,
    experts_per_token=8,
    n_shared_experts=0,
    moe_d_ff=512,
    sub_quadratic=False,
)
