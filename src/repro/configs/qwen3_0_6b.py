"""qwen3-0.6b [hf:Qwen/Qwen3-0.6B]: 28L d1024 16H kv8, qk_norm.

Qwen3 uses head_dim=128 (detached from d_model/n_heads); we follow HF.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    attn_type="gqa",
    qk_norm=True,
    rope_theta=1e6,
    mlp_type="swiglu",
    sub_quadratic=False,
)
