"""Architecture registry: ``--arch <id>`` resolves here."""

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, reduced, shape_applicable
from repro.configs.deepseek_v2_lite_16b import CONFIG as DEEPSEEK_V2_LITE_16B
from repro.configs.granite_moe_3b_a800m import CONFIG as GRANITE_MOE_3B_A800M
from repro.configs.gemma_7b import CONFIG as GEMMA_7B
from repro.configs.gemma_2b import CONFIG as GEMMA_2B
from repro.configs.qwen3_0_6b import CONFIG as QWEN3_0_6B
from repro.configs.granite_20b import CONFIG as GRANITE_20B
from repro.configs.mamba2_370m import CONFIG as MAMBA2_370M
from repro.configs.paligemma_3b import CONFIG as PALIGEMMA_3B
from repro.configs.seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T_LARGE_V2
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B

ARCHS = {c.name: c for c in [
    DEEPSEEK_V2_LITE_16B,
    GRANITE_MOE_3B_A800M,
    GEMMA_7B,
    GEMMA_2B,
    QWEN3_0_6B,
    GRANITE_20B,
    MAMBA2_370M,
    PALIGEMMA_3B,
    SEAMLESS_M4T_LARGE_V2,
    RECURRENTGEMMA_2B,
]}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeSpec", "get_arch",
           "reduced", "shape_applicable"]
