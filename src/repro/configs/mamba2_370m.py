"""mamba2-370m [arXiv:2405.21060]: 48L d1024 SSD, ssm_state=128, attn-free."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attn_type="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
    sub_quadratic=True,  # O(1)-state decode: runs long_500k
)
