"""paligemma-3b [arXiv:2407.07726; hf]: SigLIP stub + gemma-2b backbone.

The SigLIP vision tower is a STUB per the task spec: input_specs() provides
256 precomputed patch embeddings (frontend_dim=1152, SigLIP-So400m width)
projected into the LM; the decoder is the gemma-2b backbone with a
prefix-LM mask over the image tokens.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    attn_type="gqa",
    mlp_type="geglu",
    frontend="vision_stub",
    frontend_seq=256,
    frontend_dim=1152,
    sub_quadratic=False,
)
