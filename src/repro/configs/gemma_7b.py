"""gemma-7b [arXiv:2403.08295; hf]: 28L d3072 16H kv16 d_ff=24576 GeGLU."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    attn_type="gqa",
    mlp_type="geglu",
    sub_quadratic=False,
)
