"""recurrentgemma-2b [arXiv:2402.19427; hf]: RG-LRU + local attn, 1:2.

26 layers in the Griffin pattern (rec, rec, local-attn) — 2 recurrent
blocks per local-attention block, window 2048, lru_width=2560.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attn_type="gqa",
    mlp_type="geglu",
    block_pattern=("rec", "rec", "attn_local"),
    window=2048,
    lru_width=2560,
    conv_width=4,
    sub_quadratic=True,  # bounded window + O(1) recurrent state: runs long_500k
)
