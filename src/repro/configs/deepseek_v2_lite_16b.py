"""deepseek-v2-lite-16b [arXiv:2405.04434; hf].

Assigned spec: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
"MoE 64e top-6 — MLA kv_lora=512, 2 shared+160 routed top-6".
DISCREPANCY (recorded in DESIGN.md): the headline says 64 routed experts
top-6 while the trailing note says 160 routed; DeepSeek-V2-Lite's published
config is 64 routed + 2 shared, top-6, with the first layer dense and
moe_d_ff=1408 — we implement that reading.  MLA: kv_lora_rank=512,
per-head 128 nope + 64 rope dims, v_head_dim=128.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=192,  # nope 128 + rope 64
    d_ff=10944,    # dense first layer FFN (DeepSeek-V2-Lite)
    vocab_size=102400,
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=0,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    mlp_type="swiglu",
    n_experts=64,
    experts_per_token=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_k_dense=1,
    sub_quadratic=False,
)
