"""Fault-tolerance primitives: crash-safe stepping, straggler watch, retry.

At 1000+ nodes the mean time between node failures drops below job length;
the contract here is: (1) all state mutations go through the checkpoint
store's atomic publish, (2) any step may raise (device loss, preemption) and
the loop restarts from the latest checkpoint, (3) slow steps are surfaced to
a straggler callback so the scheduler can trigger hot-spares / re-mesh
(elastic.py) instead of letting one slow host gate the collective.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

log = logging.getLogger("repro.ft")


@dataclasses.dataclass
class StragglerWatch:
    """EWMA step-time watchdog: flags steps slower than `threshold` x mean."""

    threshold: float = 2.0
    alpha: float = 0.1
    mean: Optional[float] = None
    slow_steps: int = 0
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def observe(self, step: int, seconds: float) -> bool:
        if self.mean is None:
            self.mean = seconds
            return False
        is_slow = seconds > self.threshold * self.mean
        if is_slow:
            self.slow_steps += 1
            log.warning("straggler: step %d took %.3fs (mean %.3fs)",
                        step, seconds, self.mean)
            if self.on_straggler:
                self.on_straggler(step, seconds, self.mean)
        # Slow steps don't poison the mean.
        self.mean = (1 - self.alpha) * self.mean + self.alpha * min(
            seconds, self.threshold * self.mean)
        return is_slow


@dataclasses.dataclass
class RetryPolicy:
    max_restarts: int = 3
    backoff_seconds: float = 1.0

    def run(self, fn: Callable[[], None],
            on_restart: Optional[Callable[[int, BaseException], None]] = None):
        """Run fn; on failure invoke on_restart (reload checkpoint) and retry."""
        attempt = 0
        while True:
            try:
                return fn()
            except KeyboardInterrupt:
                raise
            except BaseException as e:
                attempt += 1
                if attempt > self.max_restarts:
                    log.error("giving up after %d restarts", self.max_restarts)
                    raise
                log.warning("step failed (%r); restart %d/%d",
                            e, attempt, self.max_restarts)
                if on_restart:
                    on_restart(attempt, e)
                time.sleep(self.backoff_seconds * attempt)
