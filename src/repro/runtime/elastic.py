"""Elastic scaling: checkpoint -> re-mesh -> reshard-restore -> continue.

The checkpoint format is sharding-agnostic (full arrays per leaf), so scaling
from N to M devices is: build the new mesh, recompute shardings against it,
and `device_put` the restored leaves onto them.  This module packages that
hand-off; on a real cluster the coordinator triggers it when membership
changes (node loss -> shrink; replacements -> grow).
"""

from __future__ import annotations

import logging
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.store import CheckpointStore
from repro.distributed.sharding import Sharder
from repro.launch.mesh import make_mesh_for

log = logging.getLogger("repro.elastic")


def reshard_state(state, new_shardings):
    """Move a (host or device) state pytree onto new shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.device_get(x), s), state, new_shardings)


class ElasticSession:
    """Rebuilds mesh/sharder/steps when the device count changes."""

    def __init__(self, build_steps: Callable[[Any, Sharder], Any],
                 model_parallel: int = 16):
        self.build_steps = build_steps
        self.model_parallel = model_parallel
        self.mesh = None
        self.sharder = None
        self.steps = None

    def ensure(self, n_devices: Optional[int] = None):
        n = n_devices or len(jax.devices())
        if self.mesh is not None and self.mesh.devices.size == n:
            return self.steps
        log.info("(re)meshing for %d devices", n)
        self.mesh = make_mesh_for(n, self.model_parallel)
        self.sharder = Sharder(self.mesh)
        self.steps = self.build_steps(self.mesh, self.sharder)
        return self.steps

    def restore_into(self, store: CheckpointStore, template, shardings):
        step, state, meta = store.restore_latest(template, shardings)
        return step, state
