from repro.runtime import ft, train_loop, serve_loop, elastic
