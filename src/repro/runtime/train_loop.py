"""Fault-tolerant training loop: checkpoint/restart + straggler watch.

The loop is deliberately dumb about *what* it runs (any jitted step works)
and strict about *how*: resumable data (step-keyed), atomic async
checkpoints, restart-from-latest on failure, straggler accounting.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from repro.checkpoint.store import CheckpointStore
from repro.runtime.ft import RetryPolicy, StragglerWatch

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    async_checkpoint: bool = True
    max_restarts: int = 3


def train(
    step_fn: Callable,  # (state, batch) -> (state, metrics)
    state: Any,
    batches: Callable[[int], Iterator],  # start_step -> iterator
    store: Optional[CheckpointStore],
    loop_cfg: LoopConfig,
    state_shardings=None,
    metrics_cb: Optional[Callable[[int, Dict], None]] = None,
) -> Any:
    """Run to total_steps with restart-from-checkpoint on failure."""
    watch = StragglerWatch()
    start_state = state

    def current_step(s) -> int:
        return int(jax.device_get(s["step"]))

    def resume():
        if store is None:
            return start_state
        step, restored, _ = store.restore_latest(
            jax.tree.map(lambda x: x, start_state), shardings=state_shardings)
        if restored is None:
            return start_state
        log.info("resumed from checkpoint at step %d", step)
        return restored

    holder = {"state": state}

    def body():
        state = holder["state"]
        step = current_step(state)
        it = iter(batches(step))
        while step < loop_cfg.total_steps:
            batch = next(it)
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss_total"])
            dt = time.time() - t0
            step = current_step(state)
            holder["state"] = state
            watch.observe(step, dt)
            if metrics_cb and step % loop_cfg.log_every == 0:
                metrics_cb(step, jax.device_get(metrics))
            if store is not None and step % loop_cfg.checkpoint_every == 0:
                store.save(step, state, {"step": step},
                           blocking=not loop_cfg.async_checkpoint)
        if store is not None:
            store.wait()
            store.save(loop_cfg.total_steps, holder["state"],
                       {"step": loop_cfg.total_steps}, blocking=True)
        return holder["state"]

    def on_restart(attempt, err):
        holder["state"] = resume()

    return RetryPolicy(max_restarts=loop_cfg.max_restarts).run(
        body, on_restart=on_restart)
