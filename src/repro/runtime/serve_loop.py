"""LM serving shim over the engine's serving surface (continuous batching).

The generic serving machinery lives in :mod:`repro.engine.server` — see the
README migration table: :class:`~repro.engine.server.Server` /
:class:`~repro.engine.server.QueryRequest` serve query pipelines on a shared
memory hierarchy, and :class:`~repro.engine.server.SlotLoop` is the
continuous-batching slot discipline both surfaces share.  This module keeps
the LM decode surface (``Request`` / ``ServeEngine``) as a thin shim: the
prefill/decode_step model calls stay here, while the batching loop — free
slots refill FIFO, every active request decodes one token per quantum, slot
release on EOS/length — is ``SlotLoop`` verbatim, not a parallel
implementation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine.server import QueryRequest, Server, SlotLoop
from repro.models import transformer as tf

__all__ = ["Request", "ServeEngine", "QueryRequest", "Server", "SlotLoop"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Single-host reference engine (the multi-pod path jits the same fns)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 256,
                 batch_slots: int = 4, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_slots = batch_slots
        self.eos_id = eos_id
        self._decode = jax.jit(
            lambda p, c, t, pos: tf.decode_step(p, cfg, c, t, pos))

    def _prefill_request(self, req: Request):
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, caches = tf.prefill(self.params, self.cfg, batch)
        caches = tf.pad_caches(self.cfg, caches, self.max_len)
        first = int(jnp.argmax(logits[0]))
        req.out_tokens.append(first)
        return caches, len(req.prompt)

    def submit(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Run all requests to completion with continuous batching."""
        results: Dict[int, List[int]] = {}

        def start(req: Request) -> dict:
            caches, plen = self._prefill_request(req)
            return {"caches": caches, "pos": plen}

        def step(req: Request, entry: dict) -> bool:
            token = jnp.asarray([req.out_tokens[-1]], jnp.int32)
            logits, entry["caches"] = self._decode(
                self.params, entry["caches"], token,
                jnp.asarray(entry["pos"], jnp.int32))
            entry["pos"] += 1
            nxt = int(jnp.argmax(logits[0]))
            req.out_tokens.append(nxt)
            if (len(req.out_tokens) >= req.max_new_tokens
                    or (self.eos_id is not None and nxt == self.eos_id)
                    or entry["pos"] >= self.max_len - 1):
                req.done = True
                results[req.rid] = req.out_tokens
                return True
            return False

        SlotLoop(self.batch_slots, start, step).run(requests)
        return results
