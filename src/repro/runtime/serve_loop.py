"""Batched serving loop: continuous batching over a prefill/decode engine.

Requests queue up; the engine keeps a fixed decode batch, prefills new
requests into free slots (padding their KV into the shared cache length),
and steps all active slots together — one `decode_step` per token across the
whole batch.  Slot release on EOS/length gives continuous batching.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Single-host reference engine (the multi-pod path jits the same fns)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 256,
                 batch_slots: int = 4, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.eos_id = eos_id
        self.pos = 0
        self.caches = None
        self._decode = jax.jit(
            lambda p, c, t, pos: tf.decode_step(p, cfg, c, t, pos))

    def _prefill_request(self, req: Request):
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, caches = tf.prefill(self.params, self.cfg, batch)
        caches = tf.pad_caches(self.cfg, caches, self.max_len)
        first = int(jnp.argmax(logits[0]))
        req.out_tokens.append(first)
        return caches, len(req.prompt)

    def submit(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Run all requests to completion with continuous batching."""
        pending = list(requests)
        results: Dict[int, List[int]] = {}
        # Reference implementation: per-request caches batched along slots.
        active: List[dict] = []
        while pending or active:
            while pending and len(active) < len(self.slots):
                req = pending.pop(0)
                caches, plen = self._prefill_request(req)
                active.append({"req": req, "caches": caches, "pos": plen})
            # Step every active request one token.
            for entry in list(active):
                req = entry["req"]
                token = jnp.asarray([req.out_tokens[-1]], jnp.int32)
                logits, new_caches = self._decode(
                    self.params, entry["caches"], token,
                    jnp.asarray(entry["pos"], jnp.int32))
                entry["caches"] = new_caches
                entry["pos"] += 1
                nxt = int(jnp.argmax(logits[0]))
                req.out_tokens.append(nxt)
                if (len(req.out_tokens) >= req.max_new_tokens
                        or (self.eos_id is not None and nxt == self.eos_id)
                        or entry["pos"] >= self.max_len - 1):
                    req.done = True
                    results[req.rid] = req.out_tokens
                    active.remove(entry)
        return results
