"""repro — REMOP (REmote-Memory-aware OPerator Optimization) in JAX.

Layers:
  core/        cost model L = D + tau*C, policies (Prop. 4/5/6), TPU planner
  engine/      shared spill engine: buffer pools, page cursors, transfer
               scheduler, operator/plan registry (plan_operator entry point)
  remote/      faithful paper reproduction over a simulated remote-memory tier
  models/      assigned architectures (dense/MoE/SSM/hybrid/enc-dec/VLM/audio)
  kernels/     Pallas TPU kernels sized by the REMOP planner
  distributed/ sharding rules, bucketed collectives, offload
  optim/       AdamW (ZeRO-1), gradient compression
  data/        synthetic sharded pipeline with double-buffered prefetch
  checkpoint/  async checkpoint store with elastic resharding
  runtime/     fault-tolerant train/serve loops
  launch/      production mesh, multi-pod dry-run, drivers
"""

__version__ = "1.0.0"
