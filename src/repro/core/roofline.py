"""Roofline-term derivation from compiled XLA artifacts (task §ROOFLINE).

``cost_analysis()`` on an SPMD-partitioned module reports **per-device**
quantities (calibrated empirically: a (1024,1024)x(1024,1024) matmul sharded
over 8 host devices reports 2*1024^3/8 flops).  The three terms are therefore
computed per-device over per-device rates, which equals the task's
``global / (chips * rate)`` formulation:

    compute    = flops_pd / peak_flops
    memory     = hbm_bytes_pd / hbm_bw
    collective = wire_bytes_pd / ici_bw

Collective bytes are not in ``cost_analysis()``; we parse the optimized HLO
text, resolve operand names through a symbol table (operand shapes are not
inline in modern HLO), and sum operand sizes per collective op.  We also model
"wire bytes" per device with the standard ring factors so the collective term
reflects actual link occupancy.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

from repro.core.cost_model import TPU_V5E, TPUSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],\{\}\s/]+?)\s+([\w\-]+)\(")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)


def shape_bytes(shape_text: str) -> int:
    """Sum byte sizes of every dtype[dims] token in a shape string (tuples ok)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    name: str
    operand_bytes: int
    output_bytes: int
    group_size: int

    @property
    def wire_bytes(self) -> float:
        """Per-device link bytes under ring/bidirectional schedules."""
        g = max(self.group_size, 1)
        if g == 1:
            return 0.0
        frac = (g - 1) / g
        if self.kind == "all-reduce":
            return 2.0 * self.operand_bytes * frac
        if self.kind == "all-gather":
            return self.output_bytes * frac  # output = full gathered buffer
        if self.kind == "reduce-scatter":
            return self.operand_bytes * frac
        if self.kind in ("all-to-all", "ragged-all-to-all"):
            return self.operand_bytes * frac
        if self.kind == "collective-permute":
            return float(self.operand_bytes)
        return float(self.operand_bytes)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    if "source_target_pairs" in line:
        return 2
    return default


def parse_hlo_collectives(hlo_text: str, default_group: int = 1) -> List[CollectiveOp]:
    """Extract every collective op with operand/output byte sizes.

    Handles async pairs (`all-reduce-start`/`-done`) by counting only the
    `-start`; plain sync ops are counted directly.
    """
    # Pass 1: symbol table name -> shape text.
    shapes: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, out_shape, opcode = m.group(1), m.group(2), m.group(3)
        kind = opcode
        if kind.endswith("-start"):
            kind = kind[: -len("-start")]
        elif kind.endswith("-done"):
            continue
        if kind not in _COLLECTIVES:
            continue
        # Operands: %names inside the call parens.
        call = line[line.index(opcode) + len(opcode):]
        depth, args_text = 0, ""
        for ch in call:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                args_text += ch
        operand_names = re.findall(r"%([\w\.\-]+)", args_text)
        operand_bytes = sum(shape_bytes(shapes.get(n, "")) for n in operand_names)
        if operand_bytes == 0:
            # Fall back to output size (all-reduce: in == out).
            operand_bytes = shape_bytes(out_shape)
        ops.append(CollectiveOp(
            kind=kind, name=name,
            operand_bytes=operand_bytes,
            output_bytes=shape_bytes(out_shape),
            group_size=_group_size(line, default_group),
        ))
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for op in ops:
        d = out.setdefault(op.kind, {"count": 0, "operand_bytes": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["operand_bytes"] += op.operand_bytes
        d["wire_bytes"] += op.wire_bytes
    return out


# --------------------------------------------------------------------------


@dataclasses.dataclass
class RooflineReport:
    """Per-(arch x shape x mesh) roofline record (EXPERIMENTS.md §Roofline)."""

    name: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_operand_bytes: float  # task-spec definition (sum operand sizes)
    collective_wire_bytes: float  # ring-modeled link bytes per device
    collective_counts: Dict[str, Dict[str, float]]
    model_flops: float  # 6*N*D (train) / 2*N*D (inference), global
    # Resident bytes (args + outputs + temps from memory_analysis): touching
    # each resident byte once is a *lower bound* on HBM traffic.  cost_analysis
    # "bytes accessed" on the CPU backend is an UNFUSED upper bound (every HLO
    # intermediate counted), so we report both and classify dominance with the
    # lower bound — if memory dominates even optimistically, it really does.
    resident_bytes_per_device: float = 0.0
    spec: TPUSpec = dataclasses.field(default_factory=lambda: TPU_V5E)

    @property
    def compute_seconds(self) -> float:
        return self.flops_per_device / self.spec.peak_flops

    @property
    def memory_seconds(self) -> float:
        """Upper bound: unfused HLO bytes accessed."""
        return self.hbm_bytes_per_device / self.spec.hbm_bandwidth

    @property
    def memory_seconds_lower(self) -> float:
        """Lower bound: each resident byte touched once."""
        return self.resident_bytes_per_device / self.spec.hbm_bandwidth

    @property
    def collective_seconds(self) -> float:
        return self.collective_wire_bytes / self.spec.ici_bandwidth

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_seconds,
            "memory": self.memory_seconds_lower,
            "collective": self.collective_seconds,
        }
        return max(terms, key=terms.get)

    @property
    def step_seconds(self) -> float:
        """Roofline step-time bound: max of the three overlappable terms."""
        return max(self.compute_seconds, self.memory_seconds_lower,
                   self.collective_seconds)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global); catches remat/redundancy waste."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound (the perf score)."""
        t = self.step_seconds
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * self.spec.peak_flops * t)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_operand_bytes": self.collective_operand_bytes,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_counts": self.collective_counts,
            "model_flops": self.model_flops,
            "resident_bytes_per_device": self.resident_bytes_per_device,
            "compute_seconds": self.compute_seconds,
            "memory_seconds": self.memory_seconds,
            "memory_seconds_lower": self.memory_seconds_lower,
            "collective_seconds": self.collective_seconds,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
        }


def report_from_compiled(
    name: str,
    compiled,
    chips: int,
    model_flops: float,
    spec: TPUSpec = TPU_V5E,
) -> RooflineReport:
    """Build a RooflineReport from a jax Compiled object."""
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    ops = parse_hlo_collectives(compiled.as_text())
    summary = collective_summary(ops)
    return RooflineReport(
        name=name,
        chips=chips,
        flops_per_device=flops,
        hbm_bytes_per_device=byts,
        collective_operand_bytes=float(sum(o.operand_bytes for o in ops)),
        collective_wire_bytes=float(sum(o.wire_bytes for o in ops)),
        collective_counts=summary,
        model_flops=model_flops,
        spec=spec,
    )
