"""REMOP latency cost model (paper §II).

The central object is Eq. (1):

    Latency = sum_i (d_i / BW + RTT) = D / BW + C * RTT

where ``D`` is total data volume, ``C`` the number of *transfer rounds*, and
``(BW, RTT)`` characterize the tier holding spilled data.  Definition 3
normalizes this to the dimensionless latency cost

    L = D + tau * C,        tau = BW * RTT / unit

measured in the same unit as ``D`` (pages or bytes).  ``tau -> 0`` recovers the
classical min-volume objective; large ``tau`` makes round count first-order.

Tier constants come from the paper's Table I (order-of-magnitude media) and
Table IX (the CloudLab testbed), plus the TPU-side tiers used by the framework
adaptation (DESIGN.md §3): HBM<->VMEM DMA, ICI collectives, PCIe host offload.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, FrozenSet, Optional, Tuple

# --------------------------------------------------------------------------
# Tier specifications
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """A storage/memory tier reachable from the operator's local budget.

    Attributes:
      name: human-readable identifier.
      bandwidth: sustained transfer bandwidth, bytes/second.
      rtt: fixed per-round overhead, seconds (network RTT, DMA issue
        overhead, collective launch latency, ... depending on the tier).
      page_bytes: the batching unit used when expressing D in pages.
    """

    name: str
    bandwidth: float
    rtt: float
    page_bytes: int = 256 * 1024  # DuckDB block size used by the paper.

    @property
    def tau_bytes(self) -> float:
        """tau with D measured in bytes: RTT expressed as equivalent bytes."""
        return self.bandwidth * self.rtt

    @property
    def tau_pages(self) -> float:
        """tau with D measured in pages (the paper's convention)."""
        return self.bandwidth * self.rtt / self.page_bytes

    def latency_seconds(self, d_pages: float, c_rounds: float) -> float:
        """Eq. (1): D/BW + C*RTT with D given in pages."""
        return d_pages * self.page_bytes / self.bandwidth + c_rounds * self.rtt

    def latency_seconds_bytes(self, d_bytes: float, c_rounds: float) -> float:
        return d_bytes / self.bandwidth + c_rounds * self.rtt


def latency_cost(d: float, c: float, tau: float) -> float:
    """Definition 3: L = D + tau * C (unit must match between d and tau)."""
    return d + tau * c


# Paper Table I (order of magnitude) -----------------------------------------
TABLE_I: Dict[str, TierSpec] = {
    "dram": TierSpec("dram", bandwidth=25.6e9, rtt=100e-9),
    "ssd": TierSpec("ssd", bandwidth=0.53e9, rtt=100e-6),
    "tcp": TierSpec("tcp", bandwidth=1.25e9, rtt=500e-6),
    "rdma": TierSpec("rdma", bandwidth=6.8e9, rtt=1e-6),
}

# Paper Table IX (CloudLab c6220 testbed) ------------------------------------
TESTBED: Dict[str, TierSpec] = {
    # 10 GbE TCP, RTT 0.155 ms.
    "remon_tcp": TierSpec("remon_tcp", bandwidth=1.25e9, rtt=155e-6),
    # 48.6 Gb/s InfiniBand RDMA, RTT 1.16 us.
    "infiniswap_rdma": TierSpec("infiniswap_rdma", bandwidth=6.075e9, rtt=1.16e-6),
    # Local SSD spill (DuckDB temp files) for the backend comparison.
    "disk": TierSpec("disk", bandwidth=0.53e9, rtt=100e-6),
}

def resolve_tier_name(tier: "TierSpec | str") -> TierSpec:
    """Resolve a tier name against Table I / TESTBED / TPU tiers.

    Lives next to the tables so every lookup (engine registry, hierarchy
    constructors) shares one copy; ``TierSpec`` inputs pass through.
    """
    if isinstance(tier, TierSpec):
        return tier
    for table in (TABLE_I, TESTBED, TPU_TIERS):
        if tier in table:
            return table[tier]
    known = sorted(set(TABLE_I) | set(TESTBED) | set(TPU_TIERS))
    raise KeyError(f"unknown tier {tier!r}; known: {known}")


# TPU-side tiers for the framework adaptation (DESIGN.md §3). ----------------
# "RTT" here is the fixed per-round cost of the mechanism: DMA issue +
# pipeline-bubble overhead per Pallas grid step for HBM<->VMEM; collective
# launch/setup latency for ICI; kernel-launch + descriptor overhead for PCIe.
TPU_TIERS: Dict[str, TierSpec] = {
    "hbm_dma": TierSpec("hbm_dma", bandwidth=819e9, rtt=1e-6, page_bytes=1024),
    "ici": TierSpec("ici", bandwidth=50e9, rtt=10e-6, page_bytes=1024),
    "pcie_host": TierSpec("pcie_host", bandwidth=16e9, rtt=20e-6, page_bytes=4096),
}


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """Hardware constants for the roofline target (TPU v5e-class chip)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bandwidth: float = 819e9  # bytes/s per chip
    ici_bandwidth: float = 50e9  # bytes/s per link
    vmem_bytes: int = 128 * 1024 * 1024
    hbm_bytes: int = 16 * 1024 * 1024 * 1024
    dma_overhead_s: float = 1e-6
    collective_launch_s: float = 10e-6

    @property
    def tau_dma_bytes(self) -> float:
        """Per-DMA fixed cost as equivalent HBM bytes (REMOP tau for tiling)."""
        return self.hbm_bandwidth * self.dma_overhead_s

    @property
    def tau_ici_bytes(self) -> float:
        """Per-collective fixed cost as equivalent ICI bytes."""
        return self.ici_bandwidth * self.collective_launch_s


TPU_V5E = TPUSpec()


# --------------------------------------------------------------------------
# Transfer ledger — D/C accounting shared by the simulator and the planner
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LedgerSnapshot:
    """Immutable point-in-time copy of a :class:`TransferLedger`.

    Produced by ``TransferLedger.snapshot()``; ``TransferLedger.delta`` turns
    two snapshots (or the live ledger and one snapshot) into the D/C counts
    attributable to a region of execution.  Operators report their per-call
    accounting this way instead of copying the mutable ledger.
    """

    d_read: float = 0.0
    d_write: float = 0.0
    c_read: int = 0
    c_write: int = 0
    c_prefetch_hidden: int = 0
    # Migration rounds overlapped with compute (§IV-E applied to background
    # demotion): they still count in c_read/c_write but pay no RTT when the
    # caller opts into ``overlap_migration``.
    c_migration_hidden: int = 0
    # Pushdown accounting (operator off-loading to a compute-capable tier):
    # ``c_pushdown`` request rounds (a subset of ``c_read``) carried back only
    # result pages, ``d_pushdown`` of them (a subset of ``d_read``), while
    # ``d_pushdown_saved`` pages were scanned at the tier and never shipped.
    # Pages processed by tier compute = d_pushdown + d_pushdown_saved.
    c_pushdown: int = 0
    d_pushdown: float = 0.0
    d_pushdown_saved: float = 0.0

    @property
    def d_total(self) -> float:
        return self.d_read + self.d_write

    @property
    def c_total(self) -> int:
        return self.c_read + self.c_write

    @property
    def d_pushdown_scanned(self) -> float:
        """Pages processed by tier compute (shipped results + saved pages)."""
        return self.d_pushdown + self.d_pushdown_saved

    def __add__(self, other: "LedgerSnapshot") -> "LedgerSnapshot":
        """Field-wise sum: accumulate per-region deltas into one snapshot."""
        if not isinstance(other, LedgerSnapshot):
            return NotImplemented
        return LedgerSnapshot(
            d_read=self.d_read + other.d_read,
            d_write=self.d_write + other.d_write,
            c_read=self.c_read + other.c_read,
            c_write=self.c_write + other.c_write,
            c_prefetch_hidden=self.c_prefetch_hidden + other.c_prefetch_hidden,
            c_migration_hidden=self.c_migration_hidden + other.c_migration_hidden,
            c_pushdown=self.c_pushdown + other.c_pushdown,
            d_pushdown=self.d_pushdown + other.d_pushdown,
            d_pushdown_saved=self.d_pushdown_saved + other.d_pushdown_saved,
        )

    def latency_cost(self, tau: float) -> float:
        return latency_cost(self.d_total, self.c_total, tau)

    def to_dict(self) -> Dict[str, float]:
        """Counter-per-key serialization (bench JSON, server responses).

        Spelled as an explicit dict literal — not ``dataclasses.asdict`` —
        so the LED109 contract check can verify statically that every
        counter survives serialization.
        """
        return {
            "d_read": self.d_read,
            "d_write": self.d_write,
            "c_read": self.c_read,
            "c_write": self.c_write,
            "c_prefetch_hidden": self.c_prefetch_hidden,
            "c_migration_hidden": self.c_migration_hidden,
            "c_pushdown": self.c_pushdown,
            "d_pushdown": self.d_pushdown,
            "d_pushdown_saved": self.d_pushdown_saved,
        }


@dataclasses.dataclass
class TransferLedger:
    """Counts transferred pages (D) and transfer rounds (C), split by direction.

    This is the bookkeeping abstraction behind Definitions 1 and 2: the
    remote-memory simulator increments it on every batched swap-in/flush-out,
    and the analytical policies produce closed-form predictions that tests
    compare against it.
    """

    d_read: float = 0.0
    d_write: float = 0.0
    c_read: int = 0
    c_write: int = 0
    # Rounds whose RTT was hidden by the prefetch double buffer (§IV-E).
    c_prefetch_hidden: int = 0
    # Migration rounds overlapped with operator compute (background demotion
    # modeled the way §IV-E models prefetch); disjoint from prefetch hiding.
    c_migration_hidden: int = 0
    # Pushdown rounds (subset of c_read): the request shipped a predicate or
    # partial down and only result pages (d_pushdown, subset of d_read) back;
    # d_pushdown_saved pages stayed at the tier instead of making the trip.
    c_pushdown: int = 0
    d_pushdown: float = 0.0
    d_pushdown_saved: float = 0.0

    @property
    def d_total(self) -> float:
        return self.d_read + self.d_write

    @property
    def c_total(self) -> int:
        return self.c_read + self.c_write

    @property
    def d_pushdown_scanned(self) -> float:
        """Pages processed by tier compute (shipped results + saved pages)."""
        return self.d_pushdown + self.d_pushdown_saved

    def read(self, pages: float) -> None:
        self.d_read += pages
        self.c_read += 1

    def write(self, pages: float) -> None:
        self.d_write += pages
        self.c_write += 1

    def pushdown(self, shipped: float, saved: float) -> None:
        """One pushdown request round: ``shipped`` result pages made the
        trip, ``saved`` scanned pages did not.  Counts as a read round."""
        self.d_read += shipped
        self.c_read += 1
        self.d_pushdown += shipped
        self.c_pushdown += 1
        self.d_pushdown_saved += saved

    def snapshot(self) -> LedgerSnapshot:
        """Freeze the current counters (Definition 1/2 state) for later deltas."""
        return LedgerSnapshot(
            d_read=self.d_read,
            d_write=self.d_write,
            c_read=self.c_read,
            c_write=self.c_write,
            c_prefetch_hidden=self.c_prefetch_hidden,
            c_migration_hidden=self.c_migration_hidden,
            c_pushdown=self.c_pushdown,
            d_pushdown=self.d_pushdown,
            d_pushdown_saved=self.d_pushdown_saved,
        )

    def delta(self, since: LedgerSnapshot) -> LedgerSnapshot:
        """Counters accumulated since ``since`` (a prior ``snapshot()``)."""
        return LedgerSnapshot(
            d_read=self.d_read - since.d_read,
            d_write=self.d_write - since.d_write,
            c_read=self.c_read - since.c_read,
            c_write=self.c_write - since.c_write,
            c_prefetch_hidden=self.c_prefetch_hidden - since.c_prefetch_hidden,
            c_migration_hidden=self.c_migration_hidden - since.c_migration_hidden,
            c_pushdown=self.c_pushdown - since.c_pushdown,
            d_pushdown=self.d_pushdown - since.d_pushdown,
            d_pushdown_saved=self.d_pushdown_saved - since.d_pushdown_saved,
        )

    def merge(self, other: "TransferLedger") -> None:
        self.d_read += other.d_read
        self.d_write += other.d_write
        self.c_read += other.c_read
        self.c_write += other.c_write
        self.c_prefetch_hidden += other.c_prefetch_hidden
        self.c_migration_hidden += other.c_migration_hidden
        self.c_pushdown += other.c_pushdown
        self.d_pushdown += other.d_pushdown
        self.d_pushdown_saved += other.d_pushdown_saved

    def latency_seconds(
        self,
        tier: TierSpec,
        prefetch: bool = False,
        overlap_migration: bool = False,
        compute_pps: Optional[float] = None,
    ) -> float:
        """Eq. (1) over the ledger; hidden rounds pay no RTT when opted in.

        ``prefetch`` drops the double-buffered read rounds' RTT (§IV-E);
        ``overlap_migration`` drops the RTT of migration rounds performed in
        the background (demotions overlapped with operator compute).  The
        bandwidth term always pays in full — overlap hides latency, not
        volume.  ``compute_pps`` (a compute-capable tier's processing rate)
        adds the tier-side compute time of pushdown-scanned pages.
        """
        c_paying = self.c_total
        if prefetch:
            c_paying -= self.c_prefetch_hidden
        if overlap_migration:
            c_paying -= self.c_migration_hidden
        seconds = tier.latency_seconds(self.d_total, max(c_paying, 0))
        if compute_pps:
            seconds += self.d_pushdown_scanned / compute_pps
        return seconds

    def latency_cost(self, tau: float) -> float:
        return latency_cost(self.d_total, self.c_total, tau)

    def reset(self) -> None:
        self.d_read = self.d_write = 0.0
        self.c_read = self.c_write = 0
        self.c_prefetch_hidden = 0
        self.c_migration_hidden = 0
        self.c_pushdown = 0
        self.d_pushdown = 0.0
        self.d_pushdown_saved = 0.0


# --------------------------------------------------------------------------
# Memory hierarchy — ordered tiers with capacities (Table I as a *hierarchy*)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TierLevel:
    """One level of a memory hierarchy: a tier plus its page capacity.

    ``capacity_pages`` bounds how many pages the level's store may hold;
    ``math.inf`` marks an effectively unbounded backstop (the bottom tier).

    A level may additionally be *compute-capable* (Farview/PIMDAL-style
    near-memory processing): ``compute_pps`` is the tier's processing rate in
    pages/second and ``pushdown_ops`` names the operations it can execute on
    resident pages (``"filter"``, ``"reduce"``).  ``None``/empty means no
    capability — plain DRAM and SSD levels default off; RDMA/CXL-style
    disaggregated tiers opt in per hierarchy.
    """

    tier: TierSpec
    capacity_pages: float = math.inf
    compute_pps: Optional[float] = None
    pushdown_ops: FrozenSet[str] = frozenset()

    def __post_init__(self) -> None:
        if self.capacity_pages <= 0:
            raise ValueError(
                f"tier {self.tier.name!r} needs capacity_pages > 0, "
                f"got {self.capacity_pages}"
            )
        object.__setattr__(self, "pushdown_ops",
                           frozenset(self.pushdown_ops))
        if self.compute_pps is not None and self.compute_pps <= 0:
            raise ValueError(
                f"tier {self.tier.name!r} needs compute_pps > 0 (or None), "
                f"got {self.compute_pps}"
            )
        if self.pushdown_ops and self.compute_pps is None:
            raise ValueError(
                f"tier {self.tier.name!r} declares pushdown_ops "
                f"{sorted(self.pushdown_ops)} but no compute_pps rate"
            )

    def can_push(self, op: str) -> bool:
        """Whether this level can execute pushdown op ``op`` on its pages."""
        return self.compute_pps is not None and op in self.pushdown_ops

    @property
    def compute_tau_pages(self) -> float:
        """Tier compute priced in this tier's L units (pages per page scanned).

        ``latency_seconds = L * page_bytes / bandwidth`` per tier, so one
        second of tier compute is worth ``bandwidth / page_bytes`` L-pages;
        scanning one page costs ``1 / compute_pps`` seconds.  ``inf`` for a
        tier with no compute capability.
        """
        if not self.compute_pps:
            return math.inf
        return (self.tier.bandwidth / self.tier.page_bytes) / self.compute_pps

    def compute_seconds(self, pages: float) -> float:
        """Tier-side processing time for ``pages`` scanned pages."""
        if not self.compute_pps:
            return math.inf if pages > 0 else 0.0
        return pages / self.compute_pps


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """An ordered memory hierarchy, fastest (top) tier first.

    The order is the *placement priority*: the paper's Table I read as a
    DRAM -> RDMA -> SSD waterfall.  Planning fills the cheapest (topmost)
    tier first given per-level capacities; the runtime analogue is
    :class:`repro.remote.simulator.MemoryHierarchy`.
    """

    levels: Tuple[TierLevel, ...]

    def __post_init__(self) -> None:
        if not self.levels:
            raise ValueError("a hierarchy needs at least one tier level")
        names = [lv.tier.name for lv in self.levels]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names in hierarchy: {names}")

    def __len__(self) -> int:
        return len(self.levels)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(lv.tier.name for lv in self.levels)

    @property
    def taus(self) -> Tuple[float, ...]:
        return tuple(lv.tier.tau_pages for lv in self.levels)

    @property
    def capacities(self) -> Tuple[float, ...]:
        return tuple(lv.capacity_pages for lv in self.levels)

    def index(self, tier: "int | str") -> int:
        """Resolve a tier name or index to its level index."""
        if isinstance(tier, str):
            try:
                return self.names.index(tier)
            except ValueError:
                raise KeyError(
                    f"hierarchy has no tier {tier!r}; tiers: {list(self.names)}"
                ) from None
        idx = int(tier)
        if not -len(self.levels) <= idx < len(self.levels):
            raise KeyError(f"tier index {idx} out of range for {list(self.names)}")
        return idx % len(self.levels)

    def level(self, tier: "int | str") -> TierLevel:
        return self.levels[self.index(tier)]


def hierarchy_spec(
    *levels: "TierLevel | TierSpec | str | Tuple[TierSpec | str, float]",
) -> HierarchySpec:
    """Build a :class:`HierarchySpec` from tier / ``(tier, cap)`` levels.

    Tiers are ``TierSpec``\\ s or names resolved against Table I / TESTBED /
    TPU tiers, e.g. ``hierarchy_spec(("dram", 64), ("rdma", 1024), "ssd")``;
    a bare tier gets unbounded capacity.  A fully-specified
    :class:`TierLevel` passes through unchanged — the way compute-capable
    levels (``compute_pps``/``pushdown_ops``) enter a hierarchy.  The single
    normalization point for every hierarchy constructor
    (``make_hierarchy``, ``resolve_hierarchy``).
    """
    built = []
    for lv in levels:
        if isinstance(lv, TierLevel):
            built.append(lv)
        elif isinstance(lv, (tuple, list)):
            tier, cap = lv
            built.append(TierLevel(resolve_tier_name(tier), float(cap)))
        else:
            built.append(TierLevel(resolve_tier_name(lv)))
    return HierarchySpec(tuple(built))


def _sum_snapshots(snaps: "Tuple[LedgerSnapshot, ...]") -> LedgerSnapshot:
    return LedgerSnapshot(
        d_read=sum(s.d_read for s in snaps),
        d_write=sum(s.d_write for s in snaps),
        c_read=sum(s.c_read for s in snaps),
        c_write=sum(s.c_write for s in snaps),
        c_prefetch_hidden=sum(s.c_prefetch_hidden for s in snaps),
        c_migration_hidden=sum(s.c_migration_hidden for s in snaps),
        c_pushdown=sum(s.c_pushdown for s in snaps),
        d_pushdown=sum(s.d_pushdown for s in snaps),
        d_pushdown_saved=sum(s.d_pushdown_saved for s in snaps),
    )


@dataclasses.dataclass(frozen=True)
class HierarchySnapshot:
    """Per-tier :class:`LedgerSnapshot`\\ s of one hierarchy, top tier first.

    The aggregate D/C properties make a hierarchy snapshot a drop-in for a
    single ledger's snapshot wherever only totals matter (operator result
    reporting), while ``tier()`` exposes the per-tier split; the per-tier
    ledgers always sum to the hierarchy-wide totals by construction.
    """

    tiers: Tuple[Tuple[str, LedgerSnapshot], ...]

    def tier(self, name: str) -> LedgerSnapshot:
        for n, snap in self.tiers:
            if n == name:
                return snap
        raise KeyError(
            f"snapshot has no tier {name!r}; tiers: {[n for n, _ in self.tiers]}"
        )

    @property
    def total(self) -> LedgerSnapshot:
        return _sum_snapshots(tuple(s for _, s in self.tiers))

    def __add__(self, other: "HierarchySnapshot") -> "HierarchySnapshot":
        """Tier-wise sum of two snapshots of the *same* hierarchy.

        The per-tenant ledger accounting of the multi-tenant server
        accumulates task deltas this way; tier names must match pairwise.
        """
        if not isinstance(other, HierarchySnapshot):
            return NotImplemented
        names = [n for n, _ in self.tiers]
        other_names = [n for n, _ in other.tiers]
        if names != other_names:
            raise ValueError(
                f"cannot add snapshots of different hierarchies: "
                f"{names} vs {other_names}"
            )
        return HierarchySnapshot(tiers=tuple(
            (n, a + b) for (n, a), (_, b) in zip(self.tiers, other.tiers)
        ))

    @classmethod
    def zero(cls, spec: "HierarchySpec") -> "HierarchySnapshot":
        """An all-zero snapshot shaped like ``spec`` (accumulator seed)."""
        return cls(tiers=tuple((n, LedgerSnapshot()) for n in spec.names))

    def to_dict(self) -> Dict[str, Dict[str, float]]:
        """Per-tier counter dicts keyed by tier name, plus the aggregate
        under ``"total"`` (which per-tier shares sum to by construction)."""
        out = {name: snap.to_dict() for name, snap in self.tiers}
        out["total"] = self.total.to_dict()
        return out

    # Aggregate pass-throughs (keep operator reporting tier-agnostic).
    @property
    def d_read(self) -> float:
        return sum(s.d_read for _, s in self.tiers)

    @property
    def d_write(self) -> float:
        return sum(s.d_write for _, s in self.tiers)

    @property
    def c_read(self) -> int:
        return sum(s.c_read for _, s in self.tiers)

    @property
    def c_write(self) -> int:
        return sum(s.c_write for _, s in self.tiers)

    @property
    def c_prefetch_hidden(self) -> int:
        return sum(s.c_prefetch_hidden for _, s in self.tiers)

    @property
    def c_migration_hidden(self) -> int:
        return sum(s.c_migration_hidden for _, s in self.tiers)

    @property
    def c_pushdown(self) -> int:
        return sum(s.c_pushdown for _, s in self.tiers)

    @property
    def d_pushdown(self) -> float:
        return sum(s.d_pushdown for _, s in self.tiers)

    @property
    def d_pushdown_saved(self) -> float:
        return sum(s.d_pushdown_saved for _, s in self.tiers)

    @property
    def d_total(self) -> float:
        return self.d_read + self.d_write

    @property
    def c_total(self) -> int:
        return self.c_read + self.c_write

    def latency_cost(self, tau: "float | HierarchySpec") -> float:
        """Hierarchy-aware L: per-tier D + tau_t * C summed over tiers.

        A scalar ``tau`` prices every round the same (the single-tier
        degenerate case); a :class:`HierarchySpec` prices each tier's rounds
        with that tier's ``tau_pages`` plus — for compute-capable tiers —
        the pushdown-scanned pages at ``compute_tau_pages`` each.
        """
        if isinstance(tau, HierarchySpec):
            total = 0.0
            for name, t in zip(tau.names, tau.taus):
                snap = self.tier(name)
                total += snap.latency_cost(t)
                scanned = snap.d_pushdown_scanned
                if scanned > 0:
                    total += tau.level(name).compute_tau_pages * scanned
            return total
        return self.total.latency_cost(tau)

    def latency_seconds(
        self,
        spec: HierarchySpec,
        prefetch: bool = False,
        overlap_migration: bool = False,
    ) -> float:
        """Eq. (1) summed per tier with each tier's (BW, RTT) constants.

        ``overlap_migration`` drops the RTT of background migration rounds
        (``c_migration_hidden``), mirroring how ``prefetch`` drops the
        double-buffered read rounds' RTT.  A compute-capable tier's
        pushdown-scanned pages add their tier-side processing time.
        """
        total = 0.0
        for name, snap in self.tiers:
            level = spec.level(name)
            c = snap.c_total
            if prefetch:
                c -= snap.c_prefetch_hidden
            if overlap_migration:
                c -= snap.c_migration_hidden
            total += level.tier.latency_seconds(snap.d_total, max(c, 0))
            if level.compute_pps:
                total += snap.d_pushdown_scanned / level.compute_pps
        return total


def alpha(m_pages: float, tau: float) -> float:
    """Memory-scaled network parameter alpha = M / tau (Table II)."""
    if tau <= 0:
        return math.inf
    return m_pages / tau


def beta(selectivity: float, m_pages: float) -> float:
    """Selectivity-memory parameter beta = f * M (Table II)."""
    return selectivity * m_pages
