"""REMOP core: cost model, buffer policies, memory arbiter, TPU planner."""

from repro.core.cost_model import (
    TABLE_I,
    TESTBED,
    TPU_TIERS,
    TPU_V5E,
    HierarchySnapshot,
    HierarchySpec,
    LedgerSnapshot,
    TierLevel,
    TierSpec,
    TPUSpec,
    TransferLedger,
    alpha,
    beta,
    hierarchy_spec,
    latency_cost,
)
from repro.core import arbiter, policies, planner, roofline
from repro.core.arbiter import (
    ArbiterItem,
    HierarchyItem,
    arbitrate,
    arbitrate_hierarchy,
)

__all__ = [
    "TABLE_I", "TESTBED", "TPU_TIERS", "TPU_V5E",
    "HierarchySnapshot", "HierarchySpec", "LedgerSnapshot",
    "TierLevel", "TierSpec", "TPUSpec", "TransferLedger",
    "alpha", "beta", "hierarchy_spec", "latency_cost",
    "ArbiterItem", "HierarchyItem", "arbitrate", "arbitrate_hierarchy",
    "arbiter", "policies", "planner", "roofline",
]
