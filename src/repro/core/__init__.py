"""REMOP core: latency cost model, buffer-allocation policies, TPU planner."""

from repro.core.cost_model import (
    TABLE_I,
    TESTBED,
    TPU_TIERS,
    TPU_V5E,
    LedgerSnapshot,
    TierSpec,
    TPUSpec,
    TransferLedger,
    alpha,
    beta,
    latency_cost,
)
from repro.core import policies, planner, roofline

__all__ = [
    "TABLE_I", "TESTBED", "TPU_TIERS", "TPU_V5E",
    "LedgerSnapshot", "TierSpec", "TPUSpec", "TransferLedger",
    "alpha", "beta", "latency_cost",
    "policies", "planner", "roofline",
]
