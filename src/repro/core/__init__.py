"""REMOP core: cost model, buffer policies, memory arbiter, TPU planner."""

from repro.core.cost_model import (
    TABLE_I,
    TESTBED,
    TPU_TIERS,
    TPU_V5E,
    LedgerSnapshot,
    TierSpec,
    TPUSpec,
    TransferLedger,
    alpha,
    beta,
    latency_cost,
)
from repro.core import arbiter, policies, planner, roofline
from repro.core.arbiter import ArbiterItem, arbitrate

__all__ = [
    "TABLE_I", "TESTBED", "TPU_TIERS", "TPU_V5E",
    "LedgerSnapshot", "TierSpec", "TPUSpec", "TransferLedger",
    "alpha", "beta", "latency_cost",
    "ArbiterItem", "arbitrate",
    "arbiter", "policies", "planner", "roofline",
]
