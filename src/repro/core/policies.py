"""REMOP operator buffer-allocation policies (paper §III).

Each operator family gets:
  * closed-form / numerical cost functions ``D(params)``, ``C(params)`` and the
    latency objective ``L = D + tau * C``;
  * the paper's optimal policy (Properties 4, 5, 6; Tables III, IV, VI);
  * the conventional / DuckDB baselines it is compared against (Table VII).

All sizes are in *pages* unless noted.  The same algebra is reused by the TPU
planner (``core/planner.py``) with tau calibrated from DMA / collective launch
overheads instead of network RTT.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import ClassVar, List, Sequence, Tuple

# ==========================================================================
# Generic allocator (Property 6 machinery)
# ==========================================================================


def waterfill(coeffs: Sequence[float], budget: float) -> Tuple[List[float], float]:
    """Minimize sum_j a_j / R_j subject to sum_j R_j = budget.

    By Cauchy-Schwarz the optimum is R_j proportional to sqrt(a_j) with minimum
    value (sum_j sqrt(a_j))^2 / budget (paper Property 6).

    Returns:
      (allocation list, minimal round cost C*).
    """
    roots = [math.sqrt(max(a, 0.0)) for a in coeffs]
    total = sum(roots)
    if total == 0.0 or budget <= 0.0:
        return [budget / max(len(coeffs), 1)] * len(coeffs), 0.0
    alloc = [budget * r / total for r in roots]
    c_star = total * total / budget
    return alloc, c_star


def round_cost(coeffs: Sequence[float], alloc: Sequence[float]) -> float:
    """Evaluate sum_j a_j / R_j for a concrete allocation."""
    c = 0.0
    for a, r in zip(coeffs, alloc):
        if a == 0.0:
            continue
        if r <= 0.0:
            return math.inf
        c += a / r
    return c


def _golden_min(f, lo: float, hi: float, iters: int = 200) -> float:
    """Golden-section minimizer for a unimodal objective on [lo, hi]."""
    invphi = (math.sqrt(5.0) - 1.0) / 2.0
    a, b = lo, hi
    c = b - invphi * (b - a)
    d = a + invphi * (b - a)
    fc, fd = f(c), f(d)
    for _ in range(iters):
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - invphi * (b - a)
            fc = f(c)
        else:
            a, c, fc = c, d, fd
            d = a + invphi * (b - a)
            fd = f(d)
        if abs(b - a) < 1e-12:
            break
    return (a + b) / 2.0


# ==========================================================================
# Blocked nested-loop join (§III-A)
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class BNLJPlan:
    op: ClassVar[str] = "bnlj"  # engine.registry.OperatorPlan tag
    m: float  # total budget (pages)
    r_in: float  # input-region fraction
    p_r: float  # outer fraction of the input region
    # Derived absolute sizes.
    @property
    def input_pages(self) -> float:
        return self.r_in * self.m

    @property
    def output_pages(self) -> float:
        return self.m - self.input_pages

    @property
    def outer_pages(self) -> float:
        return self.p_r * self.input_pages

    @property
    def inner_pages(self) -> float:
        return (1.0 - self.p_r) * self.input_pages


def bnlj_costs_exact(
    size_r: int, size_s: int, out: float, p_r_pages: int, p_s_pages: int, r_out_pages: int
) -> Tuple[float, float]:
    """Exact (ceil-based) D and C for BNLJ — matches the §II-C worked example.

    D_read = ceil(|R|/P_R)*|S| + |R|;  C_read = ceil(|R|/P_R)*ceil(|S|/P_S)
    + ceil(|R|/P_R); writes add O pages in ceil(O/R_out) rounds.
    """
    blocks_r = math.ceil(size_r / p_r_pages)
    blocks_s = math.ceil(size_s / p_s_pages)
    d = blocks_r * size_s + size_r + out
    c = blocks_r * blocks_s + blocks_r + (math.ceil(out / r_out_pages) if out else 0)
    return float(d), float(c)


def bnlj_costs(
    size_r: float, size_s: float, out: float, plan: BNLJPlan
) -> Tuple[float, float]:
    """Smooth approximations of D and C used by the optimizer (§III-A b)."""
    p_r_pages = max(plan.outer_pages, 1e-9)
    p_s_pages = max(plan.inner_pages, 1e-9)
    r_out = max(plan.output_pages, 1e-9)
    d = size_r + size_r * size_s / p_r_pages + out
    c = size_r * size_s / (p_r_pages * p_s_pages) + size_r / p_r_pages + out / r_out
    return d, c


def bnlj_latency(size_r, size_s, out, plan: BNLJPlan, tau: float) -> float:
    d, c = bnlj_costs(size_r, size_s, out, plan)
    return d + tau * c


def bnlj_split_opt(r_in_pages: float, tau: float) -> float:
    """Property 4: p_R*/p_S* = sqrt(1 + R_in/tau), with p_R* + p_S* = 1."""
    if tau <= 0.0:
        return 1.0  # volume-dominated limit: outer-heavy
    ratio = math.sqrt(1.0 + r_in_pages / tau)
    return ratio / (1.0 + ratio)


def bnlj_rin_objective(r_in: float, a: float, b: float) -> float:
    """Objective g(r_in) from §III-A(d), parameterized by alpha=M/tau, beta=fM.

    g = 1/(p_R* r_in) + 1/(alpha r_in^2 p_R*(1-p_R*)) + beta/(alpha (1-r_in)),
    with p_R* from Property 4 evaluated at R_in/tau = r_in * alpha.
    """
    if not (0.0 < r_in < 1.0):
        return math.inf
    p_r = _p_r_of(r_in, a)
    return (
        1.0 / (p_r * r_in)
        + 1.0 / (a * r_in * r_in * p_r * (1.0 - p_r))
        + b / (a * (1.0 - r_in))
    )


def _p_r_of(r_in: float, a: float) -> float:
    # R_in / tau = r_in * M / tau = r_in * alpha.
    ratio = math.sqrt(1.0 + r_in * a)
    return ratio / (1.0 + ratio)


def bnlj_rin_opt(a: float, b: float) -> float:
    """Optimal input fraction r_in*(alpha, beta) — reproduces Table III."""
    return _golden_min(lambda r: bnlj_rin_objective(r, a, b), 1e-6, 1.0 - 1e-6)


def bnlj_plan(
    m: float, tau: float, selectivity: float = 0.0
) -> BNLJPlan:
    """Full REMOP BNLJ policy: r_in from Table III, p_R from Property 4."""
    if tau <= 0.0:
        # Volume-dominated: conventional outer-heavy allocation.
        return bnlj_conventional(m)
    a = m / tau
    b = selectivity * m
    r_in = bnlj_rin_opt(a, b)
    p_r = bnlj_split_opt(r_in * m, tau)
    return BNLJPlan(m=m, r_in=r_in, p_r=p_r)


def bnlj_conventional(m: float) -> BNLJPlan:
    """Disk-oriented default: P_R = M-2, P_S = 1, R_out = 1 (§III-A e)."""
    r_in = (m - 1.0) / m
    p_r = (m - 2.0) / (m - 1.0)
    return BNLJPlan(m=m, r_in=r_in, p_r=p_r)


# ==========================================================================
# k-way external merge sort (§III-B)
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class EMSPlan:
    op: ClassVar[str] = "ems"  # engine.registry.OperatorPlan tag
    m: float
    k: int
    r_in: float

    @property
    def input_pages(self) -> float:
        return self.r_in * self.m

    @property
    def output_pages(self) -> float:
        return self.m - self.input_pages

    @property
    def per_run_pages(self) -> float:
        return self.input_pages / self.k


def ems_split_opt(k: int) -> float:
    """Property 5: R_in : R_out = sqrt(k) : 1  =>  r_in = sqrt(k)/(sqrt(k)+1)."""
    s = math.sqrt(k)
    return s / (s + 1.0)


def ems_passes(n: float, m: float, k: int) -> int:
    runs = math.ceil(n / m)
    if runs <= 1:
        return 0
    return max(1, math.ceil(math.log(runs) / math.log(k)))


def ems_costs(n: float, m: float, plan: EMSPlan) -> Tuple[float, float, int]:
    """(D, C, passes) for the merge phase (§III-B b).

    Per pass: D = 2N; C = k*N/R_in + N/R_out (refills through R_in/k-page
    buffers plus output flushes).
    """
    p = ems_passes(n, m, plan.k)
    d = 2.0 * n * p
    c_pass = plan.k * n / max(plan.input_pages, 1e-9) + n / max(plan.output_pages, 1e-9)
    return d, c_pass * p, p


def ems_costs_exact(n: int, m: int, k: int, r_in_pages: int) -> Tuple[float, float, int]:
    """Exact (ceil/floor) merge-phase costs — matches the §II-C worked example.

    Per pass: reads refill through floor(R_in/k)-page per-run buffers and the
    output flushes through R_out = M - R_in pages, so
    C_pass = ceil(N / floor(R_in/k)) + ceil(N / R_out); D_pass = 2N.
    """
    r_out = m - r_in_pages
    per_run = max(1, r_in_pages // k)
    p = ems_passes(n, m, k)
    c_pass = math.ceil(n / per_run) + math.ceil(n / max(r_out, 1))
    return float(2 * n * p), float(c_pass * p), p


def ems_latency(n: float, m: float, plan: EMSPlan, tau: float) -> float:
    d, c, _ = ems_costs(n, m, plan)
    return d + tau * c


def ems_run_formation_costs(n: float, m: float) -> Tuple[float, float]:
    """(D, C) of run formation (§III-B a): one read + one write round per
    M-page chunk, each chunk moving its pages twice (in to sort, out as a run).

    This is the single closed form shared by the registry's EMS latency model,
    the session ``explain()`` report, and the benchmarks; it matches the
    simulated ledger of :func:`repro.remote.ems.ems_sort` with
    ``count_run_formation=True`` exactly (one ``read``/``write`` scheduler
    round per chunk, D = 2N).
    """
    chunks = math.ceil(n / max(m, 1.0))
    return 2.0 * n, 2.0 * chunks


def ems_total_costs(n: float, m: float, plan: EMSPlan) -> Tuple[float, float]:
    """(D, C) of the whole sort: run formation plus all merge passes."""
    d_merge, c_merge, _ = ems_costs(n, m, plan)
    d_rf, c_rf = ems_run_formation_costs(n, m)
    return d_merge + d_rf, c_merge + c_rf


def ems_total_latency(n: float, m: float, plan: EMSPlan, tau: float) -> float:
    """L = D + tau*C of the whole sort including run formation."""
    d, c = ems_total_costs(n, m, plan)
    return d + tau * c


def ems_h(k: float, a: float) -> float:
    """h(k) = [2 + (sqrt(k)+1)^2 / alpha] / log2(k) (§III-B d)."""
    if k <= 1.0:
        return math.inf
    return (2.0 + (math.sqrt(k) + 1.0) ** 2 / a) / math.log2(k)


@functools.lru_cache(maxsize=65536)
def ems_kopt(a: float, k_max: int = 1 << 20) -> int:
    """Optimal integer fan-in k*(alpha) — reproduces Table IV.

    As alpha -> 0 (RTT-dominated) k* = 4; as alpha grows, k* grows toward the
    maximum feasible fan-in.  Memoized: the arbiter's marginal-cost descent
    re-evaluates the EMS plan at every candidate budget, and alpha = m/tau
    takes only ~budget x tiers distinct values per sweep.
    """
    if a <= 0.0:
        return 4
    best_k, best_h = 2, ems_h(2, a)
    # h is unimodal in k; scan integers with geometric stride then refine.
    k = 2
    while k <= k_max:
        h = ems_h(k, a)
        if h < best_h:
            best_k, best_h = k, h
        k += max(1, k // 64)
    for kk in range(max(2, best_k - 70), min(k_max, best_k + 70) + 1):
        h = ems_h(kk, a)
        if h < best_h:
            best_k, best_h = kk, h
    return best_k


def ems_plan(n: float, m: float, tau: float, k_cap: int | None = None) -> EMSPlan:
    """Full REMOP EMS policy: k from Table IV, split from Property 5."""
    if tau <= 0.0:
        k = max(2, int(m - 1))
    else:
        k = ems_kopt(m / tau)
    if k_cap is not None:
        k = min(k, k_cap)
    k = max(2, min(k, max(2, int(m - 1))))
    return EMSPlan(m=m, k=k, r_in=ems_split_opt(k))


def ems_conventional(m: float) -> EMSPlan:
    """Max fan-in: k = M-1, one page per input and output (§III-B e)."""
    k = max(2, int(m) - 1)
    return EMSPlan(m=m, k=k, r_in=(m - 1.0) / m)


def ems_duckdb(m: float) -> EMSPlan:
    """DuckDB v1.0.0: 2-way merge, R_in = 2M/3, R_out = M/3."""
    return EMSPlan(m=m, k=2, r_in=2.0 / 3.0)


# ==========================================================================
# External hash join (§III-C)
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class EHJPlan:
    op: ClassVar[str] = "ehj"  # engine.registry.OperatorPlan tag
    m_b: float  # I/O buffer-pool budget (pages)
    partitions: int  # radix P
    sigma: float  # spilled partition fraction (system-determined)
    # Per-phase allocations [R_r, R_w] / [R_r, R_s, R_o] / [R_r, R_o].
    p1: Tuple[float, ...] = ()
    p2: Tuple[float, ...] = ()
    p3: Tuple[float, ...] = ()


def ehj_phase_coeffs(
    b: float, q: float, out: float, partitions: int, sigma: float
) -> Tuple[Tuple[float, ...], Tuple[float, ...], Tuple[float, ...]]:
    """Round-cost coefficients a_j per phase (Table V numerators)."""
    p1 = (b, sigma * sigma * partitions * b)
    p2 = (q, sigma * sigma * partitions * q, (1.0 - sigma) * out)
    p3 = (sigma * (b + q), sigma * out)
    return p1, p2, p3


def ehj_data_costs(b: float, q: float, out: float, sigma: float) -> Tuple[float, float, float]:
    """Per-phase D_i (Table V): allocation-independent."""
    d1 = (1.0 + sigma) * b
    d2 = (1.0 + sigma) * q + (1.0 - sigma) * out
    d3 = sigma * (b + q) + sigma * out
    return d1, d2, d3


def ehj_plan(
    b: float, q: float, out: float, m_b: float, partitions: int, sigma: float
) -> EHJPlan:
    """Property 6: per-phase allocation R_j proportional to sqrt(a_j)."""
    c1, c2, c3 = ehj_phase_coeffs(b, q, out, partitions, sigma)
    a1, _ = waterfill(c1, m_b)
    a2, _ = waterfill(c2, m_b)
    a3, _ = waterfill(c3, m_b)
    return EHJPlan(
        m_b=m_b, partitions=partitions, sigma=sigma,
        p1=tuple(a1), p2=tuple(a2), p3=tuple(a3),
    )


def ehj_starved(m_b: float, partitions: int, sigma: float) -> EHJPlan:
    """Disk-oriented baseline: maximal read block, 1-page write pools.

    The DuckDB-default analogue the paper compares Property 6 against
    (Table VII): nearly the whole budget goes to the read block while every
    write/staging/output pool gets a single page.
    """
    return EHJPlan(
        m_b=m_b, partitions=partitions, sigma=sigma,
        p1=(m_b - 1.0, 1.0), p2=(m_b - 2.0, 1.0, 1.0), p3=(m_b - 1.0, 1.0),
    )


def ehj_round_costs(
    b: float, q: float, out: float, plan: EHJPlan
) -> Tuple[float, float, float]:
    """Evaluate Table V's C_i for a concrete plan."""
    c1, c2, c3 = ehj_phase_coeffs(b, q, out, plan.partitions, plan.sigma)
    return (
        round_cost(c1, plan.p1),
        round_cost(c2, plan.p2),
        round_cost(c3, plan.p3),
    )


def ehj_optimal_round_costs(
    b: float, q: float, out: float, m_b: float, partitions: int, sigma: float
) -> Tuple[float, float, float]:
    """Closed forms C_i* from Table VI."""
    p = partitions
    c1 = b * (1.0 + sigma * math.sqrt(p)) ** 2 / m_b
    c2 = (math.sqrt(q) + sigma * math.sqrt(p * q) + math.sqrt((1.0 - sigma) * out)) ** 2 / m_b
    c3 = sigma * (math.sqrt(b + q) + math.sqrt(out)) ** 2 / m_b
    return c1, c2, c3


def ehj_latency(b: float, q: float, out: float, plan: EHJPlan, tau: float) -> float:
    d = sum(ehj_data_costs(b, q, out, plan.sigma))
    c = sum(ehj_round_costs(b, q, out, plan))
    return d + tau * c


# ==========================================================================
# External (grace-style) hash aggregation
# ==========================================================================
#
# Same Property-6 structure as EHJ, one relation and two phases.  P1 scans the
# N-page input through R_r, aggregates resident partitions in memory and
# spills the others through a per-partition-sliced R_w pool; resident groups
# flush through R_o.  P2 re-reads each spilled partition through R_r and
# flushes its aggregated groups through R_o.  With spilled fraction sigma over
# P partitions and OUT pages of group output, the Table-V-style terms are
#
#   phase  pools        D_i                              a_j (C_j = a_j / R_j)
#   P1     R_r,R_w,R_o  (1+sigma)N + (1-sigma)OUT        N, sigma^2 P N, (1-sigma)OUT
#   P2     R_r,R_o      sigma (N + OUT)                  sigma N, sigma OUT


@dataclasses.dataclass(frozen=True)
class EAggPlan:
    op: ClassVar[str] = "eagg"  # engine.registry.OperatorPlan tag
    m_b: float  # I/O buffer-pool budget (pages)
    partitions: int  # radix P
    sigma: float  # spilled partition fraction (system-determined)
    # Per-phase allocations [R_r, R_w, R_o] / [R_r, R_o].
    p1: Tuple[float, ...] = ()
    p2: Tuple[float, ...] = ()


def eagg_phase_coeffs(
    n: float, out: float, partitions: int, sigma: float
) -> Tuple[Tuple[float, ...], Tuple[float, ...]]:
    """Round-cost coefficients a_j per phase (Table V analogue)."""
    p1 = (n, sigma * sigma * partitions * n, (1.0 - sigma) * out)
    p2 = (sigma * n, sigma * out)
    return p1, p2


def eagg_data_costs(n: float, out: float, sigma: float) -> Tuple[float, float]:
    """Per-phase D_i: allocation-independent."""
    d1 = (1.0 + sigma) * n + (1.0 - sigma) * out
    d2 = sigma * (n + out)
    return d1, d2


def eagg_plan(
    n: float, out: float, m_b: float, partitions: int, sigma: float
) -> EAggPlan:
    """Property 6 applied per phase: R_j proportional to sqrt(a_j)."""
    c1, c2 = eagg_phase_coeffs(n, out, partitions, sigma)
    a1, _ = waterfill(c1, m_b)
    a2, _ = waterfill(c2, m_b)
    return EAggPlan(
        m_b=m_b, partitions=partitions, sigma=sigma, p1=tuple(a1), p2=tuple(a2)
    )


def eagg_starved(m_b: float, partitions: int, sigma: float) -> EAggPlan:
    """Disk-oriented baseline: maximal read block, 1-page write/output pools."""
    return EAggPlan(
        m_b=m_b, partitions=partitions, sigma=sigma,
        p1=(m_b - 2.0, 1.0, 1.0), p2=(m_b - 1.0, 1.0),
    )


def eagg_round_costs(n: float, out: float, plan: EAggPlan) -> Tuple[float, float]:
    """Evaluate the per-phase C_i for a concrete plan."""
    c1, c2 = eagg_phase_coeffs(n, out, plan.partitions, plan.sigma)
    return round_cost(c1, plan.p1), round_cost(c2, plan.p2)


def eagg_optimal_round_costs(
    n: float, out: float, m_b: float, partitions: int, sigma: float
) -> Tuple[float, float]:
    """Closed forms C_i* (Property 6 / Table VI analogue)."""
    c1 = (
        math.sqrt(n)
        + sigma * math.sqrt(partitions * n)
        + math.sqrt((1.0 - sigma) * out)
    ) ** 2 / m_b
    c2 = sigma * (math.sqrt(n) + math.sqrt(out)) ** 2 / m_b
    return c1, c2


def eagg_costs_exact(
    n_pages: int,
    rows_per_page: int,
    spilled_rows: Sequence[int],
    resident_groups: int,
    spilled_groups: int,
    plan: EAggPlan,
) -> Tuple[float, float]:
    """Exact (ceil-based) D and C mirroring the engine's round semantics.

    ``spilled_rows`` are the per-spilled-partition row counts (skew-aware);
    ``resident_groups``/``spilled_groups`` the group-output row counts flushed
    in P1/P2.  Replicates the integer slice/batch sizing of
    :class:`repro.engine.BufferPool` / :class:`repro.engine.PageCursor`, so
    the simulated ledger of :func:`repro.remote.eagg.eagg` matches exactly.
    """
    n_spilled = max(len(spilled_rows), 1)
    r_r1, r_w1, r_o1 = plan.p1
    r_r2, r_o2 = plan.p2

    def pool_rounds(rows: int, slice_pages: int) -> Tuple[int, int]:
        """(pages written, write rounds) for one stream through one pool slice."""
        if rows <= 0:
            return 0, 0
        slice_rows = slice_pages * rows_per_page
        full, rem = divmod(rows, slice_rows)
        pages = full * slice_pages + math.ceil(rem / rows_per_page)
        return pages, full + (1 if rem else 0)

    d = float(n_pages)
    c = math.ceil(n_pages / max(1, int(round(r_r1))))  # P1 input scan

    slice_w = max(1, int(r_w1 / n_spilled))
    batch2 = max(1, int(round(r_r2)))
    for rows in spilled_rows:  # P1 spill writes + P2 re-reads
        pages, rounds = pool_rounds(rows, slice_w)
        d += 2 * pages
        c += rounds + (math.ceil(pages / batch2) if pages else 0)

    for groups, r_o in ((resident_groups, r_o1), (spilled_groups, r_o2)):
        pages, rounds = pool_rounds(groups, max(1, int(r_o)))
        d += pages
        c += rounds
    return d, float(c)


def eagg_latency(n: float, out: float, plan: EAggPlan, tau: float) -> float:
    d = sum(eagg_data_costs(n, out, plan.sigma))
    c = sum(eagg_round_costs(n, out, plan))
    return d + tau * c


# ==========================================================================
# Tiered placement (memory hierarchy)
# ==========================================================================
#
# The paper's Table I prices several media; read as an ordered hierarchy
# (DRAM -> RDMA -> SSD) the planning question becomes *where* spilled pages
# live, not just how buffers split.  The closed forms below mirror the
# runtime router (`repro.remote.simulator.MemoryHierarchy`): spill volume
# fills the cheapest (topmost) tier's free capacity first and overflows
# downward, and a write round that straddles a capacity boundary pays one
# round on every tier it lands on.


def tiered_split(
    pages: float,
    capacities: Sequence[float],
    occupied: Sequence[float] | None = None,
    start: int = 0,
) -> List[float]:
    """Cheapest-tier-first waterfall of ``pages`` over per-tier free capacity.

    Returns pages placed per tier (index-aligned with ``capacities``); tiers
    above ``start`` receive nothing.  Raises ``ValueError`` when the pages
    overflow the whole hierarchy (give the bottom tier ``math.inf`` capacity
    to model an unbounded backstop).
    """
    occ = [0.0] * len(capacities) if occupied is None else list(occupied)
    if len(occ) != len(capacities):
        raise ValueError("occupied and capacities must align")
    placed = [0.0] * len(capacities)
    remaining = float(pages)
    for t in range(start, len(capacities)):
        if remaining <= 0.0:
            break
        free = capacities[t] - occ[t]
        free = remaining if math.isinf(free) else max(math.floor(free), 0)
        take = min(remaining, free)
        placed[t] = take
        remaining -= take
    if remaining > 1e-9:
        raise ValueError(
            f"{pages} pages overflow the hierarchy "
            f"(capacities {list(capacities)}, occupied {occ})"
        )
    return placed


def waterfall_io(
    write_pages: float,
    round_pages: int,
    capacities: Sequence[float],
    occupied: Sequence[float] | None = None,
    start: int = 0,
) -> List[Tuple[float, float]]:
    """Exact per-tier (D, C) of a uniform-round write stream routed first-fit.

    A stream of ``write_pages`` pages arrives in rounds of ``round_pages``
    (the last round may be partial) targeting tier ``start``; the router
    places each round's pages into the first free capacity at-or-below the
    target, so stream page ``i`` lands deterministically and round
    ``floor(i / round_pages)`` pays one round on every tier it touches —
    exactly :class:`repro.remote.simulator.MemoryHierarchy` write semantics
    (integral capacities/occupancy assumed, as in the page-granular store).
    """
    if round_pages < 1:
        raise ValueError(f"round_pages must be >= 1, got {round_pages}")
    placed = tiered_split(write_pages, capacities, occupied, start)
    per_tier: List[Tuple[float, float]] = []
    offset = 0.0  # stream offset of the first page landing on this tier
    for d in placed:
        if d <= 0:
            per_tier.append((0.0, 0.0))
            continue
        first_round = math.floor(offset / round_pages)
        last_round = math.floor((offset + d - 1) / round_pages)
        per_tier.append((float(d), float(last_round - first_round + 1)))
        offset += d
    return per_tier


def tiered_latency_cost(
    per_tier_dc: Sequence[Tuple[float, ...]],
    taus: Sequence[float],
    overlap_migration: bool = False,
) -> float:
    """Hierarchy-wide L = sum_t (D_t + tau_t * C_t) (Definition 3 per tier).

    Entries are ``(D, C)`` pairs (:func:`waterfall_io`) or ``(D, C,
    C_hidden)`` triples (:func:`eviction_waterfall_io`); with
    ``overlap_migration=True`` the hidden background-migration rounds pay no
    tau, mirroring ``latency_seconds(overlap_migration=True)``.
    """
    total = 0.0
    for entry, tau in zip(per_tier_dc, taus):
        d, c = entry[0], entry[1]
        hidden = entry[2] if len(entry) > 2 else 0.0
        paying = c - hidden if overlap_migration else c
        total += d + tau * max(paying, 0.0)
    return total


def eviction_waterfall_io(
    write_pages: float,
    round_pages: int,
    capacities: Sequence[float],
    occupied: Sequence[float] | None = None,
    start: int = 0,
) -> List[Tuple[float, float, float]]:
    """Exact per-tier (D, C, C_hidden) of a write stream under proactive eviction.

    The eviction-aware counterpart of :func:`waterfall_io`: the stream's
    ``write_pages`` arrive in rounds of ``round_pages`` targeting tier
    ``start``, and instead of waterfalling overflow downward, an evictor
    demotes the tier's coldest resident pages (pre-existing ``occupied``
    pages or the stream's own oldest pages) one tier down in **one background
    migration batch per overflowing round**, recursively making room below —
    exactly :class:`repro.engine.eviction.Evictor` semantics.  Every write
    round therefore lands whole on the target tier; each demotion batch is
    one hidden read round on the ledger it leaves and one hidden write round
    on the ledger it enters.

    Returns one ``(D, C, C_hidden)`` triple per tier (D sums reads and
    writes, matching ``ledger.d_total``/``c_total``/``c_migration_hidden``
    for a hierarchy that runs only this stream).  Raises ``ValueError`` when
    a tier lacks evictable residents to cover a deficit or the bottom tier
    overflows — callers fall back to :func:`waterfall_io` semantics there.
    """
    if round_pages < 1:
        raise ValueError(f"round_pages must be >= 1, got {round_pages}")
    n = len(capacities)
    occ = [0.0] * n if occupied is None else list(occupied)
    if len(occ) != n:
        raise ValueError("occupied and capacities must align")
    res = list(occ)
    d = [0.0] * n
    c = [0.0] * n
    hidden = [0.0] * n

    def admit(t: int, amount: float) -> None:
        """Make room for ``amount`` pages arriving on tier ``t``."""
        free = capacities[t] - res[t]
        if math.isinf(free) or free >= amount:
            return
        if t == n - 1:
            raise ValueError(
                f"{amount} pages overflow the bottom tier "
                f"(capacities {list(capacities)}, resident {res})"
            )
        deficit = math.ceil(amount - free)
        if deficit > res[t]:
            raise ValueError(
                f"tier {t} holds {res[t]} evictable pages but needs to "
                f"demote {deficit}; not an eviction-covered stream"
            )
        admit(t + 1, deficit)
        d[t] += deficit  # read round leaving t (background: RTT hidden)
        c[t] += 1
        hidden[t] += 1
        d[t + 1] += deficit  # write round entering t+1 (hidden)
        c[t + 1] += 1
        hidden[t + 1] += 1
        res[t] -= deficit
        res[t + 1] += deficit

    remaining = float(write_pages)
    while remaining > 0:
        s = min(float(round_pages), remaining)
        admit(start, s)
        d[start] += s
        c[start] += 1
        res[start] += s
        remaining -= s
    return list(zip(d, c, hidden))


# ==========================================================================
# Operator pushdown (compute-capable tiers)
# ==========================================================================
#
# Farview/PIMDAL-style near-memory execution: a compute-capable TierLevel
# (``compute_pps`` pages/s, ``pushdown_ops``) can run a filter or a partial
# reduction over its resident pages and ship only results.  Ship-the-pages
# and ship-the-compute then price against each other in the same L units:
#
#   ship:  L = n + tau * ceil(n / batch)
#   push:  L = kept + tau * ceil(n / batch) + kappa * n        (filter)
#          L = out  + tau * 1               + kappa * n        (reduce)
#
# with kappa = level.compute_tau_pages (one scanned page's tier compute in
# L-pages) and kept = floor(n * sel) — the deterministic page-granular rule
# shared with ``MemoryHierarchy.scan_filtered`` (``pushdown_keep``), which is
# what makes these forms exact against the simulated ledger.


@dataclasses.dataclass(frozen=True)
class PushdownCosts:
    """Exact ledger prediction of one pushed scan over ``scanned`` pages."""

    d_ship: float  # result pages shipped back (d_pushdown)
    c_rounds: int  # request rounds (c_pushdown)
    scanned: float  # pages processed at the tier (d_pushdown + saved)
    compute_l: float  # tier compute in L units (kappa * scanned)
    compute_seconds: float  # tier compute wall time (scanned / compute_pps)

    @property
    def d_saved(self) -> float:
        return self.scanned - self.d_ship

    def latency_cost(self, tau: float) -> float:
        """L = D + tau*C + kappa*scanned of the pushed execution."""
        return self.d_ship + tau * self.c_rounds + self.compute_l


def pushdown_costs(
    n_pages: int,
    selectivity: float,
    level,
    batch_pages: int | None = None,
) -> PushdownCosts:
    """Exact costs of pushing a ``selectivity`` filter over ``n_pages``
    resident on compute-capable ``level`` (a ``TierLevel``), requested in
    ``batch_pages`` chunks (default: one round).

    Matches ``MemoryHierarchy.scan_filtered`` ledger-exactly:
    ``d_pushdown = floor(n * sel)``, ``c_pushdown = ceil(n / batch)``,
    ``d_pushdown_saved = n - floor(n * sel)``.
    """
    if n_pages < 0:
        raise ValueError(f"n_pages must be >= 0, got {n_pages}")
    if not math.isfinite(selectivity) or not 0.0 < selectivity <= 1.0:
        raise ValueError(
            f"selectivity must be finite and in (0, 1], got {selectivity}"
        )
    if not level.can_push("filter"):
        raise ValueError(
            f"tier {level.tier.name!r} cannot execute pushdown op 'filter'"
        )
    batch = int(n_pages) if batch_pages is None else int(batch_pages)
    if n_pages and batch <= 0:
        raise ValueError(f"batch_pages must be > 0, got {batch_pages}")
    kept = float(math.floor(n_pages * selectivity))
    rounds = math.ceil(n_pages / batch) if n_pages else 0
    return PushdownCosts(
        d_ship=kept,
        c_rounds=rounds,
        scanned=float(n_pages),
        compute_l=level.compute_tau_pages * n_pages if n_pages else 0.0,
        compute_seconds=level.compute_seconds(float(n_pages)),
    )


def pushdown_reduce_costs(n_pages: int, out_pages: float, level) -> PushdownCosts:
    """Exact costs of a pushed partial reduction: one request round ships
    ``out_pages`` result pages instead of ``n_pages`` raw ones
    (``MemoryHierarchy.read_reduced`` semantics)."""
    if n_pages < 0:
        raise ValueError(f"n_pages must be >= 0, got {n_pages}")
    if not level.can_push("reduce"):
        raise ValueError(
            f"tier {level.tier.name!r} cannot execute pushdown op 'reduce'"
        )
    return PushdownCosts(
        d_ship=float(out_pages),
        c_rounds=1 if n_pages else 0,
        scanned=float(n_pages),
        compute_l=level.compute_tau_pages * n_pages if n_pages else 0.0,
        compute_seconds=level.compute_seconds(float(n_pages)),
    )


@dataclasses.dataclass(frozen=True)
class PushdownChoice:
    """A round-aware ship-pages vs. ship-compute arbitration verdict."""

    op: str  # "filter" or "reduce"
    push: bool  # True: execute at the tier; False: ship the pages
    l_ship: float  # L of shipping the raw pages
    l_push: float  # L of the pushed execution (inf on a non-capable tier)
    d_saved: float  # pages that skip the trip when pushed (0 if shipped)
    c_pushdown: int  # request rounds stamped when pushed (0 if shipped)
    scanned: float  # pages the tier would process when pushed

    @property
    def l_delta(self) -> float:
        """L change of the decision vs. ship-only (<= 0 by construction)."""
        return min(self.l_push - self.l_ship, 0.0)

    @property
    def mode(self) -> str:
        return "push" if self.push else "ship"


def pushdown_or_ship(
    n_pages: int,
    selectivity: float,
    level,
    tau: float,
    batch_pages: int | None = None,
    op: str = "filter",
    out_pages: float | None = None,
) -> PushdownChoice:
    """Price ship-the-pages against ship-the-compute for one stream.

    ``op="filter"``: push ships ``floor(n * sel)`` pages in the same
    ``ceil(n / batch)`` rounds as the ship path, plus tier compute on all
    ``n`` scanned pages.  ``op="reduce"``: push ships ``out_pages`` result
    pages in one round (``selectivity`` is ignored).  A tier that cannot
    execute ``op`` always ships (``l_push = inf``); ties ship too, so the
    chooser is never worse than ship-only and declines pushdown whenever the
    tier's compute is too slow to pay for the volume it saves.
    """
    if n_pages < 0:
        raise ValueError(f"n_pages must be >= 0, got {n_pages}")
    batch = int(n_pages) if batch_pages is None else int(batch_pages)
    if n_pages and batch <= 0:
        raise ValueError(f"batch_pages must be > 0, got {batch_pages}")
    ship_rounds = math.ceil(n_pages / batch) if n_pages else 0
    l_ship = n_pages + tau * ship_rounds
    if n_pages == 0 or not level.can_push(op):
        return PushdownChoice(op=op, push=False, l_ship=l_ship,
                              l_push=math.inf, d_saved=0.0, c_pushdown=0,
                              scanned=0.0)
    if op == "filter":
        pc = pushdown_costs(n_pages, selectivity, level, batch_pages=batch)
    elif op == "reduce":
        if out_pages is None:
            raise ValueError("op='reduce' needs out_pages=")
        pc = pushdown_reduce_costs(n_pages, out_pages, level)
    else:
        raise ValueError(f"unknown pushdown op {op!r}")
    l_push = pc.latency_cost(tau)
    push = l_push < l_ship - 1e-12
    return PushdownChoice(
        op=op, push=push, l_ship=l_ship, l_push=l_push,
        d_saved=pc.d_saved if push else 0.0,
        c_pushdown=pc.c_rounds if push else 0,
        scanned=pc.scanned if push else 0.0,
    )
