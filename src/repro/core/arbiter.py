"""Query-level memory arbiter: split one page budget across a pipeline.

REMOP's §III policies optimize a *single* operator's buffers for a given
budget M.  A real spilling query runs several operators against one shared
budget, so the remaining degree of freedom is the split M = sum_i M_i.  The
arbiter minimizes the total modeled latency cost

    sum_i L_i(M_i)     s.t.  sum_i M_i = M,  M_i >= min_i

where each ``L_i`` is the operator's policy-aware closed-form cost
(``D + tau*C`` of the plan the policy would pick at budget ``M_i`` — the
``model`` hook on :class:`repro.engine.registry.OperatorSpec`).  Each L_i is
(weakly) decreasing and near-convex in M_i, so a greedy marginal-cost descent
in page quanta is near-optimal; the even split is also evaluated and the
better of the two is returned, so the arbiter is never worse than splitting
the budget evenly.

This module is pure algorithm: it knows nothing about operators or tiers,
only items with a minimum and a latency function of their budget.  The
engine-facing wrapper is :func:`repro.engine.pipeline.plan_pipeline`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ArbiterItem:
    """One pipeline member: a name, its floor, and its modeled cost L(m)."""

    name: str
    min_pages: float
    latency_of: Callable[[float], float]


def even_split(items: Sequence[ArbiterItem], budget: float) -> List[float]:
    """Budget/n each, with any item below its floor topped up from the rest."""
    _check_feasible(items, budget)
    n = len(items)
    alloc = [budget / n] * n
    # Top up floored items; shave the surplus pro rata from the unfloored.
    deficit = sum(max(it.min_pages - a, 0.0) for it, a in zip(items, alloc))
    if deficit > 0.0:
        surplus_idx = [i for i, it in enumerate(items) if alloc[i] > it.min_pages]
        headroom = sum(alloc[i] - items[i].min_pages for i in surplus_idx)
        for i, it in enumerate(items):
            if alloc[i] <= it.min_pages:
                alloc[i] = it.min_pages
            else:
                alloc[i] -= deficit * (alloc[i] - it.min_pages) / headroom
    return alloc


def greedy_split(
    items: Sequence[ArbiterItem], budget: float, step: float = 1.0
) -> List[float]:
    """Marginal-cost descent: repeatedly give one page quantum to the item
    whose modeled latency drops the most for it."""
    _check_feasible(items, budget)
    alloc = [it.min_pages for it in items]
    cur = [it.latency_of(a) for it, a in zip(items, alloc)]
    remaining = budget - sum(alloc)
    while remaining > 1e-9:
        s = min(step, remaining)
        best, best_gain, best_next = 0, -float("inf"), cur[0]
        for i, it in enumerate(items):
            nxt = it.latency_of(alloc[i] + s)
            gain = cur[i] - nxt
            if gain > best_gain:
                best, best_gain, best_next = i, gain, nxt
        alloc[best] += s
        cur[best] = best_next
        remaining -= s
    return alloc


def arbitrate(
    items: Sequence[ArbiterItem], budget: float, step: float = 1.0
) -> Tuple[List[float], float]:
    """Best of greedy marginal-cost descent and the (clamped) even split.

    Returns ``(allocations, total modeled latency)``; allocations sum to
    ``budget`` exactly and respect every item's floor.
    """
    candidates = [greedy_split(items, budget, step=step)]
    if len(items) > 1:
        candidates.append(even_split(items, budget))
    scored = [
        (sum(it.latency_of(a) for it, a in zip(items, alloc)), alloc)
        for alloc in candidates
    ]
    total, alloc = min(scored, key=lambda pair: pair[0])
    return alloc, total


def _check_feasible(items: Sequence[ArbiterItem], budget: float) -> None:
    if not items:
        raise ValueError("empty pipeline: nothing to arbitrate")
    floor = sum(it.min_pages for it in items)
    if budget < floor:
        raise ValueError(
            f"budget {budget} pages is below the pipeline floor {floor} "
            f"(minima: {[(it.name, it.min_pages) for it in items]})"
        )
