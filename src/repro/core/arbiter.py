"""Query-level memory arbiter: split one page budget across a pipeline.

REMOP's §III policies optimize a *single* operator's buffers for a given
budget M.  A real spilling query runs several operators against one shared
budget, so the remaining degree of freedom is the split M = sum_i M_i.  The
arbiter minimizes the total modeled latency cost

    sum_i L_i(M_i)     s.t.  sum_i M_i = M,  M_i >= min_i

where each ``L_i`` is the operator's policy-aware closed-form cost
(``D + tau*C`` of the plan the policy would pick at budget ``M_i`` — the
``model`` hook on :class:`repro.engine.registry.OperatorSpec`).  Each L_i is
(weakly) decreasing and near-convex in M_i, so a greedy marginal-cost descent
in page quanta is near-optimal; the even split is also evaluated and the
better of the two is returned, so the arbiter is never worse than splitting
the budget evenly.

This module is pure algorithm: it knows nothing about operators or tiers,
only items with a minimum and a latency function of their budget.  The
engine-facing wrapper is :func:`repro.engine.pipeline.plan_pipeline`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ArbiterItem:
    """One pipeline member: a name, its floor, and its modeled cost L(m)."""

    name: str
    min_pages: float
    latency_of: Callable[[float], float]


def even_split(items: Sequence[ArbiterItem], budget: float) -> List[float]:
    """Budget/n each, with any item below its floor topped up from the rest."""
    _check_feasible(items, budget)
    n = len(items)
    alloc = [budget / n] * n
    # Top up floored items; shave the surplus pro rata from the unfloored.
    deficit = sum(max(it.min_pages - a, 0.0) for it, a in zip(items, alloc))
    if deficit > 0.0:
        surplus_idx = [i for i, it in enumerate(items) if alloc[i] > it.min_pages]
        headroom = sum(alloc[i] - items[i].min_pages for i in surplus_idx)
        for i, it in enumerate(items):
            if alloc[i] <= it.min_pages:
                alloc[i] = it.min_pages
            else:
                alloc[i] -= deficit * (alloc[i] - it.min_pages) / headroom
    return alloc


def greedy_split(
    items: Sequence[ArbiterItem], budget: float, step: float = 1.0
) -> List[float]:
    """Marginal-cost descent: repeatedly give one page quantum to the item
    whose modeled latency drops the most for it."""
    _check_feasible(items, budget)
    alloc = [it.min_pages for it in items]
    cur = [it.latency_of(a) for it, a in zip(items, alloc)]
    remaining = budget - sum(alloc)
    while remaining > 1e-9:
        s = min(step, remaining)
        best, best_gain, best_next = 0, -float("inf"), cur[0]
        for i, it in enumerate(items):
            nxt = it.latency_of(alloc[i] + s)
            gain = cur[i] - nxt
            if gain > best_gain:
                best, best_gain, best_next = i, gain, nxt
        alloc[best] += s
        cur[best] = best_next
        remaining -= s
    return alloc


def arbitrate(
    items: Sequence[ArbiterItem], budget: float, step: float = 1.0
) -> Tuple[List[float], float]:
    """Best of greedy marginal-cost descent and the (clamped) even split.

    Returns ``(allocations, total modeled latency)``; allocations sum to
    ``budget`` exactly and respect every item's floor.
    """
    candidates = [greedy_split(items, budget, step=step)]
    if len(items) > 1:
        candidates.append(even_split(items, budget))
    scored = [
        (sum(it.latency_of(a) for it, a in zip(items, alloc)), alloc)
        for alloc in candidates
    ]
    total, alloc = min(scored, key=lambda pair: pair[0])
    return alloc, total


def _check_feasible(items: Sequence[ArbiterItem], budget: float) -> None:
    if not items:
        raise ValueError("empty pipeline: nothing to arbitrate")
    floor = sum(it.min_pages for it in items)
    if budget < floor:
        raise ValueError(
            f"budget {budget} pages is below the pipeline floor {floor} "
            f"(minima: {[(it.name, it.min_pages) for it in items]})"
        )


# --------------------------------------------------------------------------
# Hierarchy-aware arbitration: jointly assign (pages, tier) per operator
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HierarchyItem:
    """One pipeline member on a memory hierarchy.

    ``latency_of(m, t)`` is the modeled cost of running with budget ``m``
    placed on tier index ``t`` (L = D + tau_t * C of the policy's plan);
    ``footprint_of(m, t)`` estimates the spill pages the item parks on tier
    ``t`` — tier-dependent because the executed plan is (the tier's tau
    picks e.g. the EMS fan-in, hence pass count) — which is what tier
    capacities constrain.

    The closure is also where operator pushdown enters arbitration: the
    engine folds the ship-vs-push delta ``min(L_push - L_ship, 0)`` for
    tier ``t`` into ``latency_of`` (see ``engine.pipeline._modeled_latency``),
    so a compute-capable tier with a slower wire can still win placement
    when executing the scan tier-side saves more volume than the extra tau
    costs.  The arbiter itself stays pure — pushdown is just another term
    in the per-(m, t) cost surface it descends.
    """

    name: str
    min_pages: float
    latency_of: Callable[[float, int], float]
    footprint_of: Callable[[float, int], float] = lambda m, t: 0.0


def _placement_feasible(
    items: Sequence[HierarchyItem],
    alloc: Sequence[float],
    placement: Sequence[int],
    capacities: Sequence[float],
) -> bool:
    used = [0.0] * len(capacities)
    for it, m, t in zip(items, alloc, placement):
        used[t] += it.footprint_of(m, t)
    return all(u <= c + 1e-9 for u, c in zip(used, capacities))


def _soft_split(
    pages: float, capacities: Sequence[float], start: int
) -> List[float]:
    """First-fit waterfall that dumps any residual on the bottom tier.

    The eviction-aware planner's split: unlike
    :func:`repro.core.policies.tiered_split` it never raises — an evictor
    keeps the runtime write path unblocked, so planning prices impossible
    residuals at the bottom tier instead of failing.
    """
    placed = [0.0] * len(capacities)
    remaining = float(pages)
    for t in range(start, len(capacities)):
        free = capacities[t]
        take = remaining if math.isinf(free) else min(remaining, max(free, 0.0))
        placed[t] = take
        remaining -= take
        if remaining <= 0.0:
            break
    if remaining > 0.0:
        placed[-1] += remaining
    return placed


def _evictable_items(
    items: Sequence[HierarchyItem], capacities: Sequence[float]
) -> List[HierarchyItem]:
    """Wrap items with eviction-aware cost and footprint.

    With an evictor attached, a tier's capacity is *soft*: spill beyond it
    is demoted to lower tiers in background rounds rather than blocking, so

      * the modeled latency of placing an item on tier ``t`` blends the
        per-tier taus by the share of its footprint that actually stays on
        each tier (``_soft_split`` over free capacity), and
      * only the share resident on ``t`` counts against ``t``'s capacity.

    Each item is split against the free capacities independently (ignoring
    the other items' shares) — a deliberate planning approximation; the
    runtime evictor resolves the true interleaving.
    """
    caps = list(capacities)

    def wrap(it: HierarchyItem) -> HierarchyItem:
        def latency_of(m: float, t: int, it=it) -> float:
            fp = it.footprint_of(m, t)
            if fp <= 0.0:
                return it.latency_of(m, t)
            placed = _soft_split(fp, caps, t)
            return sum(
                share / fp * it.latency_of(m, u)
                for u, share in enumerate(placed)
                if share > 0.0
            )

        def footprint_of(m: float, t: int, it=it) -> float:
            fp = it.footprint_of(m, t)
            if fp <= 0.0:
                return fp
            return _soft_split(fp, caps, t)[t]

        return HierarchyItem(
            name=it.name, min_pages=it.min_pages,
            latency_of=latency_of, footprint_of=footprint_of,
        )

    return [wrap(it) for it in items]


def arbitrate_hierarchy(
    items: Sequence[HierarchyItem],
    budget: float,
    capacities: Sequence[float],
    step: float = 1.0,
    occupied: Sequence[float] | None = None,
    eviction: bool = False,
    pinned_tiers: Sequence[int | None] | None = None,
) -> Tuple[List[float], List[int], float]:
    """Split one page budget AND place each item on a hierarchy tier.

    Greedy marginal-cost descent over joint (grant a page quantum, choose a
    tier) moves, with capacity-feasible placements tracked by footprint; the
    best feasible *single-tier* placement (every item on one tier, pages
    split by :func:`arbitrate`) is also evaluated, so the result is never
    worse than the best single-tier placement.

    ``occupied`` gives per-tier pages already consumed — the *measured*
    residency of a partially-executed pipeline — so a mid-query
    re-arbitration places the remaining items into the capacity that is
    actually left, not the capacity the original plan assumed.

    ``eviction=True`` plans for a hierarchy with a background evictor
    attached: capacities become *soft* (an item may target a tier its
    footprint overflows — the evictor demotes the overflow in hidden
    migration rounds), the modeled cost of a placement blends per-tier taus
    by where the footprint actually comes to rest, and non-bottom
    ``occupied`` pages are treated as evictable cold data that sinks to the
    bottom tier instead of blocking placements.

    ``pinned_tiers`` (one entry per item, ``None`` = free) fixes an item's
    tier: the descent still grants it budget quanta but never moves it off
    its pinned tier — how per-task ``placement=`` pins flow through a
    frontier re-arbitration without losing the joint budget split.

    Returns ``(allocations, tier indices, total modeled latency)``;
    allocations sum to ``budget`` and respect every item's floor, and the
    placement fits every tier's remaining capacity.  When no candidate
    satisfies both (every tier finite and footprint-full), raises
    ``ValueError`` instead of returning an assignment the runtime hierarchy
    could not honor.
    """
    if not items:
        raise ValueError("empty pipeline: nothing to arbitrate")
    floor = sum(it.min_pages for it in items)
    if budget < floor:
        raise ValueError(
            f"budget {budget} pages is below the pipeline floor {floor} "
            f"(minima: {[(it.name, it.min_pages) for it in items]})"
        )
    n_tiers = len(capacities)
    if n_tiers == 0:
        raise ValueError("empty hierarchy: nothing to place on")
    if occupied is not None:
        if len(occupied) != n_tiers:
            raise ValueError(
                f"occupied has {len(occupied)} tiers, capacities {n_tiers}"
            )
        if eviction and n_tiers > 1:
            # Cold residency above the backstop is evictable: it sinks to
            # the bottom tier rather than blocking fast-tier placements.
            occupied = [0.0] * (n_tiers - 1) + [
                occupied[-1] + sum(occupied[:-1])
            ]
        capacities = [
            c if math.isinf(c) else max(c - o, 0.0)
            for c, o in zip(capacities, occupied)
        ]
    if eviction:
        items = _evictable_items(items, capacities)
    if pinned_tiers is not None:
        if len(pinned_tiers) != len(items):
            raise ValueError(
                f"{len(pinned_tiers)} pinned tiers for {len(items)} items"
            )
        for it, pt in zip(items, pinned_tiers):
            if pt is not None and not 0 <= pt < n_tiers:
                raise ValueError(
                    f"item {it.name!r} pinned to tier {pt}, hierarchy has "
                    f"{n_tiers} tiers"
                )
    else:
        pinned_tiers = [None] * len(items)

    candidates: List[Tuple[List[float], List[int]]] = [
        _greedy_joint(items, budget, capacities, step, pinned_tiers)
    ]
    # Single-tier baselines: all (unpinned) items on tier t, pages split by
    # the 1-D arbiter.  Guarantees "never worse than best single tier".
    for t in range(n_tiers):
        tiers = [t if pt is None else pt for pt in pinned_tiers]
        flat = [
            ArbiterItem(it.name, it.min_pages,
                        lambda m, it=it, ti=ti: it.latency_of(m, ti))
            for it, ti in zip(items, tiers)
        ]
        alloc, _ = arbitrate(flat, budget, step=step)
        candidates.append((alloc, tiers))

    # Only capacity-feasible, fully-allocated assignments may win: the
    # greedy pass can stop early (capacity exhausted) or fall back to an
    # over-full tier, and a single-tier baseline can overflow its tier.
    candidates = [
        (a, p) for a, p in candidates
        if _placement_feasible(items, a, p, capacities)
        and abs(sum(a) - budget) <= 1e-6
    ]
    if not candidates:
        raise ValueError(
            f"no capacity-feasible (pages, tier) assignment: capacities "
            f"{list(capacities)} cannot hold the pipeline's spill footprints "
            f"at budget {budget} (give the bottom tier math.inf capacity for "
            f"an unbounded backstop)"
        )

    def total_of(alloc: Sequence[float], placement: Sequence[int]) -> float:
        return sum(
            it.latency_of(m, t) for it, m, t in zip(items, alloc, placement)
        )

    scored = [(total_of(a, p), a, p) for a, p in candidates]
    total, alloc, placement = min(scored, key=lambda triple: triple[0])
    return list(alloc), list(placement), total


def _greedy_joint(
    items: Sequence[HierarchyItem],
    budget: float,
    capacities: Sequence[float],
    step: float,
    pinned_tiers: Sequence[int | None] | None = None,
) -> Tuple[List[float], List[int]]:
    """Greedy descent over joint (item gets a quantum, on some tier) moves."""
    n_tiers = len(capacities)
    if pinned_tiers is None:
        pinned_tiers = [None] * len(items)
    alloc = [it.min_pages for it in items]
    used = [0.0] * n_tiers
    placement: List[int] = []

    def tiers_of(i: int) -> range | Tuple[int]:
        pt = pinned_tiers[i]
        return range(n_tiers) if pt is None else (pt,)

    def fits(i: int, m: float, t: int) -> bool:
        fp = items[i].footprint_of(m, t)
        cur = used[t]
        if placement[i] == t:
            cur -= items[i].footprint_of(alloc[i], t)
        return cur + fp <= capacities[t] + 1e-9

    # Initial placement at the floors: cheapest feasible tier per item.
    for i, it in enumerate(items):
        best_t, best_l = None, float("inf")
        for t in tiers_of(i):
            if used[t] + it.footprint_of(alloc[i], t) > capacities[t] + 1e-9:
                continue
            latency = it.latency_of(alloc[i], t)
            if latency < best_l:
                best_t, best_l = t, latency
        if best_t is None:  # nothing fits: fall back to the roomiest tier
            # (the resulting assignment is filtered out as infeasible by
            # arbitrate_hierarchy unless a later move repairs it)
            best_t = (pinned_tiers[i] if pinned_tiers[i] is not None else max(
                range(n_tiers), key=lambda t: capacities[t] - used[t]))
        placement.append(best_t)
        used[best_t] += it.footprint_of(alloc[i], best_t)

    cur = [it.latency_of(a, t) for it, a, t in zip(items, alloc, placement)]
    remaining = budget - sum(alloc)
    while remaining > 1e-9:
        s = min(step, remaining)
        best = None  # (gain, i, t, next_latency)
        for i, it in enumerate(items):
            for t in tiers_of(i):
                if not fits(i, alloc[i] + s, t):
                    continue
                nxt = it.latency_of(alloc[i] + s, t)
                gain = cur[i] - nxt
                if best is None or gain > best[0]:
                    best = (gain, i, t, nxt)
        if best is None:  # no capacity-feasible grant anywhere: stop early
            break
        _, i, t, nxt = best
        used[placement[i]] -= items[i].footprint_of(alloc[i], placement[i])
        alloc[i] += s
        placement[i] = t
        used[t] += items[i].footprint_of(alloc[i], t)
        cur[i] = nxt
        remaining -= s

    # Final reassignment sweep: move items to cheaper tiers while it helps.
    improved = True
    while improved:
        improved = False
        for i, it in enumerate(items):
            for t in tiers_of(i):
                if t == placement[i] or not fits(i, alloc[i], t):
                    continue
                nxt = it.latency_of(alloc[i], t)
                if nxt < cur[i] - 1e-12:
                    used[placement[i]] -= it.footprint_of(alloc[i], placement[i])
                    placement[i] = t
                    used[t] += it.footprint_of(alloc[i], t)
                    cur[i] = nxt
                    improved = True
    return alloc, placement
