"""REMOP planner — maps the paper's buffer-allocation algebra onto TPU knobs.

Every plan below is an instance of the same trade: a budget (VMEM bytes, HBM
bytes, or a step's time) is partitioned into buffer regions; bigger regions
mean fewer, larger transfers (lower C) at the price of more total movement or
memory (higher D).  The latency objective is always Definition 3's
``L = D + tau * C`` with tau calibrated per tier (``cost_model.TPU_TIERS``):

  * matmul tiles        — BNLJ analogue (outer/inner block split, §III-A)
  * merge-sort fan-in   — EMS analogue (Property 5 / Table IV, §III-B)
  * MoE dispatch pools  — EHJ analogue (Property 6 waterfill, §III-C)
  * gradient buckets    — collective rounds over ICI
  * KV-cache pages      — paged-attention grid rounds over HBM
  * microbatch count    — accumulation rounds vs activation footprint
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

from repro.core.cost_model import TPU_V5E, TPUSpec
from repro.core import policies


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _round_down(x: int, mult: int) -> int:
    return max(mult, (x // mult) * mult)


# ==========================================================================
# BNLJ analogue: matmul tile planning
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class MatmulTilePlan:
    bm: int
    bn: int
    bk: int
    vmem_bytes: int
    d_bytes: float  # predicted HBM traffic
    c_rounds: float  # predicted DMA rounds
    l_cost: float  # D + tau * C (bytes)
    policy: str = "remop"


def matmul_costs(
    m: int, n: int, k: int, bm: int, bn: int, bk: int,
    in_bytes: int, out_bytes: int,
) -> Tuple[float, float]:
    """(D, C) for a tiled matmul with grid (m/bm, n/bn, k/bk).

    BNLJ correspondence (§III-A): the A row-block is the pinned outer block
    (one read per (i, j) tile: A is re-read once per N/bn column sweep), B is
    the rescanned inner relation, the (bm, bn) accumulator is the output
    region flushed once per (i, j).
    """
    gm, gn, gk = math.ceil(m / bm), math.ceil(n / bn), math.ceil(k / bk)
    d = (
        gn * m * k * in_bytes  # A re-read once per column sweep
        + gm * k * n * in_bytes  # B re-read once per row sweep
        + m * n * out_bytes  # C written once (K accumulated in VMEM)
    )
    c = 2.0 * gm * gn * gk + gm * gn  # A-tile + B-tile DMA per step, one flush
    return float(d), float(c)


def matmul_vmem(bm: int, bn: int, bk: int, in_bytes: int, acc_bytes: int = 4,
                double_buffer: bool = True) -> int:
    """VMEM bytes claimed by one grid step's working set."""
    factor = 2 if double_buffer else 1  # prefetch double buffer (§IV-E)
    return factor * (bm * bk + bk * bn) * in_bytes + bm * bn * acc_bytes


def plan_matmul_tiles(
    m: int, n: int, k: int,
    in_bytes: int = 2,
    acc_bytes: int = 4,
    vmem_budget: int | None = None,
    spec: TPUSpec = TPU_V5E,
    lane: int = 128,
    sublane: int = 8,
    exhaustive: bool = True,
) -> MatmulTilePlan:
    """Pick (bm, bn, bk) minimizing L = D + tau_dma * C under the VMEM budget.

    ``exhaustive=False`` applies the paper's closed form only: split the input
    region between the A and B tiles at p_R*:p_S* = sqrt(1 + R_in/tau):1
    (Property 4) and quantize to MXU alignment.  ``exhaustive=True`` (default,
    the beyond-paper mode) additionally searches the hardware-legal
    neighborhood and returns the argmin.
    """
    vmem_budget = vmem_budget or (spec.vmem_bytes // 2)
    tau = spec.tau_dma_bytes

    def aligned(x: int, cap: int, mult: int) -> int:
        return max(mult, min(_round_down(x, mult), _round_up(cap, mult)))

    # --- paper closed form -------------------------------------------------
    # Output region: selectivity analogue beta is tiny for matmul (the output
    # tile is written once per (i, j)), so r_in ~ Table III at beta -> 0.
    a_param = (vmem_budget / max(in_bytes, 1)) / max(tau, 1e-9)
    r_in = policies.bnlj_rin_opt(a_param, 1e-6)
    input_budget = r_in * vmem_budget
    p_r = policies.bnlj_split_opt(input_budget / max(in_bytes, 1), tau / max(in_bytes, 1))
    # Interpret: A-tile gets p_r of the input region, B-tile the rest; pick bk
    # to use the depth allowed by the smaller side at max lane alignment.
    bk0 = aligned(min(k, 512), k, lane)
    bm0 = aligned(int(p_r * input_budget / (2 * in_bytes * bk0)), m, sublane)
    bn0 = aligned(int((1 - p_r) * input_budget / (2 * in_bytes * bk0)), n, lane)
    bm0, bn0, bk0 = min(bm0, _round_up(m, sublane)), min(bn0, _round_up(n, lane)), min(bk0, _round_up(k, lane))

    def mk(bm: int, bn: int, bk: int, policy: str) -> MatmulTilePlan | None:
        v = matmul_vmem(bm, bn, bk, in_bytes, acc_bytes)
        if v > vmem_budget:
            return None
        d, c = matmul_costs(m, n, k, bm, bn, bk, in_bytes, acc_bytes)
        return MatmulTilePlan(bm, bn, bk, v, d, c, d + tau * c, policy)

    base = mk(bm0, bn0, bk0, "remop-closed-form")
    while base is None and bk0 > lane:
        bk0 //= 2
        base = mk(bm0, bn0, bk0, "remop-closed-form")
    while base is None and (bm0 > sublane or bn0 > lane):
        bm0 = max(sublane, bm0 // 2)
        bn0 = max(lane, bn0 // 2)
        base = mk(bm0, bn0, bk0, "remop-closed-form")
    assert base is not None, "no feasible tile under VMEM budget"
    if not exhaustive:
        return base

    # --- beyond-paper exhaustive neighborhood search -----------------------
    best = base
    bms = {aligned(x, m, sublane) for x in (64, 128, 256, 512, 1024, 2048, bm0)}
    bns = {aligned(x, n, lane) for x in (128, 256, 512, 1024, 2048, bn0)}
    bks = {aligned(x, k, lane) for x in (128, 256, 512, 1024, 2048, bk0)}
    for bm in bms:
        for bn in bns:
            for bk in bks:
                cand = mk(min(bm, _round_up(m, sublane)),
                          min(bn, _round_up(n, lane)),
                          min(bk, _round_up(k, lane)), "remop-search")
                if cand is not None and cand.l_cost < best.l_cost:
                    best = cand
    return best


def conventional_matmul_tiles(
    m: int, n: int, k: int, in_bytes: int = 2, acc_bytes: int = 4,
    vmem_budget: int | None = None, spec: TPUSpec = TPU_V5E,
) -> MatmulTilePlan:
    """Volume-minimizing baseline (the disk-era policy): maximize the A tile,
    stream B one lane-column at a time — the (M-2):1 outer-heavy split."""
    vmem_budget = vmem_budget or (spec.vmem_bytes // 2)
    tau = spec.tau_dma_bytes
    bn, bk = 128, min(k, 512)
    bm = _round_down(
        (vmem_budget - matmul_vmem(0, bn, bk, in_bytes, acc_bytes)) // (2 * in_bytes * bk + acc_bytes * bn),
        8,
    )
    bm = max(8, min(bm, _round_up(m, 8)))
    d, c = matmul_costs(m, n, k, bm, bn, bk, in_bytes, acc_bytes)
    return MatmulTilePlan(bm, bn, bk, matmul_vmem(bm, bn, bk, in_bytes, acc_bytes),
                          d, c, d + tau * c, "conventional")


# ==========================================================================
# EMS analogue: merge fan-in for blocked sort
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class SortPlan:
    n_items: int
    run_items: int  # items sorted in-core per run
    k: int  # merge fan-in per pass
    passes: int
    r_in_frac: float


def plan_sort(
    n_items: int, item_bytes: int = 8,
    vmem_budget: int | None = None, spec: TPUSpec = TPU_V5E,
) -> SortPlan:
    """EMS policy for the blocked merge sort kernel: Property 5 + Table IV."""
    vmem_budget = vmem_budget or (spec.vmem_bytes // 4)
    m_pages = vmem_budget  # bytes as "pages" of 1 byte; tau in bytes
    tau = spec.tau_dma_bytes
    k = policies.ems_kopt(m_pages / tau)
    run_items = max(1024, _round_down(vmem_budget // (2 * item_bytes), 1024))
    runs = math.ceil(n_items / run_items)
    k = max(2, min(k, max(2, runs)))
    passes = policies.ems_passes(n_items, run_items, k) if runs > 1 else 0
    return SortPlan(n_items, run_items, k, passes, policies.ems_split_opt(k))


# ==========================================================================
# EHJ analogue: MoE dispatch staging pools
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    tokens: int
    experts: int
    ep_degree: int
    sigma: float  # fraction of tokens routed off-chip
    read_pool: float  # R_r (bytes)
    stage_pool: float  # R_s (bytes) — per-destination staging total
    out_pool: float  # R_o (bytes)
    a2a_rounds: float  # predicted all-to-all rounds


def plan_dispatch(
    tokens_per_device: int,
    token_bytes: int,
    experts: int,
    ep_degree: int,
    buffer_budget: int,
    out_factor: float = 1.0,
) -> DispatchPlan:
    """EHJ probe-phase allocation for MoE all-to-all dispatch (Property 6).

    `tokens` play |Q|, destinations (ep shards) play partitions P, off-chip
    fraction sigma = 1 - 1/ep (uniform routing), output = returned expert
    results.  R_s caps tokens staged per a2a round: rounds = spilled/R_s.
    """
    sigma = 0.0 if ep_degree <= 1 else 1.0 - 1.0 / ep_degree
    q = float(tokens_per_device * token_bytes)
    out = out_factor * q
    coeffs = (q, sigma * sigma * ep_degree * q, (1.0 - sigma) * out)
    alloc, _ = policies.waterfill(coeffs, float(buffer_budget))
    r_r, r_s, r_o = alloc
    spilled = sigma * q
    rounds = spilled / max(r_s, 1.0) if spilled else 0.0
    return DispatchPlan(tokens_per_device, experts, ep_degree, sigma,
                        r_r, r_s, r_o, rounds)


# ==========================================================================
# Collective rounds: gradient-bucket planning
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    total_bytes: int
    n_buckets: int
    bucket_bytes: int
    exposed_seconds: float


def plan_grad_buckets(
    total_grad_bytes: int,
    backward_seconds: float,
    group_size: int,
    spec: TPUSpec = TPU_V5E,
    max_buckets: int = 256,
) -> BucketPlan:
    """Round-aware all-reduce bucketing.

    With B buckets, comm time = total/bw_ring + B * launch (C = B rounds each
    paying the collective-launch "RTT"); all but the last bucket can overlap
    backward compute.  Exposed time ~ max(comm - backward, 0) + last bucket.
    Minimizing this is the REMOP trade: fewer rounds vs finer overlap.
    """
    if group_size <= 1 or total_grad_bytes == 0:
        return BucketPlan(total_grad_bytes, 1, total_grad_bytes, 0.0)
    ring = 2.0 * (group_size - 1) / group_size  # ring all-reduce volume factor
    bw = spec.ici_bandwidth
    tau = spec.collective_launch_s

    def exposed(b: int) -> float:
        bucket = total_grad_bytes / b
        comm = ring * total_grad_bytes / bw + b * tau
        tail = ring * bucket / bw + tau
        return max(comm - backward_seconds, 0.0) + tail

    best_b = min(range(1, max_buckets + 1), key=exposed)
    return BucketPlan(total_grad_bytes, best_b,
                      int(math.ceil(total_grad_bytes / best_b)), exposed(best_b))


# ==========================================================================
# KV-cache paging for decode
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class KVPagePlan:
    page_tokens: int
    pages: int
    d_bytes: float
    c_rounds: float
    l_cost: float


def plan_kv_pages(
    context_len: int,
    kv_heads: int,
    head_dim: int,
    kv_bytes: int = 2,
    vmem_budget: int | None = None,
    spec: TPUSpec = TPU_V5E,
    lane: int = 128,
) -> KVPagePlan:
    """Page size for paged-attention decode: one page read = one DMA round.

    Bigger pages cut rounds (C = 2 * ceil(S/page) for K and V) but claim more
    VMEM and waste tail bandwidth (avg page/2 overfetch on the last page).
    """
    vmem_budget = vmem_budget or (spec.vmem_bytes // 8)
    tau = spec.tau_dma_bytes
    per_tok = kv_heads * head_dim * kv_bytes
    best = None
    p = lane
    while p <= max(lane, min(context_len, 4096)):
        vmem = 2 * 2 * p * per_tok  # K and V slots, double-buffered
        if vmem <= vmem_budget:
            pages = math.ceil(context_len / p)
            d = 2.0 * pages * p * per_tok  # includes tail overfetch
            c = 2.0 * pages
            l = d + tau * c
            if best is None or l < best.l_cost:
                best = KVPagePlan(p, pages, d, c, l)
        p *= 2
    assert best is not None
    return best


# ==========================================================================
# Microbatching: accumulation rounds vs activation footprint
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class MicrobatchPlan:
    microbatches: int
    per_microbatch: int
    act_bytes: int


def plan_microbatches(
    per_device_batch: int,
    seq_len: int,
    d_model: int,
    n_layers: int,
    act_bytes_per_elem: int = 2,
    act_multiplier: float = 2.0,
    hbm_activation_budget: int | None = None,
    spec: TPUSpec = TPU_V5E,
    seq_shards: int = 1,
) -> MicrobatchPlan:
    """Smallest accumulation-round count whose activations fit the budget.

    Under remat-over-layers, the checkpointed residual stream costs about
    n_layers * (mb * seq * d_model) * act_bytes * act_multiplier; each extra
    microbatch is one more accumulation round (C), so we take the minimum
    feasible count — the same min-C-subject-to-budget shape as Property 5.
    """
    budget = hbm_activation_budget or int(spec.hbm_bytes * 0.45)
    per_tok = d_model * act_bytes_per_elem * act_multiplier * n_layers / max(seq_shards, 1)
    mb = 1
    while mb < per_device_batch:
        act = (per_device_batch / mb) * seq_len * per_tok
        if act <= budget:
            break
        mb *= 2
    mb = min(mb, per_device_batch)
    while per_device_batch % mb:
        mb += 1
    act = int((per_device_batch / mb) * seq_len * per_tok)
    return MicrobatchPlan(mb, per_device_batch // mb, act)
