"""Production mesh definitions (task §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # jax 0.4.x has no ``axis_types=`` / ``jax.sharding.AxisType``; Auto is
    # already the default axis behaviour there.
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = None):
    """Elastic helper: best (data, model) mesh for an arbitrary device count."""
    model = model_parallel or min(devices, 16)
    while devices % model:
        model //= 2
    data = devices // model
    return jax.make_mesh((data, model), ("data", "model"))
