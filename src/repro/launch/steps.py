"""Builders for the jitted train / prefill / decode steps with full shardings.

The same builders serve the real drivers (launch/train.py, launch/serve.py)
and the multi-pod dry-run (launch/dryrun.py) — the dry-run just calls
``.lower(...).compile()`` on ShapeDtypeStructs instead of executing.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shlib
from repro.distributed.sharding import Sharder, use_sharder
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def state_shardings(params_struct, mesh, sharder: Sharder):
    """(params, opt m/v with ZeRO-1 over data, step) shardings."""
    p_specs = shlib.param_specs(params_struct, sharder)
    p_shard = shlib.named_sharding_tree(p_specs, mesh)
    add_data = shlib.zero1_specs(p_specs, sharder)
    if callable(add_data):
        z_specs = jax.tree.map(lambda s, p: add_data(s, p.shape), p_specs, params_struct)
    else:
        z_specs = p_specs
    z_shard = shlib.named_sharding_tree(z_specs, mesh)
    step_shard = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return {
        "params": p_shard,
        "opt": {"m": z_shard, "v": z_shard},
        "step": step_shard,
    }


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, sharder: Sharder,
                    microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state, batch):
        with use_sharder(sharder):
            params = state["params"]

            def loss_of(p, b):
                return tf.loss_fn(p, cfg, b, remat=True)

            if microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(params, batch)
            else:
                def split(x):
                    return x.reshape((microbatches, x.shape[0] // microbatches)
                                     + x.shape[1:])

                mbs = jax.tree.map(split, batch)

                def acc_fn(carry, mb):
                    (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                    gsum, lsum = carry
                    return (jax.tree.map(jnp.add, gsum, g), lsum + l), m

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), metrics = jax.lax.scan(
                    acc_fn, (g0, jnp.zeros((), jnp.float32)), mbs)
                grads = jax.tree.map(lambda g: g / microbatches, grads)
                loss = loss / microbatches
                metrics = jax.tree.map(lambda m: m.mean(), metrics)

            new_params, new_opt, opt_metrics = adamw_update(
                opt_cfg, params, grads, state["opt"], state["step"])
            metrics = dict(metrics)
            metrics.update(opt_metrics)
            metrics["loss_total"] = loss
            return {
                "params": new_params,
                "opt": new_opt,
                "step": state["step"] + 1,
            }, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, sharder: Sharder):
    def prefill_step(params, batch):
        with use_sharder(sharder):
            logits, caches = tf.prefill(params, cfg, batch)
            return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, sharder: Sharder, greedy: bool = True):
    def decode_step(params, caches, token, pos):
        with use_sharder(sharder):
            logits, caches = tf.decode_step(params, cfg, caches, token, pos)
            next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_token, logits, caches

    return decode_step


def init_state(cfg: ModelConfig, key, param_dtype=None) -> Dict[str, Any]:
    params = tf.init_params(key, cfg)
    if param_dtype is not None:
        # bf16 "master-light" mode: adamw keeps f32 m/v (the effective master
        # precision) and casts p through f32 for the update.
        params = jax.tree.map(
            lambda p: p.astype(param_dtype) if p.dtype == jnp.float32 else p,
            params)
    opt = init_opt_state(jax.tree.map(lambda p: p.astype(jnp.float32), params))
    return {"params": params,
            "opt": opt,
            "step": jnp.zeros((), jnp.int32)}


def state_struct(cfg: ModelConfig, param_dtype=None) -> Dict[str, Any]:
    """ShapeDtypeStruct tree of the train state (no allocation)."""
    return jax.eval_shape(
        lambda: init_state(cfg, jax.random.key(0), param_dtype))
