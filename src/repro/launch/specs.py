"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape x mode) cell.

No device allocation happens here: the dry-run lowers against these specs
(the shannon/kernels pattern — weak-type-correct, shardable).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import Sharder
from repro.models import transformer as tf


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training/prefill batch structure for one shape cell."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        text = s - cfg.frontend_seq
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, text), jnp.int32),
            "targets": jax.ShapeDtypeStruct((b, text), jnp.int32),
            "patches": jax.ShapeDtypeStruct((b, cfg.frontend_seq, cfg.frontend_dim),
                                            jnp.float32),
        }
    elif cfg.family == "audio_encdec":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.float32),
        }
    else:
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    if shape.kind == "prefill":
        specs.pop("targets")
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Any, Any, Any]:
    """(caches, token, pos) structs for one decode step at full context."""
    b, s = shape.global_batch, shape.seq_len
    enc_len = min(s, 4096) if cfg.n_encoder_layers else None
    caches = tf.cache_struct(cfg, batch=b, seq=s, enc_len=enc_len)
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return caches, token, pos


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------


def batch_shardings(sharder: Sharder, specs: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, v in specs.items():
        logical = ["batch"] + [None] * (v.ndim - 1)
        out[k] = sharder.sharding(logical, v.shape)
    return out


_CACHE_LOGICAL = {
    "attn": (None, "batch", "kv_seq", "kv_heads", None),
    "attn_local": (None, "batch", "kv_seq", "kv_heads", None),
    "moe": (None, "batch", "kv_seq", "kv_heads", None),
    "mla_c": (None, "batch", "kv_seq", None),
    "mla_r": (None, "batch", "kv_seq", None),
    "ssm_conv": (None, "batch", None, "ff"),
    "ssm_state": (None, "batch", "state", None, None),
    "rec_conv": (None, "batch", None, "ff"),
    "rec_h": (None, "batch", "ff"),
}


def cache_shardings(cfg: ModelConfig, sharder: Sharder, caches):
    """NamedSharding pytree matching a cache_struct pytree."""
    segs = tf._decoder_segments(cfg)

    def kv_shard(structs, logical_key):
        return tuple(
            sharder.sharding(_CACHE_LOGICAL[logical_key], a.shape) for a in structs
        )

    out = []
    for seg, seg_cache in zip(segs, caches):
        seg_out = {}
        for i, kind in enumerate(seg.kinds):
            name = f"b{i}_{kind}"
            c = seg_cache[name]
            if kind in ("attn", "attn_local", "moe"):
                key = "attn" if kind == "moe" else kind
                if len(c) == 4:  # int8-quantized: values + per-token scales
                    seg_out[name] = tuple(
                        sharder.sharding(_CACHE_LOGICAL[key], a.shape) for a in c)
                else:
                    seg_out[name] = kv_shard(c, key)
            elif kind in ("mla", "mla_moe"):
                seg_out[name] = (
                    sharder.sharding(_CACHE_LOGICAL["mla_c"], c[0].shape),
                    sharder.sharding(_CACHE_LOGICAL["mla_r"], c[1].shape),
                )
            elif kind == "ssm":
                seg_out[name] = (
                    sharder.sharding(_CACHE_LOGICAL["ssm_conv"], c[0].shape),
                    sharder.sharding(_CACHE_LOGICAL["ssm_state"], c[1].shape),
                )
            elif kind == "rec":
                seg_out[name] = (
                    sharder.sharding(_CACHE_LOGICAL["rec_conv"], c[0].shape),
                    sharder.sharding(_CACHE_LOGICAL["rec_h"], c[1].shape),
                )
            elif kind == "cross":
                seg_out[name] = {
                    "self": kv_shard(c["self"], "attn"),
                    "cross": kv_shard(c["cross"], "attn"),
                }
        out.append(seg_out)
    return out


def replicated(sharder: Sharder):
    return sharder.sharding([], ())
