from repro.launch import mesh
