"""Training driver: ``python -m repro.launch.train --arch qwen3-0.6b --reduced``.

On this CPU container use ``--reduced`` (tiny same-family config); on a real
pod the same driver builds the production mesh and full config.
"""

from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeSpec
from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import PrefetchingLoader, synthetic_batches
from repro.distributed.sharding import Sharder
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh_for, make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import LoopConfig, train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--reduced-overrides", default="",
                    help="k=v,k=v overrides for the reduced config")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = ARCHS[args.arch]
    if args.reduced:
        overrides = {}
        for kv in filter(None, args.reduced_overrides.split(",")):
            k, v = kv.split("=")
            overrides[k] = type(getattr(cfg, k))(v)
        cfg = reduced(cfg, **overrides)
    shape = ShapeSpec("cli", seq_len=args.seq_len, global_batch=args.global_batch,
                      kind="train")

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_mesh_for(len(jax.devices()))
    sharder = Sharder(mesh, sequence_parallel=mesh.devices.size > 1)

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 10, 1))
    step_fn = steps_lib.make_train_step(cfg, opt_cfg, sharder,
                                        microbatches=args.microbatches)
    state = steps_lib.init_state(cfg, jax.random.key(args.seed))
    st_shard = steps_lib.state_shardings(state["params"], mesh, sharder)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, st_shard)
    jitted = jax.jit(step_fn, in_shardings=(st_shard, None),
                 out_shardings=(st_shard, None), donate_argnums=0)

    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    losses = []

    def metrics_cb(step, m):
        losses.append(float(m["loss_total"]))
        print(f"step {step}: loss={m['loss_total']:.4f} "
              f"grad_norm={m['grad_norm']:.3f} lr={m['lr']:.2e}", flush=True)

    def batches(start_step):
        it = synthetic_batches(cfg, shape, seed=args.seed, start_step=start_step)
        return PrefetchingLoader(it)

    state = train(
        jitted, state, batches, store,
        LoopConfig(total_steps=args.steps,
                   checkpoint_every=args.checkpoint_every,
                   log_every=max(args.steps // 20, 1)),
        state_shardings=st_shard, metrics_cb=metrics_cb)
    print(f"done at step {int(jax.device_get(state['step']))}; "
          f"final loss {losses[-1] if losses else float('nan'):.4f}")
    return state, losses


if __name__ == "__main__":
    main()
