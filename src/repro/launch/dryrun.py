import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init) — task §MULTI-POD DRY-RUN step 0.

import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import dataclasses  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS, SHAPES, shape_applicable  # noqa: E402
from repro.core.roofline import report_from_compiled  # noqa: E402
from repro.distributed.sharding import Sharder  # noqa: E402
from repro.launch import specs as specs_lib  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _param_counts(cfg):
    import numpy as np

    shapes = jax.eval_shape(lambda k: tf.init_params(k, cfg), jax.random.key(0))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    embed = int(np.prod(shapes["embed"]["table"].shape))
    if cfg.n_experts:
        expert = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            names = "/".join(str(getattr(k, "key", k)) for k in path)
            if "experts" in names:
                expert += int(np.prod(leaf.shape))
        active = total - expert + int(expert * cfg.experts_per_token / cfg.n_experts)
    else:
        active = total
    return total, active, embed


def model_flops(cfg, shape, n_active: int, n_embed: int) -> float:
    """MODEL_FLOPS per task spec: 6*N*D train / 2*N*D inference (N excl. embed)."""
    n = max(n_active - n_embed, 1)
    d = shape.tokens_per_step
    return (6.0 if shape.kind == "train" else 2.0) * n * d


def _probe_cfgs(cfg):
    """(probe1, probe2, n_groups, frac_remainder) for depth extrapolation."""
    if cfg.family == "hybrid" and cfg.block_pattern:
        pat = len(cfg.block_pattern)
        groups, rem = divmod(cfg.n_layers, pat)
        return (dataclasses.replace(cfg, n_layers=pat),
                dataclasses.replace(cfg, n_layers=2 * pat),
                groups, rem / pat)
    if cfg.n_encoder_layers:
        assert cfg.n_encoder_layers == cfg.n_layers
        return (dataclasses.replace(cfg, n_layers=1, n_encoder_layers=1),
                dataclasses.replace(cfg, n_layers=2, n_encoder_layers=2),
                cfg.n_layers, 0.0)
    base = cfg.first_k_dense
    return (dataclasses.replace(cfg, n_layers=base + 1),
            dataclasses.replace(cfg, n_layers=base + 2),
            cfg.n_layers - base, 0.0)


def _lower_step(cfg, shape, mesh, sharder, microbatches):
    """Build and lower the step for a cell; returns the Lowered object."""
    if shape.kind == "train":
        import jax.numpy as _jnp
        pdt = _jnp.bfloat16 if getattr(_lower_step, "_bf16", False) else None
        state_struct = steps_lib.state_struct(cfg, param_dtype=pdt)
        st_shard = steps_lib.state_shardings(state_struct["params"], mesh, sharder)
        batch = specs_lib.batch_specs(cfg, shape)
        b_shard = specs_lib.batch_shardings(sharder, batch)
        step_fn = steps_lib.make_train_step(cfg, AdamWConfig(), sharder,
                                            microbatches=microbatches)
        return jax.jit(step_fn, in_shardings=(st_shard, b_shard),
                       donate_argnums=0).lower(state_struct, batch)
    import repro.distributed.sharding as shlib
    params_struct = steps_lib.state_struct(cfg)["params"]
    p_shard = shlib.named_sharding_tree(
        shlib.param_specs(params_struct, sharder), mesh)
    if shape.kind == "prefill":
        batch = specs_lib.batch_specs(cfg, shape)
        b_shard = specs_lib.batch_shardings(sharder, batch)
        step_fn = steps_lib.make_prefill_step(cfg, sharder)
        return jax.jit(step_fn, in_shardings=(p_shard, b_shard)).lower(
            params_struct, batch)
    caches, token, pos = specs_lib.decode_specs(cfg, shape)
    c_shard = specs_lib.cache_shardings(cfg, sharder, caches)
    t_shard = sharder.sharding(["batch"], token.shape)
    pos_shard = sharder.sharding([], ())
    step_fn = steps_lib.make_decode_step(cfg, sharder)
    return jax.jit(step_fn,
                   in_shardings=(p_shard, c_shard, t_shard, pos_shard),
                   donate_argnums=1).lower(params_struct, caches, token, pos)


def _probe_extrapolate(cfg, shape, mesh, sharder, microbatches):
    """Unrolled shallow probes -> true per-device flops/bytes/collectives.

    cost_analysis counts a scan (while) body once regardless of trip count,
    so the full scanned module under-reports; we compile two UNROLLED shallow
    variants and extrapolate linearly in depth:
        F(total) ~ F(probe1) + (groups - 1 + frac_rem) * (F(probe2) - F(probe1)).
    """
    from repro.core import roofline as rl

    p1, p2, groups, frac_rem = _probe_cfgs(cfg)
    tf.set_unroll(True)
    try:
        vals = []
        for pc in (p1, p2):
            compiled = _lower_step(pc, shape, mesh, sharder, microbatches).compile()
            ca = compiled.cost_analysis() or {}
            ops = rl.parse_hlo_collectives(compiled.as_text())
            vals.append({
                "flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "coll_operand": float(sum(o.operand_bytes for o in ops)),
                "coll_wire": float(sum(o.wire_bytes for o in ops)),
            })
    finally:
        tf.set_unroll(False)
    scale = groups - 1 + frac_rem
    return {k: vals[0][k] + scale * (vals[1][k] - vals[0][k]) for k in vals[0]}


VARIANTS = {
    # name: (sharder-rule overrides, sequence_parallel, microbatches, remat)
    "baseline": ({}, True, 1, None),
    "no_sp": ({}, False, 1, None),
    # Use the model axis as extra data parallelism (weights replicated):
    # right call when activations dwarf the weight shards (small archs).
    # batch on (data, model) = 256-way; on the multi-pod mesh the pod axis
    # replicates weights (hierarchical DP) — batch 256 is not divisible by
    # 512, and an indivisible rule would silently replicate the batch.
    "dp_only": ({"batch": ("data", "model"), "heads": None,
                 "kv_heads": None, "ff": None, "vocab": None, "expert": None,
                 "state": None, "heads_flat": None, "kv_flat": None,
                 "state_heads": None}, False, 1, None),
    # Half TP pressure: batch additionally on model is not expressible on a
    # fixed axis; instead drop SP and keep TP (activations batch-only).
    "kv_seq": ({"kv_seq": ("model",)}, True, 1, None),
    "mb4": ({}, True, 4, None),
    "remat_dots": ({}, True, 1, "dots"),
    # Manual expert-parallel MoE (shard_map): local experts + f32 psum combine
    # instead of GSPMD's expert-dim regathering.  SP off (the MoE block is
    # batch-local; SP re-gathers fight the shard_map boundary).
    "moe_ep": ({}, False, 1, None),
    # dp_only + gradient accumulation: activation footprint / 4.
    "dp_mb4": ({"batch": ("data", "model"), "heads": None,
                "kv_heads": None, "ff": None, "vocab": None, "expert": None,
                "state": None, "heads_flat": None, "kv_flat": None,
                "state_heads": None}, False, 4, None),
    # EP experts (shard_map) + replicated non-expert weights (pure DP for
    # attention/dense): kills the TP/SP activation collectives, keeps the
    # 14.4B expert bank sharded.
    "moe_ep_dp": ({"batch": ("data", "model"), "heads": None,
                   "kv_heads": None, "ff": None, "vocab": None,
                   "state": None, "heads_flat": None, "kv_flat": None,
                   "state_heads": None}, False, 1, None),
    # dp_only with bf16 params (f32 m/v retain master precision in Adam).
    "dp_bf16": ({"batch": ("data", "model"), "heads": None,
                 "kv_heads": None, "ff": None, "vocab": None, "expert": None,
                 "state": None, "heads_flat": None, "kv_flat": None,
                 "state_heads": None}, False, 1, None),
    # int8 KV cache (decode): halves cache residency + read bandwidth.
    "kv_int8": ({}, True, 1, None),
    # kv_seq + int8: sharded-KV flash decoding over a quantized cache.
    "kv_seq_int8": ({"kv_seq": ("model",)}, True, 1, None),
    # moe_ep_dp + 4-way gradient accumulation (activation footprint / 4).
    "moe_ep_dp_mb4": ({"batch": ("data", "model"), "heads": None,
                       "kv_heads": None, "ff": None, "vocab": None,
                       "state": None, "heads_flat": None, "kv_flat": None,
                       "state_heads": None}, False, 4, None),
}


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                microbatches: int = 1, sp: bool = True,
                variant: str = "baseline") -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi_pod" if multi_pod else "single_pod",
                "status": "skipped", "reason": why}

    rules, v_sp, v_mb, v_remat = VARIANTS[variant]
    if variant != "baseline":
        sp = v_sp
        microbatches = max(microbatches, v_mb)
    _lower_step._bf16 = (variant == "dp_bf16")
    tf.set_remat_policy(v_remat)
    from repro.models import moe as moe_mod
    moe_mod.set_moe_impl(
        "ep_shard_map" if variant.startswith("moe_ep") else "gspmd")
    from repro.models import attention as attn_mod
    attn_mod.set_kv_quant(variant in ("kv_int8", "kv_seq_int8"))
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    sharder = Sharder(mesh, rules=rules, sequence_parallel=sp)
    n_total, n_active, n_embed = _param_counts(cfg)
    mf = model_flops(cfg, shape, n_active, n_embed)

    t0 = time.time()
    lowered = _lower_step(cfg, shape, mesh, sharder, microbatches)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    report = report_from_compiled(
        f"{arch}/{shape_name}", compiled, chips=chips, model_flops=mf)
    report.resident_bytes_per_device = float(
        mem.argument_size_in_bytes + mem.output_size_in_bytes
        + mem.temp_size_in_bytes)
    # Depth-extrapolated true totals (scan bodies are counted once by XLA).
    probe_err = None
    try:
        probe = _probe_extrapolate(cfg, shape, mesh, sharder, microbatches)
        report.flops_per_device = probe["flops"]
        report.hbm_bytes_per_device = probe["bytes"]
        report.collective_operand_bytes = probe["coll_operand"]
        report.collective_wire_bytes = probe["coll_wire"]
    except Exception as e:
        probe_err = repr(e)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "variant": variant,
        "status": "ok",
        "chips": chips,
        "kind": shape.kind,
        "params_total": n_total,
        "params_active": n_active,
        "params_embed": n_embed,
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
            "peak_bytes_estimate": int(mem.argument_size_in_bytes
                                       + mem.temp_size_in_bytes),
        },
        "roofline": report.to_dict(),
        "probe_error": probe_err,
    }
    return result


def cell_path(arch, shape_name, multi_pod, out_dir, variant="baseline"):
    mesh = "multi" if multi_pod else "single"
    suffix = "" if variant == "baseline" else f"__{variant}"
    return os.path.join(out_dir, f"{arch}__{shape_name}__{mesh}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every pending cell in subprocesses (serial)")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence parallelism (ablation)")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        cells = [(a, s, mp)
                 for a in sorted(ARCHS)
                 for s in SHAPES
                 for mp in (False, True)]
        for a, s, mp in cells:
            path = cell_path(a, s, mp, args.out)
            if os.path.exists(path) and not args.force:
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--out", args.out]
            if mp:
                cmd.append("--multi-pod")
            print(f"[dryrun] {a} x {s} x {'multi' if mp else 'single'}",
                  flush=True)
            subprocess.run(cmd, check=False)
        return

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    path = cell_path(args.arch, args.shape, args.multi_pod, args.out,
                     args.variant)
    if os.path.exists(path) and not args.force:
        print(f"cached: {path}")
        return
    try:
        result = dryrun_cell(args.arch, args.shape, args.multi_pod,
                             microbatches=args.microbatches, sp=not args.no_sp,
                             variant=args.variant)
    except Exception as e:  # record failures — they are bugs to fix
        result = {"arch": args.arch, "shape": args.shape,
                  "mesh": "multi_pod" if args.multi_pod else "single_pod",
                  "status": "error", "error": repr(e),
                  "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    status = result["status"]
    print(f"[{status}] {args.arch} x {args.shape} "
          f"x {'multi' if args.multi_pod else 'single'}")
    if status == "ok":
        r = result["roofline"]
        print(f"  memory/device: {result['memory']['peak_bytes_estimate']/2**30:.2f} GiB; "
              f"compute {r['compute_seconds']*1e3:.2f} ms, "
              f"hbm [{r['memory_seconds_lower']*1e3:.2f}, {r['memory_seconds']*1e3:.2f}] ms, "
              f"ici {r['collective_seconds']*1e3:.2f} ms -> {r['dominant']}")
    elif status == "error":
        print(result["traceback"][-1500:])


if __name__ == "__main__":
    main()
