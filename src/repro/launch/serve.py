"""Serving driver: batched greedy decoding with continuous batching.

``python -m repro.launch.serve --arch gemma-2b --reduced --requests 6``
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import transformer as tf
from repro.runtime.serve_loop import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced(ARCHS[args.arch]) if args.reduced else ARCHS[args.arch]
    if cfg.family in ("vlm", "audio_encdec"):
        raise SystemExit("serve driver targets decoder-only archs")
    params = tf.init_params(jax.random.key(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                    dtype=np.int32),
                max_new_tokens=args.max_new_tokens)
        for i in range(args.requests)
    ]
    engine = ServeEngine(cfg, params, max_len=args.max_len,
                         batch_slots=args.slots)
    t0 = time.time()
    results = engine.submit(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    for rid in sorted(results):
        print(f"request {rid}: {results[rid]}")
    print(f"{len(results)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    return results


if __name__ == "__main__":
    main()
