"""Checkpoint store: atomic, async, reshard-on-restore.

Fault-tolerance contract (runtime/ft.py):
  * saves are atomic (write to tmp, fsync, rename) so a crash mid-save never
    corrupts the latest checkpoint;
  * an async worker thread snapshots device arrays to host then writes in the
    background, overlapping with training (one more REMOP prefetch analogue);
  * restore places leaves directly onto the *current* mesh's shardings, so a
    job restarted at a different scale (elastic re-shape) just works — the
    checkpoint format is sharding-agnostic (full arrays per leaf).
"""

from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_FLAT_SEP = "::"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _FLAT_SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _FLAT_SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- paths ---------------------------------------------------------------

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    def latest_step(self) -> Optional[int]:
        steps = []
        for f in os.listdir(self.directory):
            m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
            if m:
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, state, metadata: Optional[Dict[str, Any]] = None,
             blocking: bool = True) -> None:
        """Snapshot to host, then write (optionally in the background)."""
        self.wait()  # one outstanding async save at a time
        host_flat = _flatten(state)  # device->host copy happens here

        def write():
            try:
                tmp = self._path(step) + ".tmp"
                with open(tmp, "wb") as f:
                    np.savez(f, __meta__=json.dumps(metadata or {}), **host_flat)
                    f.flush()
                    os.fsync(f.fileno())
                os.rename(tmp, self._path(step))  # atomic publish
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self.wait()
        else:
            self._worker = threading.Thread(target=write, daemon=True)
            self._worker.start()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1))
            for f in os.listdir(self.directory)
            if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))
        )
        for s in steps[: -self.keep]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass

    # -- restore -----------------------------------------------------------------

    def restore(self, step: int, template, shardings=None):
        """Load into `template`'s structure; place onto `shardings` if given."""
        with np.load(self._path(step), allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            flat = {k: z[k] for k in z.files if k != "__meta__"}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, meta

    def restore_latest(self, template, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None, None
        state, meta = self.restore(step, template, shardings)
        return step, state, meta
