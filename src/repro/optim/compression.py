"""Gradient compression for cross-pod all-reduce (distributed-optimization trick).

At 1000+ nodes the pod-level gradient all-reduce is the scarcest bandwidth
(DCN between pods is ~10x slower than ICI).  We compress gradients to bf16 or
int8 *before* the cross-pod reduction and keep an error-feedback residual so
the quantization bias cancels over steps (Karimireddy et al., 2019).  This is
a REMOP-flavored trade on the D term: fewer bytes per round, same rounds.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def _int8_quant(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _int8_dequant(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_int8_with_feedback(grads, residual):
    """Returns (quantized tree of (q, scale), new_residual)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = _int8_quant(corrected)
        back = _int8_dequant(q, scale)
        return (q, scale), corrected - back

    pairs = jax.tree.map(one, grads, residual)
    quantized = jax.tree.map(lambda t: t[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                             and isinstance(x[0], tuple))
    # Simpler: rebuild trees explicitly.
    flat, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    qs, scales, new_res = [], [], []
    for g, r in zip(flat, flat_r):
        corrected = g.astype(jnp.float32) + r
        q, scale = _int8_quant(corrected)
        qs.append(q)
        scales.append(scale)
        new_res.append(corrected - _int8_dequant(q, scale))
    return (jax.tree.unflatten(tdef, qs), jax.tree.unflatten(tdef, scales),
            jax.tree.unflatten(tdef, new_res))


def decompress_int8(qs, scales):
    return jax.tree.map(_int8_dequant, qs, scales)


def compression_ratio(dtype_from=jnp.float32, dtype_to=jnp.int8) -> float:
    return jnp.dtype(dtype_from).itemsize / jnp.dtype(dtype_to).itemsize
