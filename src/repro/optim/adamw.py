"""AdamW (pure JAX) with ZeRO-1-ready state layout and global-norm clipping."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, opt_state, step):
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
