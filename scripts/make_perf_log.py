"""Assemble results/perf_log.json (§Perf) from baseline + hillclimb JSONs.

The narrative (hypothesis / change / verdict) encodes the actual iteration
order run during the session; numbers are read live from the result files so
the log always matches the artifacts.
"""

from __future__ import annotations

import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(cell, variant=None):
    base = os.path.join(ROOT, "results",
                        "dryrun" if variant in (None, "baseline") else "hillclimb")
    suffix = "" if variant in (None, "baseline") else f"__{variant}"
    path = os.path.join(base, f"{cell}__single{suffix}.json")
    with open(path) as f:
        return json.load(f)


def fmt(d):
    r = d["roofline"]
    return (f"coll {r['collective_seconds']*1e3:.0f}ms / "
            f"mem {r['memory_seconds_lower']*1e3:.0f}ms / "
            f"comp {r['compute_seconds']*1e3:.0f}ms / "
            f"{d['memory']['peak_bytes_estimate']/2**30:.1f}GiB / "
            f"MFU-bound {r['mfu_bound']:.4f}")


def entry(i, hypothesis, change, before, after, verdict):
    return {"i": i, "hypothesis": hypothesis, "change": change,
            "before": fmt(before), "after": fmt(after), "verdict": verdict}


def main():
    cells = []

    # ---- cell 1: granite-moe train (worst roofline fraction) ---------------
    c = "granite-moe-3b-a800m__train_4k"
    base = load(c)
    no_sp = load(c, "no_sp")
    mb4 = load(c, "mb4")
    dots = load(c, "remat_dots")
    dp = load(c, "dp_only")
    dp_mb4 = load(c, "dp_mb4")
    cells.append({
        "name": "granite-moe-3b-a800m x train_4k (single pod)",
        "why": ("worst roofline fraction of all train cells (MFU-bound 0.0033); "
                "40 experts don't divide the 16-way model axis, so EP/TP "
                "sharding degenerates and the collective term is 30.3 s"),
        "iterations": [
            entry(1,
                  "SP re-gathers the residual stream around every projection; "
                  "d_ff=512 expert matmuls are too small to amortize them — "
                  "dropping SP (batch-only activations) should cut all-gather "
                  "traffic several-fold",
                  "variant no_sp (sequence_parallel=False)", base, no_sp,
                  "confirmed: collective 30.3s -> 5.6s (5.4x); memory/device "
                  "grew 40->62 GiB (unsharded activations) — not shippable alone"),
            entry(2,
                  "4-way gradient accumulation shrinks per-round activations, "
                  "so each SP gather moves 1/4 the bytes",
                  "variant mb4 (microbatches=4)", base, mb4,
                  "partially confirmed: collective 30.3s -> 7.7s (3.9x), "
                  "memory 40 -> 21 GiB, but still collective-bound"),
            entry(3,
                  "remat recompute re-issues the dispatch collectives in the "
                  "backward pass; saving dot outputs should halve them",
                  "variant remat_dots (dots_with_no_batch_dims_saveable)",
                  base, dots,
                  "refuted: collective unchanged (30.5s) — the re-gathers come "
                  "from GSPMD resharding around the dispatch scatter, not from "
                  "recomputed dots"),
            entry(4,
                  "at 3.3B params the weight shards are tiny next to 65k "
                  "tokens/device of activations: replicating ALL weights "
                  "(pure DP over 256 chips, ZeRO-1 for optimizer state) "
                  "removes every TP/EP collective except the gradient "
                  "all-reduce",
                  "variant dp_only (batch on data x model; weights replicated)",
                  base, dp,
                  "confirmed: collective 30.3s -> 0.75s (40x); MFU-bound "
                  "0.0033 -> 0.134 (40x). 22 GiB/device is above the v5e "
                  "16 GiB budget — bf16 params + ZeRO-2 grads is the recorded "
                  "next step"),
            entry(5,
                  "dp_only + accumulation should also fix the 22 GiB",
                  "variant dp_mb4", dp, dp_mb4,
                  "refuted: the microbatch scan carries a full f32 grad "
                  "accumulator per microbatch under replication — memory "
                  "explodes (438 GiB) and collectives regress; reverted"),
            entry(6,
                  "bf16 params (f32 Adam m/v as effective master) should "
                  "halve the replicated weight footprint",
                  "variant dp_bf16", dp, load(c, "dp_bf16"),
                  "refuted: 22 -> 28 GiB — XLA materializes full f32 casts "
                  "of the bf16 params inside the fused update (and SPMD "
                  "logs an involuntary remat on the resharding); a per-tensor "
                  "donated update loop would be needed to realize the saving"),
        ],
        "summary": ("**Adopted: dp_only.** 40x MFU-bound improvement "
                    "(0.0033 -> 0.134); bottleneck stays nominally "
                    "'collective' but at 0.75s it is within 3.1x of the "
                    "compute term. Lesson: for sub-4B MoEs with experts that "
                    "do not divide the mesh, data parallelism with replicated "
                    "weights beats degenerate EP/TP outright."),
    })

    # ---- cell 2: granite-20b decode (most collective-bound) ----------------
    c = "granite-20b__decode_32k"
    base = load(c)
    no_sp = load(c, "no_sp")
    kv = load(c, "kv_seq")
    dp = load(c, "dp_only")
    cells.append({
        "name": "granite-20b x decode_32k (single pod)",
        "why": ("most collective-bound cell: collective term 180.7ms vs "
                "16.8ms memory (10.8x) — MQA (kv_heads=1) leaves the 32k KV "
                "cache unshardable on the model axis, so every decode step "
                "re-reduces across 16 TP shards"),
        "iterations": [
            entry(1,
                  "SP is irrelevant for a 1-token step; disabling it should "
                  "change nothing (control experiment)",
                  "variant no_sp", base, no_sp,
                  "confirmed (control): identical terms — the 180ms is not "
                  "sequence-parallel traffic"),
            entry(2,
                  "replicating weights (pure DP) removes TP reduces, but "
                  "decode batch 128 < 256 chips and the replicated 20B f32 "
                  "weights cannot fit",
                  "variant dp_only", base, dp,
                  "refuted as predicted: 445 GiB/device — recorded to show "
                  "why DP is not the decode answer at 20B"),
            entry(3,
                  "REMOP framing: the KV cache is the 'remote relation'; "
                  "shard its SEQUENCE dim across the model axis "
                  "(flash-decoding): each shard scans 2k of 32k positions, "
                  "partial softmax stats combine in two tiny all-reduces "
                  "per layer instead of full-activation reduces",
                  "variant kv_seq (KV cache seq dim -> model axis)",
                  base, kv,
                  "confirmed: collective 180.7 -> 1.4ms (129x); memory term "
                  "16.8 -> 8.7ms; 12.4 -> 6.2 GiB/device; MFU-bound x19. "
                  "Cell is now memory-bound at the KV-bandwidth floor, as "
                  "decode should be"),
            entry(4,
                  "with rounds minimal the remaining term is D: quantize the "
                  "KV cache to int8 (per-token-per-head scales) to halve "
                  "cache residency and read bandwidth",
                  "variant kv_seq_int8 (int8 KV + sharded-KV decoding; "
                  "decode logits within 0.02 of full precision in tests)",
                  kv, load(c, "kv_seq_int8"),
                  "confirmed: memory term 8.7 -> 7.2ms, 6.2 -> 5.2 GiB, "
                  "MFU-bound +22% — below the halving prediction because "
                  "weights and the dequant write-back share the bandwidth"),
        ],
        "summary": ("**Adopted: kv_seq + int8 KV.** 129x collective reduction "
                    "then a further 1.2x on the memory floor; decode ends "
                    "HBM-bound reading a half-size cache — the physical "
                    "floor for this batch size."),
    })

    # ---- cell 3: deepseek train (paper-representative: EHJ->dispatch) ------
    c = "deepseek-v2-lite-16b__train_4k"
    base = load(c)
    no_sp = load(c, "no_sp")
    dots = load(c, "remat_dots")
    ep = load(c, "moe_ep")
    dp = load(c, "dp_only")
    epdp = load(c, "moe_ep_dp")
    iters = [
        entry(1,
              "as for granite-moe, SP gathers dominate; drop SP",
              "variant no_sp", base, no_sp,
              "confirmed: collective 30.0s -> 13.4s (2.2x), still "
              "collective-bound"),
        entry(2,
              "save dot outputs to stop backward re-dispatching",
              "variant remat_dots", base, dots,
              "refuted: no change — the traffic is GSPMD regathering the "
              "expert dim around the dispatch scatter (measured: ~9 GB "
              "wire/MoE-layer of all-gathers on [B,E,C,d])"),
        entry(3,
              "the paper's EHJ schedule: partition tuples to their owning "
              "shard, join locally, ship only results. Implemented as manual "
              "expert parallelism (shard_map): each model shard keeps its 4 "
              "local experts, routes all tokens against them with a local "
              "scatter, and one f32 psum per layer combines outputs — the "
              "expert dim is never resharded",
              "variant moe_ep (shard_map EP dispatch; numerically exact vs "
              "baseline — loss matches to 7 digits on 8 devices)",
              base, ep,
              "confirmed: collective 30.0s -> 5.3s (5.6x), 30 -> 35 GiB "
              "(replicated activations from SP-off)"),
        entry(4,
              "what remains is TP traffic on the small non-expert weights "
              "(~1.6B); replicate them (DP) while keeping the 14.4B expert "
              "bank EP-sharded",
              "variant moe_ep_dp", ep, epdp,
              "confirmed: collective 5.3s -> 2.7s; MFU-bound 0.0093 -> "
              "0.1036 (11.1x over baseline) at 25.1 GiB/device "
              "(vs dp_only's 0.1005 at an infeasible 77.6 GiB)"),
    ]
    try:
        epmb = load(c, "moe_ep_dp_mb4")
        iters.append(entry(
            5,
            "4-way accumulation to bring 25.1 GiB toward the 16 GiB budget",
            "variant moe_ep_dp_mb4", epdp, epmb,
            ("confirmed: " if epmb["memory"]["peak_bytes_estimate"]
             < epdp["memory"]["peak_bytes_estimate"] else "refuted: ")
            + f"memory {epdp['memory']['peak_bytes_estimate']/2**30:.1f} -> "
              f"{epmb['memory']['peak_bytes_estimate']/2**30:.1f} GiB, "
              f"collective {epdp['roofline']['collective_seconds']*1e3:.0f} -> "
              f"{epmb['roofline']['collective_seconds']*1e3:.0f} ms"))
    except FileNotFoundError:
        pass
    cells.append({
        "name": "deepseek-v2-lite-16b x train_4k (single pod) — paper-representative",
        "why": ("the cell that exercises the paper's own technique end-to-end: "
                "MoE dispatch IS the EHJ radix partition (DESIGN.md §3), and "
                "the baseline's GSPMD dispatch pays exactly the cost the paper "
                "warns about — many large transfers where a "
                "partition-local schedule moves results once"),
        "iterations": iters,
        "summary": ("**Adopted: moe_ep_dp (+mb4 if memory-gated).** 11.1x "
                    "MFU-bound improvement (0.0093 -> 0.1036). The winning "
                    "change is the paper's insight transplanted: make the "
                    "'spilled partitions' (off-shard experts) join locally "
                    "and batch the result shipment, instead of letting the "
                    "runtime round-trip the whole partition contents."),
    })

    # ---- bonus cell: qwen3 train (small-model TP pathology) ----------------
    c = "qwen3-0.6b__train_4k"
    base = load(c)
    dp = load(c, "dp_only")
    cells.append({
        "name": "qwen3-0.6b x train_4k (single pod) — bonus 4th cell",
        "why": "second-worst dense train cell (MFU-bound 0.0134)",
        "iterations": [
            entry(1,
                  "0.6B params sharded 16-way = 2.6MB weight shards vs 134MB "
                  "activations: TP+SP is upside-down; pure DP should flip "
                  "the cell to compute-bound",
                  "variant dp_only", base, dp,
                  "confirmed: collective 4.11s -> 0.12s (34x); MFU-bound "
                  "0.0134 -> 0.3237 (24x); dominant term is now COMPUTE — "
                  "further gains need remat reduction, not communication"),
        ],
        "summary": ("**Adopted: dp_only.** 24x; the only cell driven all the "
                    "way to compute-bound (0.32 of peak as a bound; real MFU "
                    "would include pipeline bubbles)."),
    })

    # Multi-pod validation of the adopted variants (512 chips).
    mp_rows = []
    for cell, variant in [("granite-moe-3b-a800m__train_4k", "dp_only"),
                          ("deepseek-v2-lite-16b__train_4k", "moe_ep_dp"),
                          ("granite-20b__decode_32k", "kv_seq"),
                          ("qwen3-0.6b__train_4k", "dp_only")]:
        try:
            path = os.path.join(ROOT, "results", "hillclimb",
                                f"{cell}__multi__{variant}.json")
            d = json.load(open(path))
            r = d["roofline"]
            mp_rows.append(
                f"  * {cell} x {variant}: collective "
                f"{r['collective_seconds']*1e3:.0f} ms, "
                f"{d['memory']['peak_bytes_estimate']/2**30:.1f} GiB/device, "
                f"MFU-bound {r['mfu_bound']:.4f}")
        except FileNotFoundError:
            pass
    multipod_note = (
        "**Multi-pod validation (2x16x16 = 512 chips)** — every adopted "
        "variant also lowers+compiles on the two-pod mesh with the pod axis "
        "as hierarchical DP:\n" + "\n".join(mp_rows) + "\n\n"
        "MFU-bounds halve vs single-pod because the assigned global batch "
        "(256) is fixed: with batch sharded 256-way the second pod duplicates "
        "compute. In production the batch scales with pods; the dry-run "
        "proves the sharding is coherent either way.\n\n")

    notes = multipod_note + (
        "**Negative control (prefill)**: `gemma-2b x prefill_32k x no_sp` "
        "regresses collectives 454 -> 2429 ms — at 32k tokens sequence "
        "parallelism is load-bearing for prefill (the residual stream is "
        "16x larger unsharded), confirming the baseline sharding for the "
        "prefill family is already right.\n\n"
        "Method per task spec: baseline every cell (§Roofline), hillclimb the "
        "three selected cells in hypothesis -> change -> measure -> validate "
        "cycles; stop when the dominant term improves <5% for 3 consecutive "
        "changes or hits a physical floor. All numbers are re-derivable: "
        "`python -m repro.launch.dryrun --arch A --shape S --variant V "
        "--out results/hillclimb`.\n\n"
        "**Paper-faithful baseline vs beyond-paper optimum are both recorded**: "
        "the baseline column is the REMOP-planned implementation under GSPMD "
        "(kernels/collectives sized by core/policies); the adopted variants "
        "are the beyond-paper schedule changes (DP-ization, flash-decoding KV "
        "sharding, shard_map EP dispatch) that the roofline analysis "
        "motivated."
    )
    out = {"cells": cells, "notes": notes}
    path = os.path.join(ROOT, "results", "perf_log.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
