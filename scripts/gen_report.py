"""Generate EXPERIMENTS.md (§Repro, §Dry-run, §Roofline, §Perf) from results/.

Inputs:
  results/dryrun/*.json      — one per (arch x shape x mesh) cell
  results/perf_log.json      — hillclimb iterations (§Perf), optional
  bench_output.txt           — benchmark CSV (§Repro), optional
"""

from __future__ import annotations

import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN = os.path.join(ROOT, "results", "dryrun")
PERF_LOG = os.path.join(ROOT, "results", "perf_log.json")
BENCH_OUT = os.path.join(ROOT, "bench_output.txt")

FIX_HINTS = {
    ("collective", "train"): ("bucket/overlap gradient reduction and relax "
                              "sequence-parallel re-gathers (or lower TP degree "
                              "— activations dominate weights at this size)"),
    ("collective", "prefill"): ("lower TP degree or switch activations to pure "
                                "batch sharding: per-layer SP all-gathers "
                                "dominate at this model width"),
    ("collective", "decode"): ("replicate small weights instead of TP-sharding "
                               "them: per-token all-reduces dwarf the matmuls "
                               "at batch-per-chip this small"),
    ("memory", "train"): ("raise arithmetic intensity: fuse optimizer update "
                          "(fewer f32 state sweeps) and cut remat re-reads "
                          "with a dots-saveable policy"),
    ("memory", "prefill"): ("fuse the attention softmax chain (flash kernel) "
                            "to kill unfused intermediate traffic"),
    ("memory", "decode"): ("decode is KV-bandwidth-bound by nature: shrink KV "
                           "(MQA/MLA already help), quantize cache to int8, "
                           "or raise batch to amortize weight sweeps"),
    ("compute", "train"): ("already MXU-bound: reduce remat recompute via "
                           "selective checkpointing to approach 6ND/8ND"),
    ("compute", "prefill"): ("MXU-bound: skip fully-masked KV chunks in the "
                             "streamed attention to drop the 2x causal waste"),
    ("compute", "decode"): ("compute-bound decode means batch is large enough; "
                            "fuse projections to cut launch overhead"),
}


def load_cells():
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_gib(b):
    return f"{b / 2**30:.2f}"


def fmt_ms(s):
    return f"{s * 1e3:.1f}"


def sec_dryrun(cells):
    lines = [
        "## §Dry-run — lower+compile across (architecture x shape x mesh)",
        "",
        "Production meshes: single-pod `(data=16, model=16)` = 256 chips; "
        "multi-pod `(pod=2, data=16, model=16)` = 512 chips "
        "(`launch/mesh.py:make_production_mesh`). Every cell below was "
        "`jax.jit(step).lower(ShapeDtypeStructs).compile()` with full "
        "parameter/activation/cache shardings (`launch/dryrun.py`); "
        "`memory_analysis()` proves per-device footprint, `cost_analysis()` + "
        "HLO collective parsing feed §Roofline. Scan-body undercounting is "
        "corrected by unrolled shallow probes (depth extrapolation; see "
        "dryrun.py:_probe_extrapolate).",
        "",
        "| arch | shape | mesh | status | step | GiB/device | compile s | collectives (count) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_skip = n_err = 0
    for c in cells:
        mesh = "2x16x16" if c["mesh"] == "multi_pod" else "16x16"
        if c["status"] == "skipped":
            n_skip += 1
            lines.append(f"| {c['arch']} | {c['shape']} | {mesh} | SKIP | — | — | — | "
                         f"{c['reason'][:60]} |")
            continue
        if c["status"] != "ok":
            n_err += 1
            lines.append(f"| {c['arch']} | {c['shape']} | {mesh} | **ERROR** | — | — | — | "
                         f"{c.get('error', '')[:60]} |")
            continue
        n_ok += 1
        colls = c["roofline"]["collective_counts"]
        coll_txt = " ".join(f"{k}:{int(v['count'])}" for k, v in sorted(colls.items()))
        gib = c['memory']['peak_bytes_estimate'] / 2**30
        flag = " ⚠over-HBM" if gib > 16 else ""
        lines.append(
            f"| {c['arch']} | {c['shape']} | {mesh} | ok | {c['kind']} | "
            f"{gib:.2f}{flag} | "
            f"{c['compile_seconds']:.0f} | {coll_txt} |")
    lines.insert(2, f"**{n_ok} compiled ok, {n_skip} skipped (documented), "
                    f"{n_err} errors.** Cells marked ⚠over-HBM exceed the "
                    f"16 GiB v5e budget at baseline; §Perf variants bring the "
                    f"hillclimbed cells down (and int8-KV / bf16-master-params "
                    f"are the recorded next steps for the rest).\n")
    return "\n".join(lines)


def sec_roofline(cells):
    lines = [
        "## §Roofline — single-pod (16x16, 256 chips), per (arch x shape)",
        "",
        "Terms per task spec (per-device quantities over per-device rates — "
        "equal to global/(chips*rate) since cost_analysis reports per-device):",
        "compute = FLOPs/197 TF/s; memory = HBM bytes/819 GB/s; collective = "
        "ring-modeled wire bytes/50 GB/s per link. `mem` shows "
        "[resident-traffic lower bound, unfused-HLO upper bound] — the CPU "
        "backend does not fuse elementwise chains, so the upper bound "
        "overstates a real TPU compile; dominance uses the lower bound. "
        "MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference), "
        "N excluding embeddings; useful = MODEL_FLOPS/HLO_FLOPs (catches "
        "remat/attention/dispatch overhead).",
        "",
        "| arch | shape | compute ms | mem ms [lo, hi] | coll ms | dominant | useful | roofline-MFU bound | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    hillclimbed = {("granite-moe-3b-a800m", "train_4k"),
                   ("granite-20b", "decode_32k"),
                   ("deepseek-v2-lite-16b", "train_4k"),
                   ("qwen3-0.6b", "train_4k")}
    for c in cells:
        if c.get("mesh") != "single_pod" or c.get("status") != "ok":
            continue
        r = c["roofline"]
        hint = FIX_HINTS.get((r["dominant"], c["kind"]), "")
        if (c["arch"], c["shape"]) in hillclimbed:
            hint = "**hillclimbed — see §Perf.** " + hint
        lines.append(
            f"| {c['arch']} | {c['shape']} | {fmt_ms(r['compute_seconds'])} | "
            f"[{fmt_ms(r['memory_seconds_lower'])}, {fmt_ms(r['memory_seconds'])}] | "
            f"{fmt_ms(r['collective_seconds'])} | **{r['dominant']}** | "
            f"{r['useful_flops_fraction']:.2f} | {r['mfu_bound']:.3f} | {hint} |")
    skips = [c for c in cells if c.get("mesh") == "single_pod"
             and c.get("status") == "skipped"]
    if skips:
        lines.append("")
        lines.append("Skipped cells (per task rules, recorded in DESIGN.md "
                     "§Arch-applicability): "
                     + "; ".join(f"{c['arch']}/{c['shape']}" for c in skips))
    return "\n".join(lines)


def sec_repro():
    lines = ["## §Repro — paper-claim validation (benchmark harness)", ""]
    if os.path.exists(BENCH_OUT):
        lines.append("From `bench_output.txt` (`python -m benchmarks.run`):")
        lines.append("```")
        with open(BENCH_OUT) as f:
            lines.append(f.read().strip())
        lines.append("```")
    else:
        lines.append("(run `PYTHONPATH=src python -m benchmarks.run` — see "
                     "bench_output.txt)")
    lines += [
        "",
        "Claim-by-claim:",
        "",
        "| paper claim | ours | status |",
        "|---|---|---|",
        "| Table III r_in*(α,β), 35 cells | max abs err < 0.002 | exact ✓ |",
        "| Table IV k*(α), 7 cells | 0 mismatches | exact ✓ |",
        "| Table VI EHJ splits (Cauchy–Schwarz) | 0 rel err vs closed form | exact ✓ |",
        "| §II-C BNLJ: 6,006→210 read rounds (−96.5%), +61.5% data | 6,006→210, +61.5% | exact ✓ |",
        "| §II-C EMS: 52,000→4,784 rounds (≈10.9x) | 52,000→4,784 | exact ✓ |",
        "| Fig 4: BNLJ rounds −97%, runtime −48% | rounds −96.5% (worked ex.), sim-latency −27..38% (Eq.1 lacks engine overheads) | direction+magnitude ✓ |",
        "| Fig 5: EMS k*-rounds at k=4, runtime best at larger k | k=4 minimizes rounds; latency best k=6 in sim | ✓ |",
        "| Fig 6a: EHJ pools cut write rounds, modest runtime gain | write rounds −65..80%, latency −25..31% | ✓ |",
        "| Fig 6b: prefetch helps BNLJ most | bnlj 11% > ems 10% > ehj 1% | ordering ✓ |",
        "| Fig 7/8: spilling-subset geomean −22.7%/−26.4% | 4-query mix geomean −39% (pure Eq.1 sim) | direction ✓ |",
        "| Fig 9: gains shrink as memory grows | 34.7% tight → 0% when inner fits | ✓ |",
        "| Fig 12: gains widen with RTT (0.155→10 ms) | 23% → 67% | ✓ |",
    ]
    return "\n".join(lines)


def sec_perf():
    lines = ["## §Perf — roofline hillclimb (3 selected cells)", ""]
    if not os.path.exists(PERF_LOG):
        lines.append("(pending: results/perf_log.json)")
        return "\n".join(lines)
    with open(PERF_LOG) as f:
        log = json.load(f)
    for cell in log.get("cells", []):
        lines.append(f"### {cell['name']}  — selected because: {cell['why']}")
        lines.append("")
        lines.append("| iter | hypothesis | change | dominant term before → after | verdict |")
        lines.append("|---|---|---|---|---|")
        for it in cell["iterations"]:
            lines.append(f"| {it['i']} | {it['hypothesis']} | {it['change']} | "
                         f"{it['before']} → {it['after']} | {it['verdict']} |")
        lines.append("")
        if cell.get("summary"):
            lines.append(cell["summary"])
        lines.append("")
    if log.get("notes"):
        lines.append(log["notes"])
    return "\n".join(lines)


def main():
    cells = load_cells()
    doc = "\n\n".join([
        "# EXPERIMENTS — REMOP reproduction + TPU framework",
        ("Regenerate with `python scripts/gen_report.py` after "
         "`python -m repro.launch.dryrun --all` and "
         "`python -m benchmarks.run | tee bench_output.txt`."),
        sec_repro(),
        sec_dryrun(cells),
        sec_roofline(cells),
        sec_perf(),
        "",
    ])
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write(doc)
    print(f"wrote {out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
