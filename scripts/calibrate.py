"""Calibrate per-tier (bandwidth, RTT, tau) from measured transfer sweeps.

The simulator prices every transfer with the Table-I constants baked into
:data:`repro.core.TABLE_I` — ``L = D/BW + C*RTT`` (Eq. 1).  This script runs
*real* host<->device transfer sweeps through the execution backend
(:class:`repro.remote.backend.ExecutionBackend`), reads the measured seconds
off its :class:`WallClock`, and fits the same linear model per tier and
direction:

    seconds(bytes) = bytes / bandwidth + rounds * rtt

via least squares over a sweep of batch sizes (each batch is one round, so
the per-round intercept is the fitted RTT and the slope is 1/bandwidth).
``tau = bandwidth * rtt / page_bytes`` follows from the fit, giving a
measured counterpart to the ``TierSpec.tau_pages`` the arbiter plans with.

On a CPU-only host every "tier" is the same memcpy path, so the fitted
constants describe the *host*, not the modeled fabric — the point of the
report is the assumed-vs-fitted ratio, which says exactly how far the
simulation constants are from the machine the backend runs on.

Usage:
    PYTHONPATH=src python scripts/calibrate.py --out calibration.json
    PYTHONPATH=src python scripts/calibrate.py --quick        # CI smoke
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Sequence

import numpy as np

from repro.core import TABLE_I
from repro.remote.backend import make_backend

DEFAULT_TIERS = ("dram", "rdma", "tcp", "ssd")
SWEEP = (1, 2, 4, 8, 16, 32)
QUICK_SWEEP = (1, 2, 4, 8)


def _fit(bytes_per_round: Sequence[float], seconds: Sequence[float]):
    """Least-squares fit of seconds = bytes/bandwidth + rtt (one round each)."""
    slope, intercept = np.polyfit(np.asarray(bytes_per_round, dtype=float),
                                  np.asarray(seconds, dtype=float), 1)
    bandwidth = float("inf") if slope <= 0 else 1.0 / slope
    return bandwidth, max(float(intercept), 0.0)


def sweep_tier(name: str, batch_sizes: Sequence[int], repeats: int,
               elems_per_page: int) -> Dict:
    """Measure write (h2d) and read (d2h) rounds on a 1-tier backend."""
    spec = TABLE_I[name]
    rng = np.random.default_rng(0)
    sizes: List[float] = []
    h2d: List[float] = []
    d2h: List[float] = []
    for n_pages in batch_sizes:
        pages = [rng.integers(0, 2**30, size=elems_per_page, dtype=np.int32)
                 for _ in range(n_pages)]
        best_w = best_r = float("inf")
        for _ in range(repeats):
            backend = make_backend(spec)
            tier = backend.tiers[0]
            wall = backend.wall.tiers[name]
            ids = tier.write_batch(pages)
            best_w = min(best_w, wall.h2d_seconds)
            tier.read_batch(ids)
            best_r = min(best_r, wall.d2h_seconds)
            tier.free(ids)
        sizes.append(n_pages * elems_per_page * 4)
        h2d.append(best_w)
        d2h.append(best_r)

    bw_w, rtt_w = _fit(sizes, h2d)
    bw_r, rtt_r = _fit(sizes, d2h)
    # One symmetric figure per tier, like the TierSpec it calibrates.
    fitted_bw = min(bw_w, bw_r)
    fitted_rtt = max(rtt_w, rtt_r)
    return {
        "tier": name,
        "assumed": {"bandwidth": spec.bandwidth, "rtt": spec.rtt,
                    "tau_pages": spec.tau_pages},
        "fitted": {
            "bandwidth": fitted_bw,
            "rtt": fitted_rtt,
            "tau_pages": fitted_bw * fitted_rtt / spec.page_bytes,
            "h2d": {"bandwidth": bw_w, "rtt": rtt_w},
            "d2h": {"bandwidth": bw_r, "rtt": rtt_r},
        },
        "ratio": {
            "bandwidth": fitted_bw / spec.bandwidth,
            "rtt": (fitted_rtt / spec.rtt) if spec.rtt else float("inf"),
        },
        "sweep": {"bytes_per_round": sizes, "h2d_seconds": h2d,
                  "d2h_seconds": d2h, "repeats": repeats},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiers", default=",".join(DEFAULT_TIERS),
                    help="comma-separated Table-I tier names")
    ap.add_argument("--repeats", type=int, default=3,
                    help="repeats per batch size; the minimum is kept")
    ap.add_argument("--elems-per-page", type=int, default=16384,
                    help="int32 elements per page (default 64 KiB pages)")
    ap.add_argument("--quick", action="store_true",
                    help="short sweep, 1 repeat (CI smoke)")
    ap.add_argument("--out", default="calibration.json",
                    help="JSON report path")
    args = ap.parse_args(argv)

    batch_sizes = QUICK_SWEEP if args.quick else SWEEP
    repeats = 1 if args.quick else args.repeats
    tiers = [t.strip() for t in args.tiers.split(",") if t.strip()]
    for t in tiers:
        if t not in TABLE_I:
            ap.error(f"unknown tier {t!r}; Table I has {sorted(TABLE_I)}")

    report = {
        "elems_per_page": args.elems_per_page,
        "batch_sizes": list(batch_sizes),
        "tiers": [sweep_tier(t, batch_sizes, repeats, args.elems_per_page)
                  for t in tiers],
    }

    hdr = (f"{'tier':>6} {'assumed BW':>12} {'fitted BW':>12} "
           f"{'assumed RTT':>12} {'fitted RTT':>12} {'fitted tau':>11}")
    print(hdr)
    print("-" * len(hdr))
    for row in report["tiers"]:
        a, f = row["assumed"], row["fitted"]
        print(f"{row['tier']:>6} {a['bandwidth']:>12.3g} "
              f"{f['bandwidth']:>12.3g} {a['rtt']:>12.3g} "
              f"{f['rtt']:>12.3g} {f['tau_pages']:>11.3g}")

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"\nwrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
