"""Fail CI when benchmark latency metrics regress beyond a threshold.

Compares two ``BENCH_*.json`` artifacts — a committed baseline and a freshly
generated run — and exits non-zero when any *deterministic* latency metric
(keys named ``modeled_latency``, ``simulated_seconds``, or
``latency_cost``; these are simulation outputs, not wall-clock timings, so
they are stable across CI machines) grew by more than ``--threshold``
(default 10%).

Usage:
    python scripts/check_regression.py BASELINE CURRENT [--threshold 0.10]

New metrics (present only in CURRENT) are allowed — the next baseline commit
picks them up; metrics that *disappear* from CURRENT are reported and fail
the check, so a benchmark can't dodge the gate by dropping its numbers.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterator, Tuple

# Only deterministic simulator outputs are gated; wall-clock fields
# (us_per_call) and derived ratios are informational.
METRIC_KEYS = ("modeled_latency", "simulated_seconds", "latency_cost")


def _walk(node, path: str = "", in_metric: bool = False) -> Iterator[Tuple[str, float]]:
    """Yield (path, value) for every numeric leaf under a metric key."""
    if isinstance(node, dict):
        for key, value in sorted(node.items()):
            yield from _walk(value, f"{path}.{key}" if path else key,
                             in_metric or key in METRIC_KEYS)
    elif isinstance(node, list):
        seen: Dict[str, int] = {}
        for i, value in enumerate(node):
            label = _element_label(value, i)
            # Duplicate labels would silently shadow earlier elements in the
            # metrics dict; disambiguate with the position instead.
            if label in seen:
                label = f"{label}#{i}"
            seen[label] = i
            yield from _walk(value, f"{path}[{label}]", in_metric)
    elif in_metric and isinstance(node, (int, float)) and not isinstance(node, bool):
        yield path, float(node)


def _element_label(value, index: int) -> str:
    """Stable list labels: prefer a name/caps identity over the position."""
    if isinstance(value, dict):
        if "name" in value and isinstance(value["name"], str):
            return value["name"]
        if "caps" in value and isinstance(value["caps"], dict):
            return "caps:" + ",".join(
                f"{k}={v}" for k, v in sorted(value["caps"].items())
            )
    return str(index)


def metrics_of(path: str) -> Dict[str, float]:
    with open(path) as f:
        return dict(_walk(json.load(f)))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_*.json baseline")
    ap.add_argument("current", help="freshly generated BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="allowed relative latency growth (default 0.10)")
    args = ap.parse_args(argv)

    base = metrics_of(args.baseline)
    cur = metrics_of(args.current)
    if not base:
        print(f"warning: no gated metrics in baseline {args.baseline}; "
              "nothing to compare", file=sys.stderr)
        return 0

    failures = []
    for key, base_val in sorted(base.items()):
        if key not in cur:
            failures.append(f"metric disappeared: {key} (baseline {base_val:.6g})")
            continue
        cur_val = cur[key]
        limit = base_val * (1.0 + args.threshold)
        status = "OK"
        if cur_val > limit + 1e-12:
            pct = (cur_val / base_val - 1.0) * 100.0 if base_val else float("inf")
            failures.append(
                f"regression: {key}: {base_val:.6g} -> {cur_val:.6g} (+{pct:.1f}%)"
            )
            status = "FAIL"
        print(f"{status} {key}: {base_val:.6g} -> {cur_val:.6g}")
    for key in sorted(set(cur) - set(base)):
        print(f"NEW {key}: {cur[key]:.6g} (not gated yet)")

    if failures:
        print(f"\n{len(failures)} latency regression(s) beyond "
              f"{args.threshold:.0%}:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print(f"\nall {len(base)} gated metrics within {args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
