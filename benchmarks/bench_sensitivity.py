"""Paper Fig. 9 (memory sensitivity) + Fig. 12 (RTT sensitivity).

Derived values:
  * Fig. 9: REMOP's latency advantage at the tightest budget and its decay
    as the budget grows (the paper: configurations converge as spilling
    subsides);
  * Fig. 12: the advantage as RTT scales 0.15 ms -> 10 ms (the paper: the
    advantage *widens* with RTT — the core cost-model claim).
"""

from __future__ import annotations

import dataclasses

from repro.core import TABLE_I
from repro.core.cost_model import TierSpec
from repro.engine import WorkloadStats, plan_operator, registry
from repro.remote import RemoteMemory, make_relation
from benchmarks.common import Row, timed

BASE = TABLE_I["tcp"]


def _advantage(m: float, tier: TierSpec, r_pages=40, s_pages=80) -> float:
    """1 - L_remop/L_conv for a BNLJ workload under budget m and tier.

    Models the §IV-B in-memory fallback: when the inner relation fits the
    budget, BOTH engines pin it once and stream the outer side — spilling
    subsides and the configurations converge (paper Fig. 9).
    """
    def one(plan):
        remote = RemoteMemory(tier)
        outer = make_relation(remote, r_pages * 8, 8, 1024, seed=11)
        inner = make_relation(remote, s_pages * 8, 8, 1024, seed=12)
        registry.get("bnlj").run(remote, outer, inner, plan)
        return remote.latency_seconds()

    if s_pages + 2 <= m:  # in-memory fast path: both engines converge
        return 0.0
    stats = WorkloadStats(size_r=r_pages, size_s=s_pages, selectivity=1 / 1024)
    lat_conv = one(plan_operator("bnlj", stats, tier, m, policy="conventional"))
    lat_remop = one(plan_operator("bnlj", stats, tier, m))
    return 1 - lat_remop / lat_conv


def run() -> list[Row]:
    rows: list[Row] = []

    # Fig. 9: memory budgets (pages); larger budget -> less spilling pressure.
    def mem_sweep():
        return {m: _advantage(m, BASE) for m in (9, 33, 85)}

    us, by_m = timed(mem_sweep, repeats=1)
    tight, loose = by_m[9], by_m[85]
    rows.append(("fig9_advantage_at_tight_budget", us, round(tight, 4)))
    rows.append(("fig9_advantage_at_loose_budget", 0.0, round(loose, 4)))
    rows.append(("fig9_gain_shrinks_with_memory", 0.0, int(tight >= loose)))

    # Fig. 12: RTT sweep 0.155 ms -> 10 ms at fixed budget.
    def rtt_sweep():
        out = {}
        for rtt_ms in (0.155, 1.0, 5.0, 10.0):
            tier = dataclasses.replace(BASE, rtt=rtt_ms * 1e-3)
            out[rtt_ms] = _advantage(17, tier)
        return out

    us, by_rtt = timed(rtt_sweep, repeats=1)
    for rtt_ms, adv in sorted(by_rtt.items()):
        rows.append((f"fig12_advantage_rtt_{rtt_ms}ms", 0.0, round(adv, 4)))
    widened = by_rtt[10.0] > by_rtt[0.155]
    rows.append(("fig12_advantage_widens_with_rtt", us, int(widened)))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
