"""External hash aggregation: Property-6 pools vs starved baseline.

Same shape as the EHJ bench (fig6a) for the new fourth operator: write-round
and simulated-latency reduction of the REMOP waterfill allocation vs the
disk-oriented starved plan, across partition counts, plus exact-ledger
verification against ``eagg_costs_exact`` (derived value 1.0 == parity).
"""

from __future__ import annotations

import numpy as np

from repro.core import TABLE_I
from repro.core.policies import eagg_costs_exact
from repro.engine import WorkloadStats, plan_operator, registry
from repro.remote import RemoteMemory, make_relation
from repro.remote.eagg import _hash_part
from benchmarks.common import Row, timed

TIER = TABLE_I["tcp"]  # paper Table I constants (see bench_bnlj)
EAGG = registry.get("eagg")
N_PAGES, ROWS, DOMAIN = 192, 8, 256


def _run(plan, seed=0):
    remote = RemoteMemory(TIER)
    rel = make_relation(remote, N_PAGES * ROWS, ROWS, DOMAIN, seed=seed)
    res = EAGG.run(remote, rel, plan)
    return res, remote, rel


def _exact_parity(remote, rel, plan, res) -> bool:
    rows = np.concatenate(remote.peek_batch(rel.page_ids), axis=0)
    parts = _hash_part(rows[:, 0], plan.partitions)
    n_spilled = int(round(plan.sigma * plan.partitions))
    spilled = list(range(plan.partitions - n_spilled, plan.partitions))
    spill_mask = np.isin(parts, spilled)
    d, c = eagg_costs_exact(
        N_PAGES, ROWS,
        [int((parts == q).sum()) for q in spilled],
        len(np.unique(rows[~spill_mask][:, 0])),
        len(np.unique(rows[spill_mask][:, 0])),
        plan,
    )
    return res.d_read + res.d_write == d and res.c_read + res.c_write == c


def run() -> list[Row]:
    rows_out: list[Row] = []
    m_b, sigma = 24.0, 0.5
    for parts in (4, 8, 16):
        stats = WorkloadStats(size_r=N_PAGES, out=32, partitions=parts,
                              sigma=sigma)
        remop = plan_operator("eagg", stats, TIER, m_b)
        starved = plan_operator("eagg", stats, TIER, m_b, policy="conventional")

        def run_pair(starved=starved, remop=remop):
            res_s, rem_s, _ = _run(starved)
            res_r, rem_r, rel_r = _run(remop)
            assert res_s.group_rows == res_r.group_rows
            parity = _exact_parity(rem_r, rel_r, remop, res_r)
            return (res_s.c_write, res_r.c_write,
                    rem_s.latency_seconds(), rem_r.latency_seconds(), parity)

        us, (w_s, w_r, lat_s, lat_r, parity) = timed(run_pair, repeats=1)
        rows_out.append((f"eagg_P{parts}_write_round_reduction", us,
                         round(1 - w_r / w_s, 4)))
        rows_out.append((f"eagg_P{parts}_sim_latency_reduction", 0.0,
                         round(1 - lat_r / lat_s, 4)))
        rows_out.append((f"eagg_P{parts}_exact_ledger_parity", 0.0,
                         float(parity)))
    return rows_out


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
