"""Memory-hierarchy sweeps: placement-aware arbiter vs single-tier spilling.

Sweeps DRAM -> RDMA -> SSD capacity splits (Table I constants) for a fixed
multi-operator pipeline — planned and executed through the session API — and
compares three ways of placing spill:

  * the hierarchy-aware arbiter (joint pages + tier assignment),
  * the best *feasible* single-tier placement (all operators' spill on one
    tier, pages split by the 1-D arbiter), and
  * the worst feasible single-tier placement (the price of guessing wrong).

Both the modeled latency cost (what the arbiter minimizes) and the simulated
wall latency of running every operator against one shared
:class:`repro.remote.simulator.MemoryHierarchy` are reported; the arbiter is
never worse than the best single tier on the modeled objective by
construction, and the sweep shows how the gap moves with tier capacities.

An **eviction sweep** (ISSUE 5) additionally runs a spill-heavy pipeline
twice per capacity point — once with the PR 4 no-eviction waterfall, once
with an LRU evictor demoting cold pages in background (overlapped) migration
rounds — and requires LRU + overlap to *strictly beat* the waterfall
baseline on the tightest (spill-heaviest) configuration.

Writes ``BENCH_tiering.json`` and ``BENCH_eviction.json`` at the repo root —
machine-readable perf artifacts CI uploads and gates with
``scripts/check_regression.py``.
"""

from __future__ import annotations

import json
import os
import time
from typing import List, Optional

from repro.core import TABLE_I
from repro.core.cost_model import HierarchySpec
from repro.engine import Session, WorkloadStats, registry
from repro.engine.pipeline import OperatorBudget, PipelinePlan
from repro.engine.registry import hierarchy_spec, model_latency, plan_operator
from repro.remote import make_relation
from repro.remote.simulator import make_key_pages
from benchmarks.common import Row

ROWS = 8
M_TOTAL = 56.0
OPS = ["ehj", "ems", "eagg"]
STATS = [
    WorkloadStats(size_r=48, size_s=96, out=36, partitions=8, sigma=0.5),
    WorkloadStats(size_r=120, k_cap=8),
    WorkloadStats(size_r=64, out=12, partitions=8, sigma=0.5),
]
# (dram capacity, rdma capacity) sweep points; ssd is the unbounded backstop.
SWEEPS = [(16, 128), (48, 256), (96, 512), (256, 1024)]

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_tiering.json")
EVICTION_JSON_PATH = os.path.join(os.path.dirname(JSON_PATH),
                                  "BENCH_eviction.json")

# Eviction sweep: a spill-heavy pipeline (the cold spill of each finished
# operator squats on the fast tier while the next operator's hot streams
# arrive) over tightening DRAM/RDMA capacities; ssd is the backstop.  The
# first point is the spill-heaviest — the one the strict-win gate holds on.
EVICTION_OPS = ["eagg", "ems", "ehj"]
EVICTION_STATS = [
    WorkloadStats(size_r=64, out=12, partitions=8, sigma=0.5),
    WorkloadStats(size_r=120, k_cap=8),
    WorkloadStats(size_r=48, size_s=96, out=36, partitions=8, sigma=0.5),
]
EVICTION_SWEEPS = [(24, 96), (48, 192), (96, 384)]


def _spec(dram_cap: float, rdma_cap: float) -> HierarchySpec:
    return hierarchy_spec((TABLE_I["dram"], dram_cap), (TABLE_I["rdma"], rdma_cap),
                          TABLE_I["ssd"])


def _tasks(sess: Session, with_data: bool = True):
    """The pipeline's typed tasks; data-free tasks are enough for planning."""
    if with_data:
        build = make_relation(sess.remote, 48 * ROWS, ROWS, 96, seed=31)
        probe = make_relation(sess.remote, 96 * ROWS, ROWS, 96, seed=32)
        sort_ids = make_key_pages(sess.remote, 120, ROWS, seed=33)
        agg_rel = make_relation(sess.remote, 64 * ROWS, ROWS, 128, seed=34)
        inputs = [
            {"build": build, "probe": probe},
            {"page_ids": sort_ids},
            {"rel": agg_rel},
        ]
    else:
        inputs = [None, None, None]
    options = [{}, {"rows_per_page": ROWS}, {}]
    return [
        sess.task(op, st, inputs=inp, **opt)
        for op, st, inp, opt in zip(OPS, STATS, inputs, options)
    ]


def _single_tier_plan(spec: HierarchySpec, t: int) -> Optional[PipelinePlan]:
    """All ops placed on tier ``t`` (pages via the 1-D arbiter), if it fits."""
    level = spec.levels[t]
    planner = Session(level.tier, budget=M_TOTAL)
    single = planner.plan(_tasks(planner, with_data=False))
    footprint = sum(
        registry.get(ob.op).footprint(ob.stats, level.tier.tau_pages, ob.m_pages)
        for ob in single.ops
    )
    if footprint > level.capacity_pages + 1e-9:
        return None
    budgets = tuple(
        OperatorBudget(
            op=ob.op, stats=ob.stats, m_pages=ob.m_pages,
            plan=plan_operator(ob.op, ob.stats, level.tier, ob.m_pages),
            modeled_latency=model_latency(ob.op, ob.stats, level.tier, ob.m_pages),
            placement=spec.names[t],
        )
        for ob in single.ops
    )
    return PipelinePlan(tier=spec.levels[0].tier, m_total=M_TOTAL,
                        policy="remop", ops=budgets, hierarchy=spec)


def _simulate(spec: HierarchySpec, pplan: PipelinePlan) -> float:
    sess = Session(spec, budget=M_TOTAL)
    sess.run(_tasks(sess), plan=pplan)
    return sess.remote.latency_seconds()


def _eviction_tasks(sess: Session):
    """The spill-heavy pipeline: cold eagg spill, hot ems runs, wide ehj."""
    agg_rel = make_relation(sess.remote, 64 * ROWS, ROWS, 128, seed=34)
    sort_ids = make_key_pages(sess.remote, 120, ROWS, seed=33)
    build = make_relation(sess.remote, 48 * ROWS, ROWS, 96, seed=31)
    probe = make_relation(sess.remote, 96 * ROWS, ROWS, 96, seed=32)
    inputs = [
        {"rel": agg_rel},
        {"page_ids": sort_ids},
        {"build": build, "probe": probe},
    ]
    options = [{}, {"rows_per_page": ROWS}, {}]
    return [
        sess.task(op, st, inputs=inp, **opt)
        for op, st, inp, opt in zip(EVICTION_OPS, EVICTION_STATS, inputs,
                                    options)
    ]


def run_eviction() -> list[Row]:
    """LRU + overlapped background demotion vs the no-eviction waterfall."""
    rows_out: List[Row] = []
    report = {"schema": 1, "tiers": ["dram", "rdma", "ssd"],
              "m_total": M_TOTAL, "ops": EVICTION_OPS, "policy": "lru",
              "overlap_migration": True, "sweeps": []}
    for i, (dram_cap, rdma_cap) in enumerate(EVICTION_SWEEPS):
        spec = [("dram", dram_cap), ("rdma", rdma_cap), "ssd"]
        t0 = time.perf_counter()
        base = Session(spec, budget=M_TOTAL)
        base_res = base.run(_eviction_tasks(base))
        sim_base = base.remote.latency_seconds()
        ev = Session(spec, budget=M_TOTAL, eviction="lru")
        ev_res = ev.run(_eviction_tasks(ev))
        sim_ev = ev.remote.latency_seconds(overlap_migration=True)
        us = (time.perf_counter() - t0) * 1e6
        reduction = 1 - sim_ev / sim_base
        if i == 0 and sim_ev >= sim_base:
            raise RuntimeError(
                f"eviction gate: LRU+overlap ({sim_ev:.6f}s) must strictly "
                f"beat the no-eviction waterfall ({sim_base:.6f}s) on the "
                f"spill-heavy configuration dram={dram_cap} rdma={rdma_cap}"
            )
        tag = f"dram{dram_cap}_rdma{rdma_cap}"
        rows_out.append((f"eviction_{tag}_sim_latency_reduction_vs_waterfall",
                         us, round(reduction, 4)))
        report["sweeps"].append({
            "caps": {"dram": dram_cap, "rdma": rdma_cap},
            "baseline": {
                "placements": list(base_res.plan.placements),
                "simulated_seconds": sim_base,
            },
            "eviction": {
                "placements": list(ev_res.plan.placements),
                "simulated_seconds": sim_ev,
                "pages_demoted": ev.evictor.pages_demoted,
                "demote_batches": ev.evictor.demote_batches,
            },
            "reduction": reduction,
        })
    with open(EVICTION_JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows_out


def run() -> list[Row]:
    rows_out: List[Row] = []
    report = {"schema": 1, "tiers": ["dram", "rdma", "ssd"], "m_total": M_TOTAL,
              "ops": OPS, "sweeps": []}
    for dram_cap, rdma_cap in SWEEPS:
        spec = _spec(dram_cap, rdma_cap)
        planner = Session(spec, budget=M_TOTAL)
        arb = planner.plan(_tasks(planner, with_data=False))

        singles = []
        for t in range(len(spec)):
            plan_t = _single_tier_plan(spec, t)
            if plan_t is not None:
                singles.append((spec.names[t], plan_t))
        best_name, best_plan = min(
            singles, key=lambda pair: pair[1].total_modeled_latency
        )
        worst_name, worst_plan = max(
            singles, key=lambda pair: pair[1].total_modeled_latency
        )

        # The simulations are deterministic, so run each exactly once and
        # time the batch directly (timed() would re-run them for warmup).
        t0 = time.perf_counter()
        sim_arb = _simulate(spec, arb)
        sim_best = _simulate(spec, best_plan)
        sim_worst = _simulate(spec, worst_plan)
        us = (time.perf_counter() - t0) * 1e6
        tag = f"dram{dram_cap}_rdma{rdma_cap}"
        modeled_red = 1 - arb.total_modeled_latency / best_plan.total_modeled_latency
        sim_red = 1 - sim_arb / sim_best
        rows_out.append((f"tiering_{tag}_modeled_latency_reduction_vs_best_single",
                         us, round(modeled_red, 4)))
        rows_out.append((f"tiering_{tag}_sim_latency_reduction_vs_best_single",
                         0.0, round(sim_red, 4)))
        report["sweeps"].append({
            "caps": {"dram": dram_cap, "rdma": rdma_cap},
            "arbiter": {
                "placements": list(arb.placements),
                "budgets": list(arb.budgets),
                "modeled_latency": arb.total_modeled_latency,
                "simulated_seconds": sim_arb,
            },
            "best_single": {
                "tier": best_name,
                "modeled_latency": best_plan.total_modeled_latency,
                "simulated_seconds": sim_best,
            },
            "worst_single": {
                "tier": worst_name,
                "modeled_latency": worst_plan.total_modeled_latency,
                "simulated_seconds": sim_worst,
            },
        })
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    rows_out.extend(run_eviction())
    return rows_out


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
