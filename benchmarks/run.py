# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# All *policy* planning in the harness goes through the engine registry
# (`repro.engine.plan_operator`); no bench module imports the per-operator
# policy constructors (bnlj_plan/ems_plan/ehj_plan/...) directly.  Sweep
# benches still build explicit BNLJPlan/EMSPlan grid points by hand — those
# are plan-space coordinates, not policies.
from __future__ import annotations

import sys
import traceback

from benchmarks import (bench_bnlj, bench_cost_model, bench_ehj, bench_ems,
                        bench_endtoend, bench_kernel_policy, bench_prefetch,
                        bench_registry, bench_sensitivity, bench_table3,
                        bench_table4, bench_table6)
from benchmarks.common import emit

MODULES = [
    ("engine_registry", bench_registry),
    ("table1_eq1", bench_cost_model),
    ("table3", bench_table3),
    ("table4", bench_table4),
    ("table6", bench_table6),
    ("fig4_bnlj", bench_bnlj),
    ("fig5_ems", bench_ems),
    ("fig6a_ehj", bench_ehj),
    ("fig6b_prefetch", bench_prefetch),
    ("fig9_fig12_sensitivity", bench_sensitivity),
    ("fig7_fig8_endtoend", bench_endtoend),
    ("tpu_policies", bench_kernel_policy),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for tag, mod in MODULES:
        try:
            emit(mod.run())
        except Exception:
            failures += 1
            print(f"{tag}_FAILED,0.0,nan")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
