# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV
# and writes the same rows as machine-readable ``BENCH_run.json`` (plus any
# per-module BENCH_*.json, e.g. bench_pipeline's) for the CI perf trajectory.
#
# All *policy* planning in the harness goes through the engine registry
# (`repro.engine.plan_operator`); no bench module imports the per-operator
# policy constructors (bnlj_plan/ems_plan/ehj_plan/...) directly.  Sweep
# benches still build explicit BNLJPlan/EMSPlan grid points by hand — those
# are plan-space coordinates, not policies.
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

from benchmarks import (bench_backend, bench_bnlj, bench_cost_model,
                        bench_eagg, bench_ehj, bench_ems, bench_endtoend,
                        bench_kernel_policy, bench_pipeline, bench_prefetch,
                        bench_pushdown, bench_registry, bench_sensitivity,
                        bench_serving, bench_session, bench_table3,
                        bench_table4, bench_table6, bench_tiering, bench_tpch)
from benchmarks.common import emit

MODULES = [
    ("engine_registry", bench_registry),
    ("table1_eq1", bench_cost_model),
    ("table3", bench_table3),
    ("table4", bench_table4),
    ("table6", bench_table6),
    ("fig4_bnlj", bench_bnlj),
    ("fig5_ems", bench_ems),
    ("fig6a_ehj", bench_ehj),
    ("fig6b_prefetch", bench_prefetch),
    ("eagg", bench_eagg),
    ("fig9_fig12_sensitivity", bench_sensitivity),
    ("fig7_fig8_endtoend", bench_endtoend),
    ("pipeline_arbiter", bench_pipeline),
    ("tiering", bench_tiering),
    ("session_replan", bench_session),
    ("serving", bench_serving),
    ("tpch", bench_tpch),
    ("pushdown", bench_pushdown),
    ("tpu_policies", bench_kernel_policy),
    ("exec_backend", bench_backend),
]

# The CI `bench-smoke` subset: the registry/operator/arbiter surfaces this
# repo actively grows, fast enough for every push (~tens of seconds).
QUICK = {"engine_registry", "table1_eq1", "table3", "table4", "table6",
         "fig6a_ehj", "eagg", "pipeline_arbiter", "tiering", "session_replan",
         "serving", "tpch", "pushdown", "exec_backend"}

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_run.json")


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="run only the fast bench-smoke subset (CI per-push)")
    args = ap.parse_args(argv)
    modules = [(t, m) for t, m in MODULES if not args.quick or t in QUICK]

    print("name,us_per_call,derived")
    failures = 0
    report = {"schema": 1, "quick": args.quick, "rows": [], "failed": []}
    for tag, mod in modules:
        try:
            rows = mod.run()
        except Exception:
            failures += 1
            print(f"{tag}_FAILED,0.0,nan")
            report["failed"].append(tag)
            traceback.print_exc(file=sys.stderr)
            continue
        emit(rows)
        report["rows"].extend(
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in rows
        )
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
