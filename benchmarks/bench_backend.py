"""Execution backend vs simulator: same answers, plus a wall clock.

Runs the same tiny EMS + EHJ pipeline twice per scenario — once on the
simulated :class:`MemoryHierarchy` and once on the real
:class:`~repro.remote.backend.ExecutionBackend` (jax arrays on device,
Pallas ``merge_sort``/``dispatch`` kernels, actually-timed host<->device
copies) — and reports both clocks side by side:

  * ``simulated_seconds`` / ``latency_cost``: the deterministic Eq.-(1)
    numbers, identical between the two runs by construction (asserted), and
    the only keys the CI regression gate prices;
  * ``wall_seconds``: what the backend measured, machine-dependent and
    explicitly never gated (see ``scripts/check_regression.py``).

Parity booleans (ledger + byte-identical outputs) are part of the report so
a CI artifact diff shows at a glance if the backend ever drifts from the
simulation it claims to mirror.  Writes ``BENCH_backend.json``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import List

import numpy as np

from repro.core import TABLE_I
from repro.engine import Session, WorkloadStats
from repro.engine.registry import hierarchy_spec
from repro.remote import MemoryHierarchy, make_backend
from repro.remote.simulator import make_key_pages, make_relation
from benchmarks.common import Row

ROWS = 4
M_TOTAL = 24.0

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_backend.json")

SCENARIOS = [
    ("tcp", (TABLE_I["tcp"],)),
    ("dram_rdma_ssd", ((TABLE_I["dram"], 16), (TABLE_I["rdma"], 128),
                       TABLE_I["ssd"])),
]


def _tasks(sess: Session):
    """Tiny on purpose: interpret-mode Pallas gathers step row by row."""
    ids = make_key_pages(sess.remote, 24, ROWS, seed=3)
    build = make_relation(sess.remote, 8 * ROWS, ROWS, 16, seed=4)
    probe = make_relation(sess.remote, 16 * ROWS, ROWS, 16, seed=5)
    return [
        sess.task("ems", WorkloadStats(size_r=24, k_cap=4),
                  inputs={"page_ids": ids}, rows_per_page=ROWS),
        sess.task("ehj", WorkloadStats(size_r=8, size_s=16, out=6,
                                       partitions=4, sigma=0.5),
                  inputs={"build": build, "probe": probe}),
    ]


def _run(remote):
    sess = Session(remote, budget=M_TOTAL)
    return sess, sess.run(_tasks(sess))


def _outputs(sess, res):
    pages = []
    for op, result, _ in res.per_op:
        ids = result.run_page_ids if op == "ems" else result.output_page_ids
        pages.append(sess.remote.peek_batch(ids))
    return pages


def run() -> List[Row]:
    rows_out: List[Row] = []
    report = {"schema": 1, "m_total": M_TOTAL, "scenarios": []}
    for name, levels in SCENARIOS:
        sim_sess, sim = _run(MemoryHierarchy(hierarchy_spec(*levels)))
        backend = make_backend(*levels)
        t0 = time.perf_counter()
        bk_sess, bkr = _run(backend)
        us = (time.perf_counter() - t0) * 1e6

        ledger_parity = (
            dataclasses.asdict(sim.total) == dataclasses.asdict(bkr.total))
        output_parity = all(
            len(pa) == len(pb) and all(
                a.dtype == b.dtype and np.array_equal(a, b)
                for a, b in zip(pa, pb))
            for pa, pb in zip(_outputs(sim_sess, sim), _outputs(bk_sess, bkr)))
        assert ledger_parity and output_parity, f"backend drifted on {name}"

        simulated = sim.latency_seconds()
        rows_out.append((f"backend_{name}_wall_over_simulated", us,
                         round(bkr.wall_seconds / simulated, 4)))
        report["scenarios"].append({
            "name": name,
            "simulated_seconds": simulated,
            "latency_cost": sim.latency_cost(),
            "wall_seconds": bkr.wall_seconds,
            "parity": {"ledger": ledger_parity, "output": output_parity},
            "kernel_calls": backend.wall.kernel_calls,
            "kernel_fallbacks": backend.wall.kernel_fallbacks,
            "wall": backend.wall.to_dict(),
        })
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows_out


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
