"""Paper Fig. 5 + §II-C(b): EMS fan-in / buffer-ratio sweep on the simulator.

Derived values: round reduction of k=4 (Property 5 split) vs DuckDB's 2-way
merge (paper: ~25% in the RTT-dominated limit), simulated-latency reduction
vs the conventional max-fan-in policy, and the exact §II-C round counts.
"""

from __future__ import annotations

from repro.core import TABLE_I
from repro.core.policies import EMSPlan, ems_costs_exact, ems_split_opt
from repro.engine import WorkloadStats, plan_operator, registry
from repro.remote import RemoteMemory
from repro.remote.simulator import make_key_pages
from benchmarks.common import Row, timed

TIER = TABLE_I["tcp"]  # paper Table I constants (see bench_bnlj)
EMS = registry.get("ems")


def _run_plan(plan, n_pages=256, rows=8, seed=0):
    remote = RemoteMemory(TIER)
    ids = make_key_pages(remote, n_pages, rows, seed=seed)
    res = EMS.run(remote, ids, plan, rows_per_page=rows,
                  count_run_formation=False)
    return res.c_read + res.c_write, remote.latency_seconds(), res.passes


def run() -> list[Row]:
    rows: list[Row] = []
    m = 12.0
    stats = WorkloadStats(size_r=256)

    def duck():
        return _run_plan(plan_operator("ems", stats, TIER, m, policy="duckdb"))

    us_duck, (rounds_duck, lat_duck, _) = timed(duck, repeats=1)

    # Fig. 5 (left): sweep fan-in at the Property-5 split.
    best = None
    for k in (2, 3, 4, 6, 8):
        plan = EMSPlan(m=m, k=k, r_in=ems_split_opt(k))
        rounds, lat, passes = _run_plan(plan)
        if k == 4:
            rows.append(("fig5_ems_k4_round_reduction_vs_duckdb", us_duck,
                         round(1 - rounds / rounds_duck, 4)))
        if best is None or lat < best[1]:
            best = (k, lat)
    rows.append((f"fig5_ems_latency_best_k{best[0]}", 0.0,
                 round(1 - best[1] / lat_duck, 4)))

    # Fig. 5 (right): r_in sweep at k=4 — latency should be least sensitive.
    lats = []
    for r_in in (0.4, 0.5, 0.6, 0.7, 0.8):
        _, lat, _ = _run_plan(EMSPlan(m=m, k=4, r_in=r_in))
        lats.append(lat)
    spread = (max(lats) - min(lats)) / min(lats)
    rows.append(("fig5_ems_rin_sweep_latency_spread", 0.0, round(spread, 4)))

    # §II-C(b) exact worked example.
    def worked():
        _, c1, _ = ems_costs_exact(13_000, 101, 100, 100)
        _, c2, _ = ems_costs_exact(13_000, 101, 4, 67)
        return c1, c2

    us, (c1, c2) = timed(worked, repeats=100)
    rows.append(("sec2c_ems_conv_rounds", us, c1))
    rows.append(("sec2c_ems_k4_rounds", 0.0, c2))
    rows.append(("sec2c_ems_round_reduction_factor", 0.0, round(c1 / c2, 2)))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
