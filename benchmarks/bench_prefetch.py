"""Paper Fig. 6b: per-operator prefetch double-buffer ablation.

Derived value: simulated-latency reduction with prefetch enabled, per
operator.  The paper reports BNLJ 21.3% > EHJ 10.0% > EMS 7.4%; the ordering
(BNLJ benefits most — its inner rescans are a predictable stream) is the
claim under test.
"""

from __future__ import annotations

from repro.core import TABLE_I
from repro.core.policies import BNLJPlan, EMSPlan, ems_split_opt
from repro.engine import WorkloadStats, plan_operator, registry
from repro.remote import RemoteMemory, make_relation
from repro.remote.simulator import make_key_pages
from benchmarks.common import Row, timed

TIER = TABLE_I["tcp"]  # paper Table I constants (see bench_bnlj)


def _bnlj(prefetch):
    remote = RemoteMemory(TIER)
    outer = make_relation(remote, 80 * 8, 8, 512, seed=1)
    inner = make_relation(remote, 160 * 8, 8, 512, seed=2)
    registry.get("bnlj").run(remote, outer, inner,
                             BNLJPlan(m=13, r_in=10 / 13, p_r=0.5),
                             prefetch=prefetch)
    return remote.ledger.latency_seconds(TIER, prefetch=prefetch)


def _ems(prefetch):
    remote = RemoteMemory(TIER)
    ids = make_key_pages(remote, 256, 8, seed=3)
    registry.get("ems").run(remote, ids,
                            EMSPlan(m=12, k=4, r_in=ems_split_opt(4)),
                            rows_per_page=8, prefetch=prefetch,
                            count_run_formation=False)
    return remote.ledger.latency_seconds(TIER, prefetch=prefetch)


def _ehj(prefetch):
    remote = RemoteMemory(TIER)
    build = make_relation(remote, 96 * 8, 8, 64, seed=4)
    probe = make_relation(remote, 192 * 8, 8, 64, seed=5)
    plan = plan_operator(
        "ehj", WorkloadStats(size_r=96, size_s=192, out=64,
                             partitions=16, sigma=0.5), TIER, 24)
    registry.get("ehj").run(remote, build, probe, plan, prefetch=prefetch)
    return remote.ledger.latency_seconds(TIER, prefetch=prefetch)


def run() -> list[Row]:
    rows: list[Row] = []
    gains = {}
    for name, fn in (("bnlj", _bnlj), ("ems", _ems), ("ehj", _ehj)):
        us, lat_off = timed(lambda f=fn: f(False), repeats=1)
        lat_on = fn(True)
        gains[name] = 1 - lat_on / lat_off
        rows.append((f"fig6b_prefetch_{name}_latency_reduction", us,
                     round(gains[name], 4)))
    rows.append(("fig6b_prefetch_bnlj_benefits_most", 0.0,
                 int(gains["bnlj"] >= max(gains["ems"], gains["ehj"]))))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
