"""Paper Fig. 7/8 analogue: end-to-end multi-operator query mixes.

We compose "queries" from the three operators over shared synthetic
relations under one memory budget — the spill-heavy TPC subset stand-in —
and compare vanilla policies (conventional/DuckDB knobs) vs REMOP policies
(+prefetch) on simulated latency (Eq. 1, REMON TCP tier).

Derived values: geometric-mean latency reduction across queries (paper:
-22.7% TPC-H / -26.4% TPC-DS on spilling subsets), plus the per-query range.
"""

from __future__ import annotations

import math

from repro.core import TABLE_I
from repro.engine import WorkloadStats, plan_operator, registry
from repro.remote import RemoteMemory, make_relation
from repro.remote.simulator import make_key_pages
from benchmarks.common import Row, timed

TIER = TABLE_I["tcp"]  # paper Table I constants (see bench_bnlj)
M = 13.0  # per-operator budget (pages): tight => everything spills
M_B = 24.0


def _q_join(remote, remop: bool, seed: int):
    outer = make_relation(remote, 90 * 8, 8, 2048, seed=seed)
    inner = make_relation(remote, 180 * 8, 8, 2048, seed=seed + 1)
    plan = plan_operator("bnlj", WorkloadStats(selectivity=1 / 2048), TIER, M,
                         policy="remop" if remop else "conventional")
    registry.get("bnlj").run(remote, outer, inner, plan, prefetch=remop)


def _q_sort(remote, remop: bool, seed: int):
    ids = make_key_pages(remote, 200, 8, seed=seed)
    plan = plan_operator("ems", WorkloadStats(size_r=200, k_cap=8), TIER, M,
                         policy="remop" if remop else "duckdb")
    registry.get("ems").run(remote, ids, plan, rows_per_page=8, prefetch=remop,
                            count_run_formation=False)


def _q_hash(remote, remop: bool, seed: int):
    build = make_relation(remote, 80 * 8, 8, 96, seed=seed)
    probe = make_relation(remote, 160 * 8, 8, 96, seed=seed + 1)
    plan = plan_operator(
        "ehj", WorkloadStats(size_r=80, size_s=160, out=48,
                             partitions=16, sigma=0.5), TIER, M_B,
        policy="remop" if remop else "conventional")
    registry.get("ehj").run(remote, build, probe, plan, prefetch=remop)


QUERIES = {
    "q_join_heavy": [(_q_join, 0), (_q_join, 10)],
    "q_sort_join": [(_q_sort, 20), (_q_join, 30)],
    "q_hash_sort": [(_q_hash, 40), (_q_sort, 50)],
    "q_mixed": [(_q_join, 60), (_q_hash, 70), (_q_sort, 80)],
}


def _latency(remop: bool, query) -> float:
    remote = RemoteMemory(TIER)
    for fn, seed in query:
        fn(remote, remop, seed)
    return remote.ledger.latency_seconds(TIER, prefetch=remop)


def run() -> list[Row]:
    rows: list[Row] = []
    reductions = []

    def run_all():
        out = {}
        for name, query in QUERIES.items():
            lv = _latency(False, query)
            lr = _latency(True, query)
            out[name] = (lv, lr)
        return out

    us, results = timed(run_all, repeats=1)
    for name, (lv, lr) in results.items():
        red = 1 - lr / lv
        reductions.append(lr / lv)
        rows.append((f"fig7_{name}_latency_reduction", 0.0, round(red, 4)))
    geo = 1 - math.exp(sum(math.log(r) for r in reductions) / len(reductions))
    rows.append(("fig7_geomean_latency_reduction", us, round(geo, 4)))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
