"""Paper Table III: optimal BNLJ input ratio r_in*(alpha, beta).

Derived value: max |ours - paper| over all 35 published cells (target < 0.002).
"""

from __future__ import annotations

from repro.core.policies import bnlj_rin_opt
from benchmarks.common import Row, timed

PAPER_TABLE_III = {
    (1e-2, 1e-2): 0.966, (1e-1, 1e-2): 0.967, (1, 1e-2): 0.970,
    (10, 1e-2): 0.980, (1e2, 1e-2): 0.991, (1e3, 1e-2): 0.997, (1e4, 1e-2): 0.999,
    (1e-2, 1e-1): 0.904, (1e-1, 1e-1): 0.905, (1, 1e-1): 0.912,
    (10, 1e-1): 0.940, (1e2, 1e-1): 0.973, (1e3, 1e-1): 0.991, (1e4, 1e-1): 0.997,
    (1e-2, 1): 0.764, (1e-1, 1): 0.765, (1, 1): 0.778,
    (10, 1): 0.836, (1e2, 1): 0.921, (1e3, 1): 0.971, (1e4, 1): 0.990,
    (1e-2, 10): 0.547, (1e-1, 10): 0.549, (1, 10): 0.560,
    (10, 10): 0.633, (1e2, 10): 0.789, (1e3, 10): 0.913, (1e4, 10): 0.970,
    (1e-2, 1e2): 0.330, (1e-1, 1e2): 0.331, (1, 1e2): 0.337,
    (10, 1e2): 0.384, (1e2, 1e2): 0.549, (1e3, 1e2): 0.769, (1e4, 1e2): 0.910,
}


def run() -> list[Row]:
    def solve_all():
        return {cell: bnlj_rin_opt(*cell) for cell in PAPER_TABLE_III}

    us, got = timed(solve_all)
    max_err = max(abs(got[c] - v) for c, v in PAPER_TABLE_III.items())
    return [("table3_rin_grid_35cells_max_abs_err", us, round(max_err, 5))]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
