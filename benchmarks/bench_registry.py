"""Engine registry smoke bench: one planning entry point for every operator.

Derived values: registered operator/policy coverage (every policy of every
operator plans successfully on every Table I and TESTBED tier) and the
planning cost per operator in microseconds.  Agreement with the per-operator
closed forms is regression-tested in tests/test_engine.py.
"""

from __future__ import annotations

from repro.core import TABLE_I, TESTBED
from repro.engine import WorkloadStats, plan_operator, registry
from benchmarks.common import Row, timed

STATS = WorkloadStats(size_r=200, size_s=400, out=64, selectivity=1 / 512,
                      partitions=16, sigma=0.5, k_cap=8)


def run() -> list[Row]:
    rows: list[Row] = []
    tiers = list(TABLE_I.values()) + list(TESTBED.values())

    def plan_everything():
        n = 0
        for op in registry.names():
            spec = registry.get(op)
            for policy in spec.policies:
                for tier in tiers:
                    plan = plan_operator(op, STATS, tier, 24, policy=policy)
                    assert isinstance(plan, spec.plan_type) and plan.op == op
                    n += 1
        return n

    us, n_plans = timed(plan_everything, repeats=3)
    rows.append((f"registry_{len(registry.names())}ops_policy_tier_coverage",
                 us, n_plans))

    for op, m in (("bnlj", 13), ("ems", 12), ("ehj", 24)):
        us, _ = timed(lambda op=op, m=m: plan_operator(op, STATS, "tcp", m),
                      repeats=50)
        rows.append((f"registry_plan_{op}_us", us, 0.0))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
