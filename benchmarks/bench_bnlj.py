"""Paper Fig. 4 + §II-C(a): BNLJ buffer-ratio sweep on the live simulator.

Sweeps (r_in, p_R) like Fig. 4, comparing against the conventional
outer-heavy allocation.  Derived values:
  * transfer-round reduction at the best swept point (paper: up to 97%),
  * simulated-latency (Eq. 1, REMON TCP tier) reduction at the L-optimum,
  * the exact §II-C read-round counts (6,006 vs 210).
"""

from __future__ import annotations

from repro.core import TABLE_I
from repro.core.policies import BNLJPlan, bnlj_costs_exact
from repro.engine import WorkloadStats, plan_operator, registry
from repro.remote import RemoteMemory, make_relation
from benchmarks.common import Row, timed

# Microbench sims use the paper's Table I TCP constants (RTT 500us ->
# tau ~ 2.44 pages at 256 KiB pages); the testbed tier (RTT 155us, tau 0.74)
# is volume-dominated and exercises the tau->0 limit instead.
TIER = TABLE_I["tcp"]
BNLJ = registry.get("bnlj")


def _run_plan(plan, seed=0, r_pages=120, s_pages=240, rows=8, domain=4096):
    remote = RemoteMemory(TIER)
    outer = make_relation(remote, r_pages * rows, rows, domain, seed=seed)
    inner = make_relation(remote, s_pages * rows, rows, domain, seed=seed + 1)
    res = BNLJ.run(remote, outer, inner, plan)
    rounds = res.c_read + res.c_write
    latency = remote.latency_seconds()
    return rounds, latency, res.output_rows


def run() -> list[Row]:
    rows: list[Row] = []
    m = 13.0
    stats = WorkloadStats(size_r=120, size_s=240, selectivity=1 / 4096)
    conv = plan_operator("bnlj", stats, TIER, m, policy="conventional")

    def conv_run():
        return _run_plan(conv)

    us_conv, (rounds_conv, lat_conv, out_conv) = timed(conv_run, repeats=1)

    best = None
    for r_in in (0.4, 0.6, 0.8, 0.9):
        for p_r in (0.3, 0.5, 0.6, 0.8):
            plan = BNLJPlan(m=m, r_in=r_in, p_r=p_r)
            rounds, lat, out = _run_plan(plan)
            assert out == out_conv  # correctness across the sweep
            if best is None or lat < best[2]:
                best = (r_in, p_r, lat, rounds)
    r_in, p_r, lat_best, rounds_best = best
    rows.append(("fig4_bnlj_round_reduction_at_best", us_conv,
                 round(1 - rounds_best / rounds_conv, 4)))
    rows.append(("fig4_bnlj_sim_latency_reduction_at_best", 0.0,
                 round(1 - lat_best / lat_conv, 4)))
    rows.append((f"fig4_bnlj_best_cfg_rin{r_in}_pr{p_r}", 0.0, round(lat_best, 4)))

    # Direct REMOP policy (Table III + Property 4) vs conventional.
    policy = plan_operator("bnlj", stats, TIER, m)
    rounds_pol, lat_pol, out_pol = _run_plan(policy)
    assert out_pol == out_conv
    rows.append(("fig4_bnlj_policy_latency_reduction", 0.0,
                 round(1 - lat_pol / lat_conv, 4)))

    # §II-C(a) exact worked example.
    def worked():
        d1, c1 = bnlj_costs_exact(500, 1000, 0, 99, 1, 1)
        d2, c2 = bnlj_costs_exact(500, 1000, 0, 50, 50, 1)
        return c1, c2, d2 / d1

    us, (c1, c2, dratio) = timed(worked, repeats=100)
    rows.append(("sec2c_bnlj_conv_read_rounds", us, c1))
    rows.append(("sec2c_bnlj_equal_read_rounds", 0.0, c2))
    rows.append(("sec2c_bnlj_round_reduction", 0.0, round(1 - c2 / c1, 4)))
    rows.append(("sec2c_bnlj_data_increase", 0.0, round(dratio - 1, 4)))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
