"""Multi-tenant serving sweep: cross-query arbitration vs FIFO and even-split.

Offered load x tenant mix on one shared DRAM -> RDMA -> SSD hierarchy
(Table I constants).  Each sweep point replays the same request trace —
high-priority *interactive* sorts over RDMA-resident keys interleaved with
low-priority *batch* pipelines (a large external sort feeding an
aggregation over SSD-cold inputs) — under the three
:class:`repro.engine.Server` modes:

  * ``arbitrated`` — the headline: one cross-query arbiter re-splits the
    joint budget and tier placements on every arrival/finish event,
    priority-weighted, with preemptive demotion clearing low-priority
    residency off granted fast tiers;
  * ``even`` — static 1/slots budget and capacity shares, no
    re-arbitration (the "reserve a fixed slice per tenant" strawman);
  * ``fifo`` — one query at a time on the full machine (serial
    execution, zero interference).

The sweep's structural result: interactive queries' DRAM/RDMA phases hide
under the batch queries' conserved SSD input scans, so the arbitrated
server sustains higher throughput than FIFO serialisation, while even
split starves whichever class is scarce at that point.  The **strict-win
gate** enforces this at every sweep point: arbitrated throughput must
strictly exceed both baselines, or this bench raises.

Two more gates ride along:

  * **parity** — a single admitted tenant must reproduce the standalone
    ``Session.run(replan="measured")`` ledger byte-for-byte and its
    simulated latency exactly (the server's clock and arbitration add
    nothing when there is nothing to share);
  * **preemption demo** — on a DRAM-tight hierarchy, a high-priority
    arrival must trigger preemptive demotion of the resident batch
    sort's cold pages (visible ``PreemptionEvent``s) and must not be
    slower than the same arrival without a priority edge.

Writes ``BENCH_serving.json`` at the repo root — a machine-readable perf
artifact CI uploads and gates with ``scripts/check_regression.py``
(`simulated_seconds` leaves are the gated metrics).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from repro.core import TABLE_I
from repro.engine import QueryRequest, Server, ServerReport, Session, WorkloadStats
from repro.engine.registry import hierarchy_spec
from benchmarks.common import Row

ROWS = 8
BUDGET = 256.0
SLOTS = 3
DRAM_CAP = 8192
RDMA_CAP = 2048
HSPEC = hierarchy_spec((TABLE_I["dram"], DRAM_CAP), (TABLE_I["rdma"], RDMA_CAP),
                       TABLE_I["ssd"])

INTERACTIVE_PAGES = 768  # RDMA-hot keys, sorted in DRAM
BATCH_SORT_PAGES = 1536  # SSD-cold keys
BATCH_AGG_PAGES = 512    # SSD-cold relation
INTERACTIVE_PRIORITY = 4.0
BATCH_PRIORITY = 1.0

# (n_interactive, n_batch) x offered load (inter-arrival seconds).  Every
# point is contended: batch pipelines span many interactive arrivals.
MIXES = [(12, 2), (16, 2)]
LOADS = [0.04, 0.08, 0.15]
MODES = ["arbitrated", "even", "fifo"]

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_serving.json")


def _interactive_tasks_of(seed: int, pages: int = INTERACTIVE_PAGES):
    """A hot sort: keys already resident on RDMA, merged through DRAM."""
    def tasks_of(sess: Session):
        from repro.remote.simulator import make_key_pages

        ids = make_key_pages(sess.remote, pages, ROWS, seed=seed,
                             tier="rdma")
        return [
            sess.task("ems", WorkloadStats(size_r=pages, k_cap=8),
                      inputs={"page_ids": ids}, rows_per_page=ROWS),
        ]
    return tasks_of


def _batch_tasks_of(seed: int, sort_pages: int = BATCH_SORT_PAGES,
                    agg_pages: int = BATCH_AGG_PAGES):
    """A cold pipeline: SSD-resident sort feeding an aggregation."""
    def tasks_of(sess: Session):
        from repro.remote import make_relation
        from repro.remote.simulator import make_key_pages

        ids = make_key_pages(sess.remote, sort_pages, ROWS, seed=seed)
        rel = make_relation(sess.remote, agg_pages * ROWS, ROWS, 128,
                            seed=seed + 1)
        return [
            sess.task("ems", WorkloadStats(size_r=sort_pages, k_cap=8),
                      inputs={"page_ids": ids}, rows_per_page=ROWS),
            sess.task("eagg", WorkloadStats(size_r=agg_pages, out=96,
                                            partitions=8, sigma=0.5),
                      inputs={"rel": rel}),
        ]
    return tasks_of


def _trace(n_interactive: int, n_batch: int, interarrival: float
           ) -> List[QueryRequest]:
    """Deterministic arrival trace: batch queries spread through the mix."""
    reqs: List[QueryRequest] = []
    total = n_interactive + n_batch
    batch_every = max(total // max(n_batch, 1), 1)
    remaining_batch = n_batch
    t = 0.0
    for rid in range(total):
        if rid % batch_every == 0 and remaining_batch > 0:
            reqs.append(QueryRequest(
                rid=rid, tasks_of=_batch_tasks_of(1000 + 17 * rid),
                arrival=t, priority=BATCH_PRIORITY, label="batch"))
            remaining_batch -= 1
        else:
            reqs.append(QueryRequest(
                rid=rid, tasks_of=_interactive_tasks_of(1000 + 17 * rid),
                arrival=t, priority=INTERACTIVE_PRIORITY, label="interactive"))
        t += interarrival
    return reqs


def _mode_summary(rep: ServerReport) -> Dict[str, object]:
    interactive = sorted(q.latency for q in rep.queries
                         if q.label == "interactive")
    batch = sorted(q.latency for q in rep.queries if q.label == "batch")
    return {
        "throughput_qps": round(rep.throughput, 6),
        "preempted_pages": sum(e.pages for e in rep.preemptions),
        "rearbitrations": rep.rearbitrations,
        "simulated_seconds": {
            "makespan": rep.makespan,
            "p50_latency": rep.p50_latency,
            "p99_latency": rep.p99_latency,
            "interactive_p50": interactive[len(interactive) // 2],
            "batch_max": batch[-1],
        },
    }


def _check_accounting(rep: ServerReport) -> None:
    for name in HSPEC.names:
        if rep.tenant_total.tier(name) != rep.total.tier(name):
            raise RuntimeError(
                f"per-tenant ledgers do not sum to the hierarchy total on "
                f"{name} (mode={rep.mode})")


def _run_parity() -> Dict[str, object]:
    """Single admitted tenant == standalone Session, byte for byte."""
    tasks_of = _batch_tasks_of(4242)
    sess = Session(HSPEC, budget=BUDGET, eviction="lru")
    res = sess.run(tasks_of(sess), replan="measured")
    solo = res.latency_seconds()

    srv = Server(HSPEC, budget=BUDGET, slots=SLOTS)
    srv.submit(QueryRequest(rid=0, tasks_of=tasks_of, label="solo"))
    rep = srv.run()
    served = rep.query(0).latency
    for name in HSPEC.names:
        if res.total.tier(name) != rep.query(0).ledger.tier(name):
            raise RuntimeError(
                f"serving parity: ledger mismatch on {name}:\n"
                f"  standalone: {res.total.tier(name)}\n"
                f"  served:     {rep.query(0).ledger.tier(name)}")
    if abs(served - solo) > 1e-9 * max(solo, 1.0):
        raise RuntimeError(
            f"serving parity: latency mismatch: standalone {solo!r} "
            f"vs served {served!r}")
    _check_accounting(rep)
    return {
        "ledger_equal": True,
        "simulated_seconds": {"standalone": solo, "served": served},
    }


def _run_preemption_demo() -> Dict[str, object]:
    """Priority edge -> visible preemptive demotion on a tight hierarchy."""
    tight = hierarchy_spec((TABLE_I["dram"], 2048), (TABLE_I["rdma"], 1024),
                           TABLE_I["ssd"])

    def serve(priority: float) -> ServerReport:
        srv = Server(tight, budget=BUDGET, mode="arbitrated", slots=2)
        srv.submit([
            QueryRequest(rid=0, tasks_of=_batch_tasks_of(7000),
                         arrival=0.0, priority=BATCH_PRIORITY, label="batch"),
            QueryRequest(rid=1, tasks_of=_interactive_tasks_of(7017, 256),
                         arrival=0.3, priority=priority, label="interactive"),
        ])
        rep = srv.run()
        _check_accounting(rep)
        return rep

    with_prio = serve(8.0)
    without = serve(BATCH_PRIORITY)
    preempted = sum(e.pages for e in with_prio.preemptions)
    lat_with = with_prio.query(1).latency
    lat_without = without.query(1).latency
    if preempted <= 0:
        raise RuntimeError("preemption demo: the priority arrival did not "
                           "trigger preemptive demotion")
    if sum(e.pages for e in without.preemptions) != 0:
        raise RuntimeError("preemption demo: equal priorities must not preempt")
    if lat_with > lat_without:
        raise RuntimeError(
            f"preemption demo: priority made the interactive query slower "
            f"({lat_with!r} vs {lat_without!r})")
    return {
        "preempted_pages": preempted,
        "events": [
            {"time": e.time, "rid": e.rid, "victim_rid": e.victim_rid,
             "tier": e.tier, "pages": e.pages}
            for e in with_prio.preemptions
        ],
        "simulated_seconds": {
            "interactive_with_priority": lat_with,
            "interactive_without_priority": lat_without,
        },
    }


def run() -> List[Row]:
    rows_out: List[Row] = []
    report = {
        "schema": 1,
        "hierarchy": {"dram": DRAM_CAP, "rdma": RDMA_CAP, "ssd": "inf"},
        "budget": BUDGET,
        "slots": SLOTS,
        "workloads": {
            "interactive": {"op": "ems", "pages": INTERACTIVE_PAGES,
                            "resident": "rdma",
                            "priority": INTERACTIVE_PRIORITY},
            "batch": {"ops": ["ems", "eagg"],
                      "pages": [BATCH_SORT_PAGES, BATCH_AGG_PAGES],
                      "resident": "ssd", "priority": BATCH_PRIORITY},
        },
        "sweep": [],
    }

    for n_interactive, n_batch in MIXES:
        for interarrival in LOADS:
            t0 = time.perf_counter()
            reps: Dict[str, ServerReport] = {}
            for mode in MODES:
                srv = Server(HSPEC, budget=BUDGET, mode=mode, slots=SLOTS)
                srv.submit(_trace(n_interactive, n_batch, interarrival))
                reps[mode] = srv.run()
                _check_accounting(reps[mode])
            us = (time.perf_counter() - t0) * 1e6

            arb = reps["arbitrated"]
            win = (arb.throughput > reps["even"].throughput
                   and arb.throughput > reps["fifo"].throughput)
            tag = f"mix{n_interactive}i{n_batch}b_ia{interarrival:g}"
            if not win:
                raise RuntimeError(
                    f"strict-win gate failed at {tag}: arbitrated "
                    f"{arb.throughput:.3f} q/s vs even "
                    f"{reps['even'].throughput:.3f} / fifo "
                    f"{reps['fifo'].throughput:.3f}")
            speedup_fifo = arb.throughput / reps["fifo"].throughput
            rows_out.append((f"serving_{tag}_arb_throughput_qps", us,
                             round(arb.throughput, 4)))
            rows_out.append((f"serving_{tag}_speedup_vs_fifo", 0.0,
                             round(speedup_fifo, 4)))
            report["sweep"].append({
                "name": tag,
                "n_interactive": n_interactive,
                "n_batch": n_batch,
                "interarrival": interarrival,
                "modes": {m: _mode_summary(reps[m]) for m in MODES},
                "strict_win": win,
            })

    t0 = time.perf_counter()
    report["parity"] = _run_parity()
    rows_out.append(("serving_single_tenant_parity",
                     (time.perf_counter() - t0) * 1e6, 1.0))

    t0 = time.perf_counter()
    report["preemption_demo"] = _run_preemption_demo()
    rows_out.append(("serving_preemption_demo_pages",
                     (time.perf_counter() - t0) * 1e6,
                     float(report["preemption_demo"]["preempted_pages"])))

    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows_out


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
