"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, float]


def timed(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    """(microseconds per call, last result)."""
    result = fn()  # warmup / correctness
    t0 = time.perf_counter()
    for _ in range(repeats):
        result = fn()
    us = (time.perf_counter() - t0) / repeats * 1e6
    return us, result


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
