"""Paper Table I / Eq. (1): the latency model and the §II-A worked example.

Derived values: the round-term share of total latency per tier for the
10 GB / 20,000-round example — the paper's motivation that the C*RTT term
dominates on TCP remote memory (10s vs 8s) but not on SSD (2s vs 19s).
"""

from __future__ import annotations

from repro.core import TABLE_I
from benchmarks.common import Row, timed


def run() -> list[Row]:
    rows: list[Row] = []
    d_bytes, c = 10e9, 20_000
    for name in ("ssd", "tcp", "rdma", "dram"):
        tier = TABLE_I[name]

        def total(tier=tier):
            return tier.latency_seconds_bytes(d_bytes, c)

        us, t = timed(total, repeats=1000)
        round_share = (c * tier.rtt) / t
        rows.append((f"eq1_{name}_round_share", us, round(round_share, 4)))
    # The motivating comparison: on TCP the round term exceeds the volume term.
    tcp = TABLE_I["tcp"]
    rows.append(("eq1_tcp_round_term_dominates", 0.0,
                 int(c * tcp.rtt > d_bytes / tcp.bandwidth)))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
