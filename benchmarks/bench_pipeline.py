"""Query pipelines under one budget: memory arbiter vs even split.

Composes multi-operator pipelines (the TPC-style spilling-query stand-in) and
compares the arbiter's budget split against the naive even split, on both the
modeled latency cost (the quantity the arbiter minimizes) and the *simulated*
wall latency of running every operator against one shared RemoteMemory.

Besides the usual CSV rows, writes ``BENCH_pipeline.json`` at the repo root —
the machine-readable perf trajectory artifact CI uploads on every push.
"""

from __future__ import annotations

import json
import os

from repro.core import TABLE_I
from repro.engine import (
    WorkloadStats,
    model_latency,
    plan_pipeline,
    run_pipeline,
)
from repro.remote import RemoteMemory, make_relation
from repro.remote.simulator import make_key_pages
from benchmarks.common import Row, timed

TIER_NAME = "tcp"
TIER = TABLE_I[TIER_NAME]
ROWS = 8
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_pipeline.json")

# (name, ops, per-op stats, global budget M, workload builder).
PIPELINES = [
    (
        "join_sort", ["ehj", "ems"],
        [WorkloadStats(size_r=64, size_s=128, out=48, partitions=8, sigma=0.5),
         WorkloadStats(size_r=160, k_cap=8)],
        40.0,
    ),
    (
        "scan_sort_agg", ["bnlj", "ems", "eagg"],
        [WorkloadStats(size_r=48, size_s=96, out=24, selectivity=1 / 2048),
         WorkloadStats(size_r=120, k_cap=8),
         WorkloadStats(size_r=96, out=16, partitions=8, sigma=0.5)],
        64.0,
    ),
]


def _workloads(remote, ops, stats, seed=0):
    built = []
    for i, (op, st) in enumerate(zip(ops, stats)):
        s = seed + 10 * i
        if op in ("bnlj", "ehj"):
            r = make_relation(remote, int(st.size_r) * ROWS, ROWS, 2048 if op == "bnlj" else 96,
                              seed=s)
            q = make_relation(remote, int(st.size_s) * ROWS, ROWS, 2048 if op == "bnlj" else 96,
                              seed=s + 1)
            built.append(((r, q), {}))
        elif op == "ems":
            built.append(((make_key_pages(remote, int(st.size_r), ROWS, seed=s),),
                          {"rows_per_page": ROWS}))
        else:  # eagg
            built.append(((make_relation(remote, int(st.size_r) * ROWS, ROWS, 128,
                                         seed=s),), {}))
    return built


def _simulate(pplan, ops, stats) -> float:
    remote = RemoteMemory(TIER)
    run_pipeline(remote, pplan, _workloads(remote, ops, stats))
    return remote.latency_seconds()


def run() -> list[Row]:
    rows_out: list[Row] = []
    report = {"schema": 1, "tier": TIER_NAME, "pipelines": []}
    for name, ops, stats, m_total in PIPELINES:
        arb = plan_pipeline(ops, stats, TIER, m_total)
        even = [m_total / len(ops)] * len(ops)
        even_modeled = sum(
            model_latency(op, st, TIER, m) for op, st, m in zip(ops, stats, even)
        )
        even_plan = _even_pipeline(ops, stats, m_total)

        def simulate_pair():
            return _simulate(arb, ops, stats), _simulate(even_plan, ops, stats)

        us, (lat_arb, lat_even) = timed(simulate_pair, repeats=1)
        modeled_red = 1 - arb.total_modeled_latency / even_modeled
        sim_red = 1 - lat_arb / lat_even
        rows_out.append((f"pipeline_{name}_modeled_latency_reduction_vs_even",
                         us, round(modeled_red, 4)))
        rows_out.append((f"pipeline_{name}_sim_latency_reduction_vs_even",
                         0.0, round(sim_red, 4)))
        report["pipelines"].append({
            "name": name,
            "ops": ops,
            "m_total": m_total,
            "budgets": list(arb.budgets),
            "modeled_latency": {"arbiter": arb.total_modeled_latency,
                                "even": even_modeled},
            "simulated_seconds": {"arbiter": lat_arb, "even": lat_even},
        })
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows_out


def _even_pipeline(ops, stats, m_total):
    """An even-split PipelinePlan built through plan_operator directly."""
    from repro.engine.pipeline import OperatorBudget, PipelinePlan
    from repro.engine.registry import plan_operator, resolve_tier

    m = m_total / len(ops)
    budgets = tuple(
        OperatorBudget(op=op, stats=st, m_pages=m,
                       plan=plan_operator(op, st, TIER, m),
                       modeled_latency=model_latency(op, st, TIER, m))
        for op, st in zip(ops, stats)
    )
    return PipelinePlan(tier=resolve_tier(TIER), m_total=m_total,
                        policy="remop", ops=budgets)


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
