"""Query pipelines under one budget: memory arbiter vs even split.

Composes multi-operator pipelines (the TPC-style spilling-query stand-in)
through the session API — typed ``session.task`` inputs, ``session.plan``
arbitration, ``session.run`` execution — and compares the arbiter's budget
split against the naive even split, on both the modeled latency cost (the
quantity the arbiter minimizes) and the *simulated* wall latency of running
every operator against one shared ledger.

Besides the usual CSV rows, writes ``BENCH_pipeline.json`` at the repo root —
the machine-readable perf trajectory artifact CI uploads on every push.
"""

from __future__ import annotations

import json
import os

from repro.core import TABLE_I
from repro.engine import Session, WorkloadStats, model_latency
from repro.remote import make_relation
from repro.remote.simulator import make_key_pages
from benchmarks.common import Row, timed

TIER_NAME = "tcp"
TIER = TABLE_I[TIER_NAME]
ROWS = 8
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_pipeline.json")

# (name, ops, per-op stats, global budget M).
PIPELINES = [
    (
        "join_sort", ["ehj", "ems"],
        [WorkloadStats(size_r=64, size_s=128, out=48, partitions=8, sigma=0.5),
         WorkloadStats(size_r=160, k_cap=8)],
        40.0,
    ),
    (
        "scan_sort_agg", ["bnlj", "ems", "eagg"],
        [WorkloadStats(size_r=48, size_s=96, out=24, selectivity=1 / 2048),
         WorkloadStats(size_r=120, k_cap=8),
         WorkloadStats(size_r=96, out=16, partitions=8, sigma=0.5)],
        64.0,
    ),
]


def _tasks(sess: Session, ops, stats, seed=0, with_data: bool = True):
    """The pipeline's typed tasks; data-free tasks are enough for planning."""
    built = []
    for i, (op, st) in enumerate(zip(ops, stats)):
        s = seed + 10 * i
        if op in ("bnlj", "ehj"):
            names = ("outer", "inner") if op == "bnlj" else ("build", "probe")
            inputs = None
            if with_data:
                domain = 2048 if op == "bnlj" else 96
                r = make_relation(sess.remote, int(st.size_r) * ROWS, ROWS,
                                  domain, seed=s)
                q = make_relation(sess.remote, int(st.size_s) * ROWS, ROWS,
                                  domain, seed=s + 1)
                inputs = dict(zip(names, (r, q)))
            built.append(sess.task(op, st, inputs=inputs))
        elif op == "ems":
            ids = (make_key_pages(sess.remote, int(st.size_r), ROWS, seed=s)
                   if with_data else None)
            built.append(sess.task(
                op, st, inputs={"page_ids": ids} if with_data else None,
                rows_per_page=ROWS))
        else:  # eagg
            rel = (make_relation(sess.remote, int(st.size_r) * ROWS, ROWS, 128,
                                 seed=s) if with_data else None)
            built.append(sess.task(
                op, st, inputs={"rel": rel} if with_data else None))
    return built


def _simulate(ops, stats, m_total, plan=None) -> float:
    sess = Session(TIER, budget=m_total)
    tasks = _tasks(sess, ops, stats)
    sess.run(tasks, plan=plan if plan is not None else sess.plan(tasks))
    return sess.remote.latency_seconds()


def run() -> list[Row]:
    rows_out: list[Row] = []
    report = {"schema": 1, "tier": TIER_NAME, "pipelines": []}
    for name, ops, stats, m_total in PIPELINES:
        planner = Session(TIER, budget=m_total)
        arb = planner.plan(_tasks(planner, ops, stats, with_data=False))
        even = [m_total / len(ops)] * len(ops)
        even_modeled = sum(
            model_latency(op, st, TIER, m) for op, st, m in zip(ops, stats, even)
        )
        even_plan = _even_pipeline(ops, stats, m_total)

        def simulate_pair(ops=ops, stats=stats, m_total=m_total,
                          arb=arb, even_plan=even_plan):
            return (_simulate(ops, stats, m_total, plan=arb),
                    _simulate(ops, stats, m_total, plan=even_plan))

        us, (lat_arb, lat_even) = timed(simulate_pair, repeats=1)
        modeled_red = 1 - arb.total_modeled_latency / even_modeled
        sim_red = 1 - lat_arb / lat_even
        rows_out.append((f"pipeline_{name}_modeled_latency_reduction_vs_even",
                         us, round(modeled_red, 4)))
        rows_out.append((f"pipeline_{name}_sim_latency_reduction_vs_even",
                         0.0, round(sim_red, 4)))
        report["pipelines"].append({
            "name": name,
            "ops": ops,
            "m_total": m_total,
            "budgets": list(arb.budgets),
            "modeled_latency": {"arbiter": arb.total_modeled_latency,
                                "even": even_modeled},
            "simulated_seconds": {"arbiter": lat_arb, "even": lat_even},
        })
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows_out


def _even_pipeline(ops, stats, m_total):
    """An even-split PipelinePlan built through plan_operator directly."""
    from repro.engine.pipeline import OperatorBudget, PipelinePlan
    from repro.engine.registry import plan_operator, resolve_tier

    m = m_total / len(ops)
    budgets = tuple(
        OperatorBudget(op=op, stats=st, m_pages=m,
                       plan=plan_operator(op, st, TIER, m),
                       modeled_latency=model_latency(op, st, TIER, m))
        for op, st in zip(ops, stats)
    )
    return PipelinePlan(tier=resolve_tier(TIER), m_total=m_total,
                        policy="remop", ops=budgets)


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
