"""Mid-pipeline adaptive re-planning: ``replan="measured"`` vs a static plan.

The ROADMAP's known misestimation case: the EHJ output estimate can be ~8x
off at high selectivity.  This benchmark builds a pipeline whose sort
consumes the join's output — EHJ (out underestimated 8x) -> EMS over
``join.output`` -> an independent EAGG — and runs it twice through the
session API:

  * **static**: the arbitrated plan computed from the (wrong) estimates is
    executed as-is;
  * **replan**: ``session.run(tasks, replan="measured")`` feeds the join's
    *measured* output cardinality back after it finishes and re-arbitrates
    the remaining operators' budgets (and, on the hierarchy scenario, their
    tier placements against the measured residual capacity).

Reported per scenario: simulated wall latency of both runs and the replan's
latency reduction.  Writes ``BENCH_session.json`` at the repo root — gated by
``scripts/check_regression.py`` in CI like the other BENCH artifacts.
"""

from __future__ import annotations

import json
import os
import time
from typing import List

from repro.core import TABLE_I
from repro.engine import Session, WorkloadStats
from repro.engine.registry import hierarchy_spec
from repro.remote import make_relation
from benchmarks.common import Row

ROWS = 8
M_TOTAL = 64.0
EST_OUT = 97.0  # the EHJ out estimate; measured output is ~8x larger

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_session.json")

SCENARIOS = [
    ("tcp", lambda: TABLE_I["tcp"]),
    ("dram_rdma_ssd", lambda: hierarchy_spec(
        (TABLE_I["dram"], 64), (TABLE_I["rdma"], 512), TABLE_I["ssd"])),
]


def _tasks(sess: Session, with_data: bool = True):
    """EHJ (out ~8x underestimated) -> EMS over its output, plus an EAGG."""
    if with_data:
        build = make_relation(sess.remote, 48 * ROWS, ROWS, 48, seed=31)
        probe = make_relation(sess.remote, 96 * ROWS, ROWS, 48, seed=32)
        agg = make_relation(sess.remote, 96 * ROWS, ROWS, 128, seed=34)
        join_inputs = {"build": build, "probe": probe}
        agg_inputs = {"rel": agg}
    else:  # data-free tasks: enough for plan()/explain()
        join_inputs = agg_inputs = None
    join = sess.task("ehj", WorkloadStats(size_r=48, size_s=96, out=EST_OUT,
                                          partitions=8, sigma=0.5),
                     inputs=join_inputs)
    sort = sess.task("ems", WorkloadStats(size_r=EST_OUT, k_cap=8),
                     inputs={"page_ids": join.output}, rows_per_page=ROWS)
    aggt = sess.task("eagg", WorkloadStats(size_r=96, out=16, partitions=8,
                                           sigma=0.5), inputs=agg_inputs)
    return [join, sort, aggt]


def _run(target, replan):
    sess = Session(target, budget=M_TOTAL)
    tasks = _tasks(sess)
    res = sess.run(tasks, replan=replan)
    return sess, res


def run() -> List[Row]:
    rows_out: List[Row] = []
    report = {"schema": 1, "m_total": M_TOTAL, "est_out": EST_OUT,
              "scenarios": []}
    for name, target_fn in SCENARIOS:
        t0 = time.perf_counter()
        _, res_static = _run(target_fn(), replan=None)
        sess, res_replan = _run(target_fn(), replan="measured")
        us = (time.perf_counter() - t0) * 1e6

        lat_static = res_static.latency_seconds()
        lat_replan = res_replan.latency_seconds()
        reduction = 1 - lat_replan / lat_static
        measured_out = res_replan.per_task[0].measured.out
        events = [
            {
                "after": ev.after_label,
                "measured_out": ev.measured_out,
                "budgets_before": list(ev.budgets_before),
                "budgets_after": list(ev.budgets_after),
                "placements_before": list(ev.placements_before),
                "placements_after": list(ev.placements_after),
            }
            for ev in res_replan.replan_events
        ]
        planner = Session(target_fn(), budget=M_TOTAL)
        rows_out.append((f"session_{name}_replan_sim_latency_reduction_vs_static",
                         us, round(reduction, 4)))
        report["scenarios"].append({
            "name": name,
            "measured_out": measured_out,
            "estimate_error": measured_out / EST_OUT,
            "static_budgets": list(res_static.plan.budgets),
            "replan_events": events,
            "simulated_seconds": {"static": lat_static, "replan": lat_replan},
            "explain": planner.explain(
                _tasks(planner, with_data=False)).to_dict(),
        })
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows_out


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
