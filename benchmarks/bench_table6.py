"""Paper Table VI: EHJ per-phase optimal buffer splits (Property 6).

Derived value: max relative error between the measured round cost at the
waterfill allocation and the closed-form C_i* across a grid of (sigma, P)
configurations (target ~ 0: Cauchy-Schwarz is exact).
"""

from __future__ import annotations

from repro.core import TABLE_I
from repro.core.policies import ehj_optimal_round_costs, ehj_round_costs
from repro.engine import WorkloadStats, plan_operator
from benchmarks.common import Row, timed


def run() -> list[Row]:
    b, q, out, m_b = 4000.0, 16000.0, 8000.0, 256.0
    grid = [(s, p) for s in (0.25, 0.5, 0.75) for p in (4, 16, 64)]

    def check_all():
        worst = 0.0
        for sigma, parts in grid:
            plan = plan_operator(
                "ehj",
                WorkloadStats(size_r=b, size_s=q, out=out,
                              partitions=parts, sigma=sigma),
                TABLE_I["tcp"], m_b)
            got = ehj_round_costs(b, q, out, plan)
            want = ehj_optimal_round_costs(b, q, out, m_b, parts, sigma)
            for g, w in zip(got, want):
                worst = max(worst, abs(g - w) / w)
        return worst

    us, worst = timed(check_all)
    return [("table6_ehj_splits_9cfgs_max_rel_err", us, round(worst, 8))]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
