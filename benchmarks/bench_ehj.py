"""Paper Fig. 6a: EHJ write-pool sweep (R_w build handles, R_s staging rows).

Derived values: write-round reduction of the Property-6 waterfill pools vs
starved 1-page pools (DuckDB's default-size analogue), across partition
counts P in {4, 8, 16} — the paper reports modest (~4.6%) runtime gains with
the same direction.
"""

from __future__ import annotations

from repro.core import TABLE_I, TESTBED
from repro.core.policies import EHJPlan, ehj_plan
from repro.remote import RemoteMemory, ehj, make_relation
from benchmarks.common import Row, timed

TIER = TABLE_I["tcp"]  # paper Table I constants (see bench_bnlj)


def _run(plan, seed=0, b_pages=96, q_pages=192, rows=8, domain=64):
    remote = RemoteMemory(TIER)
    build = make_relation(remote, b_pages * rows, rows, domain, seed=seed)
    probe = make_relation(remote, q_pages * rows, rows, domain, seed=seed + 1)
    res = ehj(remote, build, probe, plan)
    return res.c_write, remote.latency_seconds(), res.output_rows


def run() -> list[Row]:
    rows_out: list[Row] = []
    m_b, sigma = 24.0, 0.5
    for parts in (4, 8, 16):
        remop = ehj_plan(96, 192, 64, m_b, parts, sigma)
        starved = EHJPlan(m_b=m_b, partitions=parts, sigma=sigma,
                          p1=(m_b - 1, 1.0), p2=(m_b - 2, 1.0, 1.0),
                          p3=(m_b - 1, 1.0))

        def run_pair():
            w_s, lat_s, out_s = _run(starved)
            w_r, lat_r, out_r = _run(remop)
            assert out_s == out_r
            return w_s, w_r, lat_s, lat_r

        us, (w_s, w_r, lat_s, lat_r) = timed(run_pair, repeats=1)
        rows_out.append((f"fig6a_ehj_P{parts}_write_round_reduction", us,
                         round(1 - w_r / w_s, 4)))
        rows_out.append((f"fig6a_ehj_P{parts}_sim_latency_reduction", 0.0,
                         round(1 - lat_r / lat_s, 4)))
    return rows_out


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
