"""Paper Fig. 6a: EHJ write-pool sweep (R_w build handles, R_s staging rows).

Derived values: write-round reduction of the Property-6 waterfill pools vs
starved 1-page pools (DuckDB's default-size analogue), across partition
counts P in {4, 8, 16} — the paper reports modest (~4.6%) runtime gains with
the same direction.
"""

from __future__ import annotations

from repro.core import TABLE_I
from repro.engine import WorkloadStats, plan_operator, registry
from repro.remote import RemoteMemory, make_relation
from benchmarks.common import Row, timed

TIER = TABLE_I["tcp"]  # paper Table I constants (see bench_bnlj)
EHJ = registry.get("ehj")


def _run(plan, seed=0, b_pages=96, q_pages=192, rows=8, domain=64):
    remote = RemoteMemory(TIER)
    build = make_relation(remote, b_pages * rows, rows, domain, seed=seed)
    probe = make_relation(remote, q_pages * rows, rows, domain, seed=seed + 1)
    res = EHJ.run(remote, build, probe, plan)
    return res.c_write, remote.latency_seconds(), res.output_rows


def run() -> list[Row]:
    rows_out: list[Row] = []
    m_b, sigma = 24.0, 0.5
    for parts in (4, 8, 16):
        stats = WorkloadStats(size_r=96, size_s=192, out=64,
                              partitions=parts, sigma=sigma)
        remop = plan_operator("ehj", stats, TIER, m_b)
        starved = plan_operator("ehj", stats, TIER, m_b, policy="conventional")

        def run_pair(starved=starved, remop=remop):
            w_s, lat_s, out_s = _run(starved)
            w_r, lat_r, out_r = _run(remop)
            assert out_s == out_r
            return w_s, w_r, lat_s, lat_r

        us, (w_s, w_r, lat_s, lat_r) = timed(run_pair, repeats=1)
        rows_out.append((f"fig6a_ehj_P{parts}_write_round_reduction", us,
                         round(1 - w_r / w_s, 4)))
        rows_out.append((f"fig6a_ehj_P{parts}_sim_latency_reduction", 0.0,
                         round(1 - lat_r / lat_s, 4)))
    return rows_out


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
