"""Paper Table IV: optimal EMS fan-in k*(alpha).

Derived value: number of mismatches vs the published row (target 0).
"""

from __future__ import annotations

from repro.core.policies import ems_kopt
from benchmarks.common import Row, timed

PAPER_TABLE_IV = {1e-9: 4, 1: 5, 4: 8, 16: 17, 64: 43, 256: 126, 1024: 396}


def run() -> list[Row]:
    def solve():
        return {a: ems_kopt(a) for a in PAPER_TABLE_IV}

    us, got = timed(solve)
    mism = sum(1 for a, k in PAPER_TABLE_IV.items() if got[a] != k)
    return [("table4_kopt_7cells_mismatches", us, mism)]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
