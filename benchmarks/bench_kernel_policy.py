"""TPU-side REMOP policies (DESIGN.md §3): planner quality + kernel checks.

Derived values:
  * matmul tiles (BNLJ analogue): DMA-round reduction and L-cost reduction of
    the REMOP plan vs the volume-minimizing conventional plan, across LLM
    matmul shapes;
  * KV paging (decode): L-cost reduction of the planned page vs 1-token rows;
  * grad-bucket plan: exposed-comm reduction vs per-tensor all-reduce;
  * dispatch staging (EHJ analogue): a2a round reduction at the waterfill
    staging pool vs a minimal pool.

us_per_call times the *planning* call (these run inside the compile path).
"""

from __future__ import annotations

import statistics

from repro.core.cost_model import TPU_V5E
from repro.core.planner import (conventional_matmul_tiles, plan_dispatch,
                                plan_grad_buckets, plan_kv_pages,
                                plan_matmul_tiles)
from benchmarks.common import Row, timed

LLM_MATMULS = [
    # (m, k, n): token-block x weight shapes from the assigned archs
    (4096, 3072, 24576),   # gemma-7b ffn up
    (4096, 6144, 24576),   # granite-20b ffn up
    (8192, 2048, 2048),    # deepseek qkv-ish
    (4096, 1024, 151936),  # qwen3 unembed
    (16384, 2048, 1408),   # deepseek expert matmul
]


def run() -> list[Row]:
    rows: list[Row] = []
    c_reds, l_reds = [], []
    for m, k, n in LLM_MATMULS:
        def plan(m=m, n=n, k=k):
            return plan_matmul_tiles(m, n, k, in_bytes=2)

        us, remop = timed(plan)
        conv = conventional_matmul_tiles(m, n, k, in_bytes=2)
        c_reds.append(1 - remop.c_rounds / conv.c_rounds)
        l_reds.append(1 - remop.l_cost / conv.l_cost)
    rows.append(("tpu_matmul_mean_dma_round_reduction", us,
                 round(statistics.mean(c_reds), 4)))
    rows.append(("tpu_matmul_mean_Lcost_reduction", 0.0,
                 round(statistics.mean(l_reds), 4)))

    def kv():
        return plan_kv_pages(context_len=32768, kv_heads=1, head_dim=128)

    us, plan = timed(kv)
    tiny = 2.0 * 32768 * 1 * 128 * 2 + TPU_V5E.tau_dma_bytes * 2.0 * 32768
    rows.append(("tpu_kv_page_tokens", us, plan.page_tokens))
    rows.append(("tpu_kv_Lcost_reduction_vs_row_rounds", 0.0,
                 round(1 - plan.l_cost / tiny, 4)))

    def buckets():
        return plan_grad_buckets(total_grad_bytes=2 * 10 ** 9,
                                 backward_seconds=0.050, group_size=16)

    us, bp = timed(buckets)
    per_tensor = plan_grad_buckets(2 * 10 ** 9, 0.050, 16, max_buckets=256)
    naive = 400  # one all-reduce per parameter tensor (~400 tensors)
    exposed_naive = None
    # evaluate naive exposed via the same model
    ring = 2.0 * 15 / 16
    comm = ring * 2e9 / TPU_V5E.ici_bandwidth + naive * TPU_V5E.collective_launch_s
    tail = ring * (2e9 / naive) / TPU_V5E.ici_bandwidth + TPU_V5E.collective_launch_s
    exposed_naive = max(comm - 0.050, 0.0) + tail
    rows.append(("tpu_grad_buckets_n", us, bp.n_buckets))
    rows.append(("tpu_grad_buckets_exposed_reduction_vs_per_tensor", 0.0,
                 round(1 - bp.exposed_seconds / exposed_naive, 4)))

    def dispatch():
        return plan_dispatch(tokens_per_device=65536, token_bytes=4096,
                             experts=64, ep_degree=16,
                             buffer_budget=64 * 2 ** 20)

    us, dp = timed(dispatch)
    starved = plan_dispatch(65536, 4096, 64, 16, buffer_budget=3 * 4096)
    rows.append(("tpu_dispatch_a2a_rounds", us, round(dp.a2a_rounds, 1)))
    rows.append(("tpu_dispatch_round_reduction_vs_starved", 0.0,
                 round(1 - dp.a2a_rounds / starved.a2a_rounds, 4)))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
