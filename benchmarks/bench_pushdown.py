"""Operator pushdown: ship-pages vs. ship-compute across the sweep grid.

One BNLJ query (R |><| sel(S), probe-side filter physicalized by the plan
frontend) swept over **selectivity x page budget x tier compute speed** on a
dram/remote hierarchy whose remote tier is compute-capable.  Per sweep point
the *same* seeded data runs twice:

  * **arb**: the default path — the arbiter prices ship-the-pages against
    ship-the-compute per (budget, tier) and realizes its verdict through
    the operator's pushdown kwargs.
  * **ship**: the forced baseline — ``pushdown=False`` as an explicit task
    option wins over the arbiter's kwargs, so every probe page crosses the
    wire and the filter runs locally.  Outputs are identical either way.

Acceptance gates of the pushdown ISSUE, computed into the artifact:

  * ``never_worse``: measured Eq.-(1) latency of ``arb`` is never above
    ``ship`` at any sweep point (ties allowed — the chooser ships on ties).
  * ``capable_strict``: on the compute-fast tier at selectivity < 1 the
    ``arb`` run is *strictly* faster (volume saved beats tier compute).
  * ``crossover_declines``: the compute-slow row (compute below the tier's
    wire rate in pages/s) declines pushdown — verdict ``ship``, zero
    ``c_pushdown``, latency exactly equal to the forced baseline.
  * ``closed_form_exact``: on every capable test tier the closed forms
    (``pushdown_costs`` / ``pushdown_reduce_costs``) match the simulated
    ledger delta field-for-field (D shipped, C rounds, ``c_pushdown``,
    ``d_pushdown_saved``).

Writes ``BENCH_pushdown.json`` at the repo root, gated by
``scripts/check_regression.py`` in CI like the other BENCH artifacts.
"""

from __future__ import annotations

import json
import math
import os
import time
from typing import List, Optional

from repro.core import TABLE_I
from repro.core.cost_model import TierLevel, hierarchy_spec
from repro.core.policies import pushdown_costs, pushdown_reduce_costs
from repro.engine import Session
from repro.engine.plan import LogicalPlan, compile_plan
from repro.engine.scheduler import TransferScheduler
from repro.remote import MemoryHierarchy, make_relation
from benchmarks.common import Row

ROWS = 8
DOMAIN = 64
SIZE_R = 30  # outer pages
SIZE_S = 50  # inner (probe/filtered) pages
SELECTIVITIES = [0.25, 0.5, 1.0]
BUDGETS = [16.0, 24.0, 32.0]

# Compute-speed axis for the remote tier.  The RDMA wire moves
# bandwidth/page_bytes ~ 25.9k pages/s, so 200k pps is comfortably faster
# than shipping (pushdown can win) and 2k pps is slower (the arbiter must
# decline: scanning at the tier costs more than the trip it saves).
SPEEDS = [
    ("fast", 200_000.0),
    ("slow", 2_000.0),
    ("none", None),
]

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_pushdown.json")


def _target(compute_pps: Optional[float]):
    remote = TierLevel(
        tier=TABLE_I["rdma"], capacity_pages=4096.0,
        compute_pps=compute_pps,
        pushdown_ops=("filter", "reduce") if compute_pps else (),
    )
    # dram too small to host the join spill: placement lands on the remote
    # tier, so the verdict is priced where the probe pages actually live.
    return hierarchy_spec((TABLE_I["dram"], 4.0), remote)


def _build(sess: Session, sel: float, *, force_ship: bool):
    r = make_relation(sess.remote, SIZE_R * ROWS, ROWS, DOMAIN, seed=11,
                      tier="rdma")
    s = make_relation(sess.remote, SIZE_S * ROWS, ROWS, DOMAIN, seed=12,
                      tier="rdma")
    lp = LogicalPlan("pushdown")
    r_n = lp.scan("R", r, rows_per_page=ROWS)
    s_n = lp.filter(lp.scan("S", s, rows_per_page=ROWS), sel, name="sel_s")
    opts = {"pushdown": False} if force_ship else {}
    lp.join(r_n, s_n, out_pages=20.0, name="J", selectivity=0.4, **opts)
    return lp


def _run(compute_pps: Optional[float], sel: float, budget: float,
         *, force_ship: bool):
    sess = Session(_target(compute_pps), budget=budget)
    cp = compile_plan(sess, _build(sess, sel, force_ship=force_ship),
                      join_op="bnlj")
    verdict = None
    for row in cp.explain(sess).tasks:
        ch = getattr(row, "pushdown", None)
        if ch is not None:
            verdict = ch.mode
    res = cp.run(sess)
    snap = sess.remote.snapshot()
    return {
        "latency": res.latency_seconds(),
        "verdict": verdict,
        "c_pushdown": snap.c_pushdown,
        "d_pushdown_saved": snap.d_pushdown_saved,
        "output_rows": res.per_task[-1].result.output_rows,
    }


def _sweep(report: dict) -> None:
    never_worse = True
    capable_strict = True
    crossover_ok = True
    for speed_name, pps in SPEEDS:
        for sel in SELECTIVITIES:
            for budget in BUDGETS:
                arb = _run(pps, sel, budget, force_ship=False)
                ship = _run(pps, sel, budget, force_ship=True)
                if arb["output_rows"] != ship["output_rows"]:
                    raise AssertionError(
                        f"pushdown changed the join output at "
                        f"speed={speed_name} sel={sel} M={budget}"
                    )
                point = {
                    "speed": speed_name, "selectivity": sel, "budget": budget,
                    "verdict": arb["verdict"],
                    "c_pushdown": arb["c_pushdown"],
                    "d_pushdown_saved": arb["d_pushdown_saved"],
                    "simulated_seconds": {
                        "arb": arb["latency"], "ship": ship["latency"],
                    },
                }
                report["points"].append(point)
                if arb["latency"] > ship["latency"] * (1 + 1e-9):
                    never_worse = False
                if speed_name == "fast" and sel < 1.0:
                    if not (arb["verdict"] == "push"
                            and arb["latency"] < ship["latency"] * (1 - 1e-9)):
                        capable_strict = False
                if speed_name == "slow":
                    if not (arb["verdict"] == "ship"
                            and arb["c_pushdown"] == 0
                            and math.isclose(arb["latency"], ship["latency"],
                                             rel_tol=1e-12)):
                        crossover_ok = False
    report["never_worse"] = never_worse
    report["capable_strict"] = capable_strict
    report["crossover_declines"] = crossover_ok


# Capable test tiers for the closed-form exactness gate: the sweep's fast
# and slow remote tiers plus a TCP tier with a very different tau.
EXACT_TIERS = [
    ("rdma_fast", TABLE_I["rdma"], 200_000.0),
    ("rdma_slow", TABLE_I["rdma"], 2_000.0),
    ("tcp_fast", TABLE_I["tcp"], 200_000.0),
]


def _exactness(report: dict) -> None:
    """Closed form vs. simulated ledger, field-for-field, per test tier."""
    all_exact = True
    for tag, tier, pps in EXACT_TIERS:
        level = TierLevel(tier=tier, capacity_pages=4096.0, compute_pps=pps,
                          pushdown_ops=("filter", "reduce"))
        hier = MemoryHierarchy(hierarchy_spec((TABLE_I["dram"], 4.0), level))
        rel = make_relation(hier, SIZE_S * ROWS, ROWS, DOMAIN, seed=21,
                            tier=tier.name)
        sched = TransferScheduler(hier)

        before = sched.snapshot()
        sched.read_filtered(rel.page_ids, selectivity=0.4, batch_pages=7)
        delta = sched.delta(before)
        pc = pushdown_costs(SIZE_S, 0.4, level, batch_pages=7)
        filt_exact = (
            delta.d_read == pc.d_ship
            and delta.c_read == pc.c_rounds
            and delta.c_pushdown == pc.c_rounds
            and delta.d_pushdown == pc.d_ship
            and delta.d_pushdown_saved == pc.d_saved
        )

        before = sched.snapshot()
        out_pages = hier.read_reduced(
            tier.name, rel.page_ids,
            lambda pages: pages[0][:2], ROWS,
        )
        delta = sched.delta(before)
        pr = pushdown_reduce_costs(SIZE_S, float(len(out_pages)), level)
        red_exact = (
            delta.d_read == pr.d_ship
            and delta.c_read == pr.c_rounds
            and delta.c_pushdown == pr.c_rounds
            and delta.d_pushdown == pr.d_ship
            and delta.d_pushdown_saved == pr.d_saved
        )

        all_exact = all_exact and filt_exact and red_exact
        report["exactness"].append({
            "name": tag, "filter_exact": filt_exact, "reduce_exact": red_exact,
            "d_pushdown": delta.d_pushdown, "c_pushdown": delta.c_pushdown,
        })
    report["closed_form_exact"] = all_exact


def run() -> List[Row]:
    t0 = time.perf_counter()
    report = {
        "schema": 1, "selectivities": SELECTIVITIES, "budgets": BUDGETS,
        "speeds": [s for s, _ in SPEEDS], "points": [], "exactness": [],
    }
    _sweep(report)
    _exactness(report)
    us = (time.perf_counter() - t0) * 1e6
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    fast = [p for p in report["points"]
            if p["speed"] == "fast" and p["selectivity"] < 1.0]
    best = max(
        1 - p["simulated_seconds"]["arb"] / p["simulated_seconds"]["ship"]
        for p in fast
    )
    gates_pass = (report["never_worse"] and report["capable_strict"]
                  and report["crossover_declines"]
                  and report["closed_form_exact"])
    return [
        ("pushdown_arb_best_latency_reduction_vs_ship", us, round(best, 4)),
        ("pushdown_gates_pass", us, float(gates_pass)),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
