"""TPC-H-shaped flagship: logical plans vs hand-wired left-deep chains.

Three query skeletons over synthetic relations sharing one key domain —
Q3 (3-way join + group-by + order-by), Q9 (4-way join + group-by) and
Q18 (join of an aggregate subquery + order-by) — each swept over tight page
budgets on the dram/rdma/ssd hierarchy.  Per sweep point two executions of
the *same* seeded data are compared:

  * **serial**: the hand-wired baseline — ``compile_plan(optimize=False)``
    keeps the SQL-order (as-written) left-deep join chain and
    ``session.run`` executes it as a flat list, exactly the PR 5 surface a
    user would wire by hand (a linear chain reproduces those ledgers
    byte-for-byte; ``tests/test_plan_dag.py`` pins that).
  * **dag**: the frontend — ``compile_plan`` costs the bounded join-order
    candidate set with the arbiter's own closed forms, and
    ``session.run(schedule="dag", replan="measured")`` overlaps ready tasks
    from independent subtrees and re-arbitrates the remaining frontier on
    every finish.

The acceptance gate of ISSUE 7 is computed into the artifact: ``dag`` must
be no worse than ``serial`` at every sweep point (``dag_no_worse``) and
strictly better on at least half (``strict_wins``/``points``) — wins come
from cheaper join orders (smaller build sides, smaller intermediates) and
from inter-operator parallelism (Q18's aggregate subquery overlaps the
customer-orders join).  Writes ``BENCH_tpch.json`` at the repo root, gated
by ``scripts/check_regression.py`` in CI like the other BENCH artifacts.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, List, Tuple

from repro.core import TABLE_I
from repro.engine import Session
from repro.engine.plan import LogicalPlan, compile_plan
from repro.engine.registry import hierarchy_spec
from repro.remote import make_relation
from benchmarks.common import Row

ROWS = 8  # rows per page
DOMAIN = 192  # shared join-key domain of every synthetic relation
BUDGETS = [48.0, 64.0, 96.0]
# Re-arbitrate the remaining frontier only on >10% cardinality misestimates
# (the filter-pushdown estimates are the honest ones to react to); reacting
# to single-digit noise can lock in a marginally worse tail plan.
REPLAN_THRESHOLD = 0.1

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_tpch.json")


def _target():
    return hierarchy_spec(
        (TABLE_I["dram"], 64), (TABLE_I["rdma"], 512), TABLE_I["ssd"])


# --------------------------------------------------------------------------
# Query skeletons: seed relations into the session, build the logical plan.
# Each is written in naive SQL FROM order (biggest table first), so the
# as-written left-deep chain is the honest hand-wired baseline.
# --------------------------------------------------------------------------


def _q3(sess: Session) -> LogicalPlan:
    """Q3 skeleton: lineitem |><| orders |><| customer -> group-by -> sort."""
    li = make_relation(sess.remote, 96 * ROWS, ROWS, DOMAIN, seed=71)
    o = make_relation(sess.remote, 48 * ROWS, ROWS, DOMAIN, seed=72)
    c = make_relation(sess.remote, 24 * ROWS, ROWS, DOMAIN, seed=73)
    lp = LogicalPlan("q3")
    l_n = lp.scan("lineitem", li, rows_per_page=ROWS)
    o_n = lp.scan("orders", o, rows_per_page=ROWS)
    c_n = lp.filter(lp.scan("customer", c, rows_per_page=ROWS), 0.5)
    j = lp.join(lp.join(l_n, o_n, out_pages=192.0), c_n, out_pages=192.0,
                sigma=0.5, partitions=8)
    lp.sort(lp.aggregate(j, out_pages=24.0, sigma=0.5, partitions=8), k_cap=8)
    return lp


def _q9(sess: Session) -> LogicalPlan:
    """Q9 skeleton: lineitem |><| part |><| supplier |><| orders -> group-by."""
    li = make_relation(sess.remote, 96 * ROWS, ROWS, DOMAIN, seed=81)
    p = make_relation(sess.remote, 16 * ROWS, ROWS, DOMAIN, seed=82)
    s = make_relation(sess.remote, 12 * ROWS, ROWS, DOMAIN, seed=83)
    o = make_relation(sess.remote, 48 * ROWS, ROWS, DOMAIN, seed=84)
    lp = LogicalPlan("q9")
    l_n = lp.scan("lineitem", li, rows_per_page=ROWS)
    p_n = lp.scan("part", p, rows_per_page=ROWS)
    s_n = lp.scan("supplier", s, rows_per_page=ROWS)
    o_n = lp.scan("orders", o, rows_per_page=ROWS)
    j = lp.join(
        lp.join(lp.join(l_n, p_n, out_pages=64.0), s_n, out_pages=32.0),
        o_n, out_pages=64.0, sigma=0.5, partitions=8,
    )
    lp.aggregate(j, out_pages=24.0, sigma=0.5, partitions=8)
    return lp


def _q18(sess: Session) -> LogicalPlan:
    """Q18 skeleton: (customer |><| orders) |><| agg(lineitem) -> sort."""
    c = make_relation(sess.remote, 24 * ROWS, ROWS, DOMAIN, seed=91)
    o = make_relation(sess.remote, 48 * ROWS, ROWS, DOMAIN, seed=92)
    li = make_relation(sess.remote, 96 * ROWS, ROWS, DOMAIN, seed=93)
    lp = LogicalPlan("q18")
    c_n = lp.scan("customer", c, rows_per_page=ROWS)
    o_n = lp.scan("orders", o, rows_per_page=ROWS)
    big = lp.aggregate(lp.scan("lineitem", li, rows_per_page=ROWS),
                       out_pages=24.0, sigma=0.5, partitions=8)
    j = lp.join(lp.join(c_n, o_n, out_pages=48.0), big, out_pages=48.0,
                sigma=0.5, partitions=8)
    lp.sort(j, k_cap=8)
    return lp


QUERIES: List[Tuple[str, Callable[[Session], LogicalPlan]]] = [
    ("q3", _q3),
    ("q9", _q9),
    ("q18", _q18),
]


# --------------------------------------------------------------------------
# One sweep point: same seeded data, serial baseline vs DAG-scheduled plan.
# --------------------------------------------------------------------------


def _point(build: Callable[[Session], LogicalPlan], budget: float):
    serial_sess = Session(_target(), budget=budget)
    cp0 = compile_plan(serial_sess, build(serial_sess), optimize=False)
    res_serial = cp0.run(serial_sess, schedule="serial", replan=None)

    dag_sess = Session(_target(), budget=budget)
    cp = compile_plan(dag_sess, build(dag_sess), optimize=True)
    res_dag = cp.run(dag_sess, replan="measured",
                     replan_threshold=REPLAN_THRESHOLD)

    return cp, {
        "budget": budget,
        "simulated_seconds": {
            "serial": res_serial.latency_seconds(),
            "dag": res_dag.makespan_seconds,
        },
        "replan_events": len(res_dag.replan_events),
        "tasks": {"serial": len(cp0.tasks), "dag": len(cp.tasks)},
    }


def run() -> List[Row]:
    rows_out: List[Row] = []
    report = {"schema": 1, "budgets": BUDGETS,
              "replan_threshold": REPLAN_THRESHOLD, "queries": [],
              "points": 0, "strict_wins": 0, "dag_no_worse": True}
    for name, build in QUERIES:
        t0 = time.perf_counter()
        sweep = []
        cp = None
        for budget in BUDGETS:
            cp, point = _point(build, budget)
            sweep.append(point)
        us = (time.perf_counter() - t0) * 1e6
        wins = sum(
            1 for pt in sweep
            if pt["simulated_seconds"]["dag"]
            < pt["simulated_seconds"]["serial"] * (1 - 1e-9)
        )
        no_worse = all(
            pt["simulated_seconds"]["dag"]
            <= pt["simulated_seconds"]["serial"] * (1 + 1e-9)
            for pt in sweep
        )
        report["points"] += len(sweep)
        report["strict_wins"] += wins
        report["dag_no_worse"] = report["dag_no_worse"] and no_worse
        best = max(
            1 - pt["simulated_seconds"]["dag"] / pt["simulated_seconds"]["serial"]
            for pt in sweep
        )
        rows_out.append((f"tpch_{name}_dag_best_latency_reduction_vs_serial",
                         us, round(best, 4)))
        report["queries"].append({
            "name": name,
            "sweep": sweep,
            "join_choices": [
                {
                    "cluster": jc.cluster,
                    "chosen": jc.chosen,
                    "chosen_cost": jc.chosen_cost,
                    "left_deep_cost": jc.left_deep_cost,
                    "candidates": [list(c) for c in jc.candidates],
                }
                for jc in cp.join_choices
            ],
        })
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows_out


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
