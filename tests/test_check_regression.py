"""The CI regression gate must never gate wall-clock measurements.

``scripts/check_regression.py`` compares committed ``BENCH_*.json`` baselines
against fresh runs, but only over *deterministic simulator outputs* — the
``modeled_latency`` / ``simulated_seconds`` / ``latency_cost`` keys.  The
execution backend now writes measured ``wall_seconds`` (and the bench harness
has always written ``us_per_call``) next to those numbers; both vary with the
CI machine, so a 10x wall-clock swing must sail through while a 1% simulated
regression still fails.  These tests pin that boundary.
"""

import importlib.util
import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_regression", REPO_ROOT / "scripts" / "check_regression.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


checker = _load_checker()


def _metrics(payload) -> dict:
    return dict(checker._walk(payload))


def test_metric_keys_are_exactly_the_three_simulated_ones():
    assert tuple(sorted(checker.METRIC_KEYS)) == (
        "latency_cost", "modeled_latency", "simulated_seconds",
    )
    for wall_key in ("wall_seconds", "us_per_call"):
        assert wall_key not in checker.METRIC_KEYS


def test_wall_clock_keys_are_never_walked():
    payload = {
        "rows": [
            {
                "name": "ems/backend",
                "us_per_call": 1234.5,
                "wall_seconds": 9.87,
                "derived": {
                    "simulated_seconds": 0.5,
                    "wall_seconds": 11.0,
                    "wall": {"kernel_seconds": 3.0, "us_per_call": 7.0},
                },
            }
        ],
        "wall_seconds": 42.0,
    }
    metrics = _metrics(payload)
    assert metrics == {"rows[ems/backend].derived.simulated_seconds": 0.5}


def test_wall_clock_regression_passes_while_simulated_fails(tmp_path, capsys):
    def bench(simulated, wall):
        return {"rows": [{"name": "x", "derived": {
            "simulated_seconds": simulated, "wall_seconds": wall}}]}

    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps(bench(1.0, 1.0)))

    # 10x wall-clock growth, simulated flat: the gate must pass.
    cur.write_text(json.dumps(bench(1.0, 10.0)))
    assert checker.main([str(base), str(cur)]) == 0

    # Simulated +20% beyond the 10% threshold: the gate must fail.
    cur.write_text(json.dumps(bench(1.2, 1.0)))
    assert checker.main([str(base), str(cur)]) == 1
    err = capsys.readouterr().err
    assert "simulated_seconds" in err
    assert "wall_seconds" not in err


def test_nested_metric_subtrees_still_gated():
    # Everything *under* a gated key is gated (per-tier latency splits), but
    # a wall_seconds sibling inside that subtree is a leaf under the gated
    # key and therefore gated too — wall keys must stay out of gated trees.
    payload = {"latency_cost": {"dram": 1.0, "ssd": 2.0}}
    assert _metrics(payload) == {
        "latency_cost.dram": 1.0, "latency_cost.ssd": 2.0,
    }
