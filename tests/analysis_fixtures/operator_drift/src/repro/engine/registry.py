"""Fixture registry: one correct-looking registration of a drifted module."""

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class WorkloadStats:
    size_r: float = 0.0
    size_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class OperatorSpec:
    name: str
    run: object
    inputs: tuple
    input_stats: dict
    streams: tuple


_REGISTRY = {}


def register(spec):
    _REGISTRY[spec.name] = spec


def _ensure_builtin():
    bnlj_mod = importlib.import_module("repro.remote.bnlj")
    register(OperatorSpec(
        name="bnlj",
        run=bnlj_mod.bnlj,
        inputs=bnlj_mod.INPUTS,
        input_stats=bnlj_mod.INPUT_STATS,
        streams=bnlj_mod.STREAMS,
    ))
