"""Fixture operator whose run signature dropped a declared input.

INPUTS declares ("outer", "inner") but the run function only binds
``outer`` — the analyzer must report exactly one OPS204 finding at its
definition line.
"""

INPUTS = ("outer", "inner")
INPUT_STATS = {"outer": "size_r", "inner": "size_s"}
STREAMS = ()


def bnlj(store, outer, plan):  # seeded: "inner" missing from the signature
    return None
