"""Fixture test file (named check_* so the real pytest run skips it)."""

from repro.core.policies import covered_latency


def check_covered():
    assert covered_latency(1.0, 2.0, 0.5) == 2.0
