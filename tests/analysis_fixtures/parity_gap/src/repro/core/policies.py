"""Fixture: one witnessed closed form, one without a test (seeded PAR401)."""


def covered_latency(d, c, tau):
    return d + tau * c


def lonely_latency(d, c, tau):  # seeded: no test references this
    return d + tau * c
