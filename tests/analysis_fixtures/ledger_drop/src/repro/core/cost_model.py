"""Fixture: a complete two-counter ledger whose snapshot() drops c_read.

Every other carry site (delta, merge, reset, __add__, to_dict,
latency_seconds) is complete, so the analyzer must report exactly one
LED102 finding at the snapshot definition.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class LedgerSnapshot:
    d_read: float = 0.0
    c_read: int = 0

    def __add__(self, other):
        return LedgerSnapshot(
            d_read=self.d_read + other.d_read,
            c_read=self.c_read + other.c_read,
        )

    def to_dict(self):
        return {"d_read": self.d_read, "c_read": self.c_read}


@dataclasses.dataclass
class TransferLedger:
    d_read: float = 0.0
    c_read: int = 0

    def snapshot(self):
        return LedgerSnapshot(d_read=self.d_read)  # seeded: drops c_read

    def delta(self, since):
        return LedgerSnapshot(
            d_read=self.d_read - since.d_read,
            c_read=self.c_read - since.c_read,
        )

    def merge(self, other):
        self.d_read += other.d_read
        self.c_read += other.c_read

    def reset(self):
        self.d_read = 0.0
        self.c_read = 0

    def latency_seconds(self, tier):
        return 0.0
