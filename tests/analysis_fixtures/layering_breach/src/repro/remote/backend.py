"""Fixture: the wall-clock carve-out file.

``remote/backend.py`` may read the clock (that is its job), so the
``time.perf_counter`` call below must NOT be reported — but unseeded
randomness is still a LAY303 breach even here.
"""

import time

import numpy as np


def timed_noise():
    t0 = time.perf_counter()  # allowed: backend carve-out
    rng = np.random.default_rng()  # seeded: unseeded RNG still flagged
    return rng.random(), time.perf_counter() - t0
