"""Fixture: wall-clock read on a simulator path (seeded LAY303)."""

import time


def stamp():
    return time.time()  # seeded: nondeterministic call
