"""Fixture: ledger mutation outside the data plane (seeded LAY302)."""


def sneak(store, pages):
    store.ledger.read(pages)  # seeded: direct mutation
