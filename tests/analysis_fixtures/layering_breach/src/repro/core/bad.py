"""Fixture: core/ reaching up into the engine layer (seeded LAY301)."""

from repro.engine import registry  # seeded: upward import


def peek():
    return registry
