"""Parity witnesses for the closed forms PAR401 found untested.

Each public name in ``core/policies.py`` must be proven, not just exported:
the smooth optimizer models against their exact (ceil-based) counterparts on
divisibility-friendly inputs where the two coincide, the phase-coefficient /
latency forms against their Table V/VI definitions, and the pushdown costs
ledger-for-ledger against the simulator's compute-capable tiers.
"""

import math

import numpy as np
import pytest

from repro.core.cost_model import HierarchySpec, TierLevel, TierSpec
from repro.core.policies import (
    BNLJPlan,
    EAggPlan,
    EHJPlan,
    EMSPlan,
    PushdownChoice,
    PushdownCosts,
    bnlj_costs,
    bnlj_costs_exact,
    bnlj_latency,
    eagg_data_costs,
    eagg_latency,
    eagg_optimal_round_costs,
    eagg_phase_coeffs,
    eagg_plan,
    eagg_round_costs,
    ehj_data_costs,
    ehj_latency,
    ehj_optimal_round_costs,
    ehj_phase_coeffs,
    ehj_plan,
    ehj_round_costs,
    ems_costs,
    ems_costs_exact,
    ems_latency,
    ems_passes,
    ems_run_formation_costs,
    ems_total_costs,
    pushdown_costs,
    pushdown_or_ship,
    pushdown_reduce_costs,
)
from repro.remote.simulator import MemoryHierarchy

TAU = 3.5


def _compute_level(pps: float = 1e6) -> TierLevel:
    return TierLevel(
        TierSpec("rdma", bandwidth=6.8e9, rtt=1e-6),
        compute_pps=pps,
        pushdown_ops=frozenset({"filter", "reduce"}),
    )


# -- BNLJ --------------------------------------------------------------------


def test_bnlj_costs_match_exact_on_divisible_sizes():
    # m=40, r_in=0.75 -> 30 input pages; p_r=2/3 -> P_R=20, P_S=10, R_out=10.
    plan = BNLJPlan(m=40.0, r_in=0.75, p_r=2.0 / 3.0)
    assert plan.outer_pages == pytest.approx(20.0)
    assert plan.output_pages == pytest.approx(10.0)
    # All block counts divide evenly, so smooth == exact.
    d, c = bnlj_costs(100.0, 50.0, 20.0, plan)
    d_x, c_x = bnlj_costs_exact(100, 50, 20.0, 20, 10, 10)
    assert d == pytest.approx(d_x) == pytest.approx(370.0)
    assert c == pytest.approx(c_x) == pytest.approx(32.0)


def test_bnlj_latency_is_definition_3():
    plan = BNLJPlan(m=40.0, r_in=0.75, p_r=2.0 / 3.0)
    d, c = bnlj_costs(100.0, 50.0, 20.0, plan)
    assert bnlj_latency(100.0, 50.0, 20.0, plan, TAU) == pytest.approx(
        d + TAU * c
    )


# -- EMS ---------------------------------------------------------------------


def test_ems_passes_log_k_of_runs():
    # 64 pages, 8-page memory -> 8 runs; fan-in 4 -> ceil(log4 8) = 2 passes.
    assert ems_passes(64.0, 8.0, 4) == 2
    assert ems_passes(8.0, 8.0, 4) == 0  # fits in memory: no merge
    assert ems_passes(64.0, 8.0, 8) == 1


def test_ems_costs_match_exact_on_divisible_split():
    # R_in = 4 pages over k=4 runs -> 1 page per run; R_out = 4.
    plan = EMSPlan(m=8.0, k=4, r_in=0.5)
    d, c, p = ems_costs(64.0, 8.0, plan)
    d_x, c_x, p_x = ems_costs_exact(64, 8, 4, 4)
    assert p == p_x == 2
    assert d == pytest.approx(d_x) == pytest.approx(256.0)
    assert c == pytest.approx(c_x) == pytest.approx(160.0)


def test_ems_latency_and_totals_compose():
    plan = EMSPlan(m=8.0, k=4, r_in=0.5)
    d_m, c_m, _ = ems_costs(64.0, 8.0, plan)
    assert ems_latency(64.0, 8.0, plan, TAU) == pytest.approx(d_m + TAU * c_m)
    d_rf, c_rf = ems_run_formation_costs(64.0, 8.0)
    d_t, c_t = ems_total_costs(64.0, 8.0, plan)
    assert d_t == pytest.approx(d_m + d_rf)
    assert c_t == pytest.approx(c_m + c_rf)


# -- EHJ ---------------------------------------------------------------------


def test_ehj_phase_coeffs_are_table_v_numerators():
    b, q, out, p, sigma = 100.0, 80.0, 40.0, 16, 0.25
    p1, p2, p3 = ehj_phase_coeffs(b, q, out, p, sigma)
    assert p1 == pytest.approx((b, sigma * sigma * p * b))
    assert p2 == pytest.approx((q, sigma * sigma * p * q, (1 - sigma) * out))
    assert p3 == pytest.approx((sigma * (b + q), sigma * out))


def test_ehj_plan_round_costs_match_table_vi_closed_forms():
    b, q, out, m_b, p, sigma = 100.0, 80.0, 40.0, 32.0, 16, 0.25
    plan = ehj_plan(b, q, out, m_b, p, sigma)
    assert isinstance(plan, EHJPlan)
    got = ehj_round_costs(b, q, out, plan)
    want = ehj_optimal_round_costs(b, q, out, m_b, p, sigma)
    assert got == pytest.approx(want)


def test_ehj_latency_is_definition_3():
    b, q, out, m_b, p, sigma = 100.0, 80.0, 40.0, 32.0, 16, 0.25
    plan = ehj_plan(b, q, out, m_b, p, sigma)
    d = sum(ehj_data_costs(b, q, out, sigma))
    c = sum(ehj_round_costs(b, q, out, plan))
    assert ehj_latency(b, q, out, plan, TAU) == pytest.approx(d + TAU * c)


# -- EAgg --------------------------------------------------------------------


def test_eagg_phase_coeffs_are_table_v_analogues():
    n, out, p, sigma = 120.0, 30.0, 8, 0.5
    p1, p2 = eagg_phase_coeffs(n, out, p, sigma)
    assert p1 == pytest.approx((n, sigma * sigma * p * n, (1 - sigma) * out))
    assert p2 == pytest.approx((sigma * n, sigma * out))


def test_eagg_plan_round_costs_match_closed_forms():
    n, out, m_b, p, sigma = 120.0, 30.0, 24.0, 8, 0.5
    plan = eagg_plan(n, out, m_b, p, sigma)
    assert isinstance(plan, EAggPlan)
    got = eagg_round_costs(n, out, plan)
    want = eagg_optimal_round_costs(n, out, m_b, p, sigma)
    assert got == pytest.approx(want)


def test_eagg_latency_is_definition_3():
    n, out, m_b, p, sigma = 120.0, 30.0, 24.0, 8, 0.5
    plan = eagg_plan(n, out, m_b, p, sigma)
    d = sum(eagg_data_costs(n, out, sigma))
    c = sum(eagg_round_costs(n, out, plan))
    assert eagg_latency(n, out, plan, TAU) == pytest.approx(d + TAU * c)


# -- Pushdown ----------------------------------------------------------------


def test_pushdown_costs_match_simulator_ledger():
    level = _compute_level()
    hier = MemoryHierarchy(HierarchySpec(levels=(level,)))
    n, sel, batch = 100, 0.3, 25
    ids = hier.write_batch(
        [np.full((4,), i, dtype=np.float32) for i in range(n)], tier=0
    )
    before = hier.tiers[0].ledger.snapshot()
    hier.scan_filtered(0, ids, selectivity=sel, batch_pages=batch)
    delta = hier.tiers[0].ledger.delta(before)

    pc = pushdown_costs(n, sel, level, batch_pages=batch)
    assert isinstance(pc, PushdownCosts)
    assert delta.d_pushdown == pytest.approx(pc.d_ship) == pytest.approx(30.0)
    assert delta.c_pushdown == pc.c_rounds == 4
    assert delta.d_pushdown_saved == pytest.approx(pc.d_saved)
    assert delta.d_pushdown_scanned == pytest.approx(pc.scanned)
    assert pc.latency_cost(TAU) == pytest.approx(
        pc.d_ship + TAU * pc.c_rounds + pc.compute_l
    )


def test_pushdown_reduce_costs_ship_one_round():
    pc = pushdown_reduce_costs(50, 2.0, _compute_level())
    assert (pc.d_ship, pc.c_rounds, pc.scanned) == (2.0, 1, 50.0)
    assert pc.d_saved == pytest.approx(48.0)


def test_pushdown_or_ship_arbitration():
    fast = _compute_level(pps=1e9)
    choice = pushdown_or_ship(100, 0.1, fast, tau=TAU, batch_pages=25)
    assert isinstance(choice, PushdownChoice)
    assert choice.push and choice.mode == "push"
    assert choice.l_push < choice.l_ship
    assert choice.l_delta <= 0.0
    assert choice.c_pushdown == 4

    # A tier with no compute capability always ships.
    bare = TierLevel(TierSpec("ssd", bandwidth=0.53e9, rtt=100e-6))
    ship = pushdown_or_ship(100, 0.1, bare, tau=TAU, batch_pages=25)
    assert not ship.push and ship.mode == "ship"
    assert math.isinf(ship.l_push)
    assert ship.d_saved == 0.0 and ship.c_pushdown == 0
