"""External hash aggregation: oracle correctness + exact D/C ledger parity.

The headline contract (ISSUE 2 acceptance): eagg's *simulated* transfer
ledger matches the ceil-exact closed form ``eagg_costs_exact`` on every
Table I / TESTBED tier, including skewed partition sizes, and tracks the
smooth Property-6 round-count closed forms.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TABLE_I, TESTBED
from repro.core.policies import (
    eagg_costs_exact,
    eagg_data_costs,
    eagg_optimal_round_costs,
    eagg_plan,
    eagg_round_costs,
    eagg_starved,
)
from repro.engine import WorkloadStats, plan_operator
from repro.remote import RemoteMemory, Relation, eagg, eagg_oracle
from repro.remote.eagg import _hash_part

TIER = TESTBED["remon_tcp"]
ROWS = 8
_TIERS = list(TABLE_I.values()) + list(TESTBED.values())


def _mk_relation(remote, n_pages, domain, seed=0, skew=0.0):
    """Relation with optionally Zipf-skewed keys (skew > 0 concentrates mass)."""
    rng = np.random.default_rng(seed)
    n_rows = n_pages * ROWS
    if skew > 0.0:
        ranks = rng.zipf(1.0 + skew, size=n_rows).astype(np.int64)
        keys = np.minimum(ranks - 1, domain - 1)
    else:
        keys = rng.integers(0, domain, size=n_rows, dtype=np.int64)
    payload = np.arange(n_rows, dtype=np.int64)
    rows = np.stack([keys, payload], axis=1)
    pages = [rows[i : i + ROWS] for i in range(0, n_rows, ROWS)]
    ids = remote.put_local(pages)
    return Relation(page_ids=ids, rows_per_page=ROWS, total_rows=n_rows)


def _exact_inputs(remote, rel, plan):
    """Recompute the skew-aware workload detail eagg_costs_exact needs."""
    rows = np.concatenate(remote.peek_batch(rel.page_ids), axis=0)
    parts = _hash_part(rows[:, 0], plan.partitions)
    n_spilled = int(round(plan.sigma * plan.partitions))
    spilled = list(range(plan.partitions - n_spilled, plan.partitions))
    spilled_rows = [int((parts == q).sum()) for q in spilled]
    spill_mask = np.isin(parts, spilled)
    resident_groups = len(np.unique(rows[~spill_mask][:, 0]))
    spilled_groups = len(np.unique(rows[spill_mask][:, 0]))
    return spilled_rows, resident_groups, spilled_groups


def test_eagg_output_matches_oracle():
    remote = RemoteMemory(TIER)
    rel = _mk_relation(remote, 120, 96, seed=1)
    plan = eagg_plan(n=120, out=12, m_b=16, partitions=8, sigma=0.5)
    res = eagg(remote, rel, plan)
    want = eagg_oracle(remote, rel)
    got = np.concatenate(remote.peek_batch(res.output_page_ids), axis=0)
    got = got[np.argsort(got[:, 0], kind="stable")]
    assert res.group_rows == len(want)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("tier", _TIERS, ids=[t.name for t in _TIERS])
@pytest.mark.parametrize("skew", [0.0, 1.2], ids=["uniform", "zipf"])
def test_eagg_ledger_matches_exact_closed_form_on_all_tiers(tier, skew):
    """Acceptance: simulated ledger == ceil-exact D/C on every tier, skew incl."""
    remote = RemoteMemory(tier)
    rel = _mk_relation(remote, 160, 512, seed=3, skew=skew)
    stats = WorkloadStats(size_r=160, out=32, partitions=16, sigma=0.5)
    plan = plan_operator("eagg", stats, tier, 20)
    res = eagg(remote, rel, plan)
    d_want, c_want = eagg_costs_exact(160, ROWS, *_exact_inputs(remote, rel, plan),
                                      plan)
    assert res.d_read + res.d_write == d_want
    assert res.c_read + res.c_write == c_want


@settings(max_examples=8, deadline=None)
@given(
    n_pages=st.integers(40, 200), parts=st.sampled_from([4, 8, 16]),
    sigma=st.sampled_from([0.25, 0.5, 0.75]), skew=st.floats(0.0, 1.5),
    seed=st.integers(0, 99),
)
def test_eagg_correct_and_exact_for_any_plan(n_pages, parts, sigma, skew, seed):
    """Property: oracle-identical groups and exact ledger for arbitrary plans."""
    remote = RemoteMemory(TIER)
    rel = _mk_relation(remote, n_pages, 256, seed=seed, skew=skew)
    plan = eagg_plan(n=n_pages, out=n_pages / 8, m_b=12, partitions=parts,
                     sigma=sigma)
    res = eagg(remote, rel, plan)
    want = eagg_oracle(remote, rel)
    assert res.group_rows == len(want)
    got = np.concatenate(remote.peek_batch(res.output_page_ids), axis=0)
    got = got[np.argsort(got[:, 0], kind="stable")]
    np.testing.assert_array_equal(got, want)
    d_want, c_want = eagg_costs_exact(n_pages, ROWS,
                                      *_exact_inputs(remote, rel, plan), plan)
    assert res.d_read + res.d_write == d_want
    assert res.c_read + res.c_write == c_want


def test_eagg_smooth_round_closed_form_tracks_waterfill():
    """Property-6 algebra: waterfill allocation attains the C_i* closed forms."""
    n, out, m_b, parts, sigma = 160.0, 32.0, 20.0, 16, 0.5
    plan = eagg_plan(n, out, m_b, parts, sigma)
    c1, c2 = eagg_round_costs(n, out, plan)
    c1_star, c2_star = eagg_optimal_round_costs(n, out, m_b, parts, sigma)
    assert c1 == pytest.approx(c1_star, rel=1e-9)
    assert c2 == pytest.approx(c2_star, rel=1e-9)
    # And the starved baseline is strictly worse on both phases.
    starved = eagg_starved(m_b, parts, sigma)
    s1, s2 = eagg_round_costs(n, out, starved)
    assert s1 > c1 and s2 > c2


def test_eagg_measured_rounds_track_smooth_closed_form():
    """Simulated rounds within ceil-effect tolerance of the C* algebra.

    Budget and partition count are sized so the per-stream pool slices don't
    all floor to one page — at that point every policy degenerates and the
    smooth model no longer describes the engine's integer slicing.
    """
    remote = RemoteMemory(TIER)
    n_pages, out_pages = 320, 40
    rel = _mk_relation(remote, n_pages, out_pages * ROWS, seed=5)
    plan = eagg_plan(n_pages, out_pages, 32, 8, 0.5)
    res = eagg(remote, rel, plan)
    c_star = sum(eagg_optimal_round_costs(n_pages, out_pages, 32, 8, 0.5))
    assert res.c_read + res.c_write == pytest.approx(c_star, rel=0.2)
    d_star = sum(eagg_data_costs(n_pages, out_pages, 0.5))
    assert res.d_read + res.d_write == pytest.approx(d_star, rel=0.15)


def test_eagg_remop_beats_starved_in_rounds_and_latency():
    remote = RemoteMemory(TIER)
    rel = _mk_relation(remote, 200, 256, seed=7)
    stats = WorkloadStats(size_r=200, out=32, partitions=8, sigma=0.5)
    tau = TIER.tau_pages

    before = remote.ledger.latency_cost(tau)
    res_s = eagg(remote, rel, plan_operator("eagg", stats, TIER, 24,
                                            policy="conventional"))
    mid = remote.ledger.latency_cost(tau)
    res_r = eagg(remote, rel, plan_operator("eagg", stats, TIER, 24))
    after = remote.ledger.latency_cost(tau)
    assert res_r.group_rows == res_s.group_rows
    assert res_r.c_write < res_s.c_write
    assert after - mid < mid - before  # REMOP latency cost strictly lower
