"""Property tests for TransferLedger / hierarchy ledger invariants (ISSUE 3).

Uses ``hypothesis`` when installed (requirements-dev.txt); otherwise the
deterministic fallback in ``tests/conftest.py`` runs the same properties over
a fixed pseudo-random sample.  Invariants:

  * ``snapshot``/``delta`` round-trip: mid-run snapshot plus the delta since
    it reconstructs the live ledger exactly;
  * ``merge`` additivity: merging ledgers sums every counter;
  * ``latency_seconds(prefetch=True)`` never exceeds the unhidden latency;
  * per-tier hierarchy ledgers always sum to the hierarchy-wide totals.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import TABLE_I, TESTBED
from repro.core.cost_model import TransferLedger
from repro.remote import make_hierarchy

TIER = TESTBED["remon_tcp"]

# An op stream: positive n = one write round of n pages, negative n = one
# read round of |n| pages (marked prefetch-hidden when |n| is even and a
# read already happened — keeps c_prefetch_hidden <= c_read by construction).
op_streams = st.lists(st.integers(min_value=-8, max_value=8), min_size=0,
                      max_size=30)


def _apply(ledger: TransferLedger, ops) -> None:
    for n in ops:
        if n > 0:
            ledger.write(float(n))
        elif n < 0:
            ledger.read(float(-n))
            if n % 2 == 0 and ledger.c_read > 1:
                ledger.c_prefetch_hidden += 1


def _fields(snap):
    return (snap.d_read, snap.d_write, snap.c_read, snap.c_write,
            snap.c_prefetch_hidden)


@settings(max_examples=60, deadline=None)
@given(before=op_streams, after=op_streams)
def test_snapshot_delta_roundtrip(before, after):
    ledger = TransferLedger()
    _apply(ledger, before)
    s0 = ledger.snapshot()
    _apply(ledger, after)
    delta = ledger.delta(s0)
    # s0 + delta reconstructs the live ledger, field by field.
    recon = tuple(a + b for a, b in zip(_fields(s0), _fields(delta)))
    assert recon == _fields(ledger.snapshot())
    # Self-delta is zero; delta totals are consistent.
    assert _fields(ledger.delta(ledger.snapshot())) == (0.0, 0.0, 0, 0, 0)
    assert delta.d_total == delta.d_read + delta.d_write
    assert delta.c_total == delta.c_read + delta.c_write


@settings(max_examples=60, deadline=None)
@given(ops_a=op_streams, ops_b=op_streams)
def test_merge_additivity(ops_a, ops_b):
    a, b = TransferLedger(), TransferLedger()
    _apply(a, ops_a)
    _apply(b, ops_b)
    expected = tuple(
        x + y for x, y in zip(_fields(a.snapshot()), _fields(b.snapshot()))
    )
    a.merge(b)
    assert _fields(a.snapshot()) == expected


@settings(max_examples=60, deadline=None)
@given(ops=op_streams)
def test_prefetch_latency_never_exceeds_unhidden(ops):
    ledger = TransferLedger()
    _apply(ledger, ops)
    assert ledger.c_prefetch_hidden <= ledger.c_total
    hidden = ledger.latency_seconds(TIER, prefetch=True)
    unhidden = ledger.latency_seconds(TIER, prefetch=False)
    assert hidden <= unhidden + 1e-12
    assert unhidden - hidden == pytest.approx(
        ledger.c_prefetch_hidden * TIER.rtt
    )


@settings(max_examples=60, deadline=None)
@given(ops=op_streams, hide=st.integers(min_value=0, max_value=10))
def test_migration_hidden_carried_by_reset_merge_delta(ops, hide):
    """Regression (ISSUE 5): ``c_migration_hidden`` must ride every ledger
    operation — ``reset`` zeroes it, ``merge``/``delta``/``snapshot`` carry
    it — and overlapped pricing discounts exactly the hidden rounds' RTT."""
    ledger = TransferLedger()
    _apply(ledger, ops)
    budget = ledger.c_total - ledger.c_prefetch_hidden
    ledger.c_migration_hidden = min(hide, max(budget, 0))
    snap = ledger.snapshot()
    assert snap.c_migration_hidden == ledger.c_migration_hidden

    # delta: a fresh window starts at zero and accumulates independently.
    mid = ledger.snapshot()
    ledger.write(3.0)
    ledger.c_migration_hidden += 1
    delta = ledger.delta(mid)
    assert delta.c_migration_hidden == 1
    assert ledger.delta(ledger.snapshot()).c_migration_hidden == 0

    # merge: adds the counter like every other field.
    other = TransferLedger()
    other.write(2.0)
    other.c_migration_hidden = 1
    before = ledger.c_migration_hidden
    ledger.merge(other)
    assert ledger.c_migration_hidden == before + 1

    # Overlapped pricing: hidden migration rounds pay no RTT, and both
    # hiding knobs compose additively.
    unhidden = ledger.latency_seconds(TIER)
    assert unhidden - ledger.latency_seconds(
        TIER, overlap_migration=True
    ) == pytest.approx(ledger.c_migration_hidden * TIER.rtt)
    assert unhidden - ledger.latency_seconds(
        TIER, prefetch=True, overlap_migration=True
    ) == pytest.approx(
        (ledger.c_migration_hidden + ledger.c_prefetch_hidden) * TIER.rtt
    )

    # The regression itself: reset must zero the new counter too.
    ledger.reset()
    assert ledger.c_migration_hidden == 0
    assert ledger.snapshot() == TransferLedger().snapshot()


@settings(max_examples=40, deadline=None)
@given(
    dram_cap=st.integers(min_value=1, max_value=8),
    rdma_cap=st.integers(min_value=1, max_value=8),
    writes=st.lists(st.integers(min_value=1, max_value=6), min_size=0,
                    max_size=12),
    read_upto=st.integers(min_value=0, max_value=40),
)
def test_per_tier_ledgers_sum_to_hierarchy_total(dram_cap, rdma_cap, writes,
                                                 read_upto):
    h = make_hierarchy((TABLE_I["dram"], dram_cap), (TABLE_I["rdma"], rdma_cap),
                       TABLE_I["ssd"])
    page = np.arange(4, dtype=np.int64)
    ids = []
    for n in writes:
        ids.extend(h.write_batch([page] * n, tier="dram"))
    migrated = 0
    if ids:
        h.read_batch(ids[: min(read_upto, len(ids))])
        bottom = [i for i in ids if h.tier_of(i) == "ssd"]
        if bottom and h.capacity_left("rdma") >= len(bottom[:2]):
            migrated = len(bottom[:2])
            h.migrate(bottom[:2], "rdma")
    snap = h.snapshot()
    total = snap.total
    per_tier = [s for _, s in snap.tiers]
    assert total.d_read == sum(s.d_read for s in per_tier)
    assert total.d_write == sum(s.d_write for s in per_tier)
    assert total.c_read == sum(s.c_read for s in per_tier)
    assert total.c_write == sum(s.c_write for s in per_tier)
    assert snap.d_total == total.d_total and snap.c_total == total.c_total
    # No pages lost or duplicated by routing: every page written lands once;
    # each migration hop re-enters exactly one tier's write ledger.
    assert total.d_write == float(sum(writes) + migrated)
    # Spec-priced cost decomposes per tier.
    assert snap.latency_cost(h.spec) == pytest.approx(sum(
        s.latency_cost(tau) for s, tau in zip(per_tier, h.spec.taus)
    ))
