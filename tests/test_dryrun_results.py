"""Integrity checks over the dry-run result cache (results/dryrun/*.json).

These validate the DELIVERABLE, not the code: all 80 (arch x shape x mesh)
cells exist, none errored, skips follow the task rules, and roofline records
are complete and self-consistent.  Skipped wholesale if the cache is absent
(fresh checkout) — regenerate with `python -m repro.launch.dryrun --all`.
"""

import glob
import json
import os

import pytest

from repro.configs import ARCHS, SHAPES, shape_applicable

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(RESULTS, "*.json")),
    reason="dry-run cache absent; run `python -m repro.launch.dryrun --all`",
)


def _load_all():
    cells = {}
    for path in glob.glob(os.path.join(RESULTS, "*.json")):
        d = json.load(open(path))
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def test_all_80_cells_present_and_clean():
    cells = _load_all()
    missing, errors = [], []
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("single_pod", "multi_pod"):
                c = cells.get((arch, shape, mesh))
                if c is None:
                    missing.append((arch, shape, mesh))
                elif c["status"] == "error":
                    errors.append((arch, shape, mesh, c.get("error", "")[:80]))
    assert not missing, f"missing cells: {missing}"
    assert not errors, f"errored cells: {errors}"


def test_skips_follow_task_rules():
    cells = _load_all()
    for (arch, shape, _mesh), c in cells.items():
        applicable, _ = shape_applicable(ARCHS[arch], SHAPES[shape])
        if c["status"] == "skipped":
            assert not applicable, f"{arch}/{shape} skipped but applicable"
        elif c["status"] == "ok":
            assert applicable, f"{arch}/{shape} ran but should be skipped"


def test_roofline_records_complete():
    cells = _load_all()
    for key, c in cells.items():
        if c["status"] != "ok":
            continue
        r = c["roofline"]
        for field in ("compute_seconds", "memory_seconds",
                      "memory_seconds_lower", "collective_seconds",
                      "dominant", "model_flops", "mfu_bound"):
            assert field in r, f"{key}: missing {field}"
        assert r["compute_seconds"] > 0, key
        assert r["memory_seconds"] >= r["memory_seconds_lower"], key
        assert r["dominant"] in ("compute", "memory", "collective"), key
        assert c["memory"]["peak_bytes_estimate"] > 0, key


def test_multi_pod_shards_the_pod_axis():
    """Per-device footprint on 512 chips must not exceed the 256-chip run
    (the pod axis adds data parallelism; training state is ZeRO-sharded)."""
    cells = _load_all()
    for arch in ARCHS:
        single = cells.get((arch, "train_4k", "single_pod"))
        multi = cells.get((arch, "train_4k", "multi_pod"))
        if not single or not multi or "ok" not in (single["status"], multi["status"]):
            continue
        if single["status"] != "ok" or multi["status"] != "ok":
            continue
        s = single["memory"]["peak_bytes_estimate"]
        m = multi["memory"]["peak_bytes_estimate"]
        assert m <= s * 1.05, (arch, s, m)
