"""DAG scheduler suite (ISSUE 7): wiring validation + execution properties.

Fail-fast wiring errors: cyclic ``inputs=``, references to tasks outside the
run, and duplicate task names/objects each raise ``ValueError`` naming the
offending task.

Property tests (hypothesis, with the deterministic conftest fallback) drive
random task DAGs through ``session.run(schedule="dag")``, pinning:

  * tasks execute in a valid topological order of the ``inputs=`` edges;
  * per-task ledger deltas sum byte-for-byte to the run total (with and
    without ``replan="measured"``);
  * the overlapped makespan never exceeds the serial Eq.-(1) latency;
  * a linear-chain DAG reproduces the PR 5 list-pipeline ledgers exactly —
    same per-task deltas, same totals, same labels.
"""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import TABLE_I
from repro.engine import Session, WorkloadStats
from repro.engine.registry import hierarchy_spec
from repro.remote import make_relation

ROWS = 8


def _hier():
    return hierarchy_spec(
        (TABLE_I["dram"], 64), (TABLE_I["rdma"], 512), TABLE_I["ssd"])


def _seed(sess, pages, seed):
    return make_relation(sess.remote, pages * ROWS, ROWS, 64, seed=seed)


def _chain(sess):
    """join -> sort chain over seeded relations (the PR 5 pipeline shape)."""
    build = _seed(sess, 24, seed=11)
    probe = _seed(sess, 48, seed=12)
    join = sess.task(
        "ehj", WorkloadStats(size_r=24, size_s=48, out=48, partitions=8,
                             sigma=0.5),
        inputs={"build": build, "probe": probe}, rows_per_page=ROWS,
    )
    sort = sess.task(
        "ems", WorkloadStats(size_r=48, out=48, k_cap=8),
        inputs={"page_ids": join.output}, rows_per_page=ROWS,
    )
    return [join, sort]


# --------------------------------------------------------------------------
# Fail-fast wiring validation
# --------------------------------------------------------------------------


def test_dag_cycle_raises_naming_task():
    sess = Session(_hier(), budget=64)
    tasks = _chain(sess)
    # Close the loop: the join consumes the sort's output.
    tasks[0].inputs["probe"] = tasks[1].output
    with pytest.raises(ValueError, match="cycle") as ei:
        sess.run(tasks, schedule="dag")
    assert tasks[0].label in str(ei.value) or tasks[1].label in str(ei.value)


def test_dag_foreign_reference_raises_naming_both_tasks():
    sess = Session(_hier(), budget=64)
    tasks = _chain(sess)
    outsider = sess.task(
        "eagg", WorkloadStats(size_r=24, out=8, partitions=8, sigma=0.5),
        inputs={"rel": _seed(sess, 24, seed=13)}, label="outsider",
    )
    tasks[1].inputs["page_ids"] = outsider.output
    with pytest.raises(ValueError, match="not part of this run") as ei:
        sess.run(tasks, schedule="dag")
    assert "outsider" in str(ei.value)
    assert tasks[1].label in str(ei.value)


def test_dag_duplicate_label_raises():
    sess = Session(_hier(), budget=64)
    a = sess.task("eagg", WorkloadStats(size_r=24, out=8, partitions=8,
                                        sigma=0.5),
                  inputs={"rel": _seed(sess, 24, seed=14)}, label="dup")
    b = sess.task("eagg", WorkloadStats(size_r=24, out=8, partitions=8,
                                        sigma=0.5),
                  inputs={"rel": _seed(sess, 24, seed=15)}, label="dup")
    with pytest.raises(ValueError, match="duplicate task name 'dup'"):
        sess.run([a, b], schedule="dag")


def test_dag_duplicate_object_raises():
    sess = Session(_hier(), budget=64)
    a = sess.task("eagg", WorkloadStats(size_r=24, out=8, partitions=8,
                                        sigma=0.5),
                  inputs={"rel": _seed(sess, 24, seed=16)})
    with pytest.raises(ValueError, match="appears twice"):
        sess.run([a, a], schedule="dag")


def test_serial_schedule_still_requires_list_order():
    sess = Session(_hier(), budget=64)
    tasks = _chain(sess)
    with pytest.raises(ValueError, match="does not run earlier"):
        sess.run(list(reversed(tasks)))


def test_dag_accepts_any_list_order():
    sess = Session(_hier(), budget=64)
    tasks = _chain(sess)
    res = sess.run(list(reversed(tasks)), schedule="dag")
    # Producer first despite the reversed list.
    assert [tr.op for tr in res.per_task] == ["ehj", "ems"]


def test_bad_schedule_raises():
    sess = Session(_hier(), budget=64)
    with pytest.raises(ValueError, match="schedule"):
        sess.run(_chain(sess), schedule="parallel")


# --------------------------------------------------------------------------
# Random-DAG properties
# --------------------------------------------------------------------------

_OPS = ["ehj", "eagg", "ems", "bnlj"]


def _build_dag(sess, shape):
    """Materialize a random DAG: each task binds inputs to earlier outputs.

    ``shape`` is a list of (op_index, [use_dep_flag per input]) pairs; input
    slot k of task j binds to task (j - 1 - k)'s output when flagged (always
    acyclic), else to a freshly seeded relation.
    """
    tasks = []
    deps = []
    for j, (op_i, flags) in enumerate(shape):
        op = _OPS[op_i]
        spec_inputs = {"ehj": ("build", "probe"), "eagg": ("rel",),
                       "ems": ("page_ids",), "bnlj": ("outer", "inner")}[op]
        inputs = {}
        jdeps = set()
        for k, name in enumerate(spec_inputs):
            d = j - 1 - k
            # A sort's output is a raveled key stream — only another sort
            # can consume it (the hash operators need (key, payload) rows).
            ok = d >= 0 and (op == "ems" or shape[d][0] != _OPS.index("ems"))
            if flags[k % len(flags)] and ok:
                inputs[name] = tasks[d].output
                jdeps.add(d)
            else:
                inputs[name] = _seed(sess, 12 + 4 * k, seed=100 + 10 * j + k)
        stats = WorkloadStats(size_r=16, size_s=16, out=16, partitions=8,
                              sigma=0.5, k_cap=8)
        kwargs = {} if op == "bnlj" else {"rows_per_page": ROWS}
        tasks.append(sess.task(op, stats, inputs=inputs, **kwargs))
        deps.append(jdeps)
    return tasks, deps


@settings(max_examples=12, deadline=None)
@given(
    codes=st.lists(st.integers(min_value=0, max_value=15),
                   min_size=2, max_size=4),
    replan=st.booleans(),
)
def test_random_dags_topo_order_and_ledger_sums(codes, replan):
    # Each code packs one task: op = low 2 bits, input-edge flags above.
    shape = [(v % len(_OPS), [bool(v & 4), bool(v & 8)]) for v in codes]
    sess = Session(_hier(), budget=96)
    tasks, deps = _build_dag(sess, shape)
    res = sess.run(tasks, schedule="dag",
                   replan="measured" if replan else None)

    # Execution order is a valid topological order of the inputs= edges.
    index = {id(t): j for j, t in enumerate(tasks)}
    order = [index[id(tr.task)] for tr in res.per_task]
    assert sorted(order) == list(range(len(tasks)))
    pos = {j: rank for rank, j in enumerate(order)}
    for j, jdeps in enumerate(deps):
        for d in jdeps:
            assert pos[d] < pos[j], (order, deps)

    # Per-task ledger deltas sum byte-for-byte to the run total.
    acc = res.per_task[0].delta
    for tr in res.per_task[1:]:
        acc = acc + tr.delta
    assert acc == res.total

    # Overlapped makespan never exceeds the serial Eq.-(1) latency.
    assert res.schedule == "dag"
    assert res.makespan_seconds <= res.latency_seconds() + 1e-9


# --------------------------------------------------------------------------
# Linear-chain parity with the PR 5 list pipeline
# --------------------------------------------------------------------------


@pytest.mark.parametrize("target_fn", [_hier, lambda: TABLE_I["tcp"]],
                         ids=["hierarchy", "single_tier"])
def test_linear_chain_reproduces_list_pipeline_ledgers(target_fn):
    serial_sess = Session(target_fn(), budget=64)
    res_serial = serial_sess.run(_chain(serial_sess))

    dag_sess = Session(target_fn(), budget=64)
    res_dag = dag_sess.run(_chain(dag_sess), schedule="dag")

    assert [tr.label for tr in res_dag.per_task] == \
        [tr.label for tr in res_serial.per_task]
    for a, b in zip(res_serial.per_task, res_dag.per_task):
        assert a.delta == b.delta  # byte-for-byte, every counter
    assert res_serial.total == res_dag.total
    # A chain has no overlap: the makespan IS the serial latency.
    assert res_dag.makespan_seconds == pytest.approx(
        res_serial.latency_seconds(), rel=1e-12)


def test_linear_chain_parity_with_replan_measured():
    serial_sess = Session(_hier(), budget=64)
    res_serial = serial_sess.run(_chain(serial_sess), replan="measured")

    dag_sess = Session(_hier(), budget=64)
    res_dag = dag_sess.run(_chain(dag_sess), schedule="dag",
                           replan="measured")

    for a, b in zip(res_serial.per_task, res_dag.per_task):
        assert a.delta == b.delta
    assert res_serial.total == res_dag.total
    assert len(res_serial.replan_events) == len(res_dag.replan_events)
