"""Tests for the static contract analyzer (src/repro/analysis).

Each fixture mini-package under ``tests/analysis_fixtures/`` seeds exactly
one violation per rule family; the tests pin the reported code, file, and
line, so a rule that drifts (stops firing, or fires somewhere else) fails
here before it silently stops guarding the real tree.  The self-check test
then asserts the real repo lints clean with zero suppressions — the
merge-bar the CI ``static-analysis`` job enforces.
"""

import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

from repro.analysis import Project, all_rules, run_analysis

FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"
REPO_ROOT = Path(__file__).resolve().parents[1]


def _line_of(path: Path, needle: str) -> int:
    for i, line in enumerate(path.read_text().splitlines(), 1):
        if needle in line:
            return i
    raise AssertionError(f"{needle!r} not found in {path}")


def _lint(root: Path, select=None):
    return run_analysis(Project(root), select=select)


# -- rule catalog ------------------------------------------------------------


def test_rule_catalog_covers_all_four_families():
    codes = [r.code for r in all_rules()]
    assert len(codes) == len(set(codes))
    families = {c[:3] for c in codes}
    assert families == {"LED", "OPS", "LAY", "PAR"}
    assert all(r.summary for r in all_rules())


# -- seeded fixtures: one violation per family, code/file/line pinned --------


def test_ledger_fixture_reports_dropped_counter():
    root = FIXTURES / "ledger_drop"
    findings, suppressed = _lint(root)
    assert suppressed == []
    assert [f.code for f in findings] == ["LED102"]
    f = findings[0]
    assert f.path == "src/repro/core/cost_model.py"
    assert f.line == _line_of(root / f.path, "def snapshot")
    assert "c_read" in f.message


def test_operator_fixture_reports_signature_drift():
    root = FIXTURES / "operator_drift"
    findings, suppressed = _lint(root)
    assert suppressed == []
    assert [f.code for f in findings] == ["OPS204"]
    f = findings[0]
    assert f.path == "src/repro/remote/bnlj.py"
    assert f.line == _line_of(root / f.path, "def bnlj")
    assert "inner" in f.message


def test_layering_fixture_reports_all_three_breaches():
    root = FIXTURES / "layering_breach"
    findings, suppressed = _lint(root)
    assert suppressed == []
    assert sorted(f.code for f in findings) == [
        "LAY301", "LAY302", "LAY303", "LAY303",
    ]
    by_code = {f.code: f for f in findings if f.code != "LAY303"}

    f = by_code["LAY301"]
    assert f.path == "src/repro/core/bad.py"
    assert f.line == _line_of(root / f.path, "from repro.engine")

    f = by_code["LAY302"]
    assert f.path == "src/repro/engine/rogue.py"
    assert f.line == _line_of(root / f.path, "store.ledger.read")

    lay303 = sorted(
        (f for f in findings if f.code == "LAY303"), key=lambda f: f.path
    )
    f = lay303[0]
    assert f.path == "src/repro/remote/backend.py"
    assert f.line == _line_of(root / f.path, "default_rng()")
    f = lay303[1]
    assert f.path == "src/repro/remote/noisy.py"
    assert f.line == _line_of(root / f.path, "time.time()")


def test_layering_clock_carveout_is_backend_only():
    """remote/backend.py may read the clock; simulator/scheduler may not.

    The fixture backend calls ``time.perf_counter`` twice — neither may be
    reported — while its unseeded ``np.random.default_rng()`` still is.
    The same clock call in any other deterministic-stack file (the noisy.py
    ``time.time()``) keeps firing, pinning the carve-out to exactly one file.
    """
    root = FIXTURES / "layering_breach"
    findings, _ = _lint(root, select=["LAY303"])
    backend = [f for f in findings if f.path.endswith("remote/backend.py")]
    assert len(backend) == 1
    assert "default_rng" in backend[0].message
    assert not any("perf_counter" in f.message for f in findings)
    assert any(f.path.endswith("remote/noisy.py") for f in findings)


def test_parity_fixture_reports_unwitnessed_form():
    root = FIXTURES / "parity_gap"
    findings, suppressed = _lint(root)
    assert suppressed == []
    assert [f.code for f in findings] == ["PAR401"]
    f = findings[0]
    assert f.path == "src/repro/core/policies.py"
    assert f.line == _line_of(root / f.path, "def lonely_latency")
    assert "lonely_latency" in f.message


# -- selection and suppression ----------------------------------------------


def test_select_filters_by_code_prefix():
    findings, _ = _lint(FIXTURES / "layering_breach", select=["LAY302"])
    assert [f.code for f in findings] == ["LAY302"]
    findings, _ = _lint(FIXTURES / "layering_breach", select=["LED"])
    assert findings == []


def test_suppression_comment_moves_finding_to_suppressed(tmp_path):
    root = tmp_path / "ledger_drop"
    shutil.copytree(FIXTURES / "ledger_drop", root)
    target = root / "src" / "repro" / "core" / "cost_model.py"
    lines = target.read_text().splitlines()
    i = _line_of(target, "def snapshot") - 1
    lines[i] = lines[i] + "  # lint: ignore[LED102]"
    target.write_text("\n".join(lines) + "\n")

    findings, suppressed = _lint(root)
    assert findings == []
    assert [f.code for f in suppressed] == ["LED102"]
    assert suppressed[0].suppressed is True


# -- CLI ---------------------------------------------------------------------


def _run_cli(*args):
    env = dict(os.environ, PYTHONPATH=str(REPO_ROOT / "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT,
    )


def test_cli_json_output_and_blocking_exit_code():
    proc = _run_cli("--root", str(FIXTURES / "ledger_drop"), "--format", "json")
    assert proc.returncode == 1, proc.stderr
    payload = json.loads(proc.stdout)
    assert [f["code"] for f in payload["findings"]] == ["LED102"]
    assert payload["suppressed"] == []


def test_cli_list_rules():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0, proc.stderr
    assert "LED102" in proc.stdout and "PAR401" in proc.stdout


# -- the merge bar: the real repo lints clean, with zero suppressions --------


def test_repo_lints_clean_with_zero_suppressions():
    findings, suppressed = _lint(REPO_ROOT)
    assert findings == [], "\n" + "\n".join(f.render() for f in findings)
    assert suppressed == [], "\n" + "\n".join(f.render() for f in suppressed)
