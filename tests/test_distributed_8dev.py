"""Sharded-execution tests on 8 fake host devices (subprocess: XLA_FLAGS must
be set before jax initializes, so these run via `python -c` children)."""

import os
import subprocess
import sys


_ENV = {**os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def _run(code: str) -> str:
    out = subprocess.run([sys.executable, "-c", code], env=_ENV,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    """Train step on a (2,4) mesh must produce the same loss as 1 device."""
    print(_run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, reduced
from repro.distributed.sharding import Sharder
from repro.launch import steps as steps_lib
from repro.optim.adamw import AdamWConfig
from repro.data.pipeline import synthetic_batches
from repro.configs.base import ShapeSpec

cfg = reduced(ARCHS["qwen3-0.6b"], n_kv_heads=4)
shape = ShapeSpec("t", seq_len=32, global_batch=8, kind="train")
batch = jax.tree.map(jnp.asarray, next(synthetic_batches(cfg, shape, seed=0)))
opt = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)

mesh = jax.make_mesh((2, 4), ("data", "model"))
sharder = Sharder(mesh, sequence_parallel=True)
state = steps_lib.init_state(cfg, jax.random.key(0))
st_shard = steps_lib.state_shardings(state["params"], mesh, sharder)
state_sharded = jax.tree.map(lambda x, s: jax.device_put(x, s), state, st_shard)
step = jax.jit(steps_lib.make_train_step(cfg, opt, sharder),
               in_shardings=(st_shard, None),
               out_shardings=(st_shard, None))
new_state, metrics = step(state_sharded, batch)
loss_sharded = float(metrics["loss"])

# Single-device reference.
from repro.models import transformer as tf
loss_ref = float(tf.loss_fn(state["params"], cfg, batch)[1]["loss"])
assert abs(loss_sharded - loss_ref) < 5e-2, (loss_sharded, loss_ref)
# One more step to exercise donated buffers.
new_state, metrics = step(new_state, batch)
assert jnp.isfinite(metrics["loss"])
print("SHARDED_TRAIN_OK", loss_sharded, loss_ref)
"""))


def test_sharded_decode_matches_prefill_consistency():
    """Sharded decode step reproduces unsharded logits."""
    print(_run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, reduced
from repro.distributed.sharding import Sharder
from repro.launch import specs as specs_lib, steps as steps_lib
import repro.distributed.sharding as shlib
from repro.models import transformer as tf

cfg = reduced(ARCHS["gemma-2b"], n_kv_heads=1, n_heads=4)
params = tf.init_params(jax.random.key(0), cfg)
tokens = jax.random.randint(jax.random.key(1), (8, 12), 0, cfg.vocab_size)
_, caches = tf.prefill(params, cfg, {"tokens": tokens[:, :11]})
caches = tf.pad_caches(cfg, caches, 16)
want, _ = tf.decode_step(params, cfg, caches, tokens[:, 11],
                         jnp.asarray(11, jnp.int32))

mesh = jax.make_mesh((2, 4), ("data", "model"))
sharder = Sharder(mesh, sequence_parallel=False)
p_shard = shlib.named_sharding_tree(shlib.param_specs(params, sharder), mesh)
c_shard = specs_lib.cache_shardings(cfg, sharder, caches)
step = jax.jit(steps_lib.make_decode_step(cfg, sharder),
               in_shardings=(p_shard, c_shard,
                             sharder.sharding(["batch"], (8,)),
                             sharder.sharding([], ())))
params_s = jax.tree.map(lambda x, s: jax.device_put(x, s), params, p_shard)
caches_s = jax.tree.map(lambda x, s: jax.device_put(x, s), caches, c_shard)
nxt, logits, _ = step(params_s, caches_s, tokens[:, 11],
                      jnp.asarray(11, jnp.int32))
np.testing.assert_allclose(np.asarray(logits, np.float32),
                           np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)
print("SHARDED_DECODE_OK")
"""))


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint on a (2,4) mesh, restore onto (4,2) — elastic re-scale."""
    print(_run(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, reduced
from repro.distributed.sharding import Sharder
from repro.launch import steps as steps_lib
import repro.distributed.sharding as shlib
from repro.checkpoint.store import CheckpointStore
from repro.models import transformer as tf

cfg = reduced(ARCHS["qwen3-0.6b"], n_kv_heads=4)
params = tf.init_params(jax.random.key(0), cfg)
store = CheckpointStore({str(tmp_path)!r})

mesh1 = jax.make_mesh((2, 4), ("data", "model"))
s1 = Sharder(mesh1)
shard1 = shlib.named_sharding_tree(shlib.param_specs(params, s1), mesh1)
p1 = jax.tree.map(lambda x, s: jax.device_put(x, s), params, shard1)
store.save(7, p1, {{"step": 7}}, blocking=True)

mesh2 = jax.make_mesh((4, 2), ("data", "model"))
s2 = Sharder(mesh2)
shard2 = shlib.named_sharding_tree(shlib.param_specs(params, s2), mesh2)
step, restored, meta = store.restore_latest(params, shard2)
assert step == 7 and meta["step"] == 7
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_RESHARD_OK")
"""))


def test_moe_ep_shard_map_matches_gspmd():
    """The shard_map EP dispatch (§Perf winner) is numerically exact."""
    print(_run("""
import jax, jax.numpy as jnp
from repro.configs import ARCHS, reduced
from repro.distributed.sharding import Sharder, use_sharder
from repro.models import moe as moe_mod, transformer as tf
cfg = reduced(ARCHS["deepseek-v2-lite-16b"], n_experts=8, experts_per_token=2,
              capacity_factor=8.0)
params = tf.init_params(jax.random.key(0), cfg)
tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
batch = {"tokens": tokens, "targets": tokens}
mesh = jax.make_mesh((2, 4), ("data", "model"))
sharder = Sharder(mesh, sequence_parallel=False)
def loss(p):
    with use_sharder(sharder):
        return tf.loss_fn(p, cfg, batch)[0]
l_base = float(jax.jit(loss)(params))
moe_mod.set_moe_impl("ep_shard_map")
try:
    l_ep = float(jax.jit(loss)(params))
    g_ep = jax.jit(jax.grad(loss))(params)
finally:
    moe_mod.set_moe_impl("gspmd")
g_base = jax.jit(jax.grad(loss))(params)
gd = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
         for a, b in zip(jax.tree.leaves(g_base), jax.tree.leaves(g_ep)))
assert abs(l_base - l_ep) < 2e-3, (l_base, l_ep)
assert gd < 2e-2, gd
print("MOE_EP_EQUIV_OK", l_base, l_ep, gd)
"""))
