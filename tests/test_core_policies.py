"""Unit + property tests for the REMOP cost model and policies (paper §II-III)."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TABLE_I, TESTBED, TransferLedger, latency_cost
from repro.core import policies as P


# ---------------------------------------------------------------------------
# Eq. (1) and tier constants
# ---------------------------------------------------------------------------


def test_eq1_ssd_vs_tcp_example():
    """§II-A worked example: 10 GB in 20,000 rounds — SSD ~19s+2s, TCP ~8s+10s."""
    d_bytes, c = 10e9, 20_000
    ssd, tcp = TABLE_I["ssd"], TABLE_I["tcp"]
    assert ssd.latency_seconds_bytes(d_bytes, 0) == pytest.approx(18.9, abs=0.3)
    assert c * ssd.rtt == pytest.approx(2.0, abs=0.01)
    assert tcp.latency_seconds_bytes(d_bytes, 0) == pytest.approx(8.0, abs=0.01)
    assert c * tcp.rtt == pytest.approx(10.0, abs=0.01)


def test_latency_cost_limits():
    # tau -> 0 reduces to min-D; large tau approaches min-C (Definition 3).
    assert latency_cost(100, 10, 0.0) == 100
    assert latency_cost(100, 10, 1e9) > latency_cost(200, 1, 1e9)


def test_ledger_accounting():
    led = TransferLedger()
    led.read(10.0)
    led.write(5.0)
    assert led.d_total == 15.0 and led.c_total == 2
    tier = TESTBED["remon_tcp"]
    t = led.latency_seconds(tier)
    assert t == pytest.approx(15 * tier.page_bytes / tier.bandwidth + 2 * tier.rtt)


# ---------------------------------------------------------------------------
# BNLJ (§III-A)
# ---------------------------------------------------------------------------


def test_bnlj_worked_example_exact():
    """§II-C(a): conventional (99,1) vs equal (50,50) split."""
    d_conv, c_conv = P.bnlj_costs_exact(500, 1000, 0, 99, 1, 1)
    d_eq, c_eq = P.bnlj_costs_exact(500, 1000, 0, 50, 50, 1)
    assert (d_conv, c_conv) == (6500.0, 6006.0)
    assert (d_eq, c_eq) == (10500.0, 210.0)
    assert d_eq / d_conv == pytest.approx(1.615, abs=0.001)  # +61.5% data
    assert 1 - c_eq / c_conv == pytest.approx(0.965, abs=0.001)  # -96.5% rounds


def test_property4_split():
    # tau -> inf: equal split; tau -> 0: outer-heavy.
    assert P.bnlj_split_opt(100.0, 1e12) == pytest.approx(0.5, abs=1e-4)
    assert P.bnlj_split_opt(100.0, 1e-9) == pytest.approx(1.0, abs=1e-3)


@settings(max_examples=40, deadline=None)
@given(
    r_in=st.floats(16.0, 4096.0),
    tau=st.floats(0.01, 1e4),
)
def test_property4_is_argmin(r_in, tau):
    """Property 4's closed form beats any other split (convex objective)."""
    def obj(p_r):
        return 1.0 / p_r + tau / (r_in * p_r * (1.0 - p_r))

    star = P.bnlj_split_opt(r_in, tau)
    best = obj(star)
    for p in [i / 64 for i in range(1, 64)]:
        assert best <= obj(p) + 1e-9 * abs(obj(p))


TABLE_III = {
    (1e-2, 1e-2): 0.966, (1e-1, 1e-2): 0.967, (1, 1e-2): 0.970,
    (10, 1e-2): 0.980, (1e2, 1e-2): 0.991, (1e3, 1e-2): 0.997, (1e4, 1e-2): 0.999,
    (1e-2, 1e-1): 0.904, (1, 1e-1): 0.912, (1e2, 1e-1): 0.973, (1e4, 1e-1): 0.997,
    (1e-2, 1): 0.764, (1, 1): 0.778, (10, 1): 0.836, (1e2, 1): 0.921, (1e4, 1): 0.990,
    (1e-2, 10): 0.547, (1, 10): 0.560, (1e2, 10): 0.789, (1e4, 10): 0.970,
    (1e-2, 1e2): 0.330, (1, 1e2): 0.337, (10, 1e2): 0.384, (1e2, 1e2): 0.549,
    (1e3, 1e2): 0.769, (1e4, 1e2): 0.910,
}


@pytest.mark.parametrize("cell,expected", sorted(TABLE_III.items()))
def test_table3_rin_opt(cell, expected):
    a, b = cell
    assert P.bnlj_rin_opt(a, b) == pytest.approx(expected, abs=0.002)


@settings(max_examples=25, deadline=None)
@given(a=st.floats(1e-2, 1e4), b=st.floats(1e-2, 1e2))
def test_table3_is_argmin(a, b):
    star = P.bnlj_rin_opt(a, b)
    best = P.bnlj_rin_objective(star, a, b)
    for r in [i / 100 for i in range(1, 100)]:
        assert best <= P.bnlj_rin_objective(r, a, b) * (1 + 1e-6)


# ---------------------------------------------------------------------------
# EMS (§III-B)
# ---------------------------------------------------------------------------


def test_ems_worked_example_exact():
    """§II-C(b): k=M-1 vs k=4 with 2:1 split."""
    d, c, p = P.ems_costs_exact(13_000, 101, 100, 100)
    assert (d, c, p) == (52_000.0, 52_000.0, 2)
    d, c, p = P.ems_costs_exact(13_000, 101, 4, 67)
    assert (d, c, p) == (104_000.0, 4_784.0, 4)


def test_property5_split():
    for k in (2, 4, 16, 64):
        assert P.ems_split_opt(k) == pytest.approx(
            math.sqrt(k) / (math.sqrt(k) + 1)
        )


@settings(max_examples=30, deadline=None)
@given(k=st.integers(2, 256), m=st.floats(32, 8192))
def test_property5_is_argmin(k, m):
    """R_in:R_out = sqrt(k):1 minimizes k/R_in + 1/R_out."""
    star = P.ems_split_opt(k)

    def rounds(r_in):
        return k / (r_in * m) + 1.0 / ((1 - r_in) * m)

    best = rounds(star)
    for r in [i / 50 for i in range(1, 50)]:
        assert best <= rounds(r) * (1 + 1e-9)


TABLE_IV = {1e-9: 4, 1: 5, 4: 8, 16: 17, 64: 43, 256: 126, 1024: 396}


@pytest.mark.parametrize("a,expected", sorted(TABLE_IV.items()))
def test_table4_kopt(a, expected):
    assert P.ems_kopt(a) == expected


def test_ems_vs_duckdb_limit():
    """RTT-dominated: k*=4 uses ~25% fewer rounds than DuckDB's 2-way merge.

    As tau->inf, L_Duck/L_opt -> [h(2)/h(4)] with h(k)=(sqrt(k)+1)^2/log2 k:
    DuckDB pays (sqrt2+1)^2/1 vs optimal (2+1)^2/2 = 4.5 -> ratio ~1.296.
    """
    a = 1e-9
    ratio = P.ems_h(2, a) / P.ems_h(4, a)
    assert ratio == pytest.approx((math.sqrt(2) + 1) ** 2 / 4.5, rel=1e-3)
    assert 1 - 1 / ratio == pytest.approx(0.25, abs=0.03)  # ~25% fewer rounds


# ---------------------------------------------------------------------------
# EHJ (§III-C)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    coeffs=st.lists(st.floats(0.1, 1e6), min_size=2, max_size=5),
    budget=st.floats(8.0, 1e5),
)
def test_property6_waterfill_is_argmin(coeffs, budget):
    """Cauchy-Schwarz allocation beats random feasible allocations."""
    alloc, c_star = P.waterfill(coeffs, budget)
    assert sum(alloc) == pytest.approx(budget, rel=1e-6)
    assert P.round_cost(coeffs, alloc) == pytest.approx(c_star, rel=1e-6)
    import random

    rng = random.Random(42)
    for _ in range(20):
        cuts = sorted(rng.random() for _ in range(len(coeffs) - 1))
        parts = []
        prev = 0.0
        for c in cuts + [1.0]:
            parts.append((c - prev) * budget)
            prev = c
        if min(parts) <= 0:
            continue
        assert c_star <= P.round_cost(coeffs, parts) * (1 + 1e-9)


def test_table6_closed_forms():
    b, q, out, m_b, part, sigma = 4000.0, 16000.0, 8000.0, 256.0, 16, 0.5
    plan = P.ehj_plan(b, q, out, m_b, part, sigma)
    got = P.ehj_round_costs(b, q, out, plan)
    want = P.ehj_optimal_round_costs(b, q, out, m_b, part, sigma)
    for g, w in zip(got, want):
        assert g == pytest.approx(w, rel=1e-6)
    # Table VI split ratios: P1 R_r:R_w = 1 : sigma*sqrt(P).
    r_r, r_w = plan.p1
    assert r_w / r_r == pytest.approx(sigma * math.sqrt(part), rel=1e-6)


def test_ehj_data_cost_allocation_independent():
    b, q, out, sigma = 1000.0, 2000.0, 500.0, 0.25
    d = sum(P.ehj_data_costs(b, q, out, sigma))
    expected = (1 + sigma) * b + (1 + sigma) * q + (1 - sigma) * out + sigma * (b + q) + sigma * out
    assert d == pytest.approx(expected)
