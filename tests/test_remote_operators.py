"""Integration tests: simulated remote-memory operators vs oracles + closed forms.

These validate that the *measured* ledger (D pages, C rounds) of the real
data-plane algorithms matches the paper's §III analysis, and that every
operator produces exactly the oracle output.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TESTBED
from repro.core.policies import (
    BNLJPlan, EMSPlan, bnlj_costs_exact, bnlj_plan, ehj_plan, ems_costs_exact,
)
from repro.remote import (
    RemoteMemory, bnlj, bnlj_oracle, ehj, ehj_oracle, ems_sort, ems_oracle,
    make_relation,
)
from repro.remote.simulator import make_key_pages

TIER = TESTBED["remon_tcp"]


def _mk():
    return RemoteMemory(TIER)


# ---------------------------------------------------------------------------
# BNLJ
# ---------------------------------------------------------------------------


def _bnlj_setup(remote, r_pages=20, s_pages=40, rows=32, domain=256, seed=1):
    outer = make_relation(remote, r_pages * rows, rows, domain, seed=seed)
    inner = make_relation(remote, s_pages * rows, rows, domain, seed=seed + 1)
    return outer, inner


def test_bnlj_output_matches_oracle():
    remote = _mk()
    outer, inner = _bnlj_setup(remote)
    plan = BNLJPlan(m=11, r_in=10 / 11, p_r=0.5)
    res = bnlj(remote, outer, inner, plan)
    got = np.concatenate([remote._store[i] for i in res.output_page_ids])
    got = got[np.lexsort((got[:, 2], got[:, 1], got[:, 0]))]
    want = bnlj_oracle(remote, outer, inner)
    assert res.output_rows == len(want)
    np.testing.assert_array_equal(got, want)


def test_bnlj_read_rounds_match_closed_form():
    """Measured C_read/D_read equal the §III-A ceil formulas (zero-output case)."""
    remote = _mk()
    # Disjoint key domains -> no output; isolates the read-side terms.
    outer = make_relation(remote, 500 * 4, 4, 1000, seed=1)
    inner = make_relation(remote, 1000 * 4, 4, 1000, seed=2)
    # Shift inner keys out of range to kill matches.
    for pid in inner.page_ids:
        remote._store[pid][:, 0] += 10_000_000
    for p_r, p_s in [(99, 1), (50, 50), (10, 90)]:
        before_c, before_d = remote.ledger.c_read, remote.ledger.d_read
        plan = BNLJPlan(m=p_r + p_s + 1, r_in=(p_r + p_s) / (p_r + p_s + 1),
                        p_r=p_r / (p_r + p_s))
        res = bnlj(remote, outer, inner, plan)
        d_want, c_want = bnlj_costs_exact(500, 1000, 0, p_r, p_s, 1)
        # closed form counts |R| once and ceil(R/PR)*|S|; ledger counts pages read.
        assert res.c_read == c_want
        assert res.d_read == d_want
        assert res.output_rows == 0


def test_bnlj_worked_example_rounds_on_simulator():
    """§II-C(a) on the live simulator: 6,006 vs 210 read rounds."""
    remote = _mk()
    outer = make_relation(remote, 500, 1, 10, seed=3)
    inner = make_relation(remote, 1000, 1, 10, seed=4)
    for pid in inner.page_ids:
        remote._store[pid][:, 0] += 999_999
    res_conv = bnlj(remote, outer, inner, BNLJPlan(m=101, r_in=100 / 101, p_r=0.99))
    res_eq = bnlj(remote, outer, inner, BNLJPlan(m=101, r_in=100 / 101, p_r=0.5))
    assert res_conv.c_read == 6006
    assert res_eq.c_read == 210
    assert res_eq.d_read / res_conv.d_read == pytest.approx(10500 / 6500, rel=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    r_pages=st.integers(4, 24), s_pages=st.integers(4, 32),
    p_r=st.floats(0.15, 0.85), domain=st.integers(8, 512), seed=st.integers(0, 99),
)
def test_bnlj_correct_for_any_plan(r_pages, s_pages, p_r, domain, seed):
    """Property: output equals oracle for arbitrary buffer plans."""
    remote = _mk()
    outer = make_relation(remote, r_pages * 16, 16, domain, seed=seed)
    inner = make_relation(remote, s_pages * 16, 16, domain, seed=seed + 1)
    plan = BNLJPlan(m=9, r_in=8 / 9, p_r=p_r)
    res = bnlj(remote, outer, inner, plan)
    want = bnlj_oracle(remote, outer, inner)
    assert res.output_rows == len(want)
    if len(want):
        got = np.concatenate([remote._store[i] for i in res.output_page_ids])
        got = got[np.lexsort((got[:, 2], got[:, 1], got[:, 0]))]
        np.testing.assert_array_equal(got, want)


def test_bnlj_remop_beats_conventional_in_latency_cost():
    """The REMOP plan should lower simulated L vs the conventional plan."""
    remote = _mk()
    outer = make_relation(remote, 120 * 8, 8, 64, seed=5)
    inner = make_relation(remote, 240 * 8, 8, 64, seed=6)
    m, tau = 13.0, TIER.tau_pages

    before = remote.ledger.latency_cost(tau)
    res_c = bnlj(remote, outer, inner, BNLJPlan(m=m, r_in=(m - 1) / m, p_r=(m - 2) / (m - 1)))
    mid = remote.ledger.latency_cost(tau)
    res_r = bnlj(remote, outer, inner, bnlj_plan(m, tau, selectivity=1 / 64))
    after = remote.ledger.latency_cost(tau)
    l_conv, l_remop = mid - before, after - mid
    assert res_r.output_rows == res_c.output_rows
    assert l_remop < l_conv
    assert (res_r.c_read + res_r.c_write) < (res_c.c_read + res_c.c_write)


# ---------------------------------------------------------------------------
# EMS
# ---------------------------------------------------------------------------


def test_ems_output_sorted_and_complete():
    remote = _mk()
    ids = make_key_pages(remote, 600, 8, 100000, seed=7)
    plan = EMSPlan(m=24, k=4, r_in=2 / 3)
    res = ems_sort(remote, ids, plan, rows_per_page=8)
    got = np.concatenate([remote._store[i].ravel() for i in res.run_page_ids])
    want = ems_oracle(remote, ids)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=8, deadline=None)
@given(
    n_pages=st.integers(40, 200), k=st.integers(2, 8),
    r_in=st.floats(0.4, 0.9), seed=st.integers(0, 99),
)
def test_ems_correct_for_any_plan(n_pages, k, r_in, seed):
    remote = _mk()
    ids = make_key_pages(remote, n_pages, 8, 10_000, seed=seed)
    plan = EMSPlan(m=10, k=k, r_in=r_in)
    res = ems_sort(remote, ids, plan, rows_per_page=8)
    got = np.concatenate([remote._store[i].ravel() for i in res.run_page_ids])
    np.testing.assert_array_equal(got, ems_oracle(remote, ids))


def test_ems_round_counts_track_closed_form():
    """Merge-phase rounds within ~15% of §III-B's formula (ceil effects)."""
    remote = _mk()
    n_pages, m = 512, 16
    ids = make_key_pages(remote, n_pages, 8, 1 << 30, seed=8)
    k, r_in_pages = 4, 12
    plan = EMSPlan(m=m, k=k, r_in=r_in_pages / m)
    res = ems_sort(remote, ids, plan, rows_per_page=8,
                   count_run_formation=False)
    d_want, c_want, p_want = ems_costs_exact(n_pages, m, k, r_in_pages)
    assert res.passes == p_want
    assert res.d_read + res.d_write == pytest.approx(d_want, rel=0.02)
    assert res.c_read + res.c_write == pytest.approx(c_want, rel=0.15)


def test_ems_k4_beats_duckdb_2way_in_rounds():
    """Paper: RTT-dominated optimum k*=4 uses fewer rounds than 2-way merge."""
    remote = _mk()
    ids = make_key_pages(remote, 256, 8, 1 << 30, seed=9)
    r2 = ems_sort(remote, ids, EMSPlan(m=12, k=2, r_in=2 / 3),
                  rows_per_page=8, count_run_formation=False)
    r4 = ems_sort(remote, ids, EMSPlan(m=12, k=4, r_in=2 / 3),
                  rows_per_page=8, count_run_formation=False)
    assert r4.c_read + r4.c_write < r2.c_read + r2.c_write
    assert r4.passes < r2.passes


# ---------------------------------------------------------------------------
# EHJ
# ---------------------------------------------------------------------------


def test_ehj_output_count_matches_oracle():
    remote = _mk()
    build = make_relation(remote, 64 * 16, 16, 256, seed=10)
    probe = make_relation(remote, 256 * 16, 16, 256, seed=11)
    plan = ehj_plan(b=64, q=256, out=32, m_b=16, partitions=8, sigma=0.5)
    res = ehj(remote, build, probe, plan)
    assert res.output_rows == ehj_oracle(remote, build, probe)


@settings(max_examples=6, deadline=None)
@given(sigma=st.sampled_from([0.25, 0.5, 0.75]), parts=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 50))
def test_ehj_correct_for_any_plan(sigma, parts, seed):
    remote = _mk()
    build = make_relation(remote, 48 * 8, 8, 128, seed=seed)
    probe = make_relation(remote, 96 * 8, 8, 128, seed=seed + 1)
    plan = ehj_plan(b=48, q=96, out=36, m_b=12, partitions=parts, sigma=sigma)
    res = ehj(remote, build, probe, plan)
    assert res.output_rows == ehj_oracle(remote, build, probe)


def test_ehj_remop_pools_reduce_write_rounds():
    """Enlarged R_w/R_s pools (Property 6) -> fewer flush rounds than 1-page pools."""
    remote = _mk()
    build = make_relation(remote, 128 * 8, 8, 64, seed=12)
    probe = make_relation(remote, 256 * 8, 8, 64, seed=13)
    sigma, parts, m_b = 0.5, 16, 24
    # Baseline: DuckDB-like minimal write pools (1 page each).
    base = ehj_plan(128, 256, 96, m_b, parts, sigma)
    starved = type(base)(m_b=m_b, partitions=parts, sigma=sigma,
                         p1=(m_b - 1, 1.0), p2=(m_b - 2, 1.0, 1.0),
                         p3=(m_b - 1, 1.0))
    res_starved = ehj(remote, build, probe, starved)
    res_remop = ehj(remote, build, probe, base)
    assert res_remop.output_rows == res_starved.output_rows
    assert res_remop.c_write < res_starved.c_write


# ---------------------------------------------------------------------------
# Prefetch (§IV-E)
# ---------------------------------------------------------------------------


def test_prefetch_hides_rounds_and_reduces_latency():
    remote = _mk()
    outer, inner = _bnlj_setup(remote, r_pages=12, s_pages=24)
    plan = BNLJPlan(m=9, r_in=8 / 9, p_r=0.5)
    res = bnlj(remote, outer, inner, plan, prefetch=True)
    led = remote.ledger
    assert led.c_prefetch_hidden > 0
    assert led.latency_seconds(TIER, prefetch=True) < led.latency_seconds(TIER, prefetch=False)
