"""Per-architecture smoke tests on REDUCED configs (CPU, 1 device).

For every assigned arch: init -> one train loss (finite, right shapes) and a
prefill/decode consistency check: decoding token t with the prefill cache must
reproduce the full-forward logits at position t.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import transformer as tf

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, key, batch=2, seq=16):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
    out = {"tokens": tokens, "targets": tokens}
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(ks[1], (batch, cfg.frontend_seq, cfg.frontend_dim))
    if cfg.family == "audio_encdec":
        out["frames"] = jax.random.normal(ks[2], (batch, seq, cfg.frontend_dim))
    return out


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(ARCHS[name])
            params = tf.init_params(jax.random.key(0), cfg)
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_loss_finite(built, name):
    cfg, params = built(name)
    batch = _batch(cfg, jax.random.key(1))
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: tf.loss_fn(p, cfg, batch)[0], has_aux=False
    )(params), None
    loss_val = jax.jit(lambda p: tf.loss_fn(p, cfg, batch)[0])(params)
    assert jnp.isfinite(loss_val), f"{name}: loss not finite"
    # Rough sanity: untrained loss should be near ln(vocab).
    assert float(loss_val) < np.log(cfg.vocab_size) * 3


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_grads_finite_and_nonzero(built, name):
    cfg, params = built(name)
    batch = _batch(cfg, jax.random.key(2), batch=1, seq=8 if cfg.family != "ssm" else 32)
    grads = jax.grad(lambda p: tf.loss_fn(p, cfg, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), f"{name}: non-finite grads"
    total = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert total > 0, f"{name}: all-zero grads"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(built, name):
    cfg, params = built(name)
    seq = 32 if cfg.family == "ssm" else 12
    batch = _batch(cfg, jax.random.key(3), batch=2, seq=seq)
    # Full forward over seq tokens.
    logits_full, _, _ = tf.forward(params, cfg, batch)
    # Prefill on the first seq-1 tokens, then decode token seq-1.
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, : seq - 1]
    _, caches = tf.prefill(params, cfg, pre_batch)
    prefix = cfg.frontend_seq if cfg.family == "vlm" else 0
    caches = tf.pad_caches(cfg, caches, prefix + seq + 4)
    pos = jnp.asarray(prefix + seq - 1, jnp.int32)
    logits_step, _ = tf.decode_step(params, cfg, caches,
                                    batch["tokens"][:, seq - 1], pos)
    want = logits_full[:, -1]
    got = logits_step
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_cache_struct_matches_prefill(built, name):
    cfg, params = built(name)
    seq = 32 if cfg.family == "ssm" else 12
    batch = _batch(cfg, jax.random.key(4), batch=2, seq=seq)
    _, caches = tf.prefill(params, cfg, batch)
    total = seq + (cfg.frontend_seq if cfg.family == "vlm" else 0)
    spec = tf.cache_struct(cfg, batch=2, seq=total, enc_len=seq)
    flat_got = jax.tree.leaves(caches)
    flat_spec = jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    assert len(flat_got) == len(flat_spec), f"{name}: cache tree mismatch"
    for g, s in zip(flat_got, flat_spec):
        assert g.shape == s.shape, f"{name}: {g.shape} != {s.shape}"


def test_param_counts_at_full_scale():
    """Full configs build param *structures* lazily and count plausibly."""
    cfg = ARCHS["qwen3-0.6b"]
    shapes = jax.eval_shape(lambda k: tf.init_params(k, cfg), jax.random.key(0))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    assert 0.4e9 < n < 1.2e9, n


def test_active_params_moe():
    cfg = reduced(ARCHS["granite-moe-3b-a800m"])
    params = tf.init_params(jax.random.key(0), cfg)
    total = tf.param_count(params)
    active = tf.active_param_count(params, cfg)
    assert active < total
