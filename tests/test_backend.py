"""Execution backend parity: real arrays, real kernels, same answers.

The :class:`repro.remote.backend.ExecutionBackend` is only allowed to exist
because it changes *nothing* the simulator asserts: every test here runs the
same workload against a simulated :class:`MemoryHierarchy` and a backend on
the same hierarchy spec and demands

* byte-identical operator output pages (dtype, shape, values),
* field-for-field equal ledger snapshots (per tier, per op, and in total),
* wall-clock measurements present on the backend and absent on the simulator.

Workloads are deliberately tiny: the Pallas kernels run in interpret mode on
CPU, where the ``gather_rows`` kernel steps one Python iteration per row.
"""

import dataclasses

import numpy as np

from repro.core import TABLE_I
from repro.engine import Session, WorkloadStats
from repro.engine.registry import hierarchy_spec
from repro.remote import MemoryHierarchy, make_backend
from repro.remote.backend import ExecutionBackend
from repro.remote.simulator import make_key_pages, make_relation

ROWS = 4
THREE = ((TABLE_I["dram"], 16), (TABLE_I["rdma"], 128), TABLE_I["ssd"])
ONE = (TABLE_I["tcp"],)


def _tasks(sess):
    """A tiny EMS + EHJ pipeline exercising both kernel hooks."""
    ids = make_key_pages(sess.remote, 24, ROWS, seed=3)
    build = make_relation(sess.remote, 8 * ROWS, ROWS, 16, seed=4)
    probe = make_relation(sess.remote, 16 * ROWS, ROWS, 16, seed=5)
    return [
        sess.task("ems", WorkloadStats(size_r=24, k_cap=4),
                  inputs={"page_ids": ids}, rows_per_page=ROWS),
        sess.task("ehj", WorkloadStats(size_r=8, size_s=16, out=6,
                                       partitions=4, sigma=0.5),
                  inputs={"build": build, "probe": probe}),
    ]


def _run(remote):
    sess = Session(remote, budget=24.0)
    return sess, sess.run(_tasks(sess))


def _output_ids(op, result):
    return result.run_page_ids if op == "ems" else result.output_page_ids


def _assert_parity(levels):
    sim_sess, sim = _run(MemoryHierarchy(hierarchy_spec(*levels)))
    backend = make_backend(*levels)
    bk_sess, bkr = _run(backend)

    # Wall clock: measured on the backend, absent from the simulator.
    assert sim.wall_seconds is None
    assert bkr.wall_seconds is not None and bkr.wall_seconds > 0.0

    # Ledger parity — field-for-field, per tier, per op, and in total.
    assert dataclasses.asdict(sim.total) == dataclasses.asdict(bkr.total)
    for (op_a, _, da), (op_b, _, db) in zip(sim.per_op, bkr.per_op):
        assert op_a == op_b
        assert dataclasses.asdict(da) == dataclasses.asdict(db)

    # Output parity — byte-identical pages, page for page.
    for (op_a, ra, _), (_, rb, _) in zip(sim.per_op, bkr.per_op):
        pages_a = sim_sess.remote.peek_batch(_output_ids(op_a, ra))
        pages_b = bk_sess.remote.peek_batch(_output_ids(op_a, rb))
        assert len(pages_a) == len(pages_b)
        for pa, pb in zip(pages_a, pages_b):
            assert pa.dtype == pb.dtype
            assert pa.shape == pb.shape
            assert np.array_equal(pa, pb)
    return backend


def test_session_parity_three_tier():
    backend = _assert_parity(THREE)
    # The hooks actually ran on device: no silent numpy fallbacks.
    assert backend.wall.kernel_calls > 0
    assert backend.wall.kernel_fallbacks == 0
    assert backend.wall.host_pinned_pages == 0


def test_session_parity_single_tier():
    backend = _assert_parity(ONE)
    assert backend.wall.kernel_calls > 0
    assert backend.wall.kernel_fallbacks == 0


# -- direct hook parity ------------------------------------------------------


def test_sort_keys_hook_matches_numpy():
    backend = make_backend(*ONE)
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 50, size=37).astype(np.int64)  # duplicates likely
    out = backend.sort_keys(keys)
    assert out.dtype == keys.dtype
    np.testing.assert_array_equal(out, np.sort(keys, kind="stable"))
    assert backend.wall.kernel_calls == 1
    assert backend.wall.kernel_fallbacks == 0


def test_partition_rows_hook_matches_masks():
    backend = make_backend(*ONE)
    rng = np.random.default_rng(11)
    rows = rng.integers(0, 1000, size=(29, 3)).astype(np.int64)
    parts = rng.integers(0, 4, size=29).astype(np.int64)
    got = backend.partition_rows(rows, parts)
    want = [(int(q), rows[parts == q]) for q in np.unique(parts)]
    assert [q for q, _ in got] == [q for q, _ in want]
    for (_, ga), (_, wa) in zip(got, want):
        assert ga.dtype == wa.dtype
        np.testing.assert_array_equal(ga, wa)  # mask order == stable order
    assert backend.wall.kernel_fallbacks == 0


def test_out_of_int32_range_keys_fall_back_but_agree():
    backend = make_backend(*ONE)
    keys = np.array([2**40, 5, 2**35, 5, -1], dtype=np.int64)
    out = backend.sort_keys(keys)
    np.testing.assert_array_equal(out, np.sort(keys, kind="stable"))
    assert backend.wall.kernel_fallbacks == 1
    assert backend.wall.kernel_calls == 0


def test_host_pinned_pages_round_trip_unchanged():
    """Pages whose values exceed int32 never get a device mirror, yet reads
    return them bit-exact (the host copy is authoritative)."""
    backend = make_backend(*ONE)
    big = np.array([2**40, 2**41, 3], dtype=np.int64)
    small = np.arange(5, dtype=np.int64)
    ids = backend.put_local([big, small])
    assert backend.wall.host_pinned_pages == 1
    got = backend.read_batch(ids)
    np.testing.assert_array_equal(got[0], big)
    assert got[0].dtype == np.int64
    np.testing.assert_array_equal(got[1], small)
    assert got[1].dtype == np.int64


def test_wall_clock_report_shape():
    backend = make_backend(*THREE)
    report = backend.wall.to_dict()
    assert set(report["tiers"]) == {"dram", "rdma", "ssd"}
    for tier in report["tiers"].values():
        for key in ("h2d_seconds", "h2d_rounds", "h2d_bytes",
                    "d2h_seconds", "d2h_rounds", "d2h_bytes"):
            assert key in tier
    assert "wall_seconds" in report
    assert "kernel_seconds" in report


def test_backend_is_a_hierarchy_and_flagged():
    backend = make_backend(*THREE)
    assert isinstance(backend, MemoryHierarchy)
    assert isinstance(backend, ExecutionBackend)
    assert backend.is_backend is True
    assert getattr(MemoryHierarchy(hierarchy_spec(*THREE)), "is_backend",
                   False) is False


def test_migrate_keeps_device_mirrors_consistent():
    backend = make_backend(*THREE)
    pages = [np.arange(i, i + ROWS, dtype=np.int64) for i in range(0, 12, ROWS)]
    ids = backend.put_local(pages)  # seeds on the bottom tier (ssd)
    backend.promote(ids)
    got = backend.read_batch(ids)
    for page, back in zip(pages, got):
        np.testing.assert_array_equal(page, back)
        assert back.dtype == np.int64
