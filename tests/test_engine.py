"""Unit tests for the shared spill-engine layer (`repro.engine`).

Covers the accounting contract documented in ``repro/engine/__init__.py``:
ceil-semantics flush rounds for BufferPool, prefetch-hidden accounting for
PageCursor, ledger snapshot/delta round-trips, read-round coalescing, and the
operator registry reproducing the legacy per-operator plan constructors.
"""

import math

import numpy as np
import pytest

from repro.core import TABLE_I, TESTBED
from repro.core.cost_model import LedgerSnapshot
from repro.core.policies import (
    bnlj_conventional, bnlj_plan, ehj_plan, ehj_starved, ems_conventional,
    ems_duckdb, ems_plan,
)
from repro.engine import (
    BufferPool, PageCursor, TransferScheduler, WorkloadStats, plan_operator,
    registry,
)
from repro.remote import RemoteMemory
from repro.remote.simulator import make_key_pages

TIER = TESTBED["remon_tcp"]
ROWS = 8


def _mk():
    remote = RemoteMemory(TIER)
    return remote, TransferScheduler(remote)


# ---------------------------------------------------------------------------
# BufferPool
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("v_pages,cap_pages", [(1, 1), (7, 2), (16, 4), (17, 4), (40, 7)])
def test_bufferpool_stream_costs_ceil_rounds(v_pages, cap_pages):
    """A stream of V pages through a c-page slice costs ceil(V/c) write rounds."""
    remote, sched = _mk()
    pool = BufferPool(sched, cap_pages, ROWS)
    rng = np.random.default_rng(0)
    total = v_pages * ROWS
    sent = []
    done = 0
    while done < total:  # add in ragged chunks to exercise mid-chunk flushes
        n = min(int(rng.integers(1, 3 * ROWS)), total - done)
        chunk = rng.integers(0, 1 << 30, size=(n, 2), dtype=np.int64)
        pool.add(chunk)
        sent.append(chunk)
        done += n
    pool.flush_all()
    assert remote.ledger.c_write == math.ceil(v_pages / cap_pages)
    assert remote.ledger.d_write == v_pages
    assert pool.rows_flushed == total
    got = np.concatenate(remote.peek_batch(pool.pages()), axis=0)
    np.testing.assert_array_equal(got, np.concatenate(sent, axis=0))


def test_bufferpool_slices_capacity_across_streams():
    """n_streams share the pool: slice = floor(capacity/n), per-stream rounds."""
    remote, sched = _mk()
    pool = BufferPool(sched, 9, ROWS, n_streams=4)  # slice = 2 pages
    assert pool.slice_pages == 2
    for q in range(4):
        pool.add(np.full((5 * ROWS, 2), q, dtype=np.int64), stream=q)
    pool.flush_all()
    # Each stream: 5 pages through a 2-page slice -> ceil(5/2) = 3 rounds.
    assert remote.ledger.c_write == 4 * 3
    for q in range(4):
        pages = remote.peek_batch(pool.pages(q))
        assert sum(len(p) for p in pages) == 5 * ROWS
        assert all((p == q).all() for p in pages)


def test_bufferpool_flush_all_is_noop_when_empty():
    remote, sched = _mk()
    pool = BufferPool(sched, 4, ROWS)
    pool.add(np.empty((0, 2), dtype=np.int64))
    pool.flush_all()
    assert remote.ledger.c_write == 0
    assert pool.pages() == []


# ---------------------------------------------------------------------------
# PageCursor
# ---------------------------------------------------------------------------


def test_pagecursor_blocks_round_and_prefetch_accounting():
    """V pages / c-page batches = ceil(V/c) rounds; all but the first hidden."""
    remote, sched = _mk()
    ids = make_key_pages(remote, 11, ROWS, seed=1)
    blocks = list(PageCursor(sched, ids, 3, prefetch=True).blocks())
    assert len(blocks) == math.ceil(11 / 3)
    assert remote.ledger.c_read == 4
    assert remote.ledger.d_read == 11
    assert remote.ledger.c_prefetch_hidden == 3  # first refill is never hidden
    got = np.concatenate([b.ravel() for b in blocks])
    np.testing.assert_array_equal(
        got, np.concatenate([p.ravel() for p in remote.peek_batch(ids)])
    )


def test_pagecursor_refill_then_blocks_drops_nothing():
    """Mixing the buffered and block APIs drains the buffer before streaming."""
    remote, sched = _mk()
    ids = make_key_pages(remote, 6, ROWS, seed=9)
    cur = PageCursor(sched, ids, 2, ravel=True)
    assert cur.refill()  # batch 1 buffered; its round is already charged
    got = np.concatenate([b.ravel() for b in cur.blocks()])
    np.testing.assert_array_equal(
        got, np.concatenate([p.ravel() for p in remote.peek_batch(ids)])
    )
    assert remote.ledger.c_read == 3  # the buffered batch is not re-read
    assert cur.exhausted


def test_pagecursor_without_prefetch_hides_nothing():
    remote, sched = _mk()
    ids = make_key_pages(remote, 6, ROWS, seed=2)
    PageCursor(sched, ids, 2).read_all()
    assert remote.ledger.c_read == 3
    assert remote.ledger.c_prefetch_hidden == 0


def test_pagecursor_streams_are_independent():
    """Two prefetching cursors each pay one unhidden (first) round."""
    remote, sched = _mk()
    a = make_key_pages(remote, 4, ROWS, seed=3)
    b = make_key_pages(remote, 4, ROWS, seed=4)
    PageCursor(sched, a, 2, prefetch=True).read_all()
    PageCursor(sched, b, 2, prefetch=True).read_all()
    assert remote.ledger.c_read == 4
    assert remote.ledger.c_prefetch_hidden == 2


def test_pagecursor_sorted_run_helpers():
    remote, sched = _mk()
    keys = np.arange(4 * ROWS, dtype=np.int64)
    ids = remote.put_local([keys[i : i + ROWS] for i in range(0, len(keys), ROWS)])
    cur = PageCursor(sched, ids, 2, ravel=True)
    assert cur.refill()
    assert cur.buffered == 2 * ROWS
    assert cur.safe_bound() == 2 * ROWS - 1  # more pages remain -> bound = buf max
    np.testing.assert_array_equal(cur.take_upto(4), np.arange(5))
    np.testing.assert_array_equal(cur.take_upto(None), np.arange(5, 2 * ROWS))
    assert cur.refill()
    assert cur.safe_bound() is None  # fully buffered: no bound needed
    assert not cur.exhausted
    cur.take_upto(None)
    assert cur.exhausted
    assert remote.ledger.c_read == 2


# ---------------------------------------------------------------------------
# TransferScheduler: snapshot/delta + coalescing
# ---------------------------------------------------------------------------


def test_ledger_snapshot_delta_roundtrip():
    remote, sched = _mk()
    ids = make_key_pages(remote, 10, ROWS, seed=5)
    sched.read(ids[:4])
    s0 = sched.snapshot()
    assert s0 == LedgerSnapshot(d_read=4.0, c_read=1)
    sched.read(ids[4:6])  # a stream's first round: never marked hidden
    sched.read(ids[6:], prefetch=True)  # overlapped round: hidden
    sched.write([np.zeros(ROWS, dtype=np.int64)])
    d = sched.delta(s0)
    assert (d.d_read, d.c_read) == (6.0, 2)
    assert (d.d_write, d.c_write) == (1.0, 1)
    assert d.c_prefetch_hidden == 1
    assert d.d_total == 7.0 and d.c_total == 3
    # Deltas compose: (now - s0) + s0 counters == live ledger.
    led = remote.ledger
    assert s0.c_total + d.c_total == led.c_total
    assert s0.d_total + d.d_total == led.d_total
    # A snapshot is immutable — later traffic must not leak into it.
    # dataclasses raises FrozenInstanceError, an AttributeError subclass.
    with pytest.raises(AttributeError):
        s0.c_read = 99


def test_snapshot_latency_cost_matches_ledger():
    remote, sched = _mk()
    ids = make_key_pages(remote, 8, ROWS, seed=6)
    before = sched.snapshot()
    sched.read(ids)
    tau = TIER.tau_pages
    assert sched.delta(before).latency_cost(tau) == pytest.approx(
        remote.ledger.latency_cost(tau)
    )


def test_read_coalesced_merges_adjacent_rounds():
    remote, sched = _mk()
    ids = make_key_pages(remote, 12, ROWS, seed=7)
    batches = [ids[i : i + 2] for i in range(0, 12, 2)]  # 6 batches of 2

    pages = sched.read_coalesced(batches, max_pages=4)
    assert remote.ledger.c_read == 3  # 6 rounds fused into 3
    assert remote.ledger.d_read == 12
    np.testing.assert_array_equal(
        np.concatenate([p.ravel() for p in pages]),
        np.concatenate([p.ravel() for p in remote.peek_batch(ids)]),
    )

    remote.reset_accounting()
    sched.read_coalesced(batches)  # unbounded: one round
    assert remote.ledger.c_read == 1

    remote.reset_accounting()
    # A batch larger than the bound is split: rounds never exceed max_pages.
    pages = sched.read_coalesced([ids[:6], ids[6:]], max_pages=4)
    assert remote.ledger.c_read == 3
    assert remote.ledger.d_read == 12
    np.testing.assert_array_equal(
        np.concatenate([p.ravel() for p in pages]),
        np.concatenate([p.ravel() for p in remote.peek_batch(ids)]),
    )


@pytest.mark.parametrize("max_pages", [0, -1, -7])
def test_read_coalesced_rejects_nonpositive_max_pages(max_pages):
    """Regression: max_pages <= 0 used to loop forever instead of raising."""
    remote, sched = _mk()
    ids = make_key_pages(remote, 4, ROWS, seed=11)
    with pytest.raises(ValueError, match="max_pages >= 1"):
        sched.read_coalesced([ids], max_pages=max_pages)
    assert remote.ledger.c_read == 0  # nothing was issued before the check


def test_free_unknown_page_raises_keyerror():
    """Regression: silently ignoring unknown ids hid double-free bugs."""
    remote, sched = _mk()
    ids = make_key_pages(remote, 3, ROWS, seed=12)
    remote.free(ids)
    with pytest.raises(KeyError, match="double free"):
        remote.free(ids[:1])
    assert remote.pages_resident == 0


# ---------------------------------------------------------------------------
# Registry / plan_operator
# ---------------------------------------------------------------------------

_TIERS = list(TABLE_I.values()) + list(TESTBED.values())


@pytest.mark.parametrize("tier", _TIERS, ids=[t.name for t in _TIERS])
def test_plan_operator_reproduces_legacy_constructors(tier):
    """Registry planning == the old bnlj_plan/ems_plan/ehj_plan on every tier."""
    tau = tier.tau_pages
    stats = WorkloadStats(size_r=200, size_s=400, out=64, selectivity=1 / 512,
                          partitions=16, sigma=0.5, k_cap=8)
    assert plan_operator("bnlj", stats, tier, 13) == bnlj_plan(13, tau, 1 / 512)
    assert plan_operator("bnlj", stats, tier, 13, policy="conventional") == \
        bnlj_conventional(13)
    assert plan_operator("ems", stats, tier, 12) == ems_plan(200, 12, tau, k_cap=8)
    assert plan_operator("ems", stats, tier, 12, policy="duckdb") == ems_duckdb(12)
    assert plan_operator("ems", stats, tier, 12, policy="conventional") == \
        ems_conventional(12)
    assert plan_operator("ehj", stats, tier, 24) == \
        ehj_plan(200, 400, 64, 24, 16, 0.5)
    assert plan_operator("ehj", stats, tier, 24, policy="conventional") == \
        ehj_starved(24, 16, 0.5)


def test_plan_operator_accepts_tier_names():
    stats = WorkloadStats(selectivity=1 / 256)
    assert plan_operator("bnlj", stats, "tcp", 13) == \
        plan_operator("bnlj", stats, TABLE_I["tcp"], 13)


def test_plan_operator_rejects_unknown_op_policy_tier():
    stats = WorkloadStats()
    # Unknown op is a ValueError naming the registered operators (not a bare
    # KeyError), so callers see what they could have asked for.
    with pytest.raises(ValueError, match="unknown operator.*bnlj"):
        plan_operator("external_agg", stats, TIER, 13)
    with pytest.raises(ValueError, match="no policy"):
        plan_operator("bnlj", stats, TIER, 13, policy="duckdb")
    with pytest.raises(KeyError, match="unknown tier"):
        plan_operator("bnlj", stats, "floppy", 13)
    with pytest.raises(ValueError, match="m_pages >="):
        plan_operator("bnlj", stats, TIER, 2)


def test_registry_specs_are_complete():
    assert registry.names() == ("bnlj", "eagg", "ehj", "ems")
    for name in registry.names():
        spec = registry.get(name)
        plan = plan_operator(name, WorkloadStats(size_r=64, size_s=128, out=32),
                             TIER, 16)
        assert isinstance(plan, spec.plan_type)
        assert plan.op == name  # OperatorPlan protocol tag
        assert spec.policies[0] == "remop"
        assert callable(spec.run) and callable(spec.oracle)
        assert spec.model is not None and spec.min_pages >= 1.0
        # Latency model is (weakly) decreasing in the budget.
        stats = WorkloadStats(size_r=64, size_s=128, out=32)
        assert spec.model(stats, TIER.tau_pages, 32.0, "remop") <= \
            spec.model(stats, TIER.tau_pages, 8.0, "remop")


def test_registry_run_matches_oracle_end_to_end():
    """Registry runner + registry plan produce oracle-identical output."""
    from repro.remote import make_relation

    remote = RemoteMemory(TIER)
    outer = make_relation(remote, 20 * ROWS, ROWS, 128, seed=21)
    inner = make_relation(remote, 40 * ROWS, ROWS, 128, seed=22)
    spec = registry.get("bnlj")
    plan = plan_operator("bnlj", WorkloadStats(selectivity=1 / 128), TIER, 11)
    res = spec.run(remote, outer, inner, plan)
    assert res.output_rows == len(spec.oracle(remote, outer, inner))


# ---------------------------------------------------------------------------
# RemoteMemory satellite
# ---------------------------------------------------------------------------


def test_pages_resident_tracks_store():
    remote, sched = _mk()
    ids = make_key_pages(remote, 5, ROWS, seed=8)
    assert remote.pages_resident == 5
    new = sched.write([np.zeros(ROWS, dtype=np.int64)] * 2)
    assert remote.pages_resident == 7
    remote.free(ids[:3])
    assert remote.pages_resident == 4
    assert remote.peek_batch(new)[0].shape == (ROWS,)
