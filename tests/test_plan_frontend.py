"""Logical-plan frontend suite (ISSUE 7): lowering, join-order choice, and
fractional per-stream placement on the public session surface."""

import numpy as np
import pytest

from repro.core import TABLE_I
from repro.engine import Session, WorkloadStats
from repro.engine.plan import LogicalPlan, compile_plan
from repro.engine.registry import hierarchy_spec
from repro.remote import make_relation

ROWS = 8


def _hier(dram=64):
    return hierarchy_spec(
        (TABLE_I["dram"], dram), (TABLE_I["rdma"], 512), TABLE_I["ssd"])


def _q3ish(sess):
    """lineitem |><| orders |><| customer -> group-by -> order-by."""
    li = make_relation(sess.remote, 48 * ROWS, ROWS, 96, seed=21)
    o = make_relation(sess.remote, 24 * ROWS, ROWS, 96, seed=22)
    c = make_relation(sess.remote, 12 * ROWS, ROWS, 96, seed=23)
    lp = LogicalPlan("q3")
    l_n = lp.scan("lineitem", li, rows_per_page=ROWS)
    o_n = lp.scan("orders", o, rows_per_page=ROWS)
    c_n = lp.filter(lp.scan("customer", c, rows_per_page=ROWS), 0.5)
    j = lp.join(lp.join(l_n, o_n, out_pages=48.0), c_n, out_pages=48.0,
                sigma=0.5, partitions=8)
    lp.sort(lp.aggregate(j, out_pages=12.0, sigma=0.5, partitions=8), k_cap=8)
    return lp


# --------------------------------------------------------------------------
# Lowering
# --------------------------------------------------------------------------


def test_compile_lowers_to_dependency_ordered_dag():
    sess = Session(_hier(), budget=64)
    cp = compile_plan(sess, _q3ish(sess))
    assert [t.op for t in cp.tasks] == ["ehj", "ehj", "eagg", "ems"]
    assert cp.root is cp.tasks[-1]
    assert cp.output.task is cp.root
    res = cp.run(sess)
    assert res.schedule == "dag"
    assert res.makespan_seconds <= res.latency_seconds() + 1e-9
    # The sort's output is the sorted group keys, fully materialized.
    final = np.concatenate([
        p.ravel()
        for p in sess.remote.peek_batch(res.per_task[-1].result.run_page_ids)
    ])
    assert (np.diff(final) >= 0).all()


def test_compiled_chain_matches_hand_wired_tasks_byte_for_byte():
    """optimize=False on a chain == the hand-wired PR 5 task list."""
    a_sess = Session(_hier(), budget=64)
    lp = LogicalPlan("q")
    l_n = lp.scan("l", make_relation(a_sess.remote, 24 * ROWS, ROWS, 64,
                                     seed=31), rows_per_page=ROWS)
    r_n = lp.scan("r", make_relation(a_sess.remote, 12 * ROWS, ROWS, 64,
                                     seed=32), rows_per_page=ROWS)
    lp.sort(lp.join(l_n, r_n, out_pages=24.0, sigma=0.5, partitions=8),
            k_cap=8)
    res_a = compile_plan(a_sess, lp, optimize=False).run(
        a_sess, schedule="serial")

    b_sess = Session(_hier(), budget=64)
    build = make_relation(b_sess.remote, 24 * ROWS, ROWS, 64, seed=31)
    probe = make_relation(b_sess.remote, 12 * ROWS, ROWS, 64, seed=32)
    join = b_sess.task(
        "ehj", WorkloadStats(size_r=24, size_s=12, out=24, sigma=0.5,
                             partitions=8),
        inputs={"build": build, "probe": probe}, rows_per_page=ROWS,
    )
    sort = b_sess.task(
        "ems", WorkloadStats(size_r=24, out=24, k_cap=8),
        inputs={"page_ids": join.output}, rows_per_page=ROWS,
    )
    res_b = b_sess.run([join, sort])

    for a, b in zip(res_a.per_task, res_b.per_task):
        assert a.delta == b.delta
    assert res_a.total == res_b.total


def test_q18_shape_overlaps_independent_subtrees():
    """join(customer |><| orders, agg(lineitem)): the agg runs concurrently."""
    sess = Session(_hier(), budget=64)
    c = make_relation(sess.remote, 12 * ROWS, ROWS, 96, seed=41)
    o = make_relation(sess.remote, 24 * ROWS, ROWS, 96, seed=42)
    li = make_relation(sess.remote, 48 * ROWS, ROWS, 96, seed=43)
    lp = LogicalPlan("q18")
    agg = lp.aggregate(lp.scan("lineitem", li, rows_per_page=ROWS),
                       out_pages=12.0, sigma=0.5, partitions=8)
    lp.join(lp.join(lp.scan("customer", c, rows_per_page=ROWS),
                    lp.scan("orders", o, rows_per_page=ROWS),
                    out_pages=24.0),
            agg, out_pages=24.0, sigma=0.5, partitions=8)
    cp = compile_plan(sess, lp, optimize=False)
    deps = Session._dag_deps(cp.tasks)
    roots = [i for i, d in enumerate(deps) if not d]
    assert len(roots) == 2  # the agg and the first join are independent
    res = cp.run(sess)
    assert res.makespan_seconds < res.latency_seconds() - 1e-12


def test_empty_and_invalid_plans_raise():
    sess = Session(_hier(), budget=64)
    with pytest.raises(ValueError, match="empty"):
        compile_plan(sess, LogicalPlan("empty"))
    lp = LogicalPlan("scan_only")
    lp.scan("t", make_relation(sess.remote, 8 * ROWS, ROWS, 32, seed=51))
    with pytest.raises(ValueError, match="no operator tasks"):
        compile_plan(sess, lp)
    with pytest.raises(ValueError, match="join_op"):
        lp2 = LogicalPlan("j")
        a = lp2.scan("a", make_relation(sess.remote, 8 * ROWS, ROWS, 32,
                                        seed=52))
        b = lp2.scan("b", make_relation(sess.remote, 8 * ROWS, ROWS, 32,
                                        seed=53))
        lp2.join(a, b)
        compile_plan(sess, lp2, join_op="sortmerge")
    with pytest.raises(ValueError, match="selectivity"):
        lp2.filter(a, 1.5)
    with pytest.raises(ValueError, match="no pages"):
        LogicalPlan("x").scan("empty", [])
    with pytest.raises(TypeError, match="plan Node"):
        LogicalPlan("y").filter("not-a-node", 0.5)


# --------------------------------------------------------------------------
# Join-order choice
# --------------------------------------------------------------------------


def test_join_choice_never_models_worse_than_as_written():
    sess = Session(_hier(), budget=64)
    cp = compile_plan(sess, _q3ish(sess))
    assert len(cp.join_choices) == 1
    jc = cp.join_choices[0]
    assert jc.chosen_cost <= jc.left_deep_cost + 1e-9
    assert jc.candidates[0][0] == "left-deep (as written)"
    # Bounded candidate set: as-written + permutations + bushy.
    descs = [d for d, _ in jc.candidates]
    assert "bushy smallest-pair" in descs
    assert jc.chosen_cost == pytest.approx(
        min(c for _, c in jc.candidates), rel=1e-12)


def test_optimize_false_keeps_as_written_order():
    sess = Session(_hier(), budget=64)
    cp = compile_plan(sess, _q3ish(sess), optimize=False)
    assert cp.join_choices == []
    # As written: lineitem |><| orders first, then |><| customer.
    assert cp.tasks[0].stats.size_r == 48.0
    assert cp.tasks[0].stats.size_s == 24.0


def test_two_leaf_join_skips_enumeration():
    sess = Session(_hier(), budget=64)
    lp = LogicalPlan("q")
    a = lp.scan("a", make_relation(sess.remote, 8 * ROWS, ROWS, 32, seed=61))
    b = lp.scan("b", make_relation(sess.remote, 8 * ROWS, ROWS, 32, seed=62))
    lp.join(a, b, out_pages=8.0, sigma=0.5, partitions=8)
    cp = compile_plan(sess, lp)
    assert cp.join_choices == []
    assert len(cp.tasks) == 1


# --------------------------------------------------------------------------
# Fractional placement (per-stream tier routing) on the public surface
# --------------------------------------------------------------------------


def test_task_placement_routes_streams_to_named_tiers():
    sess = Session(_hier(dram=256), budget=64)
    build = make_relation(sess.remote, 24 * ROWS, ROWS, 64, seed=71,
                          tier="dram")
    probe = make_relation(sess.remote, 48 * ROWS, ROWS, 64, seed=72,
                          tier="dram")
    placement = {"build": "dram", "stage": "ssd", "output": "rdma"}
    join = sess.task(
        "ehj", WorkloadStats(size_r=24, size_s=48, out=48, sigma=0.5,
                             partitions=8),
        inputs={"build": build, "probe": probe}, rows_per_page=ROWS,
        placement=placement,
    )
    res = sess.run([join])
    # Every placed stream actually wrote pages on its tier.
    for tier in ("dram", "ssd", "rdma"):
        assert res.total.tier(tier).d_write > 0, tier


def test_task_placement_renders_in_explain():
    sess = Session(_hier(), budget=64)
    join = sess.task(
        "ehj", WorkloadStats(size_r=24, size_s=48, out=48, sigma=0.5,
                             partitions=8),
        placement={"build": "dram", "stage": "rdma"},
    )
    report = sess.explain([join])
    te = report.tasks[0]
    streams = {s: t for s, t, _ in te.streams}
    assert streams["build"] == "dram"
    assert streams["stage"] == "rdma"
    assert "streams:" in str(report)
    d = report.to_dict()
    assert d["tasks"][0]["streams"][0]["stream"] in ("build", "stage",
                                                     "output")


def test_task_placement_validation():
    sess = Session(_hier(), budget=64)
    stats = WorkloadStats(size_r=24, size_s=48, out=48)
    with pytest.raises(ValueError, match="unknown stream"):
        sess.task("ehj", stats, placement={"hash_table": "dram"})
    with pytest.raises(ValueError, match="placement"):
        sess.task("ehj", stats, placement={"build": "nvme"})
    single = Session(TABLE_I["tcp"], budget=64)
    with pytest.raises(ValueError, match="hierarchy"):
        single.task("ehj", stats, placement={"build": "dram"})


def test_plan_options_reach_placement():
    """Node options pass through: placement on a logical join node."""
    sess = Session(_hier(), budget=64)
    lp = LogicalPlan("q")
    a = lp.scan("a", make_relation(sess.remote, 12 * ROWS, ROWS, 64, seed=81),
                rows_per_page=ROWS)
    b = lp.scan("b", make_relation(sess.remote, 24 * ROWS, ROWS, 64, seed=82),
                rows_per_page=ROWS)
    lp.join(a, b, out_pages=24.0, sigma=0.5, partitions=8,
            placement={"build": "dram"})
    cp = compile_plan(sess, lp)
    assert cp.tasks[0].placement["build"] == "dram"
    res = cp.run(sess)
    assert res.per_task[0].op == "ehj"
