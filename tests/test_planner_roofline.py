"""Unit + property tests for the TPU planner and the roofline HLO parser."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import TPU_V5E
from repro.core.planner import (conventional_matmul_tiles, matmul_costs,
                                matmul_vmem, plan_dispatch, plan_grad_buckets,
                                plan_kv_pages, plan_matmul_tiles,
                                plan_microbatches, plan_sort)
from repro.core.roofline import parse_hlo_collectives, shape_bytes


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(m=st.sampled_from([512, 2048, 4096, 8192]),
       n=st.sampled_from([512, 2048, 16384]),
       k=st.sampled_from([512, 1024, 4096]))
def test_matmul_plan_feasible_and_not_worse(m, n, k):
    plan = plan_matmul_tiles(m, n, k, in_bytes=2)
    assert matmul_vmem(plan.bm, plan.bn, plan.bk, 2) <= TPU_V5E.vmem_bytes // 2
    conv = conventional_matmul_tiles(m, n, k, in_bytes=2)
    assert plan.l_cost <= conv.l_cost * (1 + 1e-9)
    # MXU alignment
    assert plan.bn % 128 == 0 and plan.bk % 128 == 0 and plan.bm % 8 == 0


def test_matmul_costs_monotone_in_tile_size():
    # Bigger tiles (same budget) -> fewer rounds, at most same D per side.
    d1, c1 = matmul_costs(4096, 4096, 4096, 128, 128, 128, 2, 4)
    d2, c2 = matmul_costs(4096, 4096, 4096, 512, 512, 512, 2, 4)
    assert c2 < c1 and d2 < d1


def test_sort_plan_uses_table4_fanin():
    plan = plan_sort(1 << 22, item_bytes=8)
    assert plan.k >= 2
    assert 0.5 < plan.r_in_frac < 1.0
    assert plan.passes >= 1


def test_dispatch_plan_waterfill_ratios():
    plan = plan_dispatch(tokens_per_device=4096, token_bytes=4096, experts=64,
                         ep_degree=16, buffer_budget=1 << 24)
    assert plan.sigma == pytest.approx(15 / 16)
    # Property 6: R_s / R_r = sigma * sqrt(P).
    assert plan.stage_pool / plan.read_pool == pytest.approx(
        plan.sigma * (16 ** 0.5), rel=1e-6)
    assert plan.a2a_rounds > 0


def test_grad_bucket_plan_beats_extremes():
    total, bwd, group = 4 * 10 ** 9, 0.1, 16
    plan = plan_grad_buckets(total, bwd, group)
    def exposed(b):
        ring = 2 * (group - 1) / group
        comm = ring * total / TPU_V5E.ici_bandwidth + b * TPU_V5E.collective_launch_s
        tail = ring * (total / b) / TPU_V5E.ici_bandwidth + TPU_V5E.collective_launch_s
        return max(comm - bwd, 0) + tail

    assert plan.exposed_seconds <= exposed(1) + 1e-9
    assert plan.exposed_seconds <= exposed(256) + 1e-9


def test_kv_page_plan_fits_vmem_and_beats_tiny_pages():
    plan = plan_kv_pages(context_len=32768, kv_heads=1, head_dim=128)
    assert plan.page_tokens >= 128
    tiny_l = (2.0 * 32768 * 128 * 2
              + TPU_V5E.tau_dma_bytes * 2.0 * (32768 / 8))
    assert plan.l_cost < tiny_l


def test_microbatch_plan_fits_budget():
    plan = plan_microbatches(per_device_batch=16, seq_len=4096, d_model=6144,
                             n_layers=52, hbm_activation_budget=6 << 30)
    act = (16 / plan.microbatches) * 4096 * 6144 * 2 * 2.0 * 52
    assert act <= 6 << 30
    assert 16 % plan.microbatches == 0


# ---------------------------------------------------------------------------
# roofline parser
# ---------------------------------------------------------------------------


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("(bf16[64], f32[8,8])") == 64 * 2 + 64 * 4
    assert shape_bytes("pred[]") == 1


HLO_SAMPLE = """
HloModule test
ENTRY %main {
  %p0 = f32[512,128]{1,0} parameter(0)
  %ar = f32[512,128]{1,0} all-reduce(%p0), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[2048,128]{1,0} all-gather(%ar), replica_groups=[2,4]<=[8], dimensions={0}
  %rs = f32[128,128]{1,0} reduce-scatter(%ar), replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %out = f32[2048,128]{1,0} copy(%ag)
}
"""


def test_parse_hlo_collectives_sample():
    ops = parse_hlo_collectives(HLO_SAMPLE)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "reduce-scatter"]
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.operand_bytes == 512 * 128 * 4
    assert ar.group_size == 4
    assert ar.wire_bytes == pytest.approx(2 * 512 * 128 * 4 * 3 / 4)
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.output_bytes == 2048 * 128 * 4
    assert ag.wire_bytes == pytest.approx(2048 * 128 * 4 * 3 / 4)


def test_parse_real_compiled_module():
    """End-to-end: compile a sharded program and parse its collectives."""
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.roofline import parse_hlo_collectives
mesh = jax.make_mesh((2, 4), ("data", "model"))
def f(a, b):
    return jnp.sum(a @ b)
a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
b = jax.ShapeDtypeStruct((256, 256), jnp.float32)
sa = NamedSharding(mesh, P("data", "model"))
sb = NamedSharding(mesh, P("model", None))
c = jax.jit(f, in_shardings=(sa, sb),
            out_shardings=NamedSharding(mesh, P())).lower(a, b).compile()
ops = parse_hlo_collectives(c.as_text())
assert len(ops) >= 1, "expected at least one collective"
assert all(o.operand_bytes > 0 for o in ops)
print("PARSER_OK", len(ops))
"""
    env = {**os.environ,
           "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PARSER_OK" in out.stdout
