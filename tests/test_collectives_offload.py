"""Tests for bucketed collectives, hierarchical reduce, and host offload."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.collectives import partition_buckets
from repro.distributed.offload import HostOffloader, plan_offload_chunks

_ENV = {**os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def test_partition_buckets_balanced_and_complete():
    tree = {"a": jnp.zeros((1000,)), "b": jnp.zeros((10,)),
            "c": jnp.zeros((500,)), "d": jnp.zeros((499,))}
    buckets = partition_buckets(tree, 2)
    all_idx = sorted(i for b in buckets for i in b)
    assert all_idx == [0, 1, 2, 3]
    leaves = jax.tree.leaves(tree)
    loads = [sum(leaves[i].size for i in b) for b in buckets]
    assert max(loads) - min(loads) <= 1000  # roughly balanced


def test_bucketed_psum_matches_plain_psum():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import bucketed_psum, hierarchical_grad_reduce
mesh = jax.make_mesh((2, 4), ("pod", "data"))
tree = {"w": jnp.arange(24.0).reshape(2, 12), "b": jnp.ones((7,))}

def f(t):
    def local(t):
        return bucketed_psum(t, "data", group_size=4)
    return shard_map(local, mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)(t)

got = jax.jit(f)(tree)
want = jax.tree.map(lambda x: x * 4.0, tree)  # psum over data axis (size 4)
for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
    np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)

def h(t):
    def local(t):
        return hierarchical_grad_reduce(t, "data", "pod")
    return shard_map(local, mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)(t)

got2 = jax.jit(h)(tree)
want2 = jax.tree.map(lambda x: x * 8.0, tree)  # full 2x4 reduction
for g, w in zip(jax.tree.leaves(got2), jax.tree.leaves(want2)):
    np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
print("BUCKETED_PSUM_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], env=_ENV,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "BUCKETED_PSUM_OK" in out.stdout


def test_offload_plan_rounds():
    plan = plan_offload_chunks(1 << 30, staging_budget=256 << 20)
    assert plan.n_chunks == 8  # 1 GiB through 128 MiB double-buffered chunks
    tiny = plan_offload_chunks(1 << 20)
    assert tiny.n_chunks == 1


def test_host_offloader_roundtrip():
    off = HostOffloader(staging_budget=64 << 20)
    tree = {"k": jnp.arange(100.0), "v": {"x": jnp.ones((3, 3), jnp.bfloat16)}}
    h = off.offload(tree)
    back = off.restore(h)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert off.rounds >= 4  # 2 leaves, each offloaded + restored
    assert off.bytes_moved == 2 * (100 * 4 + 9 * 2)
    with pytest.raises(KeyError):
        off.restore(h)  # handle freed
