"""Integration tests: training loop, checkpoint/restart, fault tolerance,
optimizer, data pipeline, serving engine (single CPU device)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeSpec
from repro.data.pipeline import PrefetchingLoader, synthetic_batches
from repro.distributed.sharding import Sharder
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh_for
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim import compression
from repro.runtime.ft import RetryPolicy, StragglerWatch
from repro.runtime.serve_loop import Request, ServeEngine
from repro.runtime.train_loop import LoopConfig, train


def _trainer(arch="qwen3-0.6b", steps=30, lr=3e-3):
    cfg = reduced(ARCHS[arch])
    shape = ShapeSpec("t", seq_len=32, global_batch=4, kind="train")
    mesh = make_mesh_for(1)
    sharder = Sharder(mesh, sequence_parallel=False)
    opt = AdamWConfig(lr=lr, total_steps=steps, warmup_steps=2, weight_decay=0.0)
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt, sharder))
    state = steps_lib.init_state(cfg, jax.random.key(0))
    return cfg, shape, step_fn, state


def test_loss_decreases_over_training():
    cfg, shape, step_fn, state = _trainer(steps=30)
    # Fixed batch -> loss must drop markedly (memorization).
    batch = next(synthetic_batches(cfg, shape, seed=1))
    batch = jax.tree.map(jnp.asarray, batch)
    first = last = None
    for _ in range(30):
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
    assert last < first * 0.7, (first, last)


def test_train_loop_with_checkpoint_and_resume(tmp_path):
    cfg, shape, step_fn, state = _trainer(steps=10)
    store = CheckpointStore(str(tmp_path), keep=2)

    def batches(start):
        return PrefetchingLoader(synthetic_batches(cfg, shape, seed=0,
                                                   start_step=start))

    out = train(step_fn, state, batches, store,
                LoopConfig(total_steps=10, checkpoint_every=5, log_every=100,
                           async_checkpoint=False))
    assert int(out["step"]) == 10
    assert store.latest_step() == 10

    # Restart from scratch: loop should resume from the checkpoint, not step 0.
    out2 = train(step_fn, out, batches, store,
                 LoopConfig(total_steps=10, checkpoint_every=5, log_every=100))
    assert int(out2["step"]) == 10


def test_restart_after_injected_failure(tmp_path):
    cfg, shape, step_fn, state = _trainer(steps=8)
    store = CheckpointStore(str(tmp_path), keep=3)
    calls = {"n": 0}

    def flaky_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 6:  # die once mid-run (after ckpt at step 4)
            raise RuntimeError("injected node failure")
        return step_fn(state, batch)

    def batches(start):
        return PrefetchingLoader(synthetic_batches(cfg, shape, seed=0,
                                                   start_step=start))

    out = train(flaky_step, state, batches, store,
                LoopConfig(total_steps=8, checkpoint_every=4, log_every=100,
                           async_checkpoint=False, max_restarts=2))
    assert int(out["step"]) == 8  # completed despite the failure


def test_checkpoint_atomicity_and_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    state = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((2, 2))}}
    for s in (1, 2, 3):
        store.save(s, state, {"step": s}, blocking=True)
    assert store.latest_step() == 3
    files = sorted(os.listdir(tmp_path))
    assert len([f for f in files if f.endswith(".npz")]) == 2  # gc keep=2
    assert not any(f.endswith(".tmp") for f in files)
    restored, meta = store.restore(3, state)
    assert meta["step"] == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))


def test_straggler_watch():
    w = StragglerWatch(threshold=2.0)
    assert not w.observe(1, 1.0)
    assert not w.observe(2, 1.1)
    assert w.observe(3, 5.0)
    assert w.slow_steps == 1


def test_retry_policy_gives_up():
    p = RetryPolicy(max_restarts=2, backoff_seconds=0.0)

    def always_fails():
        raise ValueError("nope")

    with pytest.raises(ValueError):
        p.run(always_fails)


def test_adamw_quadratic_convergence():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_frac=1.0)
    params = {"x": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    step = jnp.zeros((), jnp.int32)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt, step)
        step = step + 1
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.05


def test_int8_compression_error_feedback():
    g = {"w": jnp.linspace(-1, 1, 64)}
    res = None
    acc = jnp.zeros((64,))
    acc_exact = jnp.zeros((64,))
    for _ in range(50):
        qs, scales, res = compression.compress_int8_with_feedback(g, res)
        deq = compression.decompress_int8(qs, scales)
        acc = acc + deq["w"]
        acc_exact = acc_exact + g["w"]
    # Error feedback keeps the accumulated bias tiny.
    rel = float(jnp.max(jnp.abs(acc - acc_exact)) / jnp.max(jnp.abs(acc_exact)))
    assert rel < 0.01, rel


def test_prefetching_loader_order_and_shutdown():
    cfg = reduced(ARCHS["qwen3-0.6b"])
    shape = ShapeSpec("t", seq_len=16, global_batch=2, kind="train")
    loader = PrefetchingLoader(synthetic_batches(cfg, shape, seed=3))
    b0 = next(loader)
    b1 = next(loader)
    assert b0["tokens"].shape == (2, 16)
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))
    loader.close()


def test_serve_engine_continuous_batching():
    cfg = reduced(ARCHS["gemma-2b"])
    params = tf.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6, dtype=np.int32),
                    max_new_tokens=4) for i in range(3)]
    engine = ServeEngine(cfg, params, max_len=32, batch_slots=2)
    results = engine.submit(reqs)
    assert sorted(results) == [0, 1, 2]
    assert all(len(v) == 4 for v in results.values())
    # Deterministic: same prompts -> same outputs.
    reqs2 = [Request(rid=i, prompt=reqs[i].prompt, max_new_tokens=4)
             for i in range(3)]
    results2 = ServeEngine(cfg, params, max_len=32, batch_slots=3).submit(reqs2)
    assert results == results2
