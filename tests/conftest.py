"""Shared pytest configuration.

Provides a deterministic fallback for ``hypothesis`` when the package is not
installed (e.g. minimal containers): the property tests then run against a
fixed pseudo-random sample of each strategy instead of failing collection.
The fallback covers exactly the strategy surface this suite uses
(``integers``, ``floats``, ``sampled_from``, ``booleans``, ``lists``) and the
``@settings(max_examples=..., deadline=...)`` knob; installing the real
``hypothesis`` (see requirements-dev.txt) transparently takes precedence.
"""

from __future__ import annotations

import random
import sys
import types
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _sampled_from(elements):
        pool = list(elements)
        return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def _lists(elements, min_size=0, max_size=None):
        hi = min_size + 8 if max_size is None else max_size

        def draw(rng):
            return [elements.draw(rng) for _ in range(rng.randint(min_size, hi))]

        return _Strategy(draw)

    def _given(**param_strategies):
        def decorate(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                # Seed from the test name so each test gets a stable, distinct
                # example stream across runs and processes.
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    kwargs = {
                        name: strat.draw(rng)
                        for name, strat in param_strategies.items()
                    }
                    try:
                        fn(**kwargs)
                    except AssertionError as exc:
                        raise AssertionError(
                            f"{exc}\nFalsifying example ({fn.__name__}): {kwargs!r}"
                        ) from exc

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return decorate

    def _settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn

        return decorate

    _hyp = types.ModuleType("hypothesis")
    _hyp.__doc__ = "Deterministic stand-in for hypothesis (see tests/conftest.py)."
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.lists = _lists
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
