"""Operator pushdown: closed forms, the chooser, and the arbitration path.

Four layers, matching how the feature is built:

* **Closed forms** (`pushdown_costs` / `pushdown_reduce_costs`) are
  ledger-exact against the simulated hierarchy on every capable test tier —
  field-for-field, not approximately.
* **The chooser** (`pushdown_or_ship`) prices ship-the-pages against
  ship-the-compute: pushes only when the tier's compute beats the volume it
  saves, ships on ties and on non-capable tiers, and is never worse than
  ship-only by construction.
* **The data plane** (`TransferScheduler.read_filtered`) returns identical
  survivors whether the filter is pushed or shipped; only the accounting
  moves.
* **The session/plan path**: the arbiter's verdict shows up in
  ``explain()``, explicit task options override it, and the plan frontend
  records which filters compiled physically vs. stayed annotations.
"""

import math

import pytest

from repro.core import TABLE_I
from repro.core.cost_model import TierLevel, hierarchy_spec
from repro.core.policies import (pushdown_costs, pushdown_or_ship,
                                 pushdown_reduce_costs)
from repro.engine import Session
from repro.engine.plan import LogicalPlan, compile_plan
from repro.engine.scheduler import TransferScheduler
from repro.remote import MemoryHierarchy, make_relation

ROWS = 8
DOMAIN = 64

# Wire rate of the rdma tier is ~25.9k pages/s: 200k pps beats it (pushdown
# can win), 2k pps loses to it (the chooser must decline).
FAST = 200_000.0
SLOW = 2_000.0


def _capable(tier, pps, ops=("filter", "reduce"), capacity=4096.0):
    return TierLevel(tier=tier, capacity_pages=capacity, compute_pps=pps,
                     pushdown_ops=ops)


# ---------------------------------------------------------------------------
# Closed forms vs. the simulated ledger
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tier_name", ["rdma", "tcp", "ssd"])
@pytest.mark.parametrize("batch", [1, 7, 50])
def test_pushdown_costs_ledger_exact_per_tier(tier_name, batch):
    level = _capable(TABLE_I[tier_name], FAST)
    hier = MemoryHierarchy(hierarchy_spec((TABLE_I["dram"], 4.0), level))
    rel = make_relation(hier, 50 * ROWS, ROWS, DOMAIN, seed=21,
                        tier=tier_name)
    sched = TransferScheduler(hier)

    before = sched.snapshot()
    kept = sched.read_filtered(rel.page_ids, selectivity=0.4,
                               batch_pages=batch)
    delta = sched.delta(before)
    pc = pushdown_costs(50, 0.4, level, batch_pages=batch)
    assert len(kept) == pc.d_ship == math.floor(50 * 0.4)
    assert delta.d_read == pc.d_ship
    assert delta.c_read == pc.c_rounds
    assert delta.c_pushdown == pc.c_rounds
    assert delta.d_pushdown == pc.d_ship
    assert delta.d_pushdown_saved == pc.d_saved


@pytest.mark.parametrize("tier_name", ["rdma", "tcp"])
def test_pushdown_reduce_costs_ledger_exact(tier_name):
    level = _capable(TABLE_I[tier_name], FAST)
    hier = MemoryHierarchy(hierarchy_spec((TABLE_I["dram"], 4.0), level))
    rel = make_relation(hier, 50 * ROWS, ROWS, DOMAIN, seed=22,
                        tier=tier_name)
    sched = TransferScheduler(hier)

    before = sched.snapshot()
    out = hier.read_reduced(tier_name, rel.page_ids,
                            lambda pages: pages[0][:2], ROWS)
    delta = sched.delta(before)
    pr = pushdown_reduce_costs(50, float(len(out)), level)
    assert delta.d_read == pr.d_ship
    assert delta.c_read == pr.c_rounds == 1
    assert delta.c_pushdown == pr.c_rounds
    assert delta.d_pushdown == pr.d_ship
    assert delta.d_pushdown_saved == pr.d_saved


def test_pushdown_costs_latency_cost_is_eq1_plus_compute():
    level = _capable(TABLE_I["rdma"], FAST)
    pc = pushdown_costs(40, 0.5, level, batch_pages=10)
    tau = 3.0
    expected = pc.d_ship + tau * pc.c_rounds + level.compute_tau_pages * 40
    assert pc.latency_cost(tau) == pytest.approx(expected)
    assert pc.compute_seconds == pytest.approx(40 / FAST)


@pytest.mark.parametrize("bad", [float("nan"), float("inf"), 0.0, 1.5, -0.2])
def test_pushdown_costs_rejects_bad_selectivity(bad):
    level = _capable(TABLE_I["rdma"], FAST)
    with pytest.raises(ValueError, match="selectivity"):
        pushdown_costs(10, bad, level)


def test_pushdown_costs_rejects_non_capable_tier():
    level = TierLevel(tier=TABLE_I["rdma"], capacity_pages=64.0)
    with pytest.raises(ValueError, match="cannot execute"):
        pushdown_costs(10, 0.5, level)
    with pytest.raises(ValueError, match="cannot execute"):
        pushdown_reduce_costs(10, 2.0, level)


# ---------------------------------------------------------------------------
# The ship-pages vs. ship-compute chooser
# ---------------------------------------------------------------------------


def test_chooser_pushes_when_compute_beats_the_wire():
    level = _capable(TABLE_I["rdma"], FAST)
    tau = TABLE_I["rdma"].tau_pages
    ch = pushdown_or_ship(50, 0.4, level, tau, batch_pages=10)
    assert ch.push and ch.mode == "push"
    assert ch.l_push < ch.l_ship
    assert ch.l_delta == ch.l_push - ch.l_ship < 0
    assert ch.c_pushdown == math.ceil(50 / 10)
    assert ch.d_saved == 50 - math.floor(50 * 0.4)
    assert ch.scanned == 50.0


def test_chooser_declines_when_compute_is_slower_than_the_wire():
    level = _capable(TABLE_I["rdma"], SLOW)
    tau = TABLE_I["rdma"].tau_pages
    ch = pushdown_or_ship(50, 0.4, level, tau, batch_pages=10)
    assert not ch.push and ch.mode == "ship"
    assert math.isfinite(ch.l_push) and ch.l_push > ch.l_ship
    assert ch.l_delta == 0.0
    assert ch.c_pushdown == 0 and ch.d_saved == 0.0


def test_chooser_ships_on_non_capable_tier_with_infinite_l_push():
    level = TierLevel(tier=TABLE_I["rdma"], capacity_pages=64.0)
    ch = pushdown_or_ship(50, 0.4, level, tau=3.0)
    assert not ch.push
    assert math.isinf(ch.l_push)
    assert ch.l_delta == 0.0


def test_chooser_ships_on_exact_tie():
    # Infinitely fast compute + selectivity 1 makes l_push == l_ship
    # exactly: nothing saved, nothing spent.  Ties must ship.
    level = _capable(TABLE_I["rdma"], math.inf)
    ch = pushdown_or_ship(50, 1.0, level, tau=3.0, batch_pages=10)
    assert ch.l_push == ch.l_ship
    assert not ch.push and ch.l_delta == 0.0


def test_chooser_never_worse_than_ship_across_grid():
    tau = TABLE_I["rdma"].tau_pages
    for pps in (FAST, SLOW, 30_000.0):
        level = _capable(TABLE_I["rdma"], pps)
        for sel in (0.1, 0.5, 1.0):
            for batch in (1, 8, 64):
                ch = pushdown_or_ship(64, sel, level, tau,
                                      batch_pages=batch)
                assert min(ch.l_push, ch.l_ship) <= ch.l_ship
                assert ch.l_delta <= 0.0


def test_chooser_reduce_and_edge_validation():
    level = _capable(TABLE_I["rdma"], FAST)
    ch = pushdown_or_ship(50, 1.0, level, tau=3.0, op="reduce",
                          out_pages=2.0)
    assert ch.push and ch.op == "reduce"
    assert ch.d_saved == 48.0 and ch.c_pushdown == 1
    with pytest.raises(ValueError, match="out_pages"):
        pushdown_or_ship(50, 1.0, level, tau=3.0, op="reduce")
    # An op the tier doesn't declare just ships; an op nothing knows how to
    # price raises once a tier claims it.
    shipped = pushdown_or_ship(50, 1.0, level, tau=3.0, op="project")
    assert not shipped.push and math.isinf(shipped.l_push)
    claims = _capable(TABLE_I["rdma"], FAST, ops=("project",))
    with pytest.raises(ValueError, match="unknown pushdown op"):
        pushdown_or_ship(50, 1.0, claims, tau=3.0, op="project")
    empty = pushdown_or_ship(0, 0.5, level, tau=3.0)
    assert not empty.push and empty.l_ship == 0.0


# ---------------------------------------------------------------------------
# Data plane: pushed and shipped filters return identical survivors
# ---------------------------------------------------------------------------


def _two_tier_scheduler(pps):
    level = _capable(TABLE_I["rdma"], pps, ops=("filter",))
    hier = MemoryHierarchy(hierarchy_spec((TABLE_I["dram"], 8.0), level))
    rel = make_relation(hier, 24 * ROWS, ROWS, DOMAIN, seed=31, tier="rdma")
    # Split the stream across tiers so read_filtered exercises both the
    # pushed (rdma) and the local (dram) paths in one call.
    hier.promote(rel.page_ids[:6])
    return TransferScheduler(hier), rel


def test_read_filtered_pushdown_matches_ship_survivors():
    for kwargs in ({"selectivity": 0.5},
                   {"predicate": lambda page: page[0, 0] % 2 == 0}):
        sched_a, rel_a = _two_tier_scheduler(FAST)
        sched_b, rel_b = _two_tier_scheduler(FAST)
        pushed = sched_a.read_filtered(rel_a.page_ids, batch_pages=5,
                                       pushdown=True, **kwargs)
        shipped = sched_b.read_filtered(rel_b.page_ids, batch_pages=5,
                                        pushdown=False, **kwargs)
        assert len(pushed) == len(shipped) > 0
        for p, s in zip(pushed, shipped):
            assert (p == s).all()
        # Same survivors, different accounting: the pushed run stamps
        # pushdown rounds and saves wire volume; the shipped run does not.
        da = sched_a.snapshot()
        db = sched_b.snapshot()
        assert da.c_pushdown > 0 and da.d_pushdown_saved > 0
        assert db.c_pushdown == 0 and db.d_pushdown_saved == 0
        assert da.d_read < db.d_read


def test_scan_filtered_requires_residency_and_capability():
    level = _capable(TABLE_I["rdma"], FAST, ops=("filter",))
    hier = MemoryHierarchy(hierarchy_spec((TABLE_I["dram"], 8.0), level))
    rel = make_relation(hier, 8 * ROWS, ROWS, DOMAIN, seed=32, tier="rdma")
    hier.promote(rel.page_ids[:2])
    with pytest.raises(ValueError, match="resident"):
        hier.scan_filtered("rdma", rel.page_ids, selectivity=0.5)
    with pytest.raises(ValueError, match="cannot execute"):
        hier.scan_filtered("dram", rel.page_ids[:2], selectivity=0.5)


# ---------------------------------------------------------------------------
# Session arbitration + plan frontend
# ---------------------------------------------------------------------------


def _session(pps, budget=24.0):
    remote = TierLevel(
        tier=TABLE_I["rdma"], capacity_pages=4096.0, compute_pps=pps,
        pushdown_ops=("filter", "reduce") if pps else (),
    )
    # dram too small to host the join spill: placement lands on the capable
    # remote tier, where the verdict is priced.
    return Session(hierarchy_spec((TABLE_I["dram"], 4.0), remote),
                   budget=budget)


def _compiled(sess, sel=0.4, predicate=None, **join_opts):
    r = make_relation(sess.remote, 30 * ROWS, ROWS, DOMAIN, seed=11,
                      tier="rdma")
    s = make_relation(sess.remote, 50 * ROWS, ROWS, DOMAIN, seed=12,
                      tier="rdma")
    lp = LogicalPlan("pd")
    r_n = lp.scan("R", r, rows_per_page=ROWS)
    s_n = lp.filter(lp.scan("S", s, rows_per_page=ROWS), sel, name="sel_s",
                    predicate=predicate)
    lp.join(r_n, s_n, out_pages=20.0, name="J", selectivity=0.4,
            **join_opts)
    return compile_plan(sess, lp, join_op="bnlj")


def _verdicts(report):
    return {t.label: t.pushdown for t in report.tasks
            if t.pushdown is not None}


def test_session_arbiter_pushes_on_capable_tier_and_explains_it():
    sess = _session(FAST)
    cp = _compiled(sess)
    assert cp.pushed_filters == ["sel_s"]
    assert cp.annotation_filters == []
    # Two-leaf clusters skip shape enumeration: no JoinChoice recorded.
    assert cp.join_choices == []
    report = cp.explain(sess)
    (choice,) = _verdicts(report).values()
    assert choice.push and choice.mode == "push"
    assert "pushdown: push(filter)@rdma" in str(report)
    res = cp.run(sess)
    assert sess.remote.snapshot().c_pushdown > 0
    assert res.per_task[-1].result.output_rows > 0


def test_session_arbiter_declines_past_the_compute_crossover():
    sess = _session(SLOW)
    cp = _compiled(sess)
    report = cp.explain(sess)
    (choice,) = _verdicts(report).values()
    assert not choice.push and math.isfinite(choice.l_push)
    assert "compute too slow" in str(report)
    cp.run(sess)
    assert sess.remote.snapshot().c_pushdown == 0


def test_session_explains_non_capable_tier():
    sess = _session(None)
    report = _compiled(sess).explain(sess)
    (choice,) = _verdicts(report).values()
    assert not choice.push and math.isinf(choice.l_push)
    assert "tier cannot execute it" in str(report)


def test_arbitrated_run_never_worse_and_output_identical():
    for pps in (FAST, SLOW, None):
        arb_sess = _session(pps)
        arb = _compiled(arb_sess)
        arb_res = arb.run(arb_sess)
        ship_sess = _session(pps)
        ship = _compiled(ship_sess, pushdown=False)
        ship_res = ship.run(ship_sess)
        assert (arb_res.per_task[-1].result.output_rows
                == ship_res.per_task[-1].result.output_rows)
        assert (arb_res.latency_seconds()
                <= ship_res.latency_seconds() * (1 + 1e-9))
        if pps == FAST:
            assert (arb_res.latency_seconds()
                    < ship_res.latency_seconds() * (1 - 1e-9))


def test_explicit_task_option_overrides_arbiter_verdict():
    sess = _session(FAST)
    cp = _compiled(sess, pushdown=False)
    cp.run(sess)
    assert sess.remote.snapshot().c_pushdown == 0


def test_plan_predicate_filter_reaches_the_operator():
    sess = _session(FAST)
    pred = lambda page: page[0, 0] % 2 == 0  # noqa: E731
    cp = _compiled(sess, predicate=pred)
    (join_task,) = [t for t in cp.tasks if t.op == "bnlj"]
    assert join_task.options.get("inner_filter") is pred
    res = cp.run(sess)
    assert res.per_task[-1].result.output_rows > 0


@pytest.mark.parametrize("bad", [float("nan"), float("inf"), 0.0, 1.5])
def test_plan_filter_rejects_non_finite_selectivity(bad):
    lp = LogicalPlan("bad")
    rel = list(range(4))
    with pytest.raises(ValueError, match="selectivity"):
        lp.filter(lp.scan("T", rel), bad)


def test_plan_filter_rejects_non_callable_predicate():
    lp = LogicalPlan("bad")
    with pytest.raises(TypeError, match="callable"):
        lp.filter(lp.scan("T", list(range(4))), 0.5, predicate=5)


def test_three_leaf_cluster_records_disposition_on_its_join_choice():
    sess = _session(FAST)
    a = make_relation(sess.remote, 10 * ROWS, ROWS, DOMAIN, seed=41,
                      tier="rdma")
    b = make_relation(sess.remote, 20 * ROWS, ROWS, DOMAIN, seed=42,
                      tier="rdma")
    c = make_relation(sess.remote, 40 * ROWS, ROWS, DOMAIN, seed=43,
                      tier="rdma")
    lp = LogicalPlan("q3")
    a_n = lp.scan("A", a, rows_per_page=ROWS)
    b_n = lp.scan("B", b, rows_per_page=ROWS)
    c_n = lp.filter(lp.scan("C", c, rows_per_page=ROWS), 0.3, name="fc")
    j1 = lp.join(a_n, b_n, out_pages=8.0, selectivity=0.4)
    lp.join(j1, c_n, out_pages=12.0, name="top", selectivity=0.4)
    cp = compile_plan(sess, lp, join_op="bnlj")
    (choice,) = cp.join_choices
    # The cluster-level record and the plan-level record agree, and every
    # filter lands in exactly one disposition bucket.
    assert list(choice.pushed_filters) == cp.pushed_filters
    assert sorted(cp.pushed_filters + cp.annotation_filters) == ["fc"]


def test_ehj_plan_keeps_filters_as_annotations():
    sess = _session(FAST)
    r = make_relation(sess.remote, 30 * ROWS, ROWS, DOMAIN, seed=11,
                      tier="rdma")
    s = make_relation(sess.remote, 50 * ROWS, ROWS, DOMAIN, seed=12,
                      tier="rdma")
    lp = LogicalPlan("ehj")
    r_n = lp.scan("R", r, rows_per_page=ROWS)
    s_n = lp.filter(lp.scan("S", s, rows_per_page=ROWS), 0.4, name="sel_s")
    lp.join(r_n, s_n, out_pages=20.0, name="J")
    cp = compile_plan(sess, lp, join_op="ehj")
    assert cp.pushed_filters == []
    assert cp.annotation_filters == ["sel_s"]
