"""Multi-tenant serving suite: the ``Server`` invariants this PR pins.

  * single-tenant admission reproduces the standalone
    ``Session.run(replan="measured")`` ledger byte-for-byte and its
    simulated latency exactly;
  * per-tenant ledger deltas sum field-by-field to the shared
    ``HierarchySnapshot`` totals, preemption rounds included;
  * admission control: ``slots`` bounds concurrency, FIFO-within-priority
    ordering, queueing when the joint footprint is infeasible, and a
    ``RuntimeError`` for a request that can never be admitted;
  * priority: higher-priority arrivals are admitted first and may trigger
    preemptive demotion of lower-priority residency (never the converse);
  * mode semantics: ``fifo`` serializes, ``even`` never re-arbitrates.
"""

import dataclasses

import pytest

from repro.core import TABLE_I
from repro.engine import (
    QueryRequest,
    Server,
    Session,
    WorkloadStats,
)
from repro.engine.registry import hierarchy_spec
from repro.remote import make_relation
from repro.remote.simulator import make_key_pages

ROWS = 8
HSPEC = hierarchy_spec((TABLE_I["dram"], 48), (TABLE_I["rdma"], 512),
                       TABLE_I["ssd"])
BUDGET = 96.0


def _sort_tasks_of(pages=96, seed=3, tier=None):
    def tasks_of(sess):
        ids = make_key_pages(sess.remote, pages, ROWS, seed=seed, tier=tier)
        return [
            sess.task("ems", WorkloadStats(size_r=pages, k_cap=8),
                      inputs={"page_ids": ids}, rows_per_page=ROWS),
        ]
    return tasks_of


def _pipeline_tasks_of(seed=11):
    def tasks_of(sess):
        ids = make_key_pages(sess.remote, 96, ROWS, seed=seed)
        build = make_relation(sess.remote, 48 * ROWS, ROWS, 96, seed=seed + 1)
        probe = make_relation(sess.remote, 96 * ROWS, ROWS, 96, seed=seed + 2)
        return [
            sess.task("ems", WorkloadStats(size_r=96, k_cap=8),
                      inputs={"page_ids": ids}, rows_per_page=ROWS),
            sess.task("ehj",
                      WorkloadStats(size_r=48, size_s=96, out=36,
                                    partitions=8, sigma=0.5),
                      inputs={"build": build, "probe": probe}),
        ]
    return tasks_of


def _assert_tenant_sum(rep):
    for name in HSPEC.names:
        assert rep.tenant_total.tier(name) == rep.total.tier(name), name


# ---------------------------------------------------------------------------
# Single-tenant parity: serving one query is exactly a standalone Session
# ---------------------------------------------------------------------------


def test_single_tenant_parity_ledger_and_latency():
    tasks_of = _pipeline_tasks_of()
    sess = Session(HSPEC, budget=BUDGET, eviction="lru")
    res = sess.run(tasks_of(sess), replan="measured")
    solo = res.latency_seconds()

    srv = Server(HSPEC, budget=BUDGET, slots=4)
    srv.submit(QueryRequest(rid=7, tasks_of=tasks_of, label="solo"))
    rep = srv.run()
    q = rep.query(7)

    for name in HSPEC.names:
        assert res.total.tier(name) == q.ledger.tier(name), name
    assert q.latency == pytest.approx(solo, rel=1e-12)
    assert q.wait == 0.0
    assert rep.makespan == pytest.approx(solo, rel=1e-12)
    _assert_tenant_sum(rep)


def test_single_tenant_parity_all_modes():
    """A lone query must not care how the server would share the machine."""
    lats = {}
    for mode in ("arbitrated", "even", "fifo"):
        srv = Server(HSPEC, budget=BUDGET, mode=mode, slots=2)
        srv.submit(QueryRequest(rid=0, tasks_of=_sort_tasks_of()))
        lats[mode] = srv.run().query(0).latency
    assert lats["arbitrated"] == pytest.approx(lats["fifo"], rel=1e-12)
    # Even-split plans against 1/slots of the machine even when alone; it
    # must still finish, but has no parity claim.
    assert lats["even"] > 0.0


# ---------------------------------------------------------------------------
# Shared-hierarchy accounting
# ---------------------------------------------------------------------------


def test_per_tenant_ledgers_sum_to_hierarchy_total():
    srv = Server(HSPEC, budget=BUDGET, slots=3)
    srv.submit([
        QueryRequest(rid=0, tasks_of=_pipeline_tasks_of(21), arrival=0.0),
        QueryRequest(rid=1, tasks_of=_sort_tasks_of(seed=22), arrival=0.001,
                     priority=2.0),
        QueryRequest(rid=2, tasks_of=_sort_tasks_of(seed=23, pages=48),
                     arrival=0.002, priority=4.0),
    ])
    rep = srv.run()
    assert len(rep.queries) == 3
    _assert_tenant_sum(rep)
    for q in rep.queries:
        assert q.finished >= q.admitted >= q.arrival
    assert rep.throughput > 0.0
    assert rep.p50_latency <= rep.p99_latency
    assert rep.p50_latency in [q.latency for q in rep.queries]


def test_report_round_trips_and_prints():
    srv = Server(HSPEC, budget=BUDGET, slots=2)
    srv.submit([
        QueryRequest(rid=0, tasks_of=_sort_tasks_of(seed=31), label="a"),
        QueryRequest(rid=1, tasks_of=_sort_tasks_of(seed=32), label="b",
                     arrival=0.001),
    ])
    rep = srv.run()
    d = rep.to_dict()
    assert d["mode"] == "arbitrated"
    assert {q["rid"] for q in d["queries"]} == {0, 1}
    text = str(rep)
    assert "throughput" in text and "q0" in text and "q1" in text
    with pytest.raises(KeyError):
        rep.query(99)


# ---------------------------------------------------------------------------
# Admission control and queueing
# ---------------------------------------------------------------------------


def _intervals(rep):
    return {q.rid: (q.admitted, q.finished) for q in rep.queries}


def test_slots_bound_concurrency():
    srv = Server(HSPEC, budget=BUDGET, slots=1)
    srv.submit([
        QueryRequest(rid=0, tasks_of=_sort_tasks_of(seed=41)),
        QueryRequest(rid=1, tasks_of=_sort_tasks_of(seed=42), arrival=0.001),
    ])
    rep = srv.run()
    iv = _intervals(rep)
    # With one slot the second query waits for the first to finish.
    assert iv[1][0] >= iv[0][1] - 1e-12
    assert rep.query(1).wait > 0.0
    _assert_tenant_sum(rep)


def test_fifo_mode_serializes_regardless_of_slots():
    srv = Server(HSPEC, budget=BUDGET, mode="fifo", slots=8)
    assert srv.slots == 1
    srv.submit([
        QueryRequest(rid=0, tasks_of=_sort_tasks_of(seed=51)),
        QueryRequest(rid=1, tasks_of=_sort_tasks_of(seed=52), arrival=0.001),
        QueryRequest(rid=2, tasks_of=_sort_tasks_of(seed=53), arrival=0.002),
    ])
    rep = srv.run()
    iv = _intervals(rep)
    order = sorted(iv, key=lambda r: iv[r][0])
    for a, b in zip(order, order[1:]):
        assert iv[b][0] >= iv[a][1] - 1e-12
    _assert_tenant_sum(rep)


def test_priority_orders_admission():
    """A high-priority arrival jumps the queue; FIFO within a class."""
    srv = Server(HSPEC, budget=BUDGET, slots=1)
    srv.submit([
        QueryRequest(rid=0, tasks_of=_sort_tasks_of(seed=61), arrival=0.0,
                     priority=1.0),
        QueryRequest(rid=1, tasks_of=_sort_tasks_of(seed=62), arrival=0.001,
                     priority=1.0),
        QueryRequest(rid=2, tasks_of=_sort_tasks_of(seed=63), arrival=0.002,
                     priority=8.0),
    ])
    rep = srv.run()
    iv = _intervals(rep)
    # rid=2 (high priority) is admitted before rid=1 despite arriving later.
    assert iv[2][0] < iv[1][0]
    assert iv[0][0] == 0.0
    _assert_tenant_sum(rep)


def test_even_mode_never_rearbitrates():
    srv = Server(HSPEC, budget=BUDGET, mode="even", slots=2)
    srv.submit([
        QueryRequest(rid=0, tasks_of=_sort_tasks_of(seed=71)),
        QueryRequest(rid=1, tasks_of=_sort_tasks_of(seed=72), arrival=0.001),
    ])
    rep = srv.run()
    assert rep.rearbitrations == 0
    assert rep.mode == "even"
    _assert_tenant_sum(rep)


def test_arbitrated_rearbitrates_on_events():
    srv = Server(HSPEC, budget=BUDGET, slots=2)
    srv.submit([
        QueryRequest(rid=0, tasks_of=_sort_tasks_of(seed=81)),
        QueryRequest(rid=1, tasks_of=_sort_tasks_of(seed=82), arrival=0.001),
    ])
    rep = srv.run()
    assert rep.rearbitrations > 0
    _assert_tenant_sum(rep)


def test_inadmissible_request_raises_on_idle_server():
    srv = Server(HSPEC, budget=2.0, slots=1)
    srv.submit(QueryRequest(rid=0, tasks_of=_pipeline_tasks_of(91)))
    with pytest.raises(RuntimeError, match="inadmissible"):
        srv.run()


# ---------------------------------------------------------------------------
# Preemptive demotion
# ---------------------------------------------------------------------------

TIGHT = hierarchy_spec((TABLE_I["dram"], 2048), (TABLE_I["rdma"], 1024),
                       TABLE_I["ssd"])


def _batch_tasks_of(seed=101):
    def tasks_of(sess):
        ids = make_key_pages(sess.remote, 1536, ROWS, seed=seed)
        rel = make_relation(sess.remote, 512 * ROWS, ROWS, 128, seed=seed + 1)
        return [
            sess.task("ems", WorkloadStats(size_r=1536, k_cap=8),
                      inputs={"page_ids": ids}, rows_per_page=ROWS),
            sess.task("eagg", WorkloadStats(size_r=512, out=96, partitions=8,
                                            sigma=0.5),
                      inputs={"rel": rel}),
        ]
    return tasks_of


def _serve_tight(priority):
    srv = Server(TIGHT, budget=256.0, slots=2)
    srv.submit([
        QueryRequest(rid=0, tasks_of=_batch_tasks_of(), arrival=0.0,
                     priority=1.0, label="batch"),
        QueryRequest(rid=1, tasks_of=_sort_tasks_of(pages=256, seed=102,
                                                    tier="rdma"),
                     arrival=0.3, priority=priority, label="interactive"),
    ])
    return srv.run()


def test_priority_triggers_preemptive_demotion():
    rep = _serve_tight(8.0)
    assert rep.preemptions, "high-priority admission should preempt"
    for ev in rep.preemptions:
        assert ev.rid == 1 and ev.victim_rid == 0
        assert ev.tier in TIGHT.names
        assert ev.pages > 0
    assert rep.query(0).preempted_pages == sum(
        e.pages for e in rep.preemptions
    )
    assert rep.query(1).preempted_pages == 0
    # Preemption rounds are background migration, attributed to the admitted
    # query; the per-tenant sum identity must survive them.
    assert rep.query(1).ledger.total.c_migration_hidden > 0
    _assert_tenant_sum(rep)


def test_equal_priorities_never_preempt():
    rep = _serve_tight(1.0)
    assert rep.preemptions == []
    _assert_tenant_sum(rep)


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------


def test_server_validates_mode_slots_and_target():
    with pytest.raises(ValueError, match="mode"):
        Server(HSPEC, budget=BUDGET, mode="greedy")
    with pytest.raises(ValueError, match="slots"):
        Server(HSPEC, budget=BUDGET, slots=0)
    with pytest.raises(ValueError, match="hierarchy"):
        Server(TABLE_I["rdma"], budget=BUDGET)


def test_submit_validates_requests():
    srv = Server(HSPEC, budget=BUDGET)
    srv.submit(QueryRequest(rid=0, tasks_of=_sort_tasks_of()))
    with pytest.raises(ValueError, match="duplicate"):
        srv.submit(QueryRequest(rid=0, tasks_of=_sort_tasks_of()))
    with pytest.raises(ValueError, match="priority"):
        srv.submit(QueryRequest(rid=1, tasks_of=_sort_tasks_of(),
                                priority=0.0))
    with pytest.raises(ValueError, match="arrival"):
        srv.submit(QueryRequest(rid=2, tasks_of=_sort_tasks_of(),
                                arrival=-1.0))
    with pytest.raises(ValueError, match="no tasks"):
        srv.submit(QueryRequest(rid=3, tasks_of=lambda sess: []))


def test_query_request_is_a_plain_record():
    req = QueryRequest(rid=5, tasks_of=_sort_tasks_of(), arrival=1.5,
                       priority=2.0, label="x")
    assert dataclasses.is_dataclass(req)
    assert (req.rid, req.arrival, req.priority, req.label) == (5, 1.5, 2.0, "x")
