"""Multi-tier memory hierarchy: routing, migration, placement, arbitration.

Acceptance (ISSUE 3):

  * a 1-tier ``MemoryHierarchy`` reproduces today's D/C ledgers exactly for
    all four operators (bnlj/ems/ehj/eagg);
  * on a 3-tier DRAM -> RDMA -> SSD hierarchy the tiered closed-form policy
    costs match the simulated per-tier ledgers (waterfall overflow included);
  * the hierarchy-aware arbiter is never worse than the best feasible
    single-tier placement.

Plus the transfer-fabric semantics: writes name a tier and waterfall on
overflow (one round per tier receiving pages), reads resolve placement (one
round per tier touched), migration rounds charge one round on each ledger
they cross, and per-tier ledgers always sum to the hierarchy-wide totals.
"""

import math

import numpy as np
import pytest

from repro.core import (
    TABLE_I,
    TESTBED,
    HierarchySpec,
    TierLevel,
    hierarchy_spec,
)
from repro.core.arbiter import HierarchyItem, arbitrate_hierarchy
from repro.core.policies import (
    eagg_costs_exact,
    tiered_latency_cost,
    tiered_split,
    waterfall_io,
)
from repro.engine import (
    BufferPool,
    TransferScheduler,
    WorkloadStats,
    plan_operator,
    plan_pipeline,
    registry,
    run_pipeline,
)
from repro.remote import MemoryHierarchy, RemoteMemory, make_hierarchy, make_relation
from repro.remote.simulator import make_key_pages

TIER = TESTBED["remon_tcp"]
ROWS = 8

STATS = WorkloadStats(size_r=40, size_s=80, out=24, selectivity=1 / 128,
                      partitions=8, sigma=0.5, k_cap=8)


def _run_operator(remote, op, tier_for_plan, m=14, seed=5, **run_kwargs):
    """Seed a workload and run one operator; returns its result object."""
    plan = plan_operator(op, STATS, tier_for_plan, m)
    if op in ("bnlj", "ehj"):
        r = make_relation(remote, 40 * ROWS, ROWS, 128, seed=seed)
        s = make_relation(remote, 80 * ROWS, ROWS, 128, seed=seed + 1)
        return registry.get(op).run(remote, r, s, plan, **run_kwargs)
    if op == "ems":
        ids = make_key_pages(remote, 40, ROWS, seed=seed)
        return registry.get(op).run(remote, ids, plan, rows_per_page=ROWS,
                                    **run_kwargs)
    rel = make_relation(remote, 40 * ROWS, ROWS, 64, seed=seed)
    return registry.get(op).run(remote, rel, plan, **run_kwargs)


# ---------------------------------------------------------------------------
# HierarchySpec validation
# ---------------------------------------------------------------------------


def test_hierarchy_spec_validates():
    with pytest.raises(ValueError, match="at least one tier"):
        HierarchySpec(())
    with pytest.raises(ValueError, match="duplicate tier names"):
        hierarchy_spec(TIER, TIER)
    with pytest.raises(ValueError, match="capacity_pages > 0"):
        TierLevel(TIER, 0.0)
    spec = hierarchy_spec((TABLE_I["dram"], 64), TABLE_I["ssd"])
    assert spec.names == ("dram", "ssd")
    assert spec.capacities == (64.0, math.inf)
    assert spec.index("ssd") == 1 and spec.index(-1) == 1
    with pytest.raises(KeyError, match="no tier"):
        spec.index("tape")


# ---------------------------------------------------------------------------
# Acceptance: 1-tier hierarchy == bare RemoteMemory, all four operators
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["bnlj", "ems", "ehj", "eagg"])
def test_single_tier_hierarchy_reproduces_ledgers_exactly(op):
    bare = _run_operator(RemoteMemory(TIER), op, TIER)
    hier = _run_operator(make_hierarchy(TIER), op, TIER)
    assert (hier.d_read, hier.d_write, hier.c_read, hier.c_write) == \
        (bare.d_read, bare.d_write, bare.c_read, bare.c_write)


def test_single_tier_hierarchy_matches_oracle_output():
    h = make_hierarchy(TIER)
    outer = make_relation(h, 20 * ROWS, ROWS, 128, seed=21)
    inner = make_relation(h, 40 * ROWS, ROWS, 128, seed=22)
    plan = plan_operator("bnlj", WorkloadStats(selectivity=1 / 128), TIER, 11)
    res = registry.get("bnlj").run(h, outer, inner, plan)
    assert res.output_rows == len(registry.get("bnlj").oracle(h, outer, inner))


# ---------------------------------------------------------------------------
# Tier-routed transfer fabric
# ---------------------------------------------------------------------------


def _three_tier(dram_cap=64, rdma_cap=256):
    return make_hierarchy((TABLE_I["dram"], dram_cap), (TABLE_I["rdma"], rdma_cap),
                          TABLE_I["ssd"])


def test_writes_name_a_tier_and_reads_resolve_placement():
    h = _three_tier()
    sched = TransferScheduler(h, tier="rdma")
    page = np.arange(ROWS, dtype=np.int64)
    ids_rdma = sched.write([page] * 3)  # default placement: rdma
    ids_dram = sched.write([page] * 2, tier="dram")  # explicit override
    assert {h.tier_of(i) for i in ids_rdma} == {"rdma"}
    assert {h.tier_of(i) for i in ids_dram} == {"dram"}
    assert h.tier("rdma").ledger.c_write == 1
    assert h.tier("dram").ledger.c_write == 1
    # One mixed read: one round per tier touched, pages in request order.
    got = sched.read(ids_rdma + ids_dram)
    assert len(got) == 5
    assert h.tier("rdma").ledger.c_read == 1
    assert h.tier("dram").ledger.c_read == 1
    assert h.tier("ssd").ledger.c_total == 0


def test_write_waterfalls_overflow_with_one_round_per_tier():
    h = make_hierarchy((TABLE_I["dram"], 4), (TABLE_I["rdma"], 6), TABLE_I["ssd"])
    sched = TransferScheduler(h, tier="dram")
    page = np.arange(ROWS, dtype=np.int64)
    sched.write([page] * 12)  # 4 to dram, 6 to rdma, 2 to ssd
    assert [rm.ledger.d_write for rm in h.tiers] == [4.0, 6.0, 2.0]
    assert [rm.ledger.c_write for rm in h.tiers] == [1, 1, 1]
    assert h.tier_resident("dram") == 4 and h.capacity_left("dram") == 0


def test_hierarchy_full_raises():
    h = make_hierarchy((TABLE_I["dram"], 2), (TABLE_I["ssd"], 2))
    page = np.arange(ROWS, dtype=np.int64)
    with pytest.raises(RuntimeError, match="hierarchy full"):
        h.write_batch([page] * 5, tier="dram")
    with pytest.raises(RuntimeError, match="hierarchy full"):
        h.put_local([page] * 5, tier="dram")


def test_put_local_respects_capacities_without_accounting():
    """Seeding waterfalls overflow like writes but charges no rounds."""
    h = make_hierarchy((TABLE_I["dram"], 3), (TABLE_I["rdma"], 4), TABLE_I["ssd"])
    ids = h.put_local([np.arange(ROWS, dtype=np.int64)] * 9, tier="dram")
    assert [h.tier_resident(t) for t in ("dram", "rdma", "ssd")] == [3, 4, 2]
    assert h.capacity_left("dram") == 0
    assert all(rm.ledger.c_total == 0 for rm in h.tiers)  # no transfer rounds
    assert len(ids) == 9 and h.pages_resident == 9


def test_migration_rounds_charge_each_ledger_crossed():
    h = _three_tier(dram_cap=10, rdma_cap=10)
    ids = h.put_local([np.arange(ROWS, dtype=np.int64)] * 4, tier="dram")
    h.demote(ids[:3])  # dram -> rdma: read round on dram, write round on rdma
    assert (h.tier("dram").ledger.d_read, h.tier("dram").ledger.c_read) == (3.0, 1)
    assert (h.tier("rdma").ledger.d_write, h.tier("rdma").ledger.c_write) == (3.0, 1)
    h.migrate(ids[:3], "ssd")  # one more hop: rdma read, ssd write
    assert (h.tier("rdma").ledger.d_read, h.tier("rdma").ledger.c_read) == (3.0, 1)
    assert (h.tier("ssd").ledger.d_write, h.tier("ssd").ledger.c_write) == (3.0, 1)
    h.promote(ids[:3])  # ssd -> rdma
    assert {h.tier_of(i) for i in ids[:3]} == {"rdma"}
    # Ids are stable across migration; data still readable in place.
    np.testing.assert_array_equal(h.peek_batch(ids[:1])[0], np.arange(ROWS))
    # A 2-level migration crosses the middle ledger on both sides.
    h2 = _three_tier()
    ids2 = h2.put_local([np.arange(ROWS, dtype=np.int64)] * 2, tier="dram")
    h2.migrate(ids2, "ssd")
    mid = h2.tier("rdma").ledger
    assert (mid.c_write, mid.c_read) == (1, 1)
    assert (mid.d_write, mid.d_read) == (2.0, 2.0)


def test_migrate_validates_capacity_and_membership():
    h = _three_tier(dram_cap=2)
    ids = h.put_local([np.arange(ROWS, dtype=np.int64)] * 4, tier="ssd")
    with pytest.raises(ValueError, match="cannot hold"):
        h.migrate(ids, "dram")
    with pytest.raises(KeyError, match="not resident"):
        h.migrate([12345], "dram")
    with pytest.raises(ValueError, match="one tier"):
        h.demote([ids[0], h.put_local([np.zeros(ROWS)], tier="dram")[0]])
    with pytest.raises(ValueError, match="bottom tier"):
        h.demote(ids[:1])


def test_free_raises_on_unknown_ids_everywhere():
    """Satellite: silent double-free hiding is gone on both store types."""
    remote = RemoteMemory(TIER)
    ids = make_key_pages(remote, 3, ROWS, seed=1)
    remote.free(ids[:1])
    with pytest.raises(KeyError, match="double free"):
        remote.free(ids[:1])
    h = _three_tier()
    hids = h.put_local([np.arange(ROWS)] * 2, tier="dram")
    h.free(hids)
    with pytest.raises(KeyError, match="not resident"):
        h.free(hids)


# ---------------------------------------------------------------------------
# Acceptance: tiered closed forms match simulated per-tier ledgers
# ---------------------------------------------------------------------------


def test_waterfall_io_matches_simulated_per_tier_ledgers():
    """A uniform-round spill stream: closed form == router, tier by tier."""
    h = make_hierarchy((TABLE_I["dram"], 7), (TABLE_I["rdma"], 13), TABLE_I["ssd"])
    sched = TransferScheduler(h, tier="dram")
    pool = BufferPool(sched, 4, ROWS)
    rng = np.random.default_rng(0)
    pool.add(rng.integers(0, 100, size=(31 * ROWS, 2), dtype=np.int64))
    pool.flush_all()
    closed = waterfall_io(31, 4, h.spec.capacities)
    for (d, c), rm in zip(closed, h.tiers):
        assert rm.ledger.d_write == d
        assert rm.ledger.c_write == c
    # The hierarchy-wide L prices each tier's rounds with its own tau.
    assert tiered_latency_cost(closed, h.spec.taus) == pytest.approx(
        h.latency_cost()
    )


def test_tiered_split_waterfall():
    assert tiered_split(10, [4, 4, math.inf]) == [4, 4, 2]
    assert tiered_split(3, [4, 4, math.inf], occupied=[2, 0, 0]) == [2, 1, 0]
    assert tiered_split(5, [8, math.inf], start=1) == [0, 5]
    with pytest.raises(ValueError, match="overflow"):
        tiered_split(10, [4, 4])


@pytest.mark.parametrize("op", ["bnlj", "ems", "ehj", "eagg"])
def test_operator_on_assigned_tier_matches_single_tier_ledger(op):
    """An op placed on one hierarchy tier == the same op on that bare tier.

    Inputs are seeded on the placement tier, so the whole run lands on one
    per-tier ledger — which must equal the standalone single-tier ledger
    (and hence the closed forms the single-tier tests pin down), while the
    other tiers stay silent.
    """
    rdma = TABLE_I["rdma"]
    h = make_hierarchy((TABLE_I["dram"], 512), (rdma, 2048), TABLE_I["ssd"])
    hier = _run_operator(_SeededHierarchy(h, "rdma"), op, rdma, tier="rdma")
    bare = _run_operator(RemoteMemory(rdma), op, rdma)
    delta = h.tier("rdma").ledger
    assert (hier.d_read, hier.d_write, hier.c_read, hier.c_write) == \
        (bare.d_read, bare.d_write, bare.c_read, bare.c_write)
    assert (delta.d_read, delta.d_write, delta.c_read, delta.c_write) == \
        (bare.d_read, bare.d_write, bare.c_read, bare.c_write)
    assert h.tier("dram").ledger.c_total == 0
    assert h.tier("ssd").ledger.c_total == 0


class _SeededHierarchy:
    """A MemoryHierarchy proxy that seeds oracle data on a fixed tier."""

    def __init__(self, h: MemoryHierarchy, seed_tier: str):
        self._h = h
        self._seed_tier = seed_tier

    def put_local(self, pages):
        return self._h.put_local(pages, tier=self._seed_tier)

    def __getattr__(self, name):
        return getattr(self._h, name)


def test_eagg_closed_form_matches_hierarchy_tier_ledger():
    """The ceil-exact eagg cost formula holds on a hierarchy tier's ledger."""
    rdma = TABLE_I["rdma"]
    h = make_hierarchy((TABLE_I["dram"], 512), (rdma, 4096), TABLE_I["ssd"])
    seeded = _SeededHierarchy(h, "rdma")
    rel = make_relation(seeded, 40 * ROWS, ROWS, 64, seed=5)
    plan = plan_operator("eagg", STATS, rdma, 14)
    res = registry.get("eagg").run(seeded, rel, plan, tier="rdma")

    # Reconstruct the skew-aware closed-form inputs from the oracle.
    rows = np.concatenate(h.peek_batch(rel.page_ids), axis=0)
    p = plan.partitions
    keys = rows[:, 0].astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    parts = ((keys >> np.uint64(33)) % np.uint64(p)).astype(np.int64)
    n_spilled = int(round(plan.sigma * p))
    spilled = set(range(p - n_spilled, p))
    spilled_rows = [int((parts == q).sum()) for q in sorted(spilled)]
    res_groups = len(np.unique(rows[~np.isin(parts, list(spilled))][:, 0]))
    sp_groups = len(np.unique(rows[np.isin(parts, list(spilled))][:, 0]))
    d, c = eagg_costs_exact(len(rel.page_ids), ROWS, spilled_rows,
                            res_groups, sp_groups, plan)
    led = h.tier("rdma").ledger
    assert led.d_total == d
    assert led.c_total == c


# ---------------------------------------------------------------------------
# Hierarchy-wide snapshots
# ---------------------------------------------------------------------------


def test_hierarchy_snapshot_tiers_sum_to_total():
    h = _three_tier(dram_cap=8, rdma_cap=16)
    sched = TransferScheduler(h, tier="dram")
    page = np.arange(ROWS, dtype=np.int64)
    before = sched.snapshot()
    ids = sched.write([page] * 30)  # spreads over all three tiers
    sched.read(ids[:10], prefetch=True)
    h.migrate([i for i in ids if h.tier_of(i) == "dram"][:2], "ssd")
    delta = sched.delta(before)
    total = delta.total
    assert total.d_read == sum(s.d_read for _, s in delta.tiers)
    assert total.c_total == sum(s.c_total for _, s in delta.tiers)
    assert delta.d_total == total.d_total and delta.c_total == total.c_total
    # Spec-priced L decomposes per tier as well.
    assert delta.latency_cost(h.spec) == pytest.approx(sum(
        delta.tier(name).latency_cost(tau)
        for name, tau in zip(h.spec.names, h.spec.taus)
    ))
    with pytest.raises(KeyError, match="no tier"):
        delta.tier("tape")


# ---------------------------------------------------------------------------
# Acceptance: hierarchy-aware arbiter vs single-tier placements
# ---------------------------------------------------------------------------

PIPE_OPS = ["ehj", "ems", "eagg"]
PIPE_STATS = [
    WorkloadStats(size_r=48, size_s=96, out=36, partitions=8, sigma=0.5),
    WorkloadStats(size_r=120, k_cap=8),
    WorkloadStats(size_r=64, out=12, partitions=8, sigma=0.5),
]


def test_plan_pipeline_hierarchy_assigns_pages_and_tiers():
    spec = hierarchy_spec((TABLE_I["dram"], 64), (TABLE_I["rdma"], 256),
                          TABLE_I["ssd"])
    pplan = plan_pipeline(PIPE_OPS, PIPE_STATS, spec, 56.0)
    assert pplan.hierarchy == spec
    assert sum(pplan.budgets) == pytest.approx(56.0)
    assert all(p in spec.names for p in pplan.placements)
    assert all(b >= registry.get(ob.op).min_pages
               for b, ob in zip(pplan.budgets, pplan.ops))
    # Modeled latency is priced with the placement tier's tau.
    for ob in pplan.ops:
        tau = spec.levels[spec.index(ob.placement)].tier.tau_pages
        assert ob.modeled_latency == pytest.approx(
            registry.get(ob.op).model(ob.stats, tau, ob.m_pages, "remop")
        )
    # Footprints (at each placement tier's tau) respect tier capacities.
    used = {name: 0.0 for name in spec.names}
    for ob in pplan.ops:
        tau = spec.levels[spec.index(ob.placement)].tier.tau_pages
        used[ob.placement] += registry.get(ob.op).footprint(
            ob.stats, tau, ob.m_pages
        )
    for name, cap in zip(spec.names, spec.capacities):
        assert used[name] <= cap + 1e-9


def test_hierarchy_arbiter_never_worse_than_best_single_tier():
    spec = hierarchy_spec((TABLE_I["dram"], 64), (TABLE_I["rdma"], 256),
                          TABLE_I["ssd"])
    m_total = 56.0
    pplan = plan_pipeline(PIPE_OPS, PIPE_STATS, spec, m_total)
    feasible = []
    for level in spec.levels:
        single = plan_pipeline(PIPE_OPS, PIPE_STATS, level.tier, m_total)
        footprint = sum(
            registry.get(ob.op).footprint(ob.stats, level.tier.tau_pages,
                                          ob.m_pages)
            for ob in single.ops
        )
        if footprint <= level.capacity_pages + 1e-9:
            feasible.append(single.total_modeled_latency)
    assert feasible, "the unbounded bottom tier must always be feasible"
    assert pplan.total_modeled_latency <= min(feasible) + 1e-9


def test_arbitrate_hierarchy_core_algorithm():
    # Two items, two tiers: a fast tier that only fits one footprint.
    items = [
        HierarchyItem("a", 2.0, lambda m, t: (100.0 if t else 10.0) / m,
                      footprint_of=lambda m, t: 6.0),
        HierarchyItem("b", 2.0, lambda m, t: (100.0 if t else 10.0) / m,
                      footprint_of=lambda m, t: 6.0),
    ]
    alloc, placement, total = arbitrate_hierarchy(items, 10.0, [8.0, math.inf])
    assert sum(alloc) == pytest.approx(10.0)
    assert sorted(placement) == [0, 1]  # capacity forces one item down
    with pytest.raises(ValueError, match="below the pipeline floor"):
        arbitrate_hierarchy(items, 3.0, [8.0, math.inf])
    with pytest.raises(ValueError, match="empty hierarchy"):
        arbitrate_hierarchy(items, 10.0, [])
    with pytest.raises(ValueError, match="empty pipeline"):
        arbitrate_hierarchy([], 10.0, [8.0])
    # All tiers finite and too small for the footprints: explicit error
    # instead of an assignment the runtime hierarchy could not honor.
    with pytest.raises(ValueError, match="no capacity-feasible"):
        arbitrate_hierarchy(items, 10.0, [8.0, 4.0])


def test_run_pipeline_routes_spill_to_placements():
    # rdma is roomy enough that no op's spill overflows its placement tier
    # (the ehj join output is ~8x the planner's `out` estimate; with tighter
    # capacities the waterfall would legitimately cascade the excess down).
    h = _three_tier(dram_cap=64, rdma_cap=1024)
    pplan = plan_pipeline(PIPE_OPS, PIPE_STATS, h, 56.0)
    build = make_relation(h, 48 * ROWS, ROWS, 128, seed=31)
    probe = make_relation(h, 96 * ROWS, ROWS, 128, seed=32)
    sort_ids = make_key_pages(h, 120, ROWS, seed=33)
    agg_rel = make_relation(h, 64 * ROWS, ROWS, 96, seed=34)
    res = run_pipeline(h, pplan, [
        ((build, probe), {}),
        ((sort_ids,), {"rows_per_page": ROWS}),
        ((agg_rel,), {}),
    ])
    # Inputs were seeded on the bottom tier; each op's spill writes land on
    # its placement tier (capacities here are generous: no overflow).
    for (op, _, delta), ob in zip(res.per_op, pplan.ops):
        writes_elsewhere = sum(
            s.d_write for name, s in delta.tiers if name != ob.placement
        )
        assert writes_elsewhere == 0.0, (op, ob.placement)
        assert delta.tier(ob.placement).d_write > 0.0
    # Per-op deltas compose to the measured hierarchy-wide totals.
    assert sum(d.d_total for _, _, d in res.per_op) == res.total.d_total
    assert sum(d.c_total for _, _, d in res.per_op) == res.total.c_total
    assert res.latency_cost(h.spec) == pytest.approx(h.latency_cost())

    # Wall latency must be priced per tier: TierSpec on a hierarchy run is
    # a type error, HierarchySpec prices each tier's rounds with its own
    # constants and matches the live hierarchy's reading.
    with pytest.raises(TypeError, match="pass the HierarchySpec"):
        res.latency_seconds(pplan.tier)
    assert res.latency_seconds(pplan.hierarchy) == pytest.approx(
        h.latency_seconds()
    )

    # Operators stay oracle-correct mid-pipeline on the hierarchy.
    ehj_res, ems_res, eagg_res = (r for _, r, _ in res.per_op)
    assert ehj_res.output_rows == registry.get("ehj").oracle(h, build, probe)
    got = np.concatenate(
        [h.peek_batch([i])[0].ravel() for i in ems_res.run_page_ids]
    )
    np.testing.assert_array_equal(got, registry.get("ems").oracle(h, sort_ids))
    assert eagg_res.group_rows == len(registry.get("eagg").oracle(h, agg_rel))
